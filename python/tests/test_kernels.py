"""L1 correctness: the Pallas kernels vs the pure-jnp oracles, swept over
shapes / activations with hypothesis.  This is the core kernel signal the
AOT artifacts inherit."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_linear import (
    fused_linear,
    linear_fwd_pallas,
    matmul,
    _act_grad,
)
from compile.kernels.hier_avg import group_average

jax.config.update("jax_platform_name", "cpu")

DIMS = st.integers(min_value=1, max_value=200)
ACTS = st.sampled_from(["none", "relu", "gelu"])


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS)
def test_matmul_matches_ref(m, k, n):
    x = rand(m * 7919 + k, m, k)
    w = rand(n * 104729 + k, k, n)
    np.testing.assert_allclose(
        matmul(x, w), ref.ref_matmul(x, w), rtol=1e-4, atol=1e-4
    )


def test_matmul_shape_mismatch_raises():
    with pytest.raises(ValueError):
        matmul(jnp.zeros((2, 3)), jnp.zeros((4, 5)))


def test_matmul_exact_block_multiples():
    # No padding path: dims exactly at the MXU block size.
    x = rand(1, 128, 256)
    w = rand(2, 256, 128)
    np.testing.assert_allclose(
        matmul(x, w), ref.ref_matmul(x, w), rtol=1e-4, atol=1e-4
    )


def test_matmul_grad_matches_ref():
    x = rand(3, 24, 40)
    w = rand(4, 40, 8)

    def f_pallas(x, w):
        return jnp.sum(jnp.sin(matmul(x, w)))

    def f_ref(x, w):
        return jnp.sum(jnp.sin(ref.ref_matmul(x, w)))

    gx_p, gw_p = jax.grad(f_pallas, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx_p, gx_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw_p, gw_r, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# fused linear
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, act=ACTS)
def test_fused_linear_matches_ref(m, k, n, act):
    x = rand(m + 1, m, k)
    w = rand(k + 2, k, n)
    b = rand(n + 3, n)
    np.testing.assert_allclose(
        fused_linear(x, w, b, act), ref.ref_linear(x, w, b, act), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=10, deadline=None)
@given(act=ACTS)
def test_fused_linear_emits_preactivation(act):
    x = rand(10, 16, 33)
    w = rand(11, 33, 20)
    b = rand(12, 20)
    z, y = linear_fwd_pallas(x, w, b, act)
    np.testing.assert_allclose(z, ref.ref_matmul(x, w) + b, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(y, ref.ref_act(z, act), rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 64), k=st.integers(1, 64), n=st.integers(1, 64), act=ACTS)
def test_fused_linear_vjp_matches_ref(m, k, n, act):
    x = rand(m, m, k)
    w = rand(k, k, n)
    b = rand(n, n)

    def f_pallas(x, w, b):
        return jnp.sum(fused_linear(x, w, b, act) ** 2)

    def f_ref(x, w, b):
        return jnp.sum(ref.ref_linear(x, w, b, act) ** 2)

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, c in zip(gp, gr):
        np.testing.assert_allclose(a, c, rtol=2e-3, atol=2e-3)


def test_act_grad_matches_autodiff():
    z = jnp.linspace(-3.0, 3.0, 101)
    for act in ["none", "relu", "gelu"]:
        if act == "relu":
            z_test = z + 0.005  # stay off the kink
        else:
            z_test = z
        auto = jax.vmap(jax.grad(lambda v: ref.ref_act(v, act)))(z_test)
        np.testing.assert_allclose(_act_grad(z_test, act), auto, rtol=1e-4, atol=1e-5)


def test_unknown_activation_raises():
    with pytest.raises(ValueError):
        fused_linear(jnp.zeros((2, 2)), jnp.zeros((2, 2)), jnp.zeros(2), "swish")


# ---------------------------------------------------------------------------
# group average
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(s=st.integers(1, 8), d=st.integers(1, 10000))
def test_group_average_matches_ref(s, d):
    x = rand(s * 31 + d, s, d)
    np.testing.assert_allclose(
        group_average(x), ref.ref_group_average(x), rtol=1e-5, atol=1e-6
    )


def test_group_average_constant_is_identity():
    x = jnp.ones((4, 5000)) * 3.25
    np.testing.assert_array_equal(group_average(x), jnp.full((5000,), 3.25))


# ---------------------------------------------------------------------------
# sgd update
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(d=st.integers(1, 20000), lr=st.floats(1e-4, 1.0))
def test_sgd_update_matches_ref(d, lr):
    from compile.kernels.sgd_update import sgd_update, ref_sgd_update

    w = rand(d, d)
    g = rand(d + 1, d)
    np.testing.assert_allclose(
        sgd_update(w, g, lr), ref_sgd_update(w, g, lr), rtol=1e-6, atol=1e-6
    )


def test_sgd_update_zero_lr_is_identity():
    from compile.kernels.sgd_update import sgd_update

    w = rand(5, 1000)
    g = rand(6, 1000)
    np.testing.assert_array_equal(sgd_update(w, g, 0.0), w)
