"""AOT pipeline: lowering produces parseable HLO text, the manifest is
internally consistent, and (when artifacts are built) the on-disk manifest
matches the model registry."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")

ARTIFACTS = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__)))), "artifacts")


def test_hlo_text_lowering_smoke():
    spec = M.MODELS["quickstart"]
    params = M.init_params(spec)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    f = M.make_train_step(spec, treedef, 1)
    in_specs = [jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves]
    bx, by = M.batch_specs(spec, spec.batch)
    lowered = jax.jit(f).lower(*in_specs, bx, by)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # Tuple return with n_leaves + 2 elements.
    assert "->" in text


def test_model_entry_fields():
    e = aot.model_entry(M.MODELS["resnet18_sim"])
    assert e["kind"] == "mlp"
    assert e["dims"] == [128, 256, 256, 10]
    assert e["classes"] == 10
    e = aot.model_entry(M.MODELS["lm_small"])
    assert e["kind"] == "lm"
    assert e["vocab"] == 256


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestBuiltArtifacts:
    @classmethod
    def setup_class(cls):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            cls.manifest = json.load(f)

    def test_format_version(self):
        assert self.manifest["format_version"] == aot.FORMAT_VERSION

    def test_every_model_in_registry(self):
        for name, entry in self.manifest["models"].items():
            assert name in M.MODELS
            spec = M.MODELS[name]
            assert entry["batch"] == spec.batch
            assert entry["train_p"] == list(spec.train_p)

    def test_layout_matches_init_blob(self):
        for name, entry in self.manifest["models"].items():
            total = sum(p["size"] for p in entry["params"])
            assert total == entry["n_params"], name
            blob = os.path.join(ARTIFACTS, entry["init"])
            assert os.path.getsize(blob) == 4 * total, name
            # offsets are contiguous
            off = 0
            for p in entry["params"]:
                assert p["offset"] == off, (name, p["name"])
                assert p["size"] == int(np.prod(p["shape"])) if p["shape"] else 1
                off += p["size"]

    def test_init_blob_matches_live_init(self):
        # The blob on disk must equal re-running init (same PRNG seed).
        name = "quickstart"
        entry = self.manifest["models"][name]
        blob = np.fromfile(os.path.join(ARTIFACTS, entry["init"]), dtype="<f4")
        leaves = jax.tree_util.tree_leaves(M.init_params(M.MODELS[name]))
        flat = np.concatenate([np.asarray(l).reshape(-1) for l in leaves])
        np.testing.assert_array_equal(blob, flat)

    def test_artifact_files_exist_and_are_hlo(self):
        for name, entry in self.manifest["models"].items():
            files = list(entry["train"].values()) + [entry["eval"]]
            for f in files:
                path = os.path.join(ARTIFACTS, f)
                assert os.path.exists(path), path
                with open(path) as fh:
                    head = fh.read(64)
                assert head.startswith("HloModule"), path

    def test_avg_artifacts(self):
        avg = self.manifest["avg"]
        assert avg["chunk"] == 4096
        for s, f in avg["groups"].items():
            assert os.path.exists(os.path.join(ARTIFACTS, f)), f
            assert int(s) in (2, 4, 8)
