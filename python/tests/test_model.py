"""L2 correctness: the Pallas-backed models vs kernel-free references,
plus the exported step functions' shapes/semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def mlp_spec():
    return M.MODELS["quickstart"]


def batch_for(spec, key=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(key))
    if spec.kind == "mlp":
        x = jax.random.normal(kx, (spec.batch, spec.input_dim), jnp.float32)
        y = jax.random.randint(ky, (spec.batch,), 0, spec.classes)
    else:
        x = jax.random.randint(kx, (spec.batch, spec.seq_len), 0, spec.vocab)
        y = jax.random.randint(ky, (spec.batch, spec.seq_len), 0, spec.vocab)
    return x, y


def test_mlp_pallas_matches_jnp_reference():
    spec = mlp_spec()
    params = M.init_params(spec)
    x, _ = batch_for(spec)
    lp = M.mlp_apply(spec, params, x)
    lr = M.mlp_apply(spec, params, x, use_ref=True)
    np.testing.assert_allclose(lp, lr, rtol=1e-4, atol=1e-4)


def test_mlp_gradients_match_reference_model():
    spec = mlp_spec()
    params = M.init_params(spec)
    x, y = batch_for(spec)

    def loss_pallas(p):
        return M.mlp_loss(spec, p, x, y)[0]

    def loss_ref(p):
        return M.mlp_loss(spec, p, x, y, use_ref=True)[0]

    gp = jax.grad(loss_pallas)(params)
    gr = jax.grad(loss_ref)(params)
    for a, b in zip(jax.tree_util.tree_leaves(gp), jax.tree_util.tree_leaves(gr)):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-4)


def test_train_step_signature_and_order():
    spec = mlp_spec()
    params = M.init_params(spec)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    f = M.make_train_step(spec, treedef, 1)
    x, y = batch_for(spec)
    out = f(*leaves, x, y)
    assert len(out) == len(leaves) + 2
    for g, l in zip(out[: len(leaves)], leaves):
        assert g.shape == l.shape
    loss, ncorrect = out[-2], out[-1]
    assert loss.shape == () and float(loss) > 0
    assert 0 <= float(ncorrect) <= spec.batch


def test_stacked_step_matches_singletons():
    spec = mlp_spec()
    params = M.init_params(spec)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    f1 = M.make_train_step(spec, treedef, 1)
    f4 = M.make_train_step(spec, treedef, 4)
    # Four learners with different params and batches.
    stacked_leaves = [
        jnp.stack([l + 0.01 * i for i in range(4)], axis=0) for l in leaves
    ]
    xs, ys = zip(*[batch_for(spec, key=i) for i in range(4)])
    sx, sy = jnp.stack(xs), jnp.stack(ys)
    out4 = f4(*stacked_leaves, sx, sy)
    for i in range(4):
        leaves_i = [l + 0.01 * i for l in leaves]
        out1 = f1(*leaves_i, xs[i], ys[i])
        np.testing.assert_allclose(out4[-2][i], out1[-2], rtol=1e-5, atol=1e-6)
        for g4, g1 in zip(out4[: len(leaves)], out1[: len(leaves)]):
            np.testing.assert_allclose(g4[i], g1, rtol=1e-4, atol=1e-5)


def test_eval_step_sums():
    spec = mlp_spec()
    params = M.init_params(spec)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    g = M.make_eval_step(spec, treedef)
    x, y = batch_for(spec)
    sum_loss, ncorrect = g(*leaves, x, y)
    mean_loss, (sum_loss2, ncorrect2) = M.mlp_loss(spec, params, x, y)
    np.testing.assert_allclose(sum_loss, sum_loss2, rtol=1e-6)
    np.testing.assert_allclose(float(mean_loss) * spec.batch, float(sum_loss), rtol=1e-5)
    assert float(ncorrect) == float(ncorrect2)


def test_lm_shapes_and_loss():
    spec = M.MODELS["lm_small"]
    params = M.init_params(spec)
    x, y = batch_for(spec)
    logits = M.lm_apply(spec, params, x)
    assert logits.shape == (spec.batch, spec.seq_len, spec.vocab)
    loss, (sum_loss, ncorrect) = M.lm_loss(spec, params, x, y)
    # At init the loss must be close to uniform ln(V).
    assert abs(float(loss) - np.log(spec.vocab)) < 0.5
    assert 0 <= float(ncorrect) <= spec.batch * spec.seq_len
    np.testing.assert_allclose(
        float(sum_loss), float(loss) * spec.batch * spec.seq_len, rtol=1e-5
    )


def test_lm_causality():
    # Changing a future token must not change earlier logits.
    spec = M.MODELS["lm_small"]
    params = M.init_params(spec)
    x, _ = batch_for(spec)
    base = M.lm_apply(spec, params, x)
    x2 = x.at[:, -1].set((x[:, -1] + 1) % spec.vocab)
    pert = M.lm_apply(spec, params, x2)
    np.testing.assert_allclose(base[:, :-1], pert[:, :-1], rtol=1e-4, atol=1e-5)
    assert not np.allclose(base[:, -1], pert[:, -1], atol=1e-5)


def test_param_names_are_unique_and_ordered():
    for name in ["quickstart", "lm_small"]:
        spec = M.MODELS[name]
        params = M.init_params(spec)
        named = M.param_leaves_with_paths(params)
        names = [n for n, _ in named]
        assert len(names) == len(set(names))
        leaves = jax.tree_util.tree_leaves(params)
        assert len(leaves) == len(named)
        for (_, a), b in zip(named, leaves):
            assert a.shape == b.shape


def test_registry_dims_match_rust_mirror():
    # rust/src/driver/mod.rs MODEL_DIMS must mirror this registry.
    expect = {
        "quickstart": (32, 64, 10),
        "resnet18_sim": (128, 256, 256, 10),
        "googlenet_sim": (128, 192, 192, 192, 10),
        "mobilenet_sim": (128, 96, 96, 10),
        "vgg19_sim": (128, 512, 10),
        "imagenet_sim": (256, 384, 100),
    }
    for name, dims in expect.items():
        assert M.MODELS[name].dims == dims, name


@pytest.mark.parametrize("name", ["quickstart", "lm_small"])
def test_init_is_deterministic(name):
    spec = M.MODELS[name]
    a = jax.tree_util.tree_leaves(M.init_params(spec))
    b = jax.tree_util.tree_leaves(M.init_params(spec))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
