"""L2: the JAX compute graphs AOT-compiled for the Rust coordinator.

Two model families, both built on the L1 Pallas ``fused_linear`` kernel:

- **MLP classifiers** — stand-ins for the paper's four CNNs on CIFAR-10 /
  ImageNet-1K (see DESIGN.md §1 for the substitution argument).  Four
  variants mirror the four landscapes (ResNet-18 / GoogLeNet / MobileNet /
  VGG19) plus a quickstart net and an "imagenet-sim" net.
- **Decoder-only transformer LM** — the end-to-end driver workload
  (examples/e2e_lm.rs).

Each model exports two graphs:

- ``train_step(params..., x, y) -> (grads..., loss, ncorrect)`` — gradients
  only; the Rust optimizer owns the update so that LR schedules / momentum
  live at L3, as they do in the paper's harness.
- ``eval_step(params..., x, y) -> (sum_loss, ncorrect)`` — sums so the
  coordinator can accumulate over evaluation shards.

A "stacked" train step (leading dimension P, one XLA dispatch for all P
simulated learners, per-learner parameters and batches) is exported for the
P values the experiments use.  ``lax.map`` rather than ``vmap`` carries the
learner dimension: the loop body is compiled once (compile time independent
of P) and it sidesteps Pallas-interpreter batching.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.fused_linear import fused_linear, matmul
from .kernels import ref


# ---------------------------------------------------------------------------
# Model registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MlpSpec:
    name: str
    dims: Tuple[int, ...]          # (input, hidden..., classes)
    batch: int                     # per-learner train mini-batch B
    eval_batch: int
    train_p: Tuple[int, ...]       # stacked-P variants to export
    activation: str = "relu"
    seed: int = 0

    @property
    def kind(self) -> str:
        return "mlp"

    @property
    def input_dim(self) -> int:
        return self.dims[0]

    @property
    def classes(self) -> int:
        return self.dims[-1]


@dataclasses.dataclass(frozen=True)
class LmSpec:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq_len: int
    batch: int
    eval_batch: int
    train_p: Tuple[int, ...]
    seed: int = 0

    @property
    def kind(self) -> str:
        return "lm"

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model


# The experiment matrix (DESIGN.md §5) dictates which P variants exist:
#   fig1/fig2: P=32 on all four CNN stand-ins
#   fig3/fig4: P=16 on all four
#   table1:    P=16/32/64 on resnet18-sim
#   fig5:      P=16 on imagenet-sim
MODELS: Dict[str, object] = {
    s.name: s
    for s in [
        MlpSpec("quickstart", (32, 64, 10), batch=16, eval_batch=64, train_p=(1, 4)),
        MlpSpec(
            "resnet18_sim", (128, 256, 256, 10), batch=16, eval_batch=128,
            train_p=(1, 16, 32, 64), seed=1,
        ),
        MlpSpec(
            "googlenet_sim", (128, 192, 192, 192, 10), batch=16, eval_batch=128,
            train_p=(1, 16, 32), seed=2,
        ),
        MlpSpec(
            "mobilenet_sim", (128, 96, 96, 10), batch=16, eval_batch=128,
            train_p=(1, 16, 32), seed=3,
        ),
        MlpSpec(
            "vgg19_sim", (128, 512, 10), batch=16, eval_batch=128,
            train_p=(1, 16, 32), seed=4,
        ),
        MlpSpec(
            "imagenet_sim", (256, 384, 100), batch=16, eval_batch=256,
            train_p=(1, 16), seed=5,
        ),
        LmSpec(
            "lm_small", vocab=256, d_model=128, n_layers=2, n_heads=4,
            seq_len=64, batch=8, eval_batch=16, train_p=(1, 4), seed=10,
        ),
        LmSpec(
            "lm_medium", vocab=512, d_model=256, n_layers=4, n_heads=8,
            seq_len=64, batch=8, eval_batch=16, train_p=(1, 4), seed=11,
        ),
    ]
}


# ---------------------------------------------------------------------------
# Parameter pytrees.  Params are lists/dicts of arrays; flattening order is
# jax.tree_util's canonical order and is recorded in the manifest so the
# Rust side can slice its flat buffer identically.
# ---------------------------------------------------------------------------


def init_mlp(spec: MlpSpec):
    """He-normal weights, zero biases — matched exactly by the Rust native
    backend (rust/src/native)."""
    key = jax.random.PRNGKey(spec.seed)
    params = []
    for fan_in, fan_out in zip(spec.dims[:-1], spec.dims[1:]):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (fan_in, fan_out), jnp.float32) * jnp.sqrt(
            2.0 / fan_in
        )
        params.append({"w": w, "b": jnp.zeros((fan_out,), jnp.float32)})
    return params


def mlp_apply(spec: MlpSpec, params, x, *, use_ref: bool = False):
    """Forward pass -> logits.  ``use_ref`` swaps the Pallas kernel for the
    pure-jnp oracle (the gradient-parity tests diff the two)."""
    lin = ref.ref_linear if use_ref else fused_linear
    h = x
    n = len(params)
    for i, layer in enumerate(params):
        act = spec.activation if i + 1 < n else "none"
        h = lin(h, layer["w"], layer["b"], act)
    return h


def _softmax_xent(logits, y):
    logz = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logz, y[..., None], axis=-1)[..., 0]
    return nll


def mlp_loss(spec: MlpSpec, params, x, y, *, use_ref: bool = False):
    logits = mlp_apply(spec, params, x, use_ref=use_ref)
    nll = _softmax_xent(logits, y)
    ncorrect = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return jnp.mean(nll), (jnp.sum(nll), ncorrect)


# ---------------------------------------------------------------------------
# Transformer LM
# ---------------------------------------------------------------------------


def init_lm(spec: LmSpec):
    key = jax.random.PRNGKey(spec.seed)
    d, v, t = spec.d_model, spec.vocab, spec.seq_len

    def normal(key, shape, std):
        return jax.random.normal(key, shape, jnp.float32) * std

    key, k0, k1 = jax.random.split(key, 3)
    params = {
        "embed": normal(k0, (v, d), 0.02),
        "pos": normal(k1, (t, d), 0.02),
        "blocks": [],
        "ln_f": {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)},
    }
    proj_std = 0.02 / float(jnp.sqrt(2.0 * spec.n_layers))
    for _ in range(spec.n_layers):
        key, k0, k1, k2, k3 = jax.random.split(key, 5)
        params["blocks"].append(
            {
                "ln1": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
                "wqkv": normal(k0, (d, 3 * d), 0.02),
                "bqkv": jnp.zeros((3 * d,)),
                "wo": normal(k1, (d, d), proj_std),
                "bo": jnp.zeros((d,)),
                "ln2": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
                "wi": normal(k2, (d, spec.d_ff), 0.02),
                "bi": jnp.zeros((spec.d_ff,)),
                "wo2": normal(k3, (spec.d_ff, d), proj_std),
                "bo2": jnp.zeros((d,)),
            }
        )
    return jax.tree_util.tree_map(lambda a: a.astype(jnp.float32), params)


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention(spec: LmSpec, blk, h):
    # h: [B, T, d].  QKV / output projections go through the Pallas kernel
    # (flattened over batch*time); the score computation stays in jnp.
    bsz, t, d = h.shape
    nh, hd = spec.n_heads, d // spec.n_heads
    qkv = fused_linear(h.reshape(bsz * t, d), blk["wqkv"], blk["bqkv"], "none")
    qkv = qkv.reshape(bsz, t, 3, nh, hd)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]  # [B, T, nh, hd]
    q = jnp.transpose(q, (0, 2, 1, 3))
    k = jnp.transpose(k, (0, 2, 3, 1))
    v = jnp.transpose(v, (0, 2, 1, 3))
    scores = jnp.matmul(q, k) / jnp.sqrt(float(hd))  # [B, nh, T, T]
    mask = jnp.tril(jnp.ones((t, t), jnp.bool_))
    scores = jnp.where(mask, scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.matmul(attn, v)  # [B, nh, T, hd]
    out = jnp.transpose(out, (0, 2, 1, 3)).reshape(bsz * t, d)
    out = fused_linear(out, blk["wo"], blk["bo"], "none")
    return out.reshape(bsz, t, d)


def _mlp_block(spec: LmSpec, blk, h):
    bsz, t, d = h.shape
    x = h.reshape(bsz * t, d)
    x = fused_linear(x, blk["wi"], blk["bi"], "gelu")
    x = fused_linear(x, blk["wo2"], blk["bo2"], "none")
    return x.reshape(bsz, t, d)


def lm_apply(spec: LmSpec, params, x):
    """x: i32[B, T] -> logits f32[B, T, vocab] (tied embeddings)."""
    h = params["embed"][x] + params["pos"][None, :, :]
    for blk in params["blocks"]:
        h = h + _attention(spec, blk, _layer_norm(h, blk["ln1"]["g"], blk["ln1"]["b"]))
        h = h + _mlp_block(spec, blk, _layer_norm(h, blk["ln2"]["g"], blk["ln2"]["b"]))
    h = _layer_norm(h, params["ln_f"]["g"], params["ln_f"]["b"])
    bsz, t, d = h.shape
    logits = matmul(h.reshape(bsz * t, d), params["embed"].T)
    return logits.reshape(bsz, t, spec.vocab)


def lm_loss(spec: LmSpec, params, x, y):
    logits = lm_apply(spec, params, x)
    nll = _softmax_xent(logits, y)
    ncorrect = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return jnp.mean(nll), (jnp.sum(nll), ncorrect)


# ---------------------------------------------------------------------------
# Exported graphs
# ---------------------------------------------------------------------------


def init_params(spec):
    return init_mlp(spec) if spec.kind == "mlp" else init_lm(spec)


def loss_fn(spec):
    if spec.kind == "mlp":
        return lambda params, x, y: mlp_loss(spec, params, x, y)
    return lambda params, x, y: lm_loss(spec, params, x, y)


def batch_specs(spec, batch: int):
    """ShapeDtypeStructs for (x, y) at a given per-learner batch size."""
    if spec.kind == "mlp":
        return (
            jax.ShapeDtypeStruct((batch, spec.input_dim), jnp.float32),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
        )
    return (
        jax.ShapeDtypeStruct((batch, spec.seq_len), jnp.int32),
        jax.ShapeDtypeStruct((batch, spec.seq_len), jnp.int32),
    )


def make_train_step(spec, treedef, p: int):
    """Build ``f(*param_leaves, x, y) -> (*grad_leaves, loss, ncorrect)``.

    For p == 1 the leaves are per-model shapes; for p > 1 every input and
    output carries a leading learner dimension P and the body is mapped with
    ``lax.map`` (single compiled body, sequential over learners inside one
    XLA program — the coordinator issues ONE dispatch per global step).
    """
    lf = loss_fn(spec)
    n_leaves = treedef.num_leaves

    def single(params, x, y):
        (loss, (_, ncorrect)), grads = jax.value_and_grad(lf, has_aux=True)(
            params, x, y
        )
        return grads, loss, ncorrect

    def f(*args):
        leaves, x, y = args[:n_leaves], args[n_leaves], args[n_leaves + 1]
        if p == 1:
            params = jax.tree_util.tree_unflatten(treedef, list(leaves))
            grads, loss, ncorrect = single(params, x, y)
            return tuple(jax.tree_util.tree_leaves(grads)) + (loss, ncorrect)

        def body(sl):
            sl_leaves, sx, sy = sl
            params = jax.tree_util.tree_unflatten(treedef, list(sl_leaves))
            grads, loss, ncorrect = single(params, sx, sy)
            return tuple(jax.tree_util.tree_leaves(grads)), loss, ncorrect

        grads, loss, ncorrect = jax.lax.map(body, (tuple(leaves), x, y))
        return tuple(grads) + (loss, ncorrect)

    return f


def make_eval_step(spec, treedef):
    """``f(*param_leaves, x, y) -> (sum_loss, ncorrect)``."""
    lf = loss_fn(spec)
    n_leaves = treedef.num_leaves

    def f(*args):
        leaves, x, y = args[:n_leaves], args[n_leaves], args[n_leaves + 1]
        params = jax.tree_util.tree_unflatten(treedef, list(leaves))
        _, (sum_loss, ncorrect) = lf(params, x, y)
        return sum_loss, ncorrect

    return f


def param_leaves_with_paths(params) -> List[Tuple[str, jax.Array]]:
    """(name, leaf) pairs in canonical tree order; names become manifest
    entries the Rust `ParamLayout` mirrors."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((name, leaf))
    return out
