"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package must match its oracle to fp32 tolerance; the
pytest + hypothesis suite in python/tests enforces this over a sweep of
shapes and activations.  The oracles are also used to build a kernel-free
reference model whose gradients the Pallas-backed model must reproduce.
"""

from __future__ import annotations

import jax.numpy as jnp

_SQRT_2_OVER_PI = 0.7978845608028654


def ref_act(z, activation: str):
    if activation == "none":
        return z
    if activation == "relu":
        return jnp.maximum(z, 0.0)
    if activation == "gelu":
        return 0.5 * z * (1.0 + jnp.tanh(_SQRT_2_OVER_PI * (z + 0.044715 * z**3)))
    raise ValueError(f"unknown activation {activation!r}")


def ref_matmul(x, w):
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def ref_linear(x, w, b, activation: str = "relu"):
    return ref_act(ref_matmul(x, w) + b, activation)


def ref_group_average(x):
    return jnp.mean(x, axis=0)
