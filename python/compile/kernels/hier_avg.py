"""L1 Pallas kernel: hierarchical group averaging.

Averages S parameter shards (one per learner in a local cluster) into the
cluster mean — the inner reduction of Hier-AVG's local averaging step.  The
flat parameter vector is processed in CHUNK-sized blocks so the kernel's
VMEM footprint is independent of model size (S * CHUNK * 4 bytes per block;
with S=8, CHUNK=4096 that is 128 KiB).

The Rust coordinator has a native SIMD reduction for this on the hot path;
this artifact is the alternate XLA-executed path (benchmarked against the
native one in benches/reduction.rs) and the demonstration that the paper's
reduction primitive round-trips through the three-layer stack.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 4096


def _group_avg_kernel(x_ref, o_ref, *, s: int):
    # x block: (s, bd) — all S shards of one chunk; o block: (bd,).
    o_ref[...] = jnp.sum(x_ref[...], axis=0) * (1.0 / s)


def group_average(x, *, bd: int = CHUNK):
    """Mean over axis 0 of ``x: f32[S, D]`` via a Pallas reduction blocked
    along D.  D is zero-padded to a multiple of ``bd``."""
    s, d = x.shape
    bd = min(bd, max(d, 1))
    dp = ((d + bd - 1) // bd) * bd
    xp = jnp.pad(x, ((0, 0), (0, dp - d)))
    out = pl.pallas_call(
        functools.partial(_group_avg_kernel, s=s),
        grid=(dp // bd,),
        in_specs=[pl.BlockSpec((s, bd), lambda i: (0, i))],
        out_specs=pl.BlockSpec((bd,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), jnp.float32),
        interpret=True,
    )(xp)
    return out[:d]
