"""L1 Pallas kernels: tiled matmul and fused linear (matmul + bias +
activation) with a custom VJP whose dgrad / wgrad are themselves Pallas
matmul kernels.

Hardware adaptation (paper targets P100 GPUs / cuDNN): instead of a
threadblock + shared-memory decomposition, the kernel is tiled for the TPU
MXU / VMEM model — MXU-shaped (128, 128) output blocks, a sequential K grid
dimension accumulating partial products into the output block (which lives
in VMEM for the lifetime of the (i, j) block), and the bias + activation
epilogue fused into the final K step so the pre-activation never round-trips
to HBM.  ``interpret=True`` everywhere: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so kernels are lowered through the Pallas interpreter
into plain HLO (see DESIGN.md §2).

VMEM budget per grid point (fp32, default blocks bm=bn=bk=128):
  x block 128*128*4 = 64 KiB, w block 64 KiB, out/acc block 64 KiB,
  bias block 0.5 KiB  =>  ~192.5 KiB  << 16 MiB VMEM, leaving headroom for
  double-buffering the x/w streams (2x in-flight blocks ~ 385 KiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped default blocking.
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128

_SQRT_2_OVER_PI = 0.7978845608028654

VALID_ACTIVATIONS = ("none", "relu", "gelu")


def _apply_act(z, activation: str):
    if activation == "none":
        return z
    if activation == "relu":
        return jnp.maximum(z, 0.0)
    if activation == "gelu":
        # tanh approximation, matches ref.py.
        return 0.5 * z * (1.0 + jnp.tanh(_SQRT_2_OVER_PI * (z + 0.044715 * z**3)))
    raise ValueError(f"unknown activation {activation!r}")


def _act_grad(z, activation: str):
    """d(act)/dz evaluated at pre-activation z."""
    if activation == "none":
        return jnp.ones_like(z)
    if activation == "relu":
        return (z > 0.0).astype(z.dtype)
    if activation == "gelu":
        t = jnp.tanh(_SQRT_2_OVER_PI * (z + 0.044715 * z**3))
        dt = (1.0 - t**2) * _SQRT_2_OVER_PI * (1.0 + 3 * 0.044715 * z**2)
        return 0.5 * (1.0 + t) + 0.5 * z * dt
    raise ValueError(f"unknown activation {activation!r}")


def _block_dim(full: int, block: int) -> int:
    """Pick a block size: the MXU-shaped default, shrunk (to a multiple of 8
    where possible) when the dimension itself is smaller than one block so
    small problems do not pay 128x padding waste."""
    if full >= block:
        return block
    if full >= 8:
        return ((full + 7) // 8) * 8
    return full


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad2(a, rows: int, cols: int):
    r, c = a.shape
    if r == rows and c == cols:
        return a
    return jnp.pad(a, ((0, rows - r), (0, cols - c)))


# ---------------------------------------------------------------------------
# Plain tiled matmul (no bias / activation): used for dgrad + wgrad.
# ---------------------------------------------------------------------------


def _matmul_kernel(x_ref, w_ref, o_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _matmul_pallas(x, w, *, bm: int = BLOCK_M, bn: int = BLOCK_N, bk: int = BLOCK_K):
    """Tiled Pallas matmul ``x @ w`` for fp32 2-D operands of any shape
    (inputs are zero-padded up to block multiples; the result is sliced
    back)."""
    m, kx = x.shape
    kw, n = w.shape
    if kx != kw:
        raise ValueError(f"matmul shape mismatch: {x.shape} @ {w.shape}")
    bm = _block_dim(m, bm)
    bn = _block_dim(n, bn)
    bk = _block_dim(kx, bk)
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(kx, bk)
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(_pad2(x, mp, kp), _pad2(w, kp, np_))
    return out[:m, :n]


@jax.custom_vjp
def matmul(x, w):
    """Differentiable tiled Pallas matmul: the VJP's dgrad / wgrad are
    Pallas matmul kernels themselves (autodiff never enters the
    interpreter)."""
    return _matmul_pallas(x, w)


def _matmul_fwd(x, w):
    return _matmul_pallas(x, w), (x, w)


def _matmul_bwd(res, dy):
    x, w = res
    return _matmul_pallas(dy, w.T), _matmul_pallas(x.T, dy)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


# ---------------------------------------------------------------------------
# Fused linear forward: y = act(x @ w + b), emitting the pre-activation z
# as a second output (the VJP residual).
# ---------------------------------------------------------------------------


def _linear_kernel(x_ref, w_ref, b_ref, z_ref, y_ref, *, nk: int, activation: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        z_ref[...] = jnp.zeros_like(z_ref)

    z_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        z = z_ref[...] + b_ref[...]
        z_ref[...] = z
        y_ref[...] = _apply_act(z, activation)


def linear_fwd_pallas(
    x, w, b, activation: str, *, bm: int = BLOCK_M, bn: int = BLOCK_N, bk: int = BLOCK_K
):
    """Fused ``act(x @ w + b)``; returns ``(z, y)`` with z the
    pre-activation (VJP residual)."""
    if activation not in VALID_ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    m, kx = x.shape
    kw, n = w.shape
    if kx != kw or b.shape != (n,):
        raise ValueError(f"linear shape mismatch: {x.shape} @ {w.shape} + {b.shape}")
    bm = _block_dim(m, bm)
    bn = _block_dim(n, bn)
    bk = _block_dim(kx, bk)
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(kx, bk)
    nk = kp // bk
    b2 = jnp.pad(b, (0, np_ - n)).reshape(1, np_)
    z, y = pl.pallas_call(
        functools.partial(_linear_kernel, nk=nk, activation=activation),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        ],
        interpret=True,
    )(_pad2(x, mp, kp), _pad2(w, kp, np_), b2)
    return z[:m, :n], y[:m, :n]


# ---------------------------------------------------------------------------
# custom-VJP fused linear: the building block for every L2 linear layer.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x, w, b, activation: str = "relu"):
    """``act(x @ w + b)`` as one Pallas kernel (forward) with Pallas matmul
    dgrad / wgrad kernels (backward)."""
    _, y = linear_fwd_pallas(x, w, b, activation)
    return y


def _fused_linear_fwd(x, w, b, activation):
    z, y = linear_fwd_pallas(x, w, b, activation)
    return y, (x, w, z)


def _fused_linear_bwd(activation, res, dy):
    x, w, z = res
    dz = dy * _act_grad(z, activation)
    dx = _matmul_pallas(dz, w.T)          # dgrad
    dw = _matmul_pallas(x.T, dz)          # wgrad
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


fused_linear.defvjp(_fused_linear_fwd, _fused_linear_bwd)
