"""L1 Pallas kernel: fused SGD parameter update  w' = w − lr·g.

The coordinator applies updates natively on the hot path; this artifact is
the in-graph alternative (benchmarked in rust/benches/reduction.rs against
the native optimizer) and demonstrates an elementwise-update kernel through
the same AOT path as the reductions.  Blocked along the flat parameter
vector so VMEM use is constant (2 · CHUNK · 4 bytes in-flight per block).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 4096


def _sgd_kernel(w_ref, g_ref, lr_ref, o_ref):
    o_ref[...] = w_ref[...] - lr_ref[0] * g_ref[...]


def sgd_update(w, g, lr, *, bd: int = CHUNK):
    """``w - lr * g`` for flat f32 vectors via a blocked Pallas kernel."""
    (d,) = w.shape
    if g.shape != (d,):
        raise ValueError(f"shape mismatch: {w.shape} vs {g.shape}")
    bd = min(bd, max(d, 1))
    dp = ((d + bd - 1) // bd) * bd
    wp = jnp.pad(w, (0, dp - d))
    gp = jnp.pad(g, (0, dp - d))
    lr_arr = jnp.asarray(lr, jnp.float32).reshape(1)
    out = pl.pallas_call(
        _sgd_kernel,
        grid=(dp // bd,),
        in_specs=[
            pl.BlockSpec((bd,), lambda i: (i,)),
            pl.BlockSpec((bd,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), jnp.float32),
        interpret=True,
    )(wp, gp, lr_arr)
    return out[:d]


def ref_sgd_update(w, g, lr):
    return w - lr * g
