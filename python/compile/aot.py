"""AOT lowering driver: JAX graphs -> artifacts/ for the Rust runtime.

Python runs ONCE, here; it is never on the training path.  For every model
in the registry this script emits:

  <name>.train.p<P>.hlo.txt   train step (grads), stacked over P learners
  <name>.eval.hlo.txt         eval step (sum_loss, ncorrect), single copy
  <name>.init.bin             flat little-endian f32 initial parameters
  avg_s<S>.hlo.txt            Pallas group-average reduction artifacts
  manifest.json               shapes / layouts / file map for the Rust side

The interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published `xla` crate binds) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly.  Lowering goes stablehlo -> XlaComputation with return_tuple=True;
the Rust side unwraps with `to_tuple()`.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import hier_avg, sgd_update

AVG_GROUP_SIZES = (2, 4, 8)
FORMAT_VERSION = 1


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)", flush=True)


def lower_model(spec, out_dir: str, entry: dict) -> None:
    params = M.init_params(spec)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    named = M.param_leaves_with_paths(params)
    assert len(named) == len(leaves)

    # Parameter layout: canonical tree order, contiguous in the flat buffer.
    layout, offset = [], 0
    for name, leaf in named:
        size = int(np.prod(leaf.shape)) if leaf.shape else 1
        layout.append(
            {"name": name, "shape": [int(d) for d in leaf.shape],
             "offset": offset, "size": size}
        )
        offset += size
    entry["params"] = layout
    entry["n_params"] = offset

    # Initial parameters as one flat f32 blob (every learner starts from the
    # same synchronized point, per Algorithm 1 line 1).
    flat = np.concatenate([np.asarray(l, np.float32).reshape(-1) for l in leaves])
    init_path = os.path.join(out_dir, f"{spec.name}.init.bin")
    flat.astype("<f4").tofile(init_path)
    entry["init"] = os.path.basename(init_path)
    entry["init_sha256"] = hashlib.sha256(flat.tobytes()).hexdigest()
    print(f"  wrote {init_path} ({flat.size} f32)", flush=True)

    # Train steps, one per stacked-P variant.
    entry["train"] = {}
    bx, by = M.batch_specs(spec, spec.batch)
    for p in spec.train_p:
        f = M.make_train_step(spec, treedef, p)
        if p == 1:
            in_specs = [jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves]
            xspec, yspec = bx, by
        else:
            in_specs = [
                jax.ShapeDtypeStruct((p,) + l.shape, l.dtype) for l in leaves
            ]
            xspec = jax.ShapeDtypeStruct((p,) + bx.shape, bx.dtype)
            yspec = jax.ShapeDtypeStruct((p,) + by.shape, by.dtype)
        lowered = jax.jit(f).lower(*in_specs, xspec, yspec)
        path = os.path.join(out_dir, f"{spec.name}.train.p{p}.hlo.txt")
        _write(path, to_hlo_text(lowered))
        entry["train"][str(p)] = os.path.basename(path)

    # Eval step (single parameter copy, eval batch).
    ex, ey = M.batch_specs(spec, spec.eval_batch)
    g = M.make_eval_step(spec, treedef)
    in_specs = [jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves]
    lowered = jax.jit(g).lower(*in_specs, ex, ey)
    path = os.path.join(out_dir, f"{spec.name}.eval.hlo.txt")
    _write(path, to_hlo_text(lowered))
    entry["eval"] = os.path.basename(path)


def model_entry(spec) -> dict:
    entry = {
        "kind": spec.kind,
        "batch": spec.batch,
        "eval_batch": spec.eval_batch,
        "train_p": list(spec.train_p),
        "seed": spec.seed,
    }
    if spec.kind == "mlp":
        entry.update(
            {"dims": list(spec.dims), "activation": spec.activation,
             "input_dim": spec.input_dim, "classes": spec.classes}
        )
    else:
        entry.update(
            {"vocab": spec.vocab, "d_model": spec.d_model,
             "n_layers": spec.n_layers, "n_heads": spec.n_heads,
             "seq_len": spec.seq_len}
        )
    return entry


def lower_avg(out_dir: str, manifest: dict) -> None:
    manifest["avg"] = {"chunk": hier_avg.CHUNK, "groups": {}}
    for s in AVG_GROUP_SIZES:
        f = lambda x: (hier_avg.group_average(x),)
        spec = jax.ShapeDtypeStruct((s, hier_avg.CHUNK), jnp.float32)
        lowered = jax.jit(f).lower(spec)
        path = os.path.join(out_dir, f"avg_s{s}.hlo.txt")
        _write(path, to_hlo_text(lowered))
        manifest["avg"]["groups"][str(s)] = os.path.basename(path)

    # Fused SGD update (one CHUNK block; the Rust side loops chunks).
    g = lambda w, grad, lr: (sgd_update.sgd_update(w, grad, lr),)
    vec = jax.ShapeDtypeStruct((sgd_update.CHUNK,), jnp.float32)
    lr = jax.ShapeDtypeStruct((), jnp.float32)
    lowered = jax.jit(g).lower(vec, vec, lr)
    path = os.path.join(out_dir, "sgd_update.hlo.txt")
    _write(path, to_hlo_text(lowered))
    manifest["sgd_update"] = {
        "chunk": sgd_update.CHUNK,
        "file": os.path.basename(path),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=None, help="artifacts directory")
    ap.add_argument(
        "--models", default=None,
        help="comma-separated subset of models to lower (default: all)",
    )
    args = ap.parse_args()

    out_dir = args.out_dir or os.path.join(
        os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
        "artifacts",
    )
    os.makedirs(out_dir, exist_ok=True)

    names = args.models.split(",") if args.models else list(M.MODELS)
    manifest = {"format_version": FORMAT_VERSION, "models": {}}
    for name in names:
        spec = M.MODELS[name]
        print(f"[aot] lowering {name} ({spec.kind})", flush=True)
        entry = model_entry(spec)
        lower_model(spec, out_dir, entry)
        manifest["models"][name] = entry

    print("[aot] lowering group-average kernels", flush=True)
    lower_avg(out_dir, manifest)

    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"[aot] wrote {mpath}")


if __name__ == "__main__":
    main()
