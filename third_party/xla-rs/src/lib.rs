//! Type-checking stand-in for the vendored PJRT `xla` bindings.
//!
//! The real crate (C++ PJRT shim + generated bindings) cannot live on the
//! offline registry, but `runtime/xla_backend.rs` — the production code
//! path — should still *compile* on every push so API drift is caught in
//! CI (`cargo build --features xla`), not at vendoring time.  This crate
//! mirrors exactly the surface that file uses:
//!
//! - `PjRtClient::cpu`, `buffer_from_host_buffer`, `compile`
//! - `PjRtLoadedExecutable::execute_b::<PjRtBuffer>`
//! - `PjRtBuffer::to_literal_sync`
//! - `Literal::{to_tuple, to_tuple1, to_vec, get_first_element}`
//! - `HloModuleProto::from_text_file`, `XlaComputation::from_proto`
//!
//! Every constructor returns an error and every handle type is
//! uninhabited, so the non-constructor methods are
//! unreachable-but-typechecked — the same philosophy as
//! `runtime/xla_stub.rs`, one layer down.  To execute artifacts, replace
//! this directory with the real crate; the signatures above are the
//! compatibility contract.

use std::fmt;
use std::path::Path;

pub type Result<T> = std::result::Result<T, Error>;

/// The shim's only error: "this is not the real runtime".
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "xla shim: {what} is unavailable — third_party/xla-rs is a type-checking \
         stand-in; replace it with the vendored PJRT bindings to run artifacts"
    )))
}

/// Uninhabited token: handle types carry one so they can never exist at
/// run time, making their method bodies unreachable yet fully typed.
#[derive(Debug, Clone, Copy)]
enum Never {}

/// Element types PJRT host buffers and literals can carry.
pub trait ElementType: Copy {}
impl ElementType for f32 {}
impl ElementType for i32 {}

/// Buffer-like argument types accepted by `execute_b`.
pub trait BufferArgument {}
impl BufferArgument for PjRtBuffer {}

#[derive(Clone)]
pub struct PjRtClient {
    _n: Never,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn buffer_from_host_buffer<T: ElementType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        match self._n {}
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match self._n {}
    }
}

pub struct PjRtBuffer {
    _n: Never,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self._n {}
    }
}

pub struct PjRtLoadedExecutable {
    _n: Never,
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T: BufferArgument>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self._n {}
    }
}

pub struct Literal {
    _n: Never,
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match self._n {}
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        match self._n {}
    }

    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        match self._n {}
    }

    pub fn get_first_element<T: ElementType>(&self) -> Result<T> {
        match self._n {}
    }
}

pub struct HloModuleProto {
    _n: Never,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation {
    _n: Never,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        match proto._n {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_error_with_the_vendoring_hint() {
        let e = PjRtClient::cpu().unwrap_err().to_string();
        assert!(e.contains("third_party/xla-rs"), "{e}");
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
