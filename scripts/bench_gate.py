#!/usr/bin/env python3
"""Bench regression gate: fresh BENCH_<group>.json vs committed baselines.

Usage:
    python3 scripts/bench_gate.py [--expect-armed] <baseline_dir> \\
        BENCH_a.json [BENCH_b.json ...]

For each fresh report, the committed copy stashed under <baseline_dir> is
the baseline.  A group is *unarmed* (skipped with a notice) while its
committed file is still a schema placeholder — a `note` key and/or an
empty `benches` object, as emitted by the seed tree before the first real
bless.  Once a maintainer commits a real BENCH_<group>.json (run
`scripts/bless_bench.sh` on a representative host and commit the output),
the gate arms itself for that group automatically.

With `--expect-armed`, an unarmed group is a *failure*, not a skip: use it
once the repo's baselines have been blessed, so a regression can no longer
hide behind an accidentally re-placeholder'd baseline (or a renamed
BENCH file that silently never matches its committed copy).

Armed groups fail the build when any bench shared between baseline and
fresh run regresses by more than REGRESSION_FRAC in median ns/iter
(throughput drop > 20%).  Benches that declare a work-item axis
(`units_per_sec`, from benchkit's `bench_units` — e.g. the event engine's
events/sec curve or the train-step learner-steps/sec curve) are gated on
that axis instead: a drop of more than REGRESSION_FRAC in median items/s
fails, which stays meaningful even when `units_per_iter` is retuned
between blesses (ns/iter is not comparable across such a retune; items/s
is).  Benches present only in the baseline are warnings (a rename
silently un-gates a number); new benches pass — they become gated once
the refreshed baseline is committed.

CI runs the benches with reduced sampling (BENCHKIT_SAMPLES/
BENCHKIT_TARGET_MS), so the threshold is deliberately loose: it catches
step-change regressions (an accidental O(P) loop on the hot path), not
single-digit-percent drift.  Noise-level failures on shared runners should
be resolved by re-blessing the baseline, not by widening the threshold.
"""

import json
import os
import sys

REGRESSION_FRAC = 0.20


def load(path):
    with open(path) as f:
        return json.load(f)


def gate_group(fresh_path, baseline_dir, expect_armed=False):
    name = os.path.basename(fresh_path)
    base_path = os.path.join(baseline_dir, name)
    fresh = load(fresh_path)
    group = fresh.get("group", name)

    def unarmed(why):
        if expect_armed:
            print(f"::error::[{group}] {why} but --expect-armed was given")
            return [(f"{group} ({why})", 0.0, 0.0, float("inf"), "ns/iter")]
        print(f"[{group}] {why} — gate unarmed")
        return []

    if not os.path.exists(base_path):
        return unarmed(f"no committed baseline ({name})")
    base = load(base_path)
    base_benches = base.get("benches") or {}
    if "note" in base or not base_benches:
        return unarmed("committed baseline is a schema placeholder")

    failures = []
    fresh_benches = fresh.get("benches") or {}
    for bench, b in sorted(base_benches.items()):
        f = fresh_benches.get(bench)
        if f is None:
            print(f"::warning::[{group}] bench '{bench}' present in baseline "
                  f"but missing from the fresh run — renamed or removed?")
            continue
        base_ups, fresh_ups = b.get("units_per_sec"), f.get("units_per_sec")
        if base_ups is not None and fresh_ups is not None:
            # Work-item throughput axis: slowdown = base/fresh items/s.
            ratio = base_ups / fresh_ups if fresh_ups > 0 else float("inf")
            status = "ok"
            if ratio > 1.0 + REGRESSION_FRAC:
                status = "REGRESSION"
                failures.append((bench, base_ups, fresh_ups, ratio, "items/s"))
            print(f"[{group}] {bench:<48} base {base_ups:>12.1f} it/s  "
                  f"fresh {fresh_ups:>12.1f} it/s  x{ratio:.3f}  {status}")
            continue
        base_ns, fresh_ns = b["ns_per_iter"], f["ns_per_iter"]
        ratio = fresh_ns / base_ns if base_ns > 0 else float("inf")
        status = "ok"
        if ratio > 1.0 + REGRESSION_FRAC:
            status = "REGRESSION"
            failures.append((bench, base_ns, fresh_ns, ratio, "ns/iter"))
        print(f"[{group}] {bench:<48} base {base_ns:>12.1f} ns  "
              f"fresh {fresh_ns:>12.1f} ns  x{ratio:.3f}  {status}")
    for bench in sorted(set(fresh_benches) - set(base_benches)):
        print(f"[{group}] {bench:<48} (new bench, ungated until the "
              f"refreshed baseline is committed)")
    return failures


def main(argv):
    args = list(argv[1:])
    expect_armed = "--expect-armed" in args
    args = [a for a in args if a != "--expect-armed"]
    if len(args) < 2:
        print(__doc__)
        return 2
    baseline_dir = args[0]
    all_failures = []
    for fresh_path in args[1:]:
        all_failures += gate_group(fresh_path, baseline_dir, expect_armed)
    if all_failures:
        print()
        for bench, base_v, fresh_v, ratio, unit in all_failures:
            print(f"::error::bench '{bench}' regressed x{ratio:.3f} "
                  f"({base_v:.1f} -> {fresh_v:.1f} {unit}, "
                  f"threshold x{1.0 + REGRESSION_FRAC:.2f})")
        return 1
    print("bench gate: no regressions above "
          f"{int(REGRESSION_FRAC * 100)}% on armed groups")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
