#!/usr/bin/env bash
# Bless the committed bench baselines: run the full benchkit suite at full
# sampling fidelity on the current host and stage the refreshed
# BENCH_<group>.json files for commit.
#
# Run this on a representative machine (NOT a shared CI runner) whenever
# the perf trajectory legitimately moves — a kernel rewrite, a new bench
# case, a hardware change.  Committing the output arms scripts/bench_gate.py
# for every group that gained real numbers: from then on CI fails any
# >20% median regression against these files, and can be run with
# `--expect-armed` so a group can never silently slip back to placeholder.
#
# Usage:
#     scripts/bless_bench.sh            # run everything, stage BENCH_*.json
#     scripts/bless_bench.sh --no-stage # run everything, leave git alone
#
# After blessing, optionally re-derive the analytic cost-model constants
# from the fresh numbers:
#     python3 scripts/calibrate_cost_model.py

set -euo pipefail
cd "$(dirname "$0")/.."

STAGE=1
if [[ "${1:-}" == "--no-stage" ]]; then
    STAGE=0
fi

# Full fidelity: benchkit's defaults (15 samples, 80 ms target) apply when
# the CI-smoke knobs are unset.  A stray BENCHKIT_FILTER would suppress
# the JSON rewrite entirely, so clear it too.
unset BENCHKIT_SAMPLES BENCHKIT_TARGET_MS BENCHKIT_FILTER

# Baselines must record the default dispatch; a leftover scalar override
# would bless scalar-speed numbers and make every later SIMD run look
# like a (nonexistent) improvement.
unset HIER_FORCE_SCALAR

echo "== building release benches =="
cargo build --release --benches

# Each bench binary writes BENCH_<group>.json at the repo root on finish().
# `figures` and `theory` are analysis/plot harnesses, not perf groups —
# they do not feed the gate.
for bench in reduction step_throughput event_loop schedule_policy compress; do
    echo "== cargo bench --bench $bench =="
    cargo bench --bench "$bench"
done

echo
echo "== refreshed baselines =="
ls -l BENCH_*.json

if [[ "$STAGE" == "1" ]]; then
    git add BENCH_*.json
    echo "staged; commit with e.g.:"
    echo "    git commit -m 'Bless bench baselines on <host description>'"
else
    echo "(--no-stage: not touching the git index)"
fi
