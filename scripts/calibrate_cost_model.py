#!/usr/bin/env python3
"""Derive simulation cost-model constants from measured bench baselines.

Usage:
    python3 scripts/calibrate_cost_model.py [repo_root]

Reads `BENCH_step.json` and `BENCH_reduction.json` (as written by
`scripts/bless_bench.sh`) and prints suggested replacements for the two
places the simulator hard-codes literature constants:

  * `sim_step_seconds` in rust/src/coordinator/mod.rs — the per-step
    compute time model `6·B·n / DEVICE_FLOPS`.  From the measured
    single-replica native step (`native/resnet18_sim/p1`) we solve for
    the DEVICE_FLOPS this host actually sustains on the MLP hot path,
    and print the equivalent constant.

  * The α/β link parameters in rust/src/comm/cost.rs (`CostModel::
    default`).  The native group-average benches sweep group size and
    payload, so a least-squares fit of the ring-allreduce cost form
        T(s, n) ≈ 2(s-1)·α + 2·((s-1)/s)·(4n)·β
    over the measured `native/group_avg/<label>/s<s>` points yields the
    host's effective latency (α) and per-byte (β) terms.  A simulation
    host can only observe its own memory fabric, so the fit calibrates
    the *intra-node* tier directly; the inter-node and rack tiers are
    suggested by scaling the fitted values by the default model's
    literature ratios (NVLink : EDR IB : rack uplink).

The printed JSON snippet uses the config keys the run loader already
accepts (`alpha_intra` … `beta_rack`), so it can be pasted into a run
config verbatim.  On a tree whose baselines are still schema
placeholders (no toolchain has blessed them yet) the script says so and
exits 0 — it never invents numbers.
"""

import json
import os
import re
import sys

# Shapes encoded in the bench labels (benchkit JSON does not carry them).
# Keep in sync with rust/benches/{step_throughput,reduction}.rs and
# driver::MODEL_DIMS.
STEP_BENCH = "native/resnet18_sim/p1"
STEP_BATCH = 16
STEP_N_PARAMS = 101_386  # MLP [128, 256, 256, 10]
STEP_REPLICAS = 1

GROUP_AVG_RE = re.compile(r"^native/group_avg/(100k|3\.4M)/s(\d+)$")
PAYLOAD = {"100k": 101_386, "3.4M": 3_400_000}

# CostModel::default literature constants (rust/src/comm/cost.rs) — used
# only for the inter/rack tier *ratios* relative to intra.
DEFAULT = {
    "alpha_intra": 5e-6, "beta_intra": 1.0 / 40e9,
    "alpha_inter": 20e-6, "beta_inter": 1.0 / 10e9,
    "alpha_rack": 50e-6, "beta_rack": 1.0 / 5e9,
}


def load(root, name):
    path = os.path.join(root, name)
    if not os.path.exists(path):
        return None, f"{name}: not found"
    with open(path) as f:
        rep = json.load(f)
    benches = rep.get("benches") or {}
    if "note" in rep or not benches:
        return None, (f"{name}: still a schema placeholder — run "
                      "scripts/bless_bench.sh on a host with a Rust "
                      "toolchain first")
    return benches, None


def fit_alpha_beta(points):
    """Least-squares fit T = a·x + b·y with x=2(s-1), y=2((s-1)/s)·bytes."""
    sxx = sxy = syy = sxt = syt = 0.0
    for s, n, t in points:
        x = 2.0 * (s - 1)
        y = 2.0 * ((s - 1) / s) * (4.0 * n)
        sxx += x * x
        sxy += x * y
        syy += y * y
        sxt += x * t
        syt += y * t
    det = sxx * syy - sxy * sxy
    if det <= 0.0:
        return None
    alpha = (sxt * syy - syt * sxy) / det
    beta = (syt * sxx - sxt * sxy) / det
    return alpha, beta


def main(argv):
    root = argv[1] if len(argv) > 1 else os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..")

    step, step_err = load(root, "BENCH_step.json")
    red, red_err = load(root, "BENCH_reduction.json")
    for err in (step_err, red_err):
        if err:
            print(f"calibrate_cost_model: {err}")
    if step is None and red is None:
        print("calibrate_cost_model: nothing to calibrate from; keeping "
              "the literature defaults in rust/src/comm/cost.rs and "
              "rust/src/coordinator/mod.rs")
        return 0

    print("calibrate_cost_model: suggested constants from committed "
          "baselines\n")

    if step is not None:
        b = step.get(STEP_BENCH)
        if b is None:
            print(f"  (step: bench '{STEP_BENCH}' missing; skipping "
                  "compute calibration)")
        else:
            # ns for one grads() call over STEP_REPLICAS replicas.
            step_s = b["ns_per_iter"] * 1e-9 / STEP_REPLICAS
            flops = 6.0 * STEP_BATCH * STEP_N_PARAMS
            device_flops = flops / step_s
            print("  # rust/src/coordinator/mod.rs :: sim_step_seconds")
            print(f"  #   measured {STEP_BENCH}: {step_s * 1e6:.1f} us/step "
                  f"(B={STEP_BATCH}, n={STEP_N_PARAMS})")
            print(f"  const DEVICE_FLOPS: f64 = {device_flops:.3e}; "
                  "// this host, native MLP hot path")
            print(f"  # -> sim_step_seconds(B, n) = 6*B*n / DEVICE_FLOPS "
                  f"= {step_s:.3e} s at the bench shape\n")

    if red is not None:
        points = []
        for name, b in red.items():
            m = GROUP_AVG_RE.match(name)
            if m:
                points.append((int(m.group(2)), PAYLOAD[m.group(1)],
                               b["ns_per_iter"] * 1e-9))
        fitted = fit_alpha_beta(points) if len(points) >= 2 else None
        if fitted is None:
            print("  (reduction: too few native/group_avg points for an "
                  "alpha/beta fit; skipping link calibration)")
        else:
            alpha, beta = fitted
            alpha = max(alpha, 0.0)  # tiny negative intercept = pure-bw host
            suggestion = {
                "alpha_intra": alpha,
                "beta_intra": beta,
                "alpha_inter": alpha * DEFAULT["alpha_inter"] / DEFAULT["alpha_intra"],
                "beta_inter": beta * DEFAULT["beta_inter"] / DEFAULT["beta_intra"],
                "alpha_rack": alpha * DEFAULT["alpha_rack"] / DEFAULT["alpha_intra"],
                "beta_rack": beta * DEFAULT["beta_rack"] / DEFAULT["beta_intra"],
            }
            print("  # rust/src/comm/cost.rs :: CostModel (intra fitted "
                  f"from {len(points)} group_avg points; inter/rack scaled "
                  "by the literature ratios)")
            print("  " + json.dumps(
                {k: float(f"{v:.4e}") for k, v in suggestion.items()},
                indent=2).replace("\n", "\n  "))
            eff_bw = 1.0 / beta if beta > 0 else float("inf")
            print(f"  # fitted: alpha={alpha * 1e6:.2f} us, "
                  f"beta -> {eff_bw / 1e9:.1f} GB/s effective")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
