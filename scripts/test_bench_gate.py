#!/usr/bin/env python3
"""Unit tests for scripts/bench_gate.py (run: python3 scripts/test_bench_gate.py).

The gate guards the perf trajectory, so its own arming/threshold logic
must be pinned: a placeholder baseline must stay unarmed, a >20% median
regression must fail, renames must warn rather than silently un-gate,
and new benches must pass until their baseline is committed.
"""

import importlib.util
import json
import os
import sys
import tempfile
import unittest

_HERE = os.path.dirname(os.path.abspath(__file__))
_SPEC = importlib.util.spec_from_file_location(
    "bench_gate", os.path.join(_HERE, "bench_gate.py")
)
bench_gate = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(bench_gate)


def report(group, benches, note=None):
    out = {"group": group, "target_sample_ms": 80, "benches": benches}
    if note is not None:
        out["note"] = note
    return out


def bench(ns):
    return {"ns_per_iter": ns, "samples": 5}


def units_bench(ns, units):
    return {
        "ns_per_iter": ns,
        "samples": 5,
        "units_per_iter": units,
        "units_per_sec": units / ns * 1e9,
    }


class GateGroupTests(unittest.TestCase):
    def setUp(self):
        self._tmp = tempfile.TemporaryDirectory()
        self.dir = self._tmp.name
        self.baseline_dir = os.path.join(self.dir, "baseline")
        os.makedirs(self.baseline_dir)

    def tearDown(self):
        self._tmp.cleanup()

    def write(self, where, name, payload):
        path = os.path.join(where, name)
        with open(path, "w") as f:
            json.dump(payload, f)
        return path

    def gate(self, fresh, baseline=None):
        if baseline is not None:
            self.write(self.baseline_dir, "BENCH_t.json", baseline)
        fresh_path = self.write(self.dir, "BENCH_t.json", fresh)
        return bench_gate.gate_group(fresh_path, self.baseline_dir)

    def test_missing_baseline_is_unarmed(self):
        failures = self.gate(report("t", {"a": bench(100.0)}))
        self.assertEqual(failures, [])

    def test_placeholder_note_is_unarmed(self):
        base = report("t", {"a": bench(1.0)}, note="schema placeholder")
        fresh = report("t", {"a": bench(1e9)})
        self.assertEqual(self.gate(fresh, base), [])

    def test_empty_benches_is_unarmed(self):
        base = report("t", {})
        fresh = report("t", {"a": bench(1e9)})
        self.assertEqual(self.gate(fresh, base), [])

    def test_within_threshold_passes(self):
        frac = bench_gate.REGRESSION_FRAC
        base = report("t", {"a": bench(100.0), "b": bench(200.0)})
        fresh = report(
            "t", {"a": bench(100.0 * (1.0 + frac)), "b": bench(150.0)}
        )
        self.assertEqual(self.gate(fresh, base), [])

    def test_regression_beyond_threshold_fails(self):
        frac = bench_gate.REGRESSION_FRAC
        base = report("t", {"a": bench(100.0), "b": bench(200.0)})
        fresh = report("t", {"a": bench(100.0 * (1.0 + frac) + 1.0), "b": bench(200.0)})
        failures = self.gate(fresh, base)
        self.assertEqual(len(failures), 1)
        self.assertEqual(failures[0][0], "a")

    def test_zero_baseline_regression_is_infinite(self):
        base = report("t", {"a": bench(0.0)})
        fresh = report("t", {"a": bench(5.0)})
        failures = self.gate(fresh, base)
        self.assertEqual(len(failures), 1)
        self.assertEqual(failures[0][3], float("inf"))

    def test_units_axis_gates_on_items_per_sec(self):
        frac = bench_gate.REGRESSION_FRAC
        # items/s drop beyond the threshold fails even though ns/iter alone
        # would look like a modest slowdown on a retuned units_per_iter.
        base = report("t", {"a": units_bench(100.0, 64)})
        slow_ns = 100.0 * (1.0 + frac) + 10.0
        fresh = report("t", {"a": units_bench(slow_ns, 64)})
        failures = self.gate(fresh, base)
        self.assertEqual(len(failures), 1)
        self.assertEqual(failures[0][0], "a")
        self.assertEqual(failures[0][4], "items/s")

    def test_units_axis_within_threshold_passes(self):
        base = report("t", {"a": units_bench(100.0, 64)})
        # 10% items/s drop: inside the 20% threshold.
        fresh = report("t", {"a": units_bench(111.0, 64)})
        self.assertEqual(self.gate(fresh, base), [])

    def test_units_axis_survives_units_per_iter_retune(self):
        # The curve was re-specified (P doubled per iteration) but items/s
        # held: ns/iter doubled, which must NOT fail on the units axis.
        base = report("t", {"a": units_bench(100.0, 64)})
        fresh = report("t", {"a": units_bench(200.0, 128)})
        self.assertEqual(self.gate(fresh, base), [])

    def test_units_axis_falls_back_to_ns_when_baseline_lacks_units(self):
        # Mixed schema (baseline pre-dates bench_units): ns/iter gates.
        frac = bench_gate.REGRESSION_FRAC
        base = report("t", {"a": bench(100.0)})
        fresh = report("t", {"a": units_bench(100.0 * (1.0 + frac) + 1.0, 64)})
        failures = self.gate(fresh, base)
        self.assertEqual(len(failures), 1)
        self.assertEqual(failures[0][4], "ns/iter")

    def test_bench_missing_from_fresh_run_warns_not_fails(self):
        base = report("t", {"renamed_away": bench(100.0)})
        fresh = report("t", {"new_name": bench(100.0)})
        self.assertEqual(self.gate(fresh, base), [])

    def test_new_bench_is_ungated(self):
        base = report("t", {"a": bench(100.0)})
        fresh = report("t", {"a": bench(100.0), "fresh_case": bench(1e9)})
        self.assertEqual(self.gate(fresh, base), [])

    def test_expect_armed_turns_placeholder_into_failure(self):
        base = report("t", {"a": bench(1.0)}, note="schema placeholder")
        self.write(self.baseline_dir, "BENCH_t.json", base)
        fresh = self.write(self.dir, "BENCH_t.json", report("t", {"a": bench(1.0)}))
        failures = bench_gate.gate_group(fresh, self.baseline_dir, expect_armed=True)
        self.assertEqual(len(failures), 1)
        self.assertIn("placeholder", failures[0][0])
        # ... and a missing baseline fails the same way.
        os.remove(os.path.join(self.baseline_dir, "BENCH_t.json"))
        failures = bench_gate.gate_group(fresh, self.baseline_dir, expect_armed=True)
        self.assertEqual(len(failures), 1)
        self.assertIn("no committed baseline", failures[0][0])

    def test_expect_armed_flag_through_main(self):
        base = report("t", {"a": bench(1.0)}, note="schema placeholder")
        self.write(self.baseline_dir, "BENCH_t.json", base)
        fresh = self.write(self.dir, "BENCH_t.json", report("t", {"a": bench(1.0)}))
        self.assertEqual(
            bench_gate.main(["bench_gate.py", self.baseline_dir, fresh]), 0
        )
        self.assertEqual(
            bench_gate.main(
                ["bench_gate.py", "--expect-armed", self.baseline_dir, fresh]
            ),
            1,
        )
        # An armed, non-regressed group passes under --expect-armed.
        self.write(self.baseline_dir, "BENCH_t.json", report("t", {"a": bench(100.0)}))
        ok = self.write(self.dir, "BENCH_t.json", report("t", {"a": bench(90.0)}))
        self.assertEqual(
            bench_gate.main(["bench_gate.py", "--expect-armed", self.baseline_dir, ok]),
            0,
        )

    def test_main_exit_codes(self):
        base = report("t", {"a": bench(100.0)})
        self.write(self.baseline_dir, "BENCH_t.json", base)
        ok = self.write(self.dir, "BENCH_t.json", report("t", {"a": bench(90.0)}))
        self.assertEqual(bench_gate.main(["bench_gate.py", self.baseline_dir, ok]), 0)
        bad = self.write(self.dir, "BENCH_t.json", report("t", {"a": bench(500.0)}))
        self.assertEqual(bench_gate.main(["bench_gate.py", self.baseline_dir, bad]), 1)
        self.assertEqual(bench_gate.main(["bench_gate.py"]), 2)


if __name__ == "__main__":
    unittest.main()
