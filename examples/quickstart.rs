//! Quickstart: train a small classifier with Hier-AVG through the full
//! three-layer stack (Pallas kernel -> JAX graph -> HLO artifact -> PJRT).
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Falls back to the native backend when artifacts are not built.

use hier_avg::config::{BackendKind, RunConfig};
use hier_avg::driver;
use hier_avg::optimizer::LrSchedule;
use hier_avg::runtime::Manifest;

fn main() -> anyhow::Result<()> {
    // Hier-AVG with P=4 learners in clusters of S=2: local averaging every
    // K1=2 steps, global reduction every K2=8.
    let mut cfg = RunConfig::defaults("quickstart");
    cfg.p = 4;
    cfg.s = 2;
    cfg.k1 = 2;
    cfg.k2 = 8;
    cfg.epochs = 5;
    cfg.train_n = 4096;
    cfg.test_n = 512;
    cfg.lr = LrSchedule::Constant(0.1);
    // A gentle two-mode mixture so the quickstart converges in seconds.
    cfg.subclusters = 2;
    cfg.label_noise = 0.0;
    cfg.backend = if Manifest::load_default().is_ok() {
        BackendKind::Xla
    } else {
        eprintln!("artifacts/ not built; using the native backend (run `make artifacts`)");
        BackendKind::Native
    };

    println!(
        "Hier-AVG quickstart: P={} S={} K1={} K2={} backend={:?}",
        cfg.p, cfg.s, cfg.k1, cfg.k2, cfg.backend
    );
    let rec = driver::run(&cfg)?;
    for e in &rec.epochs {
        println!(
            "epoch {:>2}  train_loss {:.4}  test_acc {:.4}",
            e.epoch, e.train_loss, e.test_acc
        );
    }
    println!(
        "\n{} steps; {} global + {} local reductions; modelled comm {:.2} ms",
        rec.total_steps,
        rec.comm.global_reductions,
        rec.comm.local_reductions,
        rec.comm.total_seconds() * 1e3,
    );
    println!("final test accuracy: {:.2}%", rec.final_test_acc() * 100.0);
    Ok(())
}
