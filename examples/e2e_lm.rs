//! End-to-end driver (DESIGN.md §5 "e2e"): train a decoder-only
//! transformer LM with Hier-AVG on a synthetic Markov corpus, through the
//! full stack — Pallas fused-linear kernels inside a JAX transformer,
//! AOT-lowered to HLO, executed by the Rust coordinator via PJRT, with
//! hierarchical parameter averaging between the P learners.
//!
//!     make artifacts && cargo run --release --example e2e_lm [--model lm_medium]
//!         [--steps N] [--p N] [--out results/e2e_lm.json]
//!
//! Logs the per-step loss curve and compares the final loss against the
//! corpus's entropy floor.  Defaults match the recorded reference run.

use anyhow::Result;

use hier_avg::config::{BackendKind, RunConfig};
use hier_avg::data::{TokenData, TokenSpec};
use hier_avg::driver;
use hier_avg::optimizer::LrSchedule;
use hier_avg::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(&[])?;
    let model = args.get_or("model", "lm_small").to_string();
    let steps: usize = args.parse_or("steps", 300)?;
    let p: usize = args.parse_or("p", 4)?;

    let mut cfg = RunConfig::defaults(&model);
    cfg.backend = BackendKind::Xla;
    cfg.p = p;
    cfg.s = 2;
    cfg.k1 = 2;
    cfg.k2 = 8;
    cfg.record_steps = true;
    // Split the step budget into 10 "epochs" so we get periodic eval.
    cfg.epochs = 10;
    let b = 8; // lm batch (manifest)
    cfg.train_n = (steps / cfg.epochs).max(1) * p * b;
    cfg.test_n = 64;
    cfg.lr = LrSchedule::WarmupCosine {
        peak: 0.5,
        final_lr: 0.05,
        warmup_epochs: 1,
        total_epochs: 10,
    };

    println!(
        "e2e LM training: {model}, P={p} S={} K1={} K2={}, ~{steps} steps",
        cfg.s, cfg.k1, cfg.k2
    );
    let started = std::time::Instant::now();
    let rec = driver::run(&cfg)?;
    let wall = started.elapsed().as_secs_f64();

    // Entropy floor of the generating channel, for context.
    let floor = TokenData::generate(TokenSpec::tiny_corpus(256, 64)).entropy_floor();

    println!("\nstep losses (every 10th):");
    for (i, l) in rec.step_loss.iter().enumerate().step_by(10) {
        println!("  step {i:>4}  loss {l:.4}");
    }
    println!("\nper-epoch eval:");
    for e in &rec.epochs {
        println!(
            "  epoch {:>2}  train_loss {:.4}  test_loss {:.4}  token_acc {:.4}",
            e.epoch, e.train_loss, e.test_loss, e.test_acc
        );
    }
    let first = rec.step_loss.first().copied().unwrap_or(f32::NAN);
    let last_losses: Vec<f32> =
        rec.step_loss.iter().rev().take(10).copied().collect();
    let last = last_losses.iter().sum::<f32>() / last_losses.len().max(1) as f32;
    println!("\nsummary:");
    println!("  steps: {}   wall: {wall:.1}s   ({:.0} ms/step)", rec.total_steps, wall * 1e3 / rec.total_steps as f64);
    println!("  loss: {first:.4} -> {last:.4}   (channel entropy floor ~ {floor:.4} nats)");
    println!(
        "  reductions: {} global, {} local; modelled comm {:.3}s on the simulated cluster",
        rec.comm.global_reductions,
        rec.comm.local_reductions,
        rec.comm.total_seconds()
    );
    if let Some(out) = args.get("out") {
        rec.write_json(std::path::Path::new(out))?;
        println!("  wrote {out}");
    }
    Ok(())
}
