//! Table-1 style head-to-head: K-AVG at its tuned K vs Hier-AVG at
//! K2 = 2K with local averaging, at equal data budgets — accuracy AND the
//! modelled communication bill (§3.5: trade local for global reductions).
//!
//!     cargo run --release --example kavg_vs_hier [--p 16] [--k 8]
//!         [--backend xla|native] [--epochs N]

use anyhow::Result;

use hier_avg::config::{BackendKind, RunConfig};
use hier_avg::driver;
use hier_avg::optimizer::LrSchedule;
use hier_avg::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(&[])?;
    let p: usize = args.parse_or("p", 16)?;
    let k: u64 = args.parse_or("k", 8)?;
    let backend = BackendKind::parse(args.get_or("backend", "native"))?;
    let epochs: usize = args.parse_or("epochs", 16)?;

    let mk = |s: usize, k1: u64, k2: u64| {
        let mut cfg = RunConfig::defaults("resnet18_sim");
        cfg.backend = backend;
        cfg.p = p;
        cfg.s = s;
        cfg.k1 = k1;
        cfg.k2 = k2;
        cfg.epochs = epochs;
        cfg.train_n = 64 * p * 16;
        cfg.test_n = 1024;
        cfg.lr =
            LrSchedule::StepDecay { initial: 0.1, milestones: vec![(epochs * 3 / 4, 0.01)] };
        cfg
    };

    println!("K-AVG(K={k}) vs Hier-AVG(K2={}, K1∈{{1,{}}}, S=4), P={p}", 2 * k, k / 2);
    println!(
        "{:<26} {:>10} {:>10} {:>12} {:>12} {:>14}",
        "run", "test_acc", "best_acc", "glob_reds", "loc_reds", "comm_model_s"
    );
    let kavg = driver::run(&mk(1, k, k))?;
    let rows: Vec<(String, RunCfgResult)> = vec![
        ("K-AVG".into(), summarize(&kavg)),
        ("Hier-AVG K1=1".into(), summarize(&driver::run(&mk(4, 1, 2 * k))?)),
        (format!("Hier-AVG K1={}", (k / 2).max(1)), summarize(&driver::run(&mk(4, (k / 2).max(1), 2 * k))?)),
    ];
    for (name, r) in &rows {
        println!(
            "{:<26} {:>10.4} {:>10.4} {:>12} {:>12} {:>14.4}",
            name, r.acc, r.best, r.glob, r.loc, r.comm_s
        );
    }
    let base = &rows[0].1;
    for (name, r) in &rows[1..] {
        println!(
            "{name}: {:.1}% of K-AVG's global reductions, {:.2}x modelled comm speedup, acc delta {:+.4}",
            100.0 * r.glob as f64 / base.glob as f64,
            base.comm_s / r.comm_s,
            r.acc - base.acc
        );
    }
    Ok(())
}

struct RunCfgResult {
    acc: f64,
    best: f64,
    glob: u64,
    loc: u64,
    comm_s: f64,
}

fn summarize(rec: &hier_avg::metrics::RunRecord) -> RunCfgResult {
    RunCfgResult {
        acc: rec.final_test_acc(),
        best: rec.best_test_acc(),
        glob: rec.comm.global_reductions,
        loc: rec.comm.local_reductions,
        comm_s: rec.comm.total_seconds(),
    }
}
