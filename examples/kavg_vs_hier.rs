//! Table-1 style head-to-head: K-AVG at its tuned K vs Hier-AVG at
//! K2 = 2K with local averaging, at equal data budgets — accuracy, the
//! modelled communication bill (§3.5: trade local for global reductions),
//! and, under the event execution model, where the straggler stall lands
//! (local vs global barriers) and the resulting makespan.
//!
//!     cargo run --release --example kavg_vs_hier [--p 16] [--k 8]
//!         [--backend xla|native] [--epochs N]
//!         [--schedule static|adaptive[:target]|warmup[:k]]
//!         [--exec lockstep|event] [--het F] [--straggler P[:M]]
//!
//! Default: event mode with a mild rate ramp and rare straggler spikes,
//! so the stall columns are populated.  `--exec lockstep` restores the
//! legacy shared-clock accounting (stall columns read zero; the
//! heterogeneity knobs are ignored there — lockstep cannot express them).
//! `--schedule` runs every row under a schedule policy (e.g.
//! `adaptive:0.1` lets the straggler-aware controller widen each row's
//! intervals online); `examples/adaptive_vs_static.rs` compares the
//! policies head to head on one fixed shape.

use anyhow::Result;

use hier_avg::config::{BackendKind, RunConfig};
use hier_avg::driver;
use hier_avg::optimizer::LrSchedule;
use hier_avg::sim::{ExecKind, HetSpec};
use hier_avg::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(&[])?;
    let p: usize = args.parse_or("p", 16)?;
    let k: u64 = args.parse_or("k", 8)?;
    let backend = BackendKind::parse(args.get_or("backend", "native"))?;
    let epochs: usize = args.parse_or("epochs", 16)?;
    let exec = ExecKind::parse(args.get_or("exec", "event"))?;
    let policy = hier_avg::algorithms::PolicyKind::parse(args.get_or("schedule", "static"))?;
    // The example's demo defaults (mild ramp, rare spikes), overridable
    // through the shared --het/--straggler grammar.
    let mut spec =
        HetSpec { het: 0.15, straggler_prob: 0.02, ..HetSpec::default() };
    spec.apply_args(&args)?;
    let (het, sprob, smult) = (spec.het, spec.straggler_prob, spec.straggler_mult);

    let mk = |s: usize, k1: u64, k2: u64| {
        let mut cfg = RunConfig::defaults("resnet18_sim");
        cfg.backend = backend;
        cfg.p = p;
        cfg.s = s;
        cfg.k1 = k1;
        cfg.k2 = k2;
        cfg.epochs = epochs;
        cfg.train_n = 64 * p * 16;
        cfg.test_n = 1024;
        cfg.lr =
            LrSchedule::StepDecay { initial: 0.1, milestones: vec![(epochs * 3 / 4, 0.01)] };
        cfg.exec = exec;
        cfg.schedule_policy = policy;
        if exec == ExecKind::Event {
            cfg.het = het;
            cfg.straggler_prob = sprob;
            cfg.straggler_mult = smult;
        }
        cfg
    };

    println!(
        "K-AVG(K={k}) vs Hier-AVG(K2={}, K1∈{{1,{}}}, S=4), P={p}, exec={}, schedule={}",
        2 * k,
        k / 2,
        exec.name(),
        policy.spec()
    );
    if exec == ExecKind::Event {
        println!("event model: het={het} straggler={sprob}:{smult} (time model only — numerics match lockstep)");
    }
    println!(
        "{:<26} {:>10} {:>10} {:>10} {:>10} {:>12} {:>12} {:>12} {:>12}",
        "run", "test_acc", "best_acc", "glob_reds", "loc_reds", "comm_model_s",
        "stall_loc_s", "stall_glob_s", "makespan_s"
    );
    let kavg = driver::run(&mk(1, k, k))?;
    let rows: Vec<(String, RunCfgResult)> = vec![
        ("K-AVG".into(), summarize(&kavg)),
        ("Hier-AVG K1=1".into(), summarize(&driver::run(&mk(4, 1, 2 * k))?)),
        (format!("Hier-AVG K1={}", (k / 2).max(1)), summarize(&driver::run(&mk(4, (k / 2).max(1), 2 * k))?)),
    ];
    for (name, r) in &rows {
        println!(
            "{:<26} {:>10.4} {:>10.4} {:>10} {:>10} {:>12.4} {:>12.4} {:>12.4} {:>12.4}",
            name, r.acc, r.best, r.glob, r.loc, r.comm_s, r.stall_local, r.stall_global,
            r.makespan
        );
    }
    let base = &rows[0].1;
    for (name, r) in &rows[1..] {
        println!(
            "{name}: {:.1}% of K-AVG's global reductions, {:.2}x modelled comm speedup, \
             {:.2}x makespan speedup, acc delta {:+.4}",
            100.0 * r.glob as f64 / base.glob as f64,
            base.comm_s / r.comm_s,
            base.makespan / r.makespan,
            r.acc - base.acc
        );
    }
    if exec == ExecKind::Event {
        println!(
            "\nreading the stall columns: K-AVG pays every wait at the global barrier \
             (its S=1 local tier is a no-op); Hier-AVG's local barriers absorb \
             within-group drift cheaply between the sparse global reductions."
        );
    }
    Ok(())
}

struct RunCfgResult {
    acc: f64,
    best: f64,
    glob: u64,
    loc: u64,
    comm_s: f64,
    stall_local: f64,
    stall_global: f64,
    makespan: f64,
}

fn summarize(rec: &hier_avg::metrics::RunRecord) -> RunCfgResult {
    RunCfgResult {
        acc: rec.final_test_acc(),
        best: rec.best_test_acc(),
        glob: rec.comm.global_reductions,
        loc: rec.comm.local_reductions,
        comm_s: rec.comm.total_seconds(),
        stall_local: rec.level_stall_seconds.first().copied().unwrap_or(0.0),
        stall_global: rec.level_stall_seconds.last().copied().unwrap_or(0.0),
        makespan: rec.makespan_seconds,
    }
}
