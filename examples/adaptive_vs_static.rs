//! Schedule-policy head-to-head: the same hierarchy under `--schedule
//! static`, `adaptive`, and `warmup`, on a heterogeneous (straggler-
//! ridden) virtual cluster — realized K2 trajectory, global-reduction
//! counts, makespan, and where the barrier stall lands.
//!
//!     cargo run --release --example adaptive_vs_static [--p 16] [--k1 2]
//!         [--k2 8] [--epochs N] [--target F] [--warmup N]
//!         [--het F] [--straggler P[:M]]
//!
//! Default: a mild rate ramp plus occasional straggler spikes (the
//! regime the adaptive controller is built for).  Expected shape of the
//! table: the adaptive run fires at most as many global reductions as
//! the static run (its intervals widen under stall, clamped by step-size
//! condition (3.5), floored at the base schedule), finishing no later;
//! the warmup run fires more (dense early averaging) and decays back to
//! the base schedule.

use anyhow::Result;

use hier_avg::algorithms::PolicyKind;
use hier_avg::config::{BackendKind, RunConfig};
use hier_avg::driver;
use hier_avg::optimizer::LrSchedule;
use hier_avg::sim::{ExecKind, HetSpec};
use hier_avg::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(&[])?;
    let p: usize = args.parse_or("p", 16)?;
    let k1: u64 = args.parse_or("k1", 2)?;
    let k2: u64 = args.parse_or("k2", 8)?;
    let epochs: usize = args.parse_or("epochs", 8)?;
    let target: f64 = args.parse_or("target", 0.1)?;
    let warmup: u64 = args.parse_or("warmup", 32)?;
    let mut spec = HetSpec { het: 0.4, straggler_prob: 0.05, ..HetSpec::default() };
    spec.apply_args(&args)?;

    let mk = |policy: PolicyKind| -> Result<RunConfig> {
        let mut cfg = RunConfig::defaults("resnet18_sim");
        cfg.backend = BackendKind::Native;
        cfg.p = p;
        cfg.s = 4;
        cfg.k1 = k1;
        cfg.k2 = k2;
        cfg.epochs = epochs;
        cfg.train_n = 64 * p * 16;
        cfg.test_n = 1024;
        cfg.lr = LrSchedule::Constant(0.1);
        cfg.exec = ExecKind::Event;
        cfg.set_het_spec(&spec);
        cfg.schedule_policy = policy;
        cfg.validate()?;
        Ok(cfg)
    };

    println!(
        "schedule policies at P={p}, K=[{k1},{k2}], S=4, event exec \
         (het={} straggler={}:{})",
        spec.het, spec.straggler_prob, spec.straggler_mult
    );
    println!(
        "{:<18} {:>10} {:>10} {:>14} {:>8} {:>12} {:>12} {:>12} {:>10}",
        "policy", "glob_reds", "loc_reds", "final_K", "adapts", "stall_loc_s",
        "stall_glob_s", "makespan_s", "test_acc"
    );
    let runs = [
        ("static", PolicyKind::Static),
        ("adaptive", PolicyKind::Adaptive { target, gain: 1.0 }),
        ("warmup", PolicyKind::Warmup { stage_steps: warmup }),
    ];
    let mut base_makespan = 0.0f64;
    let mut base_glob = 0u64;
    for (name, policy) in runs {
        let rec = driver::run(&mk(policy)?)?;
        let sched = rec.schedule.as_ref().expect("trainer fills the schedule block");
        let glob = *sched.realized.last().unwrap();
        let loc: u64 = sched.realized.iter().rev().skip(1).sum();
        let final_k: Vec<String> =
            sched.final_intervals.iter().map(|k| k.to_string()).collect();
        println!(
            "{:<18} {:>10} {:>10} {:>14} {:>8} {:>12.4} {:>12.4} {:>12.4} {:>10.4}",
            name,
            glob,
            loc,
            format!("[{}]", final_k.join(",")),
            sched.changes.len(),
            rec.level_stall_seconds.first().copied().unwrap_or(0.0),
            rec.level_stall_seconds.last().copied().unwrap_or(0.0),
            rec.makespan_seconds,
            rec.final_test_acc(),
        );
        if name == "static" {
            base_makespan = rec.makespan_seconds;
            base_glob = glob;
        } else if name == "adaptive" {
            println!(
                "  -> adaptive: {:.1}% of static's global reductions, {:.2}x makespan \
                 speedup, every interval within k2_clamp={} (trajectory: {} changes)",
                100.0 * glob as f64 / base_glob.max(1) as f64,
                base_makespan / rec.makespan_seconds,
                sched.k2_clamp,
                sched.changes.len()
            );
            for c in sched.changes.iter().take(6) {
                let ks: Vec<String> = c.intervals.iter().map(|k| k.to_string()).collect();
                println!("     step {:>6}: K -> [{}]", c.step, ks.join(","));
            }
            if sched.changes.len() > 6 {
                println!("     ... {} more changes", sched.changes.len() - 6);
            }
        }
    }
    println!(
        "\nreading the table: the controller trades global barrier frequency against \
         the straggler tax it observes on the seeded timeline; warmup spends extra \
         reductions early (when averaging is cheapest in convergence terms) and \
         decays to the configured schedule."
    );
    Ok(())
}
