//! A compact version of the paper's §4.1/§4.2 study on one model: sweep
//! K2 ∈ {8,16,32} (fig 1/2 axis), then K1 ∈ {4,8} and S ∈ {2,4}
//! (fig 3/4 axes) on cifar-sim, printing the orderings the paper reports.
//!
//!     cargo run --release --example cifar_sim_sweep [--backend xla|native]
//!         [--model resnet18_sim] [--epochs N]

use anyhow::Result;

use hier_avg::config::{BackendKind, RunConfig};
use hier_avg::driver;
use hier_avg::metrics::RunRecord;
use hier_avg::optimizer::LrSchedule;
use hier_avg::util::cli::Args;

fn cfg_for(model: &str, backend: BackendKind, epochs: usize, p: usize, s: usize, k1: u64, k2: u64) -> RunConfig {
    let mut cfg = RunConfig::defaults(model);
    cfg.backend = backend;
    cfg.p = p;
    cfg.s = s;
    cfg.k1 = k1;
    cfg.k2 = k2;
    cfg.epochs = epochs;
    cfg.train_n = 64 * p * 16; // 64 steps/epoch
    cfg.test_n = 1024;
    cfg.lr = LrSchedule::StepDecay { initial: 0.1, milestones: vec![(epochs * 3 / 4, 0.01)] };
    cfg
}

fn tail_loss(r: &RunRecord) -> f64 {
    let n = r.epochs.len();
    let k = (n / 4).max(1);
    r.epochs[n - k..].iter().map(|e| e.train_loss).sum::<f64>() / k as f64
}

fn main() -> Result<()> {
    let args = Args::from_env(&[])?;
    let model = args.get_or("model", "resnet18_sim").to_string();
    let backend = BackendKind::parse(args.get_or("backend", "native"))?;
    let epochs: usize = args.parse_or("epochs", 16)?;

    println!("== K2 sweep (P=16, K1=4, S=4) on {model} ==");
    for k2 in [8u64, 16, 32] {
        let cfg = cfg_for(&model, backend, epochs, 16, 4, 4, k2);
        let rec = driver::run(&cfg)?;
        println!(
            "  K2={k2:<3} tail_train_loss {:.4}  final_test_acc {:.4}  best {:.4}  global_reds {}",
            tail_loss(&rec),
            rec.final_test_acc(),
            rec.best_test_acc(),
            rec.comm.global_reductions
        );
    }

    println!("== K1 sweep (P=16, K2=32, S=4) ==");
    for k1 in [4u64, 8] {
        let cfg = cfg_for(&model, backend, epochs, 16, 4, k1, 32);
        let rec = driver::run(&cfg)?;
        println!("  K1={k1:<3} tail_train_loss {:.4}", tail_loss(&rec));
    }

    println!("== S sweep (P=16, K2=32, K1=4) ==");
    for s in [2usize, 4] {
        let cfg = cfg_for(&model, backend, epochs, 16, s, 4, 32);
        let rec = driver::run(&cfg)?;
        println!("  S={s:<3}  tail_train_loss {:.4}", tail_loss(&rec));
    }

    println!("\npaper expectations: K2 larger is not worse (often better on test);");
    println!("K1=4 < K1=8 on training loss; S=4 < S=2 on training loss.");
    Ok(())
}
