//! Elastic-fleet sweep: the same hierarchy trained under increasing
//! spot-preemption pressure — how much loss quality and wall clock an
//! elastic run gives up as learners drop out and re-enter.
//!
//!     cargo run --release --example elastic_fleet [--p 16] [--k1 2]
//!         [--k2 8] [--epochs N] [--mttr N] [--het F] [--straggler P[:M]]
//!
//! Each row arms the fault layer at one preemption hazard (probability a
//! live learner is preempted at each virtual step; repair after --mttr
//! steps).  While a learner is down its groups reduce over the
//! survivors; on repair it restores from the fleet's checkpointed
//! average and warm-syncs to its innermost group.  Expected shape of the
//! table: preemptions and lost time grow with the hazard, the makespan
//! stretches by roughly the re-entry restore surcharges, and the final
//! loss degrades gracefully — survivors keep averaging, so training
//! never collapses the way a full-fleet barrier stall would.

use anyhow::Result;

use hier_avg::config::{BackendKind, RunConfig};
use hier_avg::driver;
use hier_avg::optimizer::LrSchedule;
use hier_avg::sim::{ExecKind, FaultPlan, FaultSpec, HetSpec};
use hier_avg::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(&[])?;
    let p: usize = args.parse_or("p", 16)?;
    let k1: u64 = args.parse_or("k1", 2)?;
    let k2: u64 = args.parse_or("k2", 8)?;
    let epochs: usize = args.parse_or("epochs", 8)?;
    let mttr: u64 = args.parse_or("mttr", 16)?;
    let mut spec = HetSpec { het: 0.4, straggler_prob: 0.05, ..HetSpec::default() };
    spec.apply_args(&args)?;

    let mk = |faults: Option<FaultPlan>| -> Result<RunConfig> {
        let mut cfg = RunConfig::defaults("resnet18_sim");
        cfg.backend = BackendKind::Native;
        cfg.p = p;
        cfg.s = 4;
        cfg.k1 = k1;
        cfg.k2 = k2;
        cfg.epochs = epochs;
        cfg.train_n = 64 * p * 16;
        cfg.test_n = 1024;
        cfg.lr = LrSchedule::Constant(0.1);
        cfg.exec = ExecKind::Event;
        cfg.set_het_spec(&spec);
        cfg.faults = faults;
        cfg.validate()?;
        Ok(cfg)
    };

    println!(
        "elastic fleet at P={p}, K=[{k1},{k2}], S=4, event exec \
         (het={} straggler={}:{} mttr={mttr})",
        spec.het, spec.straggler_prob, spec.straggler_mult
    );
    println!(
        "{:<12} {:>8} {:>8} {:>10} {:>10} {:>10} {:>12} {:>12} {:>10}",
        "hazard", "preempt", "reenter", "surv_reds", "mem_epoch", "lost_s", "makespan_s",
        "train_loss", "test_acc"
    );
    let mut base_makespan = 0.0f64;
    let mut base_loss = 0.0f64;
    for &prob in &[0.0f64, 0.002, 0.01, 0.05] {
        let faults =
            (prob > 0.0).then(|| FaultPlan::Sampled(FaultSpec { prob, mttr }));
        let rec = driver::run(&mk(faults)?)?;
        let (preempt, reenter, surv, epoch, lost) = match &rec.faults {
            Some(f) => (
                f.preemptions,
                f.reentries,
                f.survivor_reductions,
                f.membership_epoch,
                f.lost_seconds,
            ),
            None => (0, 0, 0, 0, 0.0),
        };
        println!(
            "{:<12} {:>8} {:>8} {:>10} {:>10} {:>10.4} {:>12.4} {:>12.4} {:>10.4}",
            if prob > 0.0 { format!("{prob}") } else { "fault-free".to_string() },
            preempt,
            reenter,
            surv,
            epoch,
            lost,
            rec.makespan_seconds,
            rec.final_train_loss(),
            rec.final_test_acc(),
        );
        if prob == 0.0 {
            base_makespan = rec.makespan_seconds;
            base_loss = rec.final_train_loss();
        } else {
            println!(
                "  -> hazard {prob}: {:+.1}% makespan, {:+.4} final train loss vs fault-free",
                100.0 * (rec.makespan_seconds / base_makespan - 1.0),
                rec.final_train_loss() - base_loss,
            );
        }
    }
    println!(
        "\nreading the table: a down learner's time lands in lost_s (its groups keep \
         reducing over the survivors, reweighted to the members that arrived); every \
         re-entry restores from the checkpointed average and warm-syncs to its \
         innermost group, charging the restore surcharge to the timeline.  The same \
         seed replays the same outages bit for bit."
    );
    Ok(())
}
