//! Payload-compression hot path: what one learner's `compress_split`
//! costs per barrier at n_params ∈ {4k, 256k}, and what the
//! `CompressedCollective` wrapper adds on top of the dense simulated
//! engine for a full 8-learner group barrier.
//!
//! Top-k carries the O(n) magnitude selection (select_nth over the
//! (-|v|, index) order), rand-k the partial Fisher–Yates, q8/q4 a pure
//! per-coordinate pass — the `dense` rows (spec `none`) are the floor
//! the lossy variants are judged against (`BENCH_compress.json`).  The
//! trailing simd/scalar pairs record the AVX2 speedup of the quantizer
//! and top-k scan (bit-identical by util::simd's dispatch contract).

mod benchkit;

use hier_avg::comm::compress::compress_split;
use hier_avg::comm::{Collective, CompressedCollective, Compression, SimulatedCollective};
use hier_avg::params::ParamArena;
use hier_avg::util::rng::Pcg32;

const SPECS: [&str; 5] = ["none", "topk:0.05", "randk:0.05", "q8", "q4"];

fn main() {
    let mut b = benchkit::Bench::new("compress");
    // One learner's split at two payload scales (quickstart-sized and a
    // quarter-million-parameter model).
    for &n in &[4096usize, 262_144] {
        let acc: Vec<f32> = {
            let mut rng = Pcg32::seeded(0xACC);
            (0..n).map(|_| rng.next_normal()).collect()
        };
        let mut t = vec![0.0f32; n];
        let mut e = vec![0.0f32; n];
        for spec_str in SPECS {
            let spec = Compression::parse(spec_str).unwrap();
            let label = format!("split/{}/n{n}", spec_str.replace(':', ""));
            let mut rng = Pcg32::seeded(0x5EED);
            b.bench_with_throughput(&label, n * 4, || {
                std::hint::black_box(compress_split(spec, &acc, &mut t, &mut e, &mut rng));
            });
        }
    }
    // SIMD vs forced-scalar split on the larger payload: the quantizer
    // (max_abs scan + round/clamp pass) and top-k magnitude scan carry
    // the vector work.  Bit-identical by the dispatch contract — asserted
    // before timing — so the pair is pure speed.  HIER_FORCE_SCALAR is
    // read per call, so the env toggle flips the dispatch in-process.
    {
        let n = 262_144usize;
        let acc: Vec<f32> = {
            let mut rng = Pcg32::seeded(0xACC);
            (0..n).map(|_| rng.next_normal()).collect()
        };
        let mut t = vec![0.0f32; n];
        let mut e = vec![0.0f32; n];
        for spec_str in ["topk:0.05", "q8", "q4"] {
            let spec = Compression::parse(spec_str).unwrap();
            {
                let (mut ts, mut es) = (vec![0.0f32; n], vec![0.0f32; n]);
                let mut rng = Pcg32::seeded(0x5EED);
                compress_split(spec, &acc, &mut t, &mut e, &mut rng);
                std::env::set_var("HIER_FORCE_SCALAR", "1");
                let mut rng = Pcg32::seeded(0x5EED);
                compress_split(spec, &acc, &mut ts, &mut es, &mut rng);
                std::env::remove_var("HIER_FORCE_SCALAR");
                assert_eq!(t, ts, "{spec_str}: SIMD split must be bit-identical to scalar");
                assert_eq!(e, es, "{spec_str}: SIMD residual must be bit-identical to scalar");
            }
            for &(case, force) in &[("simd", false), ("scalar", true)] {
                let label = format!("split/{}/n{n}/{case}", spec_str.replace(':', ""));
                let mut rng = Pcg32::seeded(0x5EED);
                if force {
                    std::env::set_var("HIER_FORCE_SCALAR", "1");
                }
                b.bench_with_throughput(&label, n * 4, || {
                    std::hint::black_box(compress_split(spec, &acc, &mut t, &mut e, &mut rng));
                });
                if force {
                    std::env::remove_var("HIER_FORCE_SCALAR");
                }
            }
        }
    }
    // A full group barrier through the wrapper vs the bare dense engine:
    // the wrapper's delta/reference bookkeeping plus P splits.
    let (p, n) = (8usize, 4096usize);
    let base: ParamArena = {
        let mut rng = Pcg32::seeded(0xF1EE7);
        let rows: Vec<Vec<f32>> =
            (0..p).map(|_| (0..n).map(|_| rng.next_normal()).collect()).collect();
        ParamArena::from_rows(&rows)
    };
    let mut scratch = vec![0.0f32; n];
    {
        let mut replicas = base.clone();
        b.bench(&format!("group/dense/p{p}/n{n}"), || {
            SimulatedCollective.average_group(replicas.view_mut(), 0..p, &mut scratch);
            std::hint::black_box(&replicas);
        });
    }
    for spec_str in ["topk:0.05", "randk:0.05", "q8", "q4"] {
        let spec = Compression::parse(spec_str).unwrap();
        let (cc, _state) = CompressedCollective::new(Box::new(SimulatedCollective), spec, 42);
        let mut replicas = base.clone();
        let label = format!("group/{}/p{p}/n{n}", spec_str.replace(':', ""));
        b.bench(&label, || {
            cc.average_group(replicas.view_mut(), 0..p, &mut scratch);
            std::hint::black_box(&replicas);
        });
    }
    b.finish();
}
