//! Step execution throughput: the XLA train-step artifacts (singleton and
//! stacked dispatch) and the native backend, plus the optimizer update.
//! These are the per-step costs that multiply into every experiment.

mod benchkit;

use hier_avg::backend::{StepBackend, StepOut};
use hier_avg::data::{BatchBuf, ClassifyData, DataSource, MixtureSpec};
use hier_avg::driver;
use hier_avg::native::{NativeMlp, ParallelNativeMlp};
use hier_avg::optimizer::Sgd;
use hier_avg::params::ParamArena;
use hier_avg::runtime::{Manifest, XlaBackend};
use hier_avg::util::rng::Pcg32;

fn mk_data(dim: usize, classes: usize) -> ClassifyData {
    ClassifyData::generate(MixtureSpec {
        dim,
        classes,
        train_n: 4096,
        test_n: 256,
        radius: 1.0,
        noise: 1.2,
        subclusters: 1,
        label_noise: 0.0,
        seed: 3,
    })
}

fn bench_backend(
    b: &mut benchkit::Bench,
    label: &str,
    backend: &mut dyn StepBackend,
    p: usize,
    dim: usize,
    classes: usize,
    init: &[f32],
) {
    let data = mk_data(dim, classes);
    let mut rng = Pcg32::seeded(9);
    let mut batch = BatchBuf::default();
    for _ in 0..p {
        data.fill_train(&mut rng, backend.train_batch(), &mut batch);
    }
    let replicas = ParamArena::replicated(init, p);
    let mut grads = ParamArena::zeroed(p, backend.n_params());
    let mut outs = vec![StepOut::default(); p];
    b.bench(label, || {
        backend.grads(replicas.view(), &batch, grads.view_mut(), &mut outs).unwrap();
    });
}

fn main() {
    let mut b = benchkit::Bench::new("step");

    // Native MLP backend (serial).
    for &(name, p) in &[("resnet18_sim", 1usize), ("resnet18_sim", 16)] {
        let (dims, batch, eval_b) = driver::model_dims(name).unwrap();
        let mut backend = NativeMlp::new(dims, batch, eval_b).unwrap();
        let init = backend.init(&mut Pcg32::seeded(1));
        let dim = dims[0];
        let classes = *dims.last().unwrap();
        bench_backend(
            &mut b,
            &format!("native/{name}/p{p}"),
            &mut backend,
            p,
            dim,
            classes,
            &init,
        );
    }

    // SIMD vs forced-scalar matmul microkernels on the serial native
    // step (the same shape as native/resnet18_sim/p16 above, which runs
    // the default dispatch).  Bit-identical by the summation-order
    // contract (util::simd / native::linalg), so the pair is pure speed;
    // HIER_FORCE_SCALAR is read per call, so the env toggle flips the
    // dispatch in-process.
    {
        let (name, p) = ("resnet18_sim", 16usize);
        let (dims, batch, eval_b) = driver::model_dims(name).unwrap();
        let mut backend = NativeMlp::new(dims, batch, eval_b).unwrap();
        let init = backend.init(&mut Pcg32::seeded(1));
        let dim = dims[0];
        let classes = *dims.last().unwrap();
        for &(case, force) in &[("simd", false), ("scalar", true)] {
            if force {
                std::env::set_var("HIER_FORCE_SCALAR", "1");
            }
            bench_backend(
                &mut b,
                &format!("native/{name}/p{p}/{case}"),
                &mut backend,
                p,
                dim,
                classes,
                &init,
            );
            if force {
                std::env::remove_var("HIER_FORCE_SCALAR");
            }
        }
    }

    // Parallel native backend: lane fan-out over the persistent worker
    // pool (what the driver uses at P >= 8).  Compared against the serial
    // native/p16 case above, this isolates the per-step dispatch overhead
    // that used to be a thread spawn per step.  Lane counts above the
    // host's parallelism would clamp and silently duplicate an existing
    // case under one bench name, so they are filtered out (with the
    // host's own count always included).
    {
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let mut lane_counts: Vec<usize> =
            [2usize, 4, 8].into_iter().filter(|&l| l <= hw).collect();
        if lane_counts.is_empty() {
            lane_counts.push(hw.max(1));
        }
        let (name, p) = ("resnet18_sim", 16usize);
        let (dims, batch, eval_b) = driver::model_dims(name).unwrap();
        let proto = NativeMlp::new(dims, batch, eval_b).unwrap();
        let init = proto.init(&mut Pcg32::seeded(1));
        let dim = dims[0];
        let classes = *dims.last().unwrap();
        for &lanes in &lane_counts {
            let mut backend = ParallelNativeMlp::new(dims, batch, eval_b, lanes).unwrap();
            bench_backend(
                &mut b,
                &format!("native_pooled/{name}/p{p}/lanes{lanes}"),
                &mut backend,
                p,
                dim,
                classes,
                &init,
            );
        }
    }

    // XLA backends (artifacts required).
    match Manifest::load_default() {
        Ok(m) => {
            for &(name, p) in &[
                ("quickstart", 1usize),
                ("quickstart", 4),
                ("resnet18_sim", 16),
                ("resnet18_sim", 32),
            ] {
                let entry = m.model(name).unwrap();
                let (dim, classes) =
                    (entry.input_dim().unwrap(), entry.classes().unwrap());
                let init = m.load_init(entry).unwrap();
                let mut backend = XlaBackend::load(&m, name, p).unwrap();
                bench_backend(
                    &mut b,
                    &format!("xla/{name}/p{p}"),
                    &mut backend,
                    p,
                    dim,
                    classes,
                    &init,
                );
            }
            // LM step (the e2e driver's inner loop).
            if m.model("lm_small").is_ok() {
                let entry = m.model("lm_small").unwrap();
                let init = m.load_init(entry).unwrap();
                let mut backend = XlaBackend::load(&m, "lm_small", 4).unwrap();
                let data = hier_avg::data::TokenData::generate(
                    hier_avg::data::TokenSpec::tiny_corpus(256, 64),
                );
                let mut rng = Pcg32::seeded(5);
                let mut batch = BatchBuf::default();
                for _ in 0..4 {
                    data.fill_train(&mut rng, backend.train_batch(), &mut batch);
                }
                let replicas = ParamArena::replicated(&init, 4);
                let mut grads = ParamArena::zeroed(4, backend.n_params());
                let mut outs = vec![StepOut::default(); 4];
                b.bench("xla/lm_small/p4", || {
                    backend.grads(replicas.view(), &batch, grads.view_mut(), &mut outs).unwrap();
                });
            }
        }
        Err(e) => eprintln!("(skipping XLA step benches: {e})"),
    }

    // Optimizer update at model scale.
    {
        let n = 101_386;
        let mut rng = Pcg32::seeded(2);
        let mut w: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
        let g: Vec<f32> = (0..n).map(|_| rng.next_normal() * 0.01).collect();
        let mut plain = Sgd::plain();
        b.bench_with_throughput("sgd/plain/100k", 2 * n * 4, || {
            plain.apply(&mut w, &g, 1e-6);
        });
        let mut mom = Sgd::new(0.9, 1e-4, n);
        b.bench_with_throughput("sgd/momentum_wd/100k", 3 * n * 4, || {
            mom.apply(&mut w, &g, 1e-6);
        });
    }

    b.finish();
}
