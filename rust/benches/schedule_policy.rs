//! Schedule-policy dispatch overhead: what the policy layer costs per
//! step on the timeline hot path, at P ∈ {16, 64} over a fixed 512-step
//! two-level schedule (K = [4, 32]).
//!
//! The baseline is the static policy driven through the same
//! `drive_timeline_policy` loop the engine mirrors; the adaptive rows add
//! the controller's observe/EWMA work (under a straggler regime so every
//! barrier actually feeds it), and the warmup rows the per-step stage
//! recomputation.  Controller overhead per step must stay ~0 vs static —
//! the whole layer is a handful of integer/float ops per step
//! (`BENCH_schedule.json`).

mod benchkit;

use hier_avg::algorithms::{HierSchedule, PolicyKind};
use hier_avg::sim::{drive_timeline_policy, ExecKind, ExecModel, HetSpec};
use hier_avg::topology::HierTopology;

const STEPS: u64 = 512;

fn main() {
    let mut b = benchkit::Bench::new("schedule");
    let base = 1e-3;
    let level_seconds = [1e-4, 1e-3];
    for &p in &[16usize, 64] {
        let topo = HierTopology::new(vec![4, p]).unwrap();
        let sched = HierSchedule::new(vec![4, 32]).unwrap();
        let straggler =
            HetSpec { het: 0.2, straggler_prob: 0.05, straggler_mult: 4.0, seed: 42 };
        let mut run = |name: &str, kind: PolicyKind, spec: &HetSpec| {
            let label = format!("policy/{name}/p{p}/512steps");
            let spec = *spec;
            b.bench(&label, || {
                let mut model = ExecKind::Event.build(p, 2, base, &spec);
                let mut policy = kind.build(1 << 16, base, p);
                let realized = drive_timeline_policy(
                    model.as_mut(),
                    &topo,
                    policy.as_mut(),
                    &sched,
                    STEPS,
                    &level_seconds,
                );
                std::hint::black_box((model.now(), realized));
            });
        };
        run("static", PolicyKind::Static, &HetSpec::default());
        run(
            "adaptive_homogeneous",
            PolicyKind::Adaptive { target: 0.25, gain: 1.0 },
            &HetSpec::default(),
        );
        // The controller's real cost: every barrier observes and may
        // rewrite the table.
        run(
            "adaptive_straggler",
            PolicyKind::Adaptive { target: 0.05, gain: 1.0 },
            &straggler,
        );
        run("warmup", PolicyKind::Warmup { stage_steps: 64 }, &HetSpec::default());
    }
    b.finish();
}
