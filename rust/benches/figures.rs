//! End-to-end miniatures of every paper figure/table: each benchmark runs
//! the exact experiment code path (config grid -> trainer -> metrics) at a
//! micro scale, giving a per-figure wall-clock cost and guarding the repro
//! harness against regressions.  The full-size regeneration is
//! `hier-avg repro <exp>` (see DESIGN.md for the experiment index).

mod benchkit;

use hier_avg::config::{BackendKind, RunConfig};
use hier_avg::driver;
use hier_avg::optimizer::LrSchedule;
use hier_avg::theory::{self, BoundParams};

fn micro_cfg(model: &str, p: usize, s: usize, k1: u64, k2: u64) -> RunConfig {
    let mut cfg = RunConfig::defaults(model);
    cfg.backend = BackendKind::Native;
    cfg.p = p;
    cfg.s = s;
    cfg.k1 = k1;
    cfg.k2 = k2;
    cfg.epochs = 2;
    cfg.train_n = p * 16 * 8; // 8 steps/epoch
    cfg.test_n = 512;
    cfg.lr = LrSchedule::Constant(0.1);
    cfg
}

fn main() {
    let mut b = benchkit::Bench::new("figures");

    // fig1/fig2 micro: one (model, K2) cell, P=32, K1=4, S=4.
    b.bench("fig1_cell/resnet18_sim/p32", || {
        let cfg = micro_cfg("resnet18_sim", 32, 4, 4, 32);
        std::hint::black_box(driver::run(&cfg).unwrap());
    });

    // fig3 micro: K1 variation cell, P=16.
    b.bench("fig3_cell/googlenet_sim/p16", || {
        let cfg = micro_cfg("googlenet_sim", 16, 4, 8, 32);
        std::hint::black_box(driver::run(&cfg).unwrap());
    });

    // fig4 micro: S variation cell.
    b.bench("fig4_cell/mobilenet_sim/p16s2", || {
        let cfg = micro_cfg("mobilenet_sim", 16, 2, 4, 32);
        std::hint::black_box(driver::run(&cfg).unwrap());
    });

    // table1 micro: the P=64 row (the most expensive).
    b.bench("table1_row/resnet18_sim/p64", || {
        let cfg = micro_cfg("resnet18_sim", 64, 4, 1, 8);
        std::hint::black_box(driver::run(&cfg).unwrap());
    });

    // fig5 micro: imagenet-sim cell with the ragged (43, 20) schedule.
    b.bench("fig5_cell/imagenet_sim/p16", || {
        let cfg = micro_cfg("imagenet_sim", 16, 4, 20, 43);
        std::hint::black_box(driver::run(&cfg).unwrap());
    });

    // Theory reproductions (thm34/35/36 grids are pure math).
    let p = BoundParams::default();
    b.bench("thm34_grid/k2_1_to_128", || {
        let mut acc = 0.0;
        for k2 in 1..=128u64 {
            acc += theory::thm34_budget_bound(&p, 20_000, 1, k2, 4);
        }
        std::hint::black_box(acc);
    });
    b.bench("thm36_grid/full_paper_range", || {
        let mut acc = 0.0;
        for k in 2..=64u64 {
            for a in [0.0, 0.2, 0.4, 0.6] {
                let (h, x) = theory::thm36_pair(&p, 10_000, k, a);
                acc += h / x;
            }
        }
        std::hint::black_box(acc);
    });

    b.finish();
}
