//! L3 reduction hot path: native group averaging at realistic model sizes
//! and group shapes, versus the Pallas group-average artifact through XLA
//! (the alternate path), plus the analytic cost model itself.
//!
//! The native reducer is the one on the training hot path; its target is
//! memory-bandwidth-bound throughput (DESIGN.md §Performance).  The
//! sharded (spawn-per-call) vs pooled (persistent worker pool) cases at
//! equal shapes isolate the per-reduction thread-spawn overhead.

mod benchkit;

use hier_avg::comm::{Collective, CostModel, PooledCollective, ReduceStrategy, Reducer, ShardedCollective};
use hier_avg::params::ParamArena;
use hier_avg::runtime::xla_backend::XlaGroupAvg;
use hier_avg::runtime::Manifest;
use hier_avg::topology::Topology;
use hier_avg::util::rng::Pcg32;

fn replicas(p: usize, n: usize, rng: &mut Pcg32) -> ParamArena {
    let rows: Vec<Vec<f32>> =
        (0..p).map(|_| (0..n).map(|_| rng.next_normal()).collect()).collect();
    ParamArena::from_rows(&rows)
}

fn main() {
    let mut b = benchkit::Bench::new("reduction");
    let mut rng = Pcg32::seeded(42);

    // resnet18-sim (101k params) and lm_medium-class (3.4M params).
    for &(label, n) in &[("100k", 101_386usize), ("3.4M", 3_400_000usize)] {
        for &s in &[2usize, 4, 8] {
            let mut r = replicas(s, n, &mut rng);
            let topo = Topology::new(s, s).unwrap();
            let mut red = Reducer::new(CostModel::default(), ReduceStrategy::Ring, n);
            // bytes touched per reduction: read S + write S buffers
            let bytes = 2 * s * n * 4;
            b.bench_with_throughput(&format!("native/group_avg/{label}/s{s}"), bytes, || {
                red.global_average(r.view_mut(), &topo);
            });
        }
    }

    // Global average at P=64 (table-1 regime).
    {
        let n = 101_386;
        let mut r = replicas(64, n, &mut rng);
        let topo = Topology::new(64, 4).unwrap();
        let mut red = Reducer::new(CostModel::default(), ReduceStrategy::Ring, n);
        b.bench_with_throughput("native/global_avg/100k/p64", 2 * 64 * n * 4, || {
            red.global_average(r.view_mut(), &topo);
        });
        b.bench_with_throughput("native/local_avg/100k/p64s4", 2 * 64 * n * 4, || {
            red.local_average(r.view_mut(), &topo);
        });
    }

    // The sharded thread-parallel collective: shards of the flat vector
    // reduce concurrently across worker threads (reduce-scatter/all-gather
    // style).  Numerics are bit-identical to the simulated reducer —
    // verified here before timing — so the speedup on multi-core hosts is
    // free of accuracy caveats; on a single hardware thread it degrades to
    // the simulated path's throughput minus scoped-thread overhead.
    {
        let n = 3_400_000usize;
        let p = 8usize;
        let topo = Topology::new(p, p).unwrap();
        let base = replicas(p, n, &mut rng);
        {
            let mut simulated = base.clone();
            let mut sharded = base.clone();
            let mut sim_red = Reducer::new(CostModel::default(), ReduceStrategy::Ring, n);
            sim_red.global_average(simulated.view_mut(), &topo);
            let mut sh_red = Reducer::with_collective(
                CostModel::default(),
                ReduceStrategy::Ring,
                n,
                Box::new(ShardedCollective::new(0)),
            );
            sh_red.global_average(sharded.view_mut(), &topo);
            assert_eq!(simulated, sharded, "sharded collective must be bit-identical");
        }
        for &threads in &[1usize, 2, 4, 8] {
            let mut r = base.clone();
            let mut red = Reducer::with_collective(
                CostModel::default(),
                ReduceStrategy::Ring,
                n,
                Box::new(ShardedCollective::new(threads)),
            );
            let bytes = 2 * p * n * 4;
            b.bench_with_throughput(&format!("native/group_avg_sharded/3.4M/p8/t{threads}"), bytes, || {
                red.global_average(r.view_mut(), &topo);
            });
        }
        for &threads in &[2usize, 4, 8] {
            let mut r = base.clone();
            let mut red = Reducer::with_collective(
                CostModel::default(),
                ReduceStrategy::Ring,
                n,
                Box::new(PooledCollective::new(threads)),
            );
            let bytes = 2 * p * n * 4;
            b.bench_with_throughput(&format!("native/group_avg_pooled/3.4M/p8/t{threads}"), bytes, || {
                red.global_average(r.view_mut(), &topo);
            });
        }
    }

    // Sharded (spawn-per-call) vs pooled (persistent workers) head to head
    // at small/medium group sizes and param counts — the regime where the
    // per-call spawn+join dominates the sharded engine's time and the
    // pooled engine either dispatches cheaply or falls back to the serial
    // kernel (tiny shapes).  Bit-identity is asserted before timing.
    {
        for &(label, n) in &[("100k", 101_386usize), ("400k", 400_000usize)] {
            for &s in &[2usize, 4, 8] {
                let topo = Topology::new(s, s).unwrap();
                let base = replicas(s, n, &mut rng);
                {
                    let mut a = base.clone();
                    let mut b0 = base.clone();
                    let mut sa = vec![0.0f32; n];
                    let mut sb = vec![0.0f32; n];
                    ShardedCollective::new(2).average_group(a.view_mut(), 0..s, &mut sa);
                    PooledCollective::new(2).average_group(b0.view_mut(), 0..s, &mut sb);
                    assert_eq!(a, b0, "pooled collective must be bit-identical");
                }
                let mut r = base.clone();
                let mut red = Reducer::with_collective(
                    CostModel::default(),
                    ReduceStrategy::Ring,
                    n,
                    Box::new(ShardedCollective::new(0)),
                );
                let bytes = 2 * s * n * 4;
                b.bench_with_throughput(
                    &format!("native/group_avg_sharded/{label}/s{s}"),
                    bytes,
                    || {
                        red.global_average(r.view_mut(), &topo);
                    },
                );
                let mut r = base.clone();
                let mut red = Reducer::with_collective(
                    CostModel::default(),
                    ReduceStrategy::Ring,
                    n,
                    Box::new(PooledCollective::new(0)),
                );
                b.bench_with_throughput(
                    &format!("native/group_avg_pooled/{label}/s{s}"),
                    bytes,
                    || {
                        red.global_average(r.view_mut(), &topo);
                    },
                );
            }
        }
    }

    // SIMD vs forced-scalar mean kernel on one large shape.  The dispatch
    // contract (util::simd) makes the two bit-identical — asserted before
    // timing — so the pair is pure speed: the committed baseline records
    // this host's AVX2 speedup on the reduction hot path, and the CI
    // smoke prints the ratio.  HIER_FORCE_SCALAR is read per call, so
    // toggling the env var between cases flips the dispatch in-process.
    {
        let n = 3_400_000usize;
        let s = 8usize;
        let topo = Topology::new(s, s).unwrap();
        let base = replicas(s, n, &mut rng);
        let bytes = 2 * s * n * 4;
        {
            let mut with_simd = base.clone();
            let mut forced = base.clone();
            let mut red = Reducer::new(CostModel::default(), ReduceStrategy::Ring, n);
            red.global_average(with_simd.view_mut(), &topo);
            std::env::set_var("HIER_FORCE_SCALAR", "1");
            let mut red = Reducer::new(CostModel::default(), ReduceStrategy::Ring, n);
            red.global_average(forced.view_mut(), &topo);
            std::env::remove_var("HIER_FORCE_SCALAR");
            assert_eq!(with_simd, forced, "SIMD mean kernel must be bit-identical to scalar");
        }
        for &(case, force) in &[("simd", false), ("scalar", true)] {
            let mut r = base.clone();
            let mut red = Reducer::new(CostModel::default(), ReduceStrategy::Ring, n);
            if force {
                std::env::set_var("HIER_FORCE_SCALAR", "1");
            }
            b.bench_with_throughput(&format!("native/group_avg/3.4M/s8/{case}"), bytes, || {
                red.global_average(r.view_mut(), &topo);
            });
            if force {
                std::env::remove_var("HIER_FORCE_SCALAR");
            }
        }
    }

    // The Pallas group-average + SGD-update artifacts (XLA path), if built.
    if let Ok(m) = Manifest::load_default() {
        if let Ok(mut avg) = XlaGroupAvg::load(&m, 4) {
            let n = 101_386;
            let shards = replicas(4, n, &mut rng);
            let refs: Vec<&[f32]> = (0..shards.rows()).map(|j| shards.row(j)).collect();
            let mut out = vec![0.0f32; n];
            b.bench_with_throughput("xla/pallas_group_avg/100k/s4", 2 * 4 * n * 4, || {
                avg.average(&refs, &mut out).unwrap();
            });
        }
        if let Ok(mut upd) = hier_avg::runtime::xla_backend::XlaSgdUpdate::load(&m) {
            let n = 101_386;
            let mut w: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
            let g: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
            b.bench_with_throughput("xla/pallas_sgd_update/100k", 2 * n * 4, || {
                upd.apply(&mut w, &g, 1e-7).unwrap();
            });
            let mut opt = hier_avg::optimizer::Sgd::plain();
            b.bench_with_throughput("native/sgd_update/100k", 2 * n * 4, || {
                opt.apply(&mut w, &g, 1e-7);
            });
        }
    } else {
        eprintln!("(artifacts not built; skipping XLA reduction benches)");
    }

    // Analytic cost model evaluation (used inside every reduction event).
    {
        let cm = CostModel::default();
        let mut acc = 0.0f64;
        b.bench("cost_model/allreduce_seconds", || {
            for p in [4usize, 16, 64] {
                acc += cm.allreduce_seconds(
                    p,
                    400_000,
                    hier_avg::topology::LinkClass::InterNode,
                    ReduceStrategy::Ring,
                );
            }
        });
        std::hint::black_box(acc);
    }

    b.finish();
}
