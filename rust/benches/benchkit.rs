//! A small criterion-style micro-benchmark harness (criterion is not
//! available in this offline environment).
//!
//! Usage from a `harness = false` bench target:
//! ```ignore
//! mod benchkit;
//! fn main() {
//!     let mut b = benchkit::Bench::new("reduction");
//!     b.bench("mean/4x100k", || { ... });
//!     b.finish();
//! }
//! ```
//!
//! Each benchmark is auto-calibrated to ~80 ms per sample, 15 samples are
//! collected, and min / median / mean / p95 plus derived throughput are
//! printed in a stable, grep-friendly format.  `finish()` additionally
//! writes a machine-readable `BENCH_<group>.json` (bench name → ns/iter
//! plus calibration counts) at the repo root so the perf trajectory is
//! tracked PR over PR.
//!
//! Env knobs (for CI smoke runs): `BENCHKIT_SAMPLES` overrides the sample
//! count, `BENCHKIT_TARGET_MS` the per-sample calibration target.

use std::time::{Duration, Instant};

pub struct Bench {
    group: String,
    filter: Option<String>,
    samples: usize,
    target_sample: Duration,
    results: Vec<(String, Stats)>,
}

#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
    pub iters_per_sample: u64,
    /// Work items (e.g. timeline events) per iteration; 0 when the bench
    /// didn't declare any.  `> 0` adds items/s to the console line and
    /// `units_per_iter` / `units_per_sec` to the JSON — how the event
    /// bench emits its events/sec-vs-P scaling curve.
    pub units_per_iter: u64,
}

const TARGET_SAMPLE_MS: u64 = 80;
const SAMPLES: usize = 15;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).filter(|&v| v > 0).unwrap_or(default)
}

impl Bench {
    pub fn new(group: &str) -> Bench {
        // `cargo bench -- <filter>` forwards the filter in argv.
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with('-') && a != "--bench");
        let samples = env_usize("BENCHKIT_SAMPLES", SAMPLES);
        let target_ms = env_usize("BENCHKIT_TARGET_MS", TARGET_SAMPLE_MS as usize) as u64;
        println!("== bench group: {group} ==");
        Bench {
            group: group.to_string(),
            filter,
            samples,
            target_sample: Duration::from_millis(target_ms),
            results: Vec::new(),
        }
    }

    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) {
        self.run(name, 0, 0, f)
    }

    /// `bytes_per_iter > 0` additionally reports GiB/s.
    pub fn bench_with_throughput<F: FnMut()>(&mut self, name: &str, bytes_per_iter: usize, f: F) {
        self.run(name, bytes_per_iter, 0, f)
    }

    /// `units_per_iter > 0` additionally reports items/s (and writes
    /// `units_per_sec` into the JSON) — for benches whose natural
    /// throughput axis is work items, not bytes (e.g. timeline events).
    pub fn bench_units<F: FnMut()>(&mut self, name: &str, units_per_iter: u64, f: F) {
        self.run(name, 0, units_per_iter, f)
    }

    fn run<F: FnMut()>(
        &mut self,
        name: &str,
        bytes_per_iter: usize,
        units_per_iter: u64,
        mut f: F,
    ) {
        if let Some(filter) = &self.filter {
            if !name.contains(filter.as_str()) && !self.group.contains(filter.as_str()) {
                return;
            }
        }
        // Warmup + calibration: find iters such that a sample ≈ the target.
        let warmup_floor = (self.target_sample / 4).max(Duration::from_millis(1));
        let mut iters: u64 = 1;
        loop {
            let start = Instant::now();
            for _ in 0..iters {
                f();
            }
            let el = start.elapsed();
            if el >= warmup_floor || iters >= 1 << 24 {
                let per = el.as_nanos().max(1) as f64 / iters as f64;
                iters = ((self.target_sample.as_nanos() as f64 / per).ceil() as u64).max(1);
                break;
            }
            iters *= 4;
        }
        let n_samples = self.samples.max(1);
        let mut samples: Vec<f64> = Vec::with_capacity(n_samples);
        for _ in 0..n_samples {
            let start = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(start.elapsed().as_nanos() as f64 / iters as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = Stats {
            min_ns: samples[0],
            median_ns: samples[n_samples / 2],
            mean_ns: samples.iter().sum::<f64>() / n_samples as f64,
            p95_ns: samples[((n_samples as f64 * 0.95) as usize).saturating_sub(1)],
            iters_per_sample: iters,
            units_per_iter,
        };
        let thr = if bytes_per_iter > 0 {
            format!(
                "  {:>8.3} GiB/s",
                bytes_per_iter as f64 / stats.median_ns * 1e9 / (1u64 << 30) as f64
            )
        } else if units_per_iter > 0 {
            format!(
                "  {:>8.3} Mitems/s",
                units_per_iter as f64 / stats.median_ns * 1e9 / 1e6
            )
        } else {
            String::new()
        };
        println!(
            "{:<44} min {:>12}  med {:>12}  mean {:>12}  p95 {:>12}{}",
            format!("{}/{}", self.group, name),
            fmt_ns(stats.min_ns),
            fmt_ns(stats.median_ns),
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.p95_ns),
            thr
        );
        self.results.push((name.to_string(), stats));
    }

    pub fn finish(self) -> Vec<(String, Stats)> {
        if self.filter.is_some() {
            // A filtered run covers only a slice of the group; silently
            // overwriting the committed BENCH_<group>.json baseline with a
            // partial file would corrupt the PR-over-PR perf trajectory.
            println!("(filtered run: not rewriting BENCH_{}.json)", self.group);
        } else {
            self.write_json();
        }
        println!("== {} done ({} benchmarks) ==", self.group, self.results.len());
        self.results
    }

    /// Emit `BENCH_<group>.json` at the repo root: bench name → ns/iter
    /// (median, plus min/mean/p95) and the calibration counts (which
    /// double as provenance — a reduced-sampling smoke run is visible in
    /// `samples`/`target_sample_ms`), so the perf trajectory is diffable
    /// PR over PR.
    fn write_json(&self) {
        use hier_avg::util::json::Json;
        let mut benches = Json::obj();
        for (name, s) in &self.results {
            let mut o = Json::obj();
            o.set("ns_per_iter", Json::from(s.median_ns))
                .set("min_ns", Json::from(s.min_ns))
                .set("mean_ns", Json::from(s.mean_ns))
                .set("p95_ns", Json::from(s.p95_ns))
                .set("iters_per_sample", Json::from(s.iters_per_sample as usize))
                .set("samples", Json::from(self.samples));
            if s.units_per_iter > 0 {
                o.set("units_per_iter", Json::from(s.units_per_iter as usize)).set(
                    "units_per_sec",
                    Json::from(s.units_per_iter as f64 / s.median_ns * 1e9),
                );
            }
            benches.set(name, o);
        }
        let mut root = Json::obj();
        root.set("group", Json::from(self.group.as_str()))
            .set("target_sample_ms", Json::from(self.target_sample.as_millis() as usize))
            .set("benches", benches);
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join(format!("BENCH_{}.json", self.group));
        match std::fs::write(&path, root.pretty()) {
            Ok(()) => println!("wrote {}", path.display()),
            Err(e) => eprintln!("(could not write {}: {e})", path.display()),
        }
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}
