//! A small criterion-style micro-benchmark harness (criterion is not
//! available in this offline environment).
//!
//! Usage from a `harness = false` bench target:
//! ```ignore
//! mod benchkit;
//! fn main() {
//!     let mut b = benchkit::Bench::new("reduction");
//!     b.bench("mean/4x100k", || { ... });
//!     b.finish();
//! }
//! ```
//!
//! Each benchmark is auto-calibrated to ~80 ms per sample, 15 samples are
//! collected, and min / median / mean / p95 plus derived throughput are
//! printed in a stable, grep-friendly format.

use std::time::{Duration, Instant};

pub struct Bench {
    group: String,
    filter: Option<String>,
    results: Vec<(String, Stats)>,
}

#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
    pub iters_per_sample: u64,
}

const TARGET_SAMPLE: Duration = Duration::from_millis(80);
const SAMPLES: usize = 15;

impl Bench {
    pub fn new(group: &str) -> Bench {
        // `cargo bench -- <filter>` forwards the filter in argv.
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with('-') && a != "--bench");
        println!("== bench group: {group} ==");
        Bench { group: group.to_string(), filter, results: Vec::new() }
    }

    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) {
        self.bench_with_throughput(name, 0, f)
    }

    /// `bytes_per_iter > 0` additionally reports GiB/s.
    pub fn bench_with_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        bytes_per_iter: usize,
        mut f: F,
    ) {
        if let Some(filter) = &self.filter {
            if !name.contains(filter.as_str()) && !self.group.contains(filter.as_str()) {
                return;
            }
        }
        // Warmup + calibration: find iters such that a sample ≈ TARGET.
        let mut iters: u64 = 1;
        loop {
            let start = Instant::now();
            for _ in 0..iters {
                f();
            }
            let el = start.elapsed();
            if el >= Duration::from_millis(20) || iters >= 1 << 24 {
                let per = el.as_nanos().max(1) as f64 / iters as f64;
                iters = ((TARGET_SAMPLE.as_nanos() as f64 / per).ceil() as u64).max(1);
                break;
            }
            iters *= 4;
        }
        let mut samples: Vec<f64> = Vec::with_capacity(SAMPLES);
        for _ in 0..SAMPLES {
            let start = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(start.elapsed().as_nanos() as f64 / iters as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = Stats {
            min_ns: samples[0],
            median_ns: samples[SAMPLES / 2],
            mean_ns: samples.iter().sum::<f64>() / SAMPLES as f64,
            p95_ns: samples[(SAMPLES as f64 * 0.95) as usize - 1],
            iters_per_sample: iters,
        };
        let thr = if bytes_per_iter > 0 {
            format!(
                "  {:>8.3} GiB/s",
                bytes_per_iter as f64 / stats.median_ns * 1e9 / (1u64 << 30) as f64
            )
        } else {
            String::new()
        };
        println!(
            "{:<44} min {:>12}  med {:>12}  mean {:>12}  p95 {:>12}{}",
            format!("{}/{}", self.group, name),
            fmt_ns(stats.min_ns),
            fmt_ns(stats.median_ns),
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.p95_ns),
            thr
        );
        self.results.push((name.to_string(), stats));
    }

    pub fn finish(self) -> Vec<(String, Stats)> {
        println!("== {} done ({} benchmarks) ==", self.group, self.results.len());
        self.results
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}
