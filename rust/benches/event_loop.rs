//! Timeline dispatch overhead: what the virtual-time layer costs per
//! step, lockstep (shared clock, O(1)/step) vs the event engine
//! (per-learner clocks + group-local barriers, O(P)/step), at P ∈ {16,
//! 64}.  The event model's dispatch cost rides the training hot path when
//! `--exec event` is set, so it must stay visible in the perf trajectory
//! (`BENCH_event.json`).
//!
//! Each iteration drives one model through a fixed 512-step two-level
//! schedule (K = [4, 32]) — the measured number is the whole timeline,
//! so per-step cost = reported time / 512.
//!
//! The `replay_timeline_only/*` benches emit the events/sec-vs-P scaling
//! curve for the heap core's timeline-only replay (the planner's pricing
//! path) at P ∈ {64, 4096, 65536, 1048576}: each declares its timeline
//! event count per iteration via `bench_units`, so `BENCH_event.json`
//! carries `units_per_sec` (events/sec) directly.  Homogeneous replay
//! rides the shared step node — the curve should be flat in P — while
//! the straggler variant pays the flat pooled per-learner arrays.

mod benchkit;

use hier_avg::algorithms::HierSchedule;
use hier_avg::sim::{
    drive_timeline, replay_timeline_stats, replay_timeline_stats_faults, ExecKind, ExecModel,
    FaultPlan, FaultSpec, HetSpec,
};
use hier_avg::topology::HierTopology;

const STEPS: u64 = 512;

fn main() {
    let mut b = benchkit::Bench::new("event");
    let base = 1e-3;
    let level_seconds = [1e-4, 1e-3];
    for &p in &[16usize, 64] {
        let topo = HierTopology::new(vec![4, p]).unwrap();
        let sched = HierSchedule::new(vec![4, 32]).unwrap();
        let homogeneous = HetSpec::default();
        let straggler =
            HetSpec { het: 0.2, straggler_prob: 0.05, straggler_mult: 4.0, seed: 42 };

        b.bench(&format!("timeline/lockstep/p{p}/512steps"), || {
            let mut m = ExecKind::Lockstep.build(p, 2, base, &homogeneous);
            drive_timeline(m.as_mut(), &topo, &sched, STEPS, &level_seconds);
            std::hint::black_box(m.now());
        });
        b.bench(&format!("timeline/event/p{p}/512steps"), || {
            let mut m = ExecKind::Event.build(p, 2, base, &homogeneous);
            drive_timeline(m.as_mut(), &topo, &sched, STEPS, &level_seconds);
            std::hint::black_box(m.now());
        });
        // The RNG draw per learner-step is the event model's marginal cost
        // over the homogeneous path.
        b.bench(&format!("timeline/event_straggler/p{p}/512steps"), || {
            let mut m = ExecKind::Event.build(p, 2, base, &straggler);
            drive_timeline(m.as_mut(), &topo, &sched, STEPS, &level_seconds);
            std::hint::black_box(m.now());
        });
        // The elastic layer's marginal cost: membership resolution per
        // step + survivor-aware barriers on the same hot path.
        let plan = FaultPlan::Sampled(FaultSpec { prob: 0.01, mttr: 10 });
        b.bench(&format!("timeline/event_faults/p{p}/512steps"), || {
            let mut m = ExecKind::Event.build(p, 2, base, &straggler);
            m.install_faults(straggler.seed, &plan);
            drive_timeline(m.as_mut(), &topo, &sched, STEPS, &level_seconds);
            std::hint::black_box(m.now());
        });
        // Breakdown assembly (per-run, not per-step, but part of the
        // record path).
        b.bench(&format!("timeline/event_breakdown/p{p}"), || {
            let mut m = ExecKind::Event.build(p, 2, base, &straggler);
            drive_timeline(m.as_mut(), &topo, &sched, STEPS, &level_seconds);
            std::hint::black_box(m.breakdown());
        });
    }

    // events/sec-vs-P scaling curve: timeline-only replay of a 4096-step
    // two-level schedule.  units = steps + barrier nodes fired, so the
    // JSON's units_per_sec is timeline events per second at each P.
    let horizon = 4096u64;
    let sched = HierSchedule::new(vec![4, 32]).unwrap();
    let n_reductions: u64 = sched.reduction_counts(horizon).iter().sum();
    let units = horizon + n_reductions;
    for &p in &[64usize, 4096, 65536, 1_048_576] {
        let topo = HierTopology::new(vec![64, p]).unwrap();
        b.bench_units(&format!("replay_timeline_only/p{p}/4096steps"), units, || {
            std::hint::black_box(replay_timeline_stats(
                &topo,
                &sched,
                horizon,
                base,
                &level_seconds,
                &HetSpec::default(),
            ));
        });
    }
    // The heterogeneous curve pays the flat pooled per-learner arrays
    // (O(horizon · P) exact RNG replay), so it is measured at smaller P.
    let straggler = HetSpec { het: 0.2, straggler_prob: 0.05, straggler_mult: 4.0, seed: 42 };
    for &p in &[64usize, 1024] {
        let topo = HierTopology::new(vec![64, p]).unwrap();
        b.bench_units(&format!("replay_timeline_only_straggler/p{p}/4096steps"), units, || {
            std::hint::black_box(replay_timeline_stats(
                &topo,
                &sched,
                horizon,
                base,
                &level_seconds,
                &straggler,
            ));
        });
    }
    // Fault-armed replay (the planner's `sweep --faults` pricing path):
    // forces per-learner state like the straggler curve, plus the
    // membership trace — measured at the same P points for comparison.
    let plan = FaultPlan::Sampled(FaultSpec { prob: 0.01, mttr: 10 });
    for &p in &[64usize, 1024] {
        let topo = HierTopology::new(vec![64, p]).unwrap();
        // survivor pricing scales the level charge by the participant
        // fraction — shape-realistic without dragging in a CostModel
        let survivor = |level: usize, n_part: usize| {
            level_seconds[level] * n_part as f64 / topo.size(level) as f64
        };
        b.bench_units(&format!("replay_timeline_only_faults/p{p}/4096steps"), units, || {
            std::hint::black_box(replay_timeline_stats_faults(
                &topo,
                &sched,
                horizon,
                base,
                &level_seconds,
                &straggler,
                &plan,
                &survivor,
            ));
        });
    }
    b.finish();
}
