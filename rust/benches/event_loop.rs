//! Timeline dispatch overhead: what the virtual-time layer costs per
//! step, lockstep (shared clock, O(1)/step) vs the event engine
//! (per-learner clocks + group-local barriers, O(P)/step), at P ∈ {16,
//! 64}.  The event model's dispatch cost rides the training hot path when
//! `--exec event` is set, so it must stay visible in the perf trajectory
//! (`BENCH_event.json`).
//!
//! Each iteration drives one model through a fixed 512-step two-level
//! schedule (K = [4, 32]) — the measured number is the whole timeline,
//! so per-step cost = reported time / 512.

mod benchkit;

use hier_avg::algorithms::HierSchedule;
use hier_avg::sim::{drive_timeline, ExecKind, ExecModel, HetSpec};
use hier_avg::topology::HierTopology;

const STEPS: u64 = 512;

fn main() {
    let mut b = benchkit::Bench::new("event");
    let base = 1e-3;
    let level_seconds = [1e-4, 1e-3];
    for &p in &[16usize, 64] {
        let topo = HierTopology::new(vec![4, p]).unwrap();
        let sched = HierSchedule::new(vec![4, 32]).unwrap();
        let homogeneous = HetSpec::default();
        let straggler =
            HetSpec { het: 0.2, straggler_prob: 0.05, straggler_mult: 4.0, seed: 42 };

        b.bench(&format!("timeline/lockstep/p{p}/512steps"), || {
            let mut m = ExecKind::Lockstep.build(p, 2, base, &homogeneous);
            drive_timeline(m.as_mut(), &topo, &sched, STEPS, &level_seconds);
            std::hint::black_box(m.now());
        });
        b.bench(&format!("timeline/event/p{p}/512steps"), || {
            let mut m = ExecKind::Event.build(p, 2, base, &homogeneous);
            drive_timeline(m.as_mut(), &topo, &sched, STEPS, &level_seconds);
            std::hint::black_box(m.now());
        });
        // The RNG draw per learner-step is the event model's marginal cost
        // over the homogeneous path.
        b.bench(&format!("timeline/event_straggler/p{p}/512steps"), || {
            let mut m = ExecKind::Event.build(p, 2, base, &straggler);
            drive_timeline(m.as_mut(), &topo, &sched, STEPS, &level_seconds);
            std::hint::black_box(m.now());
        });
        // Breakdown assembly (per-run, not per-step, but part of the
        // record path).
        b.bench(&format!("timeline/event_breakdown/p{p}"), || {
            let mut m = ExecKind::Event.build(p, 2, base, &straggler);
            drive_timeline(m.as_mut(), &topo, &sched, STEPS, &level_seconds);
            std::hint::black_box(m.breakdown());
        });
    }
    b.finish();
}
