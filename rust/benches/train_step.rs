//! End-to-end `Engine::step` throughput (learner-steps/sec) across the
//! P scaling curve, arena-pooled pipeline vs the serial reference path.
//!
//! The pooled pipeline (`--pool-threads >= 2`, P >= POOL_STEP_MIN_P) runs
//! batch fill, the fused SGD apply, and the loss tree-reduction on the
//! persistent worker pool over the flat learner arena; the serial case
//! (`pool_threads = 0`) is the executable bit-exact reference
//! (DESIGN.md §Memory layout).  Bit-identity between the two is asserted
//! before timing, so the pooled/serial pairs at each P are pure speed —
//! `units_per_sec` (learner-steps/sec) is the gated throughput axis in
//! `BENCH_train.json` (scripts/bench_gate.py).

mod benchkit;

use hier_avg::backend::StepBackend;
use hier_avg::config::{BackendKind, RunConfig};
use hier_avg::coordinator::{sim_step_seconds, Engine};
use hier_avg::data::{ClassifyData, MixtureSpec};
use hier_avg::native::NativeMlp;
use hier_avg::params::FlatParams;
use hier_avg::util::rng::Pcg32;

const DIMS: &[usize] = &[24, 48, 6];
const BATCH: usize = 8;
const LR: f32 = 0.05;

fn mk_cfg(p: usize, pool_threads: usize) -> RunConfig {
    let mut cfg = RunConfig::defaults("native-train-bench");
    cfg.backend = BackendKind::Native;
    cfg.p = p;
    cfg.s = 4.min(p);
    cfg.k1 = 2;
    cfg.k2 = 8;
    cfg.seed = 7;
    cfg.momentum = 0.9;
    cfg.weight_decay = 1e-4;
    cfg.pool_threads = pool_threads;
    cfg.quiet = true;
    cfg
}

fn mk_data() -> ClassifyData {
    ClassifyData::generate(MixtureSpec {
        dim: DIMS[0],
        classes: *DIMS.last().unwrap(),
        train_n: 4096,
        test_n: 256,
        radius: 1.0,
        noise: 1.2,
        subclusters: 1,
        label_noise: 0.0,
        seed: 3,
    })
}

/// Run `steps` engine steps under `cfg` and return the mean parameters.
fn run_steps(cfg: &RunConfig, data: &ClassifyData, steps: usize) -> FlatParams {
    let mut backend = NativeMlp::new(DIMS, BATCH, 64).unwrap();
    let init = backend.init(&mut Pcg32::seeded(1));
    let n_params = backend.n_params();
    let step_secs = sim_step_seconds(BATCH, n_params);
    let policy = cfg.schedule_policy.build(cfg.k2_clamp(BATCH), step_secs, cfg.p);
    let mut engine = Engine::new(cfg, n_params, &init, step_secs, policy).unwrap();
    let sched = cfg.hier_schedule_at(0).unwrap();
    for _ in 0..steps {
        engine.step(&mut backend, data, LR, &sched).unwrap();
    }
    let mut mean = vec![0.0f32; n_params];
    engine.mean_params(&mut mean);
    mean
}

fn main() {
    let mut b = benchkit::Bench::new("train");
    let data = mk_data();
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let pool_threads = hw.max(2);

    // The pooled pipeline must be bit-identical to the serial reference
    // before any timing: same trajectory through fill + grads + apply +
    // reduce over a K1/K2 cadence that fires both reduction levels.
    {
        let serial = run_steps(&mk_cfg(16, 0), &data, 17);
        let pooled = run_steps(&mk_cfg(16, pool_threads), &data, 17);
        assert_eq!(serial, pooled, "pooled step pipeline must be bit-identical");
    }

    for &p in &[4usize, 16, 64, 256] {
        for &(case, threads) in &[("serial", 0usize), ("pooled", pool_threads)] {
            let cfg = mk_cfg(p, threads);
            let mut backend = NativeMlp::new(DIMS, BATCH, 64).unwrap();
            let init = backend.init(&mut Pcg32::seeded(1));
            let n_params = backend.n_params();
            let step_secs = sim_step_seconds(BATCH, n_params);
            let policy =
                cfg.schedule_policy.build(cfg.k2_clamp(BATCH), step_secs, cfg.p);
            let mut engine =
                Engine::new(&cfg, n_params, &init, step_secs, policy).unwrap();
            let sched = cfg.hier_schedule_at(0).unwrap();
            // units = learner-steps: one engine step advances P learners.
            b.bench_units(&format!("step/p{p}/{case}"), p as u64, || {
                engine.step(&mut backend, &data, LR, &sched).unwrap();
            });
        }
    }

    b.finish();
}
