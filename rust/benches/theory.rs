//! Bound-evaluator micro-benchmarks: the theory module is called inside
//! sweep loops (optimal-K2 searches over large grids), so its evaluators
//! should be allocation-free and nanosecond-scale.

mod benchkit;

use hier_avg::theory::{self, BoundParams};

fn main() {
    let mut b = benchkit::Bench::new("theory");
    let p = BoundParams::default();

    b.bench("thm31_bound", || {
        std::hint::black_box(theory::thm31_bound(&p, 100_000, 32));
    });
    b.bench("thm32_bound", || {
        std::hint::black_box(theory::thm32_bound(&p, 1_000, 4, 32, 4));
    });
    b.bench("thm34_budget_bound", || {
        std::hint::black_box(theory::thm34_budget_bound(&p, 20_000, 4, 32, 4));
    });
    b.bench("optimal_k2/search_to_1024", || {
        std::hint::black_box(theory::optimal_k2(&p, 20_000, 1, 4, 1024));
    });
    b.bench("thm36_pair", || {
        std::hint::black_box(theory::thm36_pair(&p, 10_000, 32, 0.4));
    });

    b.finish();
}
