//! Property and regression tests for the three-layer engine refactor:
//!
//! (a) an N-level topology/schedule with L=2 reproduces the legacy
//!     `HierAvgSchedule::event_after` stream and reduction counts exactly,
//!     and a trainer run expressed via explicit `levels`/`ks` is
//!     bit-identical to the `(p, s, k1, k2)` form;
//! (b) the sharded thread-parallel collective is bit-identical to the
//!     simulated reducer for random replicas;
//! (c) the execution-model layer: homogeneous `--exec event` runs are
//!     bit-identical to lockstep on random small topologies (params,
//!     trace, comm, timeline breakdown), and straggler runs attribute
//!     more barrier stall to the global tier than the local one on the
//!     paper's 2-level K1 < K2 shape;
//! plus end-to-end coverage of a ≥3-level hierarchy through the CLI
//! config path with per-level reduction counts in the metrics.

use hier_avg::algorithms::{HierAvgSchedule, HierSchedule, PolicyKind, ReduceEvent};
use hier_avg::comm::{
    CollectiveKind, CostModel, PooledCollective, ReduceStrategy, Reducer, ShardedCollective,
};
use hier_avg::config::{BackendKind, RunConfig};
use hier_avg::coordinator::Trainer;
use hier_avg::data::{ClassifyData, MixtureSpec};
use hier_avg::metrics::RunRecord;
use hier_avg::native::NativeMlp;
use hier_avg::optimizer::LrSchedule;
use hier_avg::params::ParamArena;
use hier_avg::topology::{HierTopology, LinkClass, Topology};
use hier_avg::util::cli::Args;
use hier_avg::util::rng::Pcg32;

const CASES: usize = 300;

// ---------------------------------------------------------------------------
// (a) L=2 identities: schedule stream + reduction counts
// ---------------------------------------------------------------------------

#[test]
fn prop_two_level_schedule_matches_legacy_stream() {
    let mut rng = Pcg32::seeded(0x2EE7);
    for case in 0..CASES {
        let k1 = 1 + rng.next_below(16) as u64;
        let k2 = k1 + rng.next_below(48) as u64;
        let t_max = 1 + rng.next_below(2000) as u64;
        let legacy = HierAvgSchedule::new(k1, k2).unwrap();
        let hier = HierSchedule::two_level(k1, k2).unwrap();
        for t in 1..=t_max {
            let expect = match legacy.event_after(t) {
                ReduceEvent::Global => Some(1),
                ReduceEvent::Local => Some(0),
                ReduceEvent::None => None,
            };
            assert_eq!(
                hier.event_after(t),
                expect,
                "case {case}: k1={k1} k2={k2} t={t}"
            );
        }
        let (g, l) = legacy.reduction_counts(t_max);
        assert_eq!(
            hier.reduction_counts(t_max),
            vec![l, g],
            "case {case}: k1={k1} k2={k2} t={t_max}"
        );
    }
}

#[test]
fn prop_multilevel_counts_match_event_scan() {
    let mut rng = Pcg32::seeded(0x3C4A);
    for case in 0..100 {
        let n_levels = 1 + rng.next_below(4) as usize;
        let mut intervals = Vec::with_capacity(n_levels);
        let mut k = 1 + rng.next_below(6) as u64;
        for _ in 0..n_levels {
            k += rng.next_below(12) as u64;
            intervals.push(k);
        }
        let s = HierSchedule::new(intervals.clone()).unwrap();
        let t = 1 + rng.next_below(3000) as u64;
        let mut scan = vec![0u64; n_levels];
        for i in 1..=t {
            if let Some(lev) = s.event_after(i) {
                scan[lev] += 1;
            }
        }
        assert_eq!(
            s.reduction_counts(t),
            scan,
            "case {case}: intervals {intervals:?} t={t}"
        );
    }
}

// ---------------------------------------------------------------------------
// (b) sharded collective ≡ simulated reducer, bit for bit
// ---------------------------------------------------------------------------

#[test]
fn prop_sharded_collective_bit_identical() {
    let mut rng = Pcg32::seeded(0x5AAD);
    for case in 0..60 {
        let s = 1 + rng.next_below(4) as usize;
        let clusters = 1 + rng.next_below(4) as usize;
        let p = s * clusters;
        let n = 1 + rng.next_below(10_000) as usize;
        let threads = 1 + rng.next_below(6) as usize;
        let topo = Topology::new(p, s).unwrap();
        let rows: Vec<Vec<f32>> =
            (0..p).map(|_| (0..n).map(|_| rng.next_normal()).collect()).collect();
        let base = ParamArena::from_rows(&rows);

        let mut a = base.clone();
        let mut sim = Reducer::new(CostModel::default(), ReduceStrategy::Ring, n);
        sim.local_average(a.view_mut(), &topo);
        sim.global_average(a.view_mut(), &topo);

        let mut b = base.clone();
        let mut sh = Reducer::with_collective(
            CostModel::default(),
            ReduceStrategy::Ring,
            n,
            Box::new(ShardedCollective::new(threads)),
        );
        sh.local_average(b.view_mut(), &topo);
        sh.global_average(b.view_mut(), &topo);

        assert_eq!(a, b, "case {case}: p={p} s={s} n={n} threads={threads}");
        assert_eq!(sim.stats, sh.stats, "case {case}");

        // mean_of parity as well
        let mut ma = Vec::new();
        let mut mb = Vec::new();
        sim.mean_of(base.view(), &mut ma);
        sh.mean_of(base.view(), &mut mb);
        assert_eq!(ma, mb, "case {case}");
    }
}

// ---------------------------------------------------------------------------
// (b') pooled collective ≡ simulated reducer, bit for bit, across thread
// counts — including counts far above the available parallelism
// ---------------------------------------------------------------------------

#[test]
fn prop_pooled_collective_bit_identical() {
    let mut rng = Pcg32::seeded(0x900D);
    for case in 0..60 {
        let s = 1 + rng.next_below(4) as usize;
        let clusters = 1 + rng.next_below(4) as usize;
        let p = s * clusters;
        // Spread n across the serial-fallback threshold: tiny shapes take
        // the serial path, large ones the pooled shards.
        let n = 1 + rng.next_below(60_000) as usize;
        let threads = 1 + rng.next_below(8) as usize;
        let topo = Topology::new(p, s).unwrap();
        let rows: Vec<Vec<f32>> =
            (0..p).map(|_| (0..n).map(|_| rng.next_normal()).collect()).collect();
        let base = ParamArena::from_rows(&rows);

        let mut a = base.clone();
        let mut sim = Reducer::new(CostModel::default(), ReduceStrategy::Ring, n);
        sim.local_average(a.view_mut(), &topo);
        sim.global_average(a.view_mut(), &topo);

        let mut b = base.clone();
        let mut po = Reducer::with_collective(
            CostModel::default(),
            ReduceStrategy::Ring,
            n,
            Box::new(PooledCollective::new(threads)),
        );
        po.local_average(b.view_mut(), &topo);
        po.global_average(b.view_mut(), &topo);

        assert_eq!(a, b, "case {case}: p={p} s={s} n={n} threads={threads}");
        assert_eq!(sim.stats, po.stats, "case {case}");

        let mut ma = Vec::new();
        let mut mb = Vec::new();
        sim.mean_of(base.view(), &mut ma);
        po.mean_of(base.view(), &mut mb);
        assert_eq!(ma, mb, "case {case}");
    }
}

#[test]
fn pooled_collective_deterministic_under_oversubscription() {
    // pool-threads far above the host's parallelism: the static
    // index→slot assignment keeps every run bit-identical.
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let threads = (hw * 8).max(16);
    let p = 8;
    let n = 200_003; // odd, well above the serial-fallback threshold
    let mut rng = Pcg32::seeded(0x0E5B);
    let rows: Vec<Vec<f32>> =
        (0..p).map(|_| (0..n).map(|_| rng.next_normal()).collect()).collect();
    let base = ParamArena::from_rows(&rows);
    let topo = Topology::new(p, 4).unwrap();

    let run = |threads: usize| {
        let mut r = base.clone();
        let mut red = Reducer::with_collective(
            CostModel::default(),
            ReduceStrategy::Ring,
            n,
            Box::new(PooledCollective::new(threads)),
        );
        red.local_average(r.view_mut(), &topo);
        red.global_average(r.view_mut(), &topo);
        r
    };
    let first = run(threads);
    let second = run(threads);
    assert_eq!(first, second, "oversubscribed pool must be deterministic");
    // ... and identical to the simulated engine.
    let mut sim_r = base.clone();
    let mut sim = Reducer::new(CostModel::default(), ReduceStrategy::Ring, n);
    sim.local_average(sim_r.view_mut(), &topo);
    sim.global_average(sim_r.view_mut(), &topo);
    assert_eq!(first, sim_r);
}

// ---------------------------------------------------------------------------
// Trainer-level regression: (p, s, k1, k2) vs explicit levels/ks, and
// simulated vs sharded collective
// ---------------------------------------------------------------------------

fn quick_cfg() -> RunConfig {
    let mut cfg = RunConfig::defaults("native-hier-test");
    cfg.backend = BackendKind::Native;
    cfg.p = 8;
    cfg.s = 4;
    cfg.k1 = 2;
    cfg.k2 = 8;
    cfg.epochs = 4;
    cfg.train_n = 1024;
    cfg.test_n = 256;
    cfg.lr = LrSchedule::Constant(0.1);
    cfg.noise = 0.8;
    cfg
}

const DIMS: &[usize] = &[18, 36, 5];

fn run_native(cfg: &RunConfig) -> RunRecord {
    let backend = NativeMlp::new(DIMS, 8, 64).unwrap();
    let data = ClassifyData::generate(MixtureSpec {
        dim: DIMS[0],
        classes: *DIMS.last().unwrap(),
        train_n: cfg.train_n,
        test_n: cfg.test_n,
        radius: cfg.radius,
        noise: cfg.noise,
        subclusters: 1,
        label_noise: 0.0,
        seed: cfg.seed ^ 0x5eed,
    });
    let mut rng = Pcg32::seeded(cfg.seed);
    let init = backend.init(&mut rng);
    Trainer::new(cfg, Box::new(backend), Box::new(data), init).unwrap().run().unwrap()
}

fn assert_records_identical(a: &RunRecord, b: &RunRecord) {
    assert_eq!(a.total_steps, b.total_steps);
    assert_eq!(a.epochs.len(), b.epochs.len());
    for (x, y) in a.epochs.iter().zip(&b.epochs) {
        assert_eq!(x.train_loss, y.train_loss);
        assert_eq!(x.train_acc, y.train_acc);
        // NaNs (skipped evals) compare equal via bits
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits());
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits());
    }
    assert_eq!(a.comm, b.comm);
}

#[test]
fn explicit_two_level_config_is_bit_identical() {
    let implicit = quick_cfg();
    let mut explicit = quick_cfg();
    explicit.set_levels(vec![4, 8]);
    explicit.set_ks(vec![2, 8]);
    let ra = run_native(&implicit);
    let rb = run_native(&explicit);
    assert_records_identical(&ra, &rb);
    // per-level accounts mirror the aggregate local/global split
    assert_eq!(rb.comm_levels.len(), 2);
    assert_eq!(rb.comm_levels[0].reductions, rb.comm.local_reductions);
    assert_eq!(rb.comm_levels[1].reductions, rb.comm.global_reductions);
    assert_eq!(ra.comm_levels, rb.comm_levels);
}

#[test]
fn sharded_collective_trainer_is_bit_identical() {
    let simulated = quick_cfg();
    let mut sharded = quick_cfg();
    sharded.collective = CollectiveKind::Sharded { threads: 3 };
    let ra = run_native(&simulated);
    let rb = run_native(&sharded);
    assert_records_identical(&ra, &rb);
    assert_eq!(ra.comm_levels, rb.comm_levels);
}

#[test]
fn pooled_collective_trainer_is_bit_identical() {
    let simulated = quick_cfg();
    let mut pooled = quick_cfg();
    pooled.collective = CollectiveKind::Pooled { threads: 3 };
    let ra = run_native(&simulated);
    let rb = run_native(&pooled);
    assert_records_identical(&ra, &rb);
    assert_eq!(ra.comm_levels, rb.comm_levels);
}

#[test]
fn rack_link_override_is_surfaced_and_charged() {
    let mut cfg = quick_cfg();
    cfg.set_levels(vec![4, 8]);
    cfg.set_ks(vec![2, 8]);
    cfg.links = vec![LinkClass::IntraNode, LinkClass::RackFabric];
    let rec = run_native(&cfg);
    // The outer level's reductions land on the rack account, not global.
    assert_eq!(rec.comm.global_reductions, 0);
    assert!(rec.comm.rack_reductions > 0);
    assert!(rec.comm.rack_seconds > 0.0);
    // ... and are more expensive than the default inter-node tier.
    let mut default_cfg = quick_cfg();
    default_cfg.set_levels(vec![4, 8]);
    default_cfg.set_ks(vec![2, 8]);
    let def = run_native(&default_cfg);
    assert_eq!(def.comm.global_reductions, rec.comm.rack_reductions);
    assert!(rec.comm.rack_seconds > def.comm.global_seconds);
    // Training dynamics are untouched by the cost-model relabelling.
    for (x, y) in rec.epochs.iter().zip(&def.epochs) {
        assert_eq!(x.train_loss, y.train_loss);
    }
    // The JSON output names each level's link class.
    let json = rec.to_json();
    let levels = json.req("comm_levels").unwrap().as_arr().unwrap().to_vec();
    assert_eq!(levels[0].req("link").unwrap().as_str().unwrap(), "intra");
    assert_eq!(levels[1].req("link").unwrap().as_str().unwrap(), "rack");
    assert!(json.req("comm").unwrap().req("rack_seconds").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn adaptive_k2_identical_across_forms() {
    let mut implicit = quick_cfg();
    implicit.k2_schedule = vec![(2, 4)];
    let mut explicit = quick_cfg();
    explicit.set_levels(vec![4, 8]);
    explicit.set_ks(vec![2, 8]);
    explicit.k2_schedule = vec![(2, 4)];
    let ra = run_native(&implicit);
    let rb = run_native(&explicit);
    assert_records_identical(&ra, &rb);
}

// ---------------------------------------------------------------------------
// ≥3-level hierarchy end to end via the CLI config path
// ---------------------------------------------------------------------------

#[test]
fn three_level_hierarchy_runs_via_cli_args() {
    let argv: Vec<String> = [
        "train",
        "--model",
        "quickstart",
        "--backend",
        "native",
        "--levels",
        "2,4,8",
        "--ks",
        "2,4,8",
        "--collective",
        "sharded:2",
        "--epochs",
        "2",
        "--train-n",
        "1024",
        "--test-n",
        "256",
        "--lr",
        "const:0.1",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let args = Args::parse(argv, &["record-steps", "help"]).unwrap();
    let cfg = RunConfig::from_args(&args).unwrap();
    assert_eq!(cfg.hierarchy().unwrap().n_levels(), 3);

    let rec = hier_avg::driver::run(&cfg).unwrap();
    assert!(rec.total_steps > 0);
    assert!(rec.epochs.last().unwrap().train_loss.is_finite());

    // Per-level reduction counts are reported and match the schedule: each
    // level-l event reduces every group at that level.
    let topo = cfg.hierarchy().unwrap();
    let sched = cfg.hier_schedule().unwrap();
    let events = sched.reduction_counts(rec.total_steps);
    assert_eq!(rec.comm_levels.len(), 3);
    for lev in 0..3 {
        assert_eq!(
            rec.comm_levels[lev].reductions,
            events[lev] * topo.n_groups(lev) as u64,
            "level {lev}"
        );
    }
    // aggregate split: level 0 is intra-node, levels 1..=2 inter-node
    assert_eq!(topo.link(0), LinkClass::IntraNode);
    assert_eq!(rec.comm.local_reductions, rec.comm_levels[0].reductions);
    assert_eq!(
        rec.comm.global_reductions,
        rec.comm_levels[1].reductions + rec.comm_levels[2].reductions
    );
    // the record serializes the per-level accounts
    let json = rec.to_json();
    assert_eq!(
        json.req("comm_levels").unwrap().as_arr().unwrap().len(),
        3
    );
}

#[test]
fn deeper_hierarchy_reduces_modelled_global_time() {
    // The paper's argument, one level deeper: pushing reductions down the
    // hierarchy (cheap links, small groups) cuts the modelled time spent on
    // the global fabric for the same total number of reduction events.
    let mut two = quick_cfg();
    two.set_levels(vec![2, 8]);
    two.set_ks(vec![2, 4]);
    let mut three = quick_cfg();
    three.set_levels(vec![2, 4, 8]);
    three.set_ks(vec![2, 4, 8]);
    let r2 = run_native(&two);
    let r3 = run_native(&three);
    assert_eq!(r2.total_steps, r3.total_steps);
    assert!(
        r3.comm.global_seconds < r2.comm.global_seconds,
        "3-level global {} vs 2-level {}",
        r3.comm.global_seconds,
        r2.comm.global_seconds
    );
    // both still learn (chance for 5 classes is 0.2)
    assert!(r3.epochs.last().unwrap().test_acc > 0.4);
}

#[test]
fn flat_single_level_hierarchy_is_kavg() {
    // levels=[P], ks=[K]: pure K-AVG — global-only reductions.
    let mut flat = quick_cfg();
    flat.set_levels(vec![8]);
    flat.set_ks(vec![4]);
    let rec = run_native(&flat);
    assert_eq!(rec.comm.local_reductions, 0);
    assert_eq!(rec.comm.global_reductions, rec.total_steps / 4);
    assert_eq!(rec.comm_levels.len(), 1);

    // ... and matches the (s=1, k1=k2) two-level encoding bit for bit.
    let mut legacy = quick_cfg();
    legacy.s = 1;
    legacy.k1 = 4;
    legacy.k2 = 4;
    let rl = run_native(&legacy);
    for (x, y) in rec.epochs.iter().zip(&rl.epochs) {
        assert_eq!(x.train_loss, y.train_loss);
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits());
    }
    assert_eq!(rec.comm.global_reductions, rl.comm.global_reductions);
}

// ---------------------------------------------------------------------------
// Execution-model layer: homogeneous event ≡ lockstep on random small
// topologies, and straggler stall attribution on the paper's 2-level shape
// ---------------------------------------------------------------------------

fn assert_exec_breakdowns_identical(a: &RunRecord, b: &RunRecord) {
    assert_eq!(a.makespan_seconds.to_bits(), b.makespan_seconds.to_bits());
    assert_eq!(a.busy_seconds.len(), b.busy_seconds.len());
    for (x, y) in a.busy_seconds.iter().zip(&b.busy_seconds) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    assert_eq!(a.blocked_seconds, b.blocked_seconds);
    assert_eq!(a.idle_seconds, b.idle_seconds);
    assert_eq!(a.level_stall_seconds, b.level_stall_seconds);
    assert_eq!(a.straggler_events, b.straggler_events);
}

#[test]
fn prop_homogeneous_event_matches_lockstep_on_random_topologies() {
    // Valid divisor chains over small P (2-, 3-level, with degenerate
    // size-1 and flat cases in the pool).
    let shapes: &[&[usize]] = &[
        &[2, 4],
        &[4, 8],
        &[1, 8],
        &[2, 6],
        &[2, 4, 8],
        &[2, 2, 8],
        &[8],
    ];
    let mut rng = Pcg32::seeded(0xE7E7);
    for case in 0..12 {
        let shape = shapes[rng.next_below(shapes.len() as u32) as usize];
        // Random non-decreasing intervals per level.
        let mut ks = Vec::with_capacity(shape.len());
        let mut k = 1 + rng.next_below(3) as u64;
        for _ in 0..shape.len() {
            ks.push(k);
            k += rng.next_below(5) as u64;
        }
        let mut lockstep = quick_cfg();
        lockstep.set_levels(shape.to_vec());
        lockstep.set_ks(ks.clone());
        lockstep.record_trace = true;
        lockstep.keep_final_params = true;
        let mut event = lockstep.clone();
        event.exec = hier_avg::sim::ExecKind::Event;
        let ra = run_native(&lockstep);
        let rb = run_native(&event);
        assert_records_identical(&ra, &rb);
        assert_eq!(ra.comm_levels, rb.comm_levels, "case {case}: {shape:?} ks {ks:?}");
        assert_eq!(ra.trace, rb.trace, "case {case}");
        assert_eq!(
            ra.final_params, rb.final_params,
            "case {case}: parameter drift between execution models"
        );
        assert_exec_breakdowns_identical(&ra, &rb);
        // homogeneous: nobody ever waits or idles
        assert!(rb.blocked_seconds.iter().all(|&x| x == 0.0), "case {case}");
        assert!(rb.idle_seconds.iter().all(|&x| x == 0.0), "case {case}");
        for (x, y) in ra.epochs.iter().zip(&rb.epochs) {
            assert_eq!(x.sim_seconds.to_bits(), y.sim_seconds.to_bits(), "case {case}");
        }
    }
}

#[test]
fn straggler_stall_attribution_favors_the_global_tier() {
    // The acceptance scenario: a 2-level K1 < K2 run under stragglers.
    // Local barriers re-synchronize pairs every K1 = 2 steps, absorbing
    // only the small within-pair drift; the global barrier waits for the
    // slowest of all P learners after a whole K2 = 8 interval of
    // accumulated cross-group drift — so the stall bill lands on the
    // global tier.
    let mut cfg = quick_cfg();
    cfg.set_levels(vec![2, 8]);
    cfg.set_ks(vec![2, 8]);
    cfg.exec = hier_avg::sim::ExecKind::Event;
    cfg.het = 0.4;
    cfg.straggler_prob = 0.02;
    cfg.straggler_mult = 4.0;
    let rec = run_native(&cfg);
    assert_eq!(rec.exec_model, "event");
    assert_eq!(rec.level_stall_seconds.len(), 2);
    let (local_stall, global_stall) = (rec.level_stall_seconds[0], rec.level_stall_seconds[1]);
    assert!(local_stall > 0.0, "local barriers never stalled");
    assert!(
        global_stall >= local_stall,
        "global stall {global_stall} < local stall {local_stall}"
    );
    // stall attribution is conservative: it partitions total blocked time
    let blocked: f64 = rec.blocked_seconds.iter().sum();
    let stalls: f64 = rec.level_stall_seconds.iter().sum();
    assert!((blocked - stalls).abs() < 1e-9 * blocked.max(1.0));
    // and the makespan dominates the homogeneous lockstep clock of the
    // same shape
    let mut lockstep_cfg = quick_cfg();
    lockstep_cfg.set_levels(vec![2, 8]);
    lockstep_cfg.set_ks(vec![2, 8]);
    let lockstep = run_native(&lockstep_cfg);
    assert!(rec.makespan_seconds > lockstep.makespan_seconds);
    // training numerics are still bit-identical to the lockstep twin
    assert_records_identical(&lockstep, &rec);
}

// ---------------------------------------------------------------------------
// Schedule-policy layer: neutral adaptive ≡ static bit for bit, the
// straggler-aware controller's acceptance behaviour, and the checkpoint
// sidecar's policy guard
// ---------------------------------------------------------------------------

#[test]
fn prop_neutral_adaptive_is_bit_identical_to_static() {
    // The satellite invariant: AdaptivePolicy with zero gain (the neutral
    // controller) is bit-identical to StaticPolicy — random topologies,
    // both exec models, all three collectives.
    let shapes: &[&[usize]] = &[&[2, 4], &[4, 8], &[1, 8], &[2, 4, 8], &[8]];
    let collectives = [
        CollectiveKind::Simulated,
        CollectiveKind::Sharded { threads: 3 },
        CollectiveKind::Pooled { threads: 2 },
    ];
    let execs = [hier_avg::sim::ExecKind::Lockstep, hier_avg::sim::ExecKind::Event];
    let mut rng = Pcg32::seeded(0xADA7);
    for case in 0..8 {
        let shape = shapes[rng.next_below(shapes.len() as u32) as usize];
        let mut ks = Vec::with_capacity(shape.len());
        let mut k = 1 + rng.next_below(3) as u64;
        for _ in 0..shape.len() {
            ks.push(k);
            k += rng.next_below(5) as u64;
        }
        let collective = collectives[rng.next_below(3) as usize];
        let exec = execs[rng.next_below(2) as usize];
        let mut stat = quick_cfg();
        stat.set_levels(shape.to_vec());
        stat.set_ks(ks.clone());
        stat.collective = collective;
        stat.exec = exec;
        stat.record_trace = true;
        stat.keep_final_params = true;
        let mut neutral = stat.clone();
        neutral.schedule_policy = PolicyKind::Adaptive { target: 0.25, gain: 0.0 };
        let ra = run_native(&stat);
        let rb = run_native(&neutral);
        assert_records_identical(&ra, &rb);
        assert_eq!(ra.comm_levels, rb.comm_levels, "case {case}: {shape:?} ks {ks:?}");
        assert_eq!(ra.trace, rb.trace, "case {case}");
        assert_eq!(ra.final_params, rb.final_params, "case {case}");
        assert_exec_breakdowns_identical(&ra, &rb);
        // The schedule block agrees on everything but the policy name.
        let (sa, sb) = (ra.schedule.as_ref().unwrap(), rb.schedule.as_ref().unwrap());
        assert_eq!(sa.policy, "static");
        assert_eq!(sb.policy, "adaptive:0.25:0");
        assert_eq!(sa.realized, sb.realized, "case {case}");
        assert!(sb.changes.is_empty(), "neutral controller adapted: case {case}");
    }
}

#[test]
fn adaptive_straggler_run_thins_the_global_tier() {
    // The acceptance scenario, engine-level: under a seeded
    // --het/--straggler event run the adaptive policy must fire at most
    // as many global-tier reductions as the static run, keep every
    // realized interval within the condition-(3.5) clamp, and still
    // train.
    let mut stat = quick_cfg();
    stat.set_levels(vec![2, 8]);
    stat.set_ks(vec![2, 8]);
    stat.exec = hier_avg::sim::ExecKind::Event;
    stat.het = 0.8;
    stat.straggler_prob = 0.1;
    stat.straggler_mult = 4.0;
    let mut adap = stat.clone();
    adap.schedule_policy = PolicyKind::Adaptive { target: 0.05, gain: 1.0 };
    let rs = run_native(&stat);
    let ra = run_native(&adap);
    assert_eq!(rs.total_steps, ra.total_steps);
    let (ss, sa) = (rs.schedule.as_ref().unwrap(), ra.schedule.as_ref().unwrap());
    let global = |s: &hier_avg::algorithms::ScheduleSummary| *s.realized.last().unwrap();
    assert!(
        global(sa) < global(ss),
        "adaptive fired {} global reductions vs static {}",
        global(sa),
        global(ss)
    );
    // Every realized interval stays inside the theory clamp and at or
    // above the base schedule.
    assert!(sa.k2_clamp >= 8);
    for c in &sa.changes {
        for (l, &k) in c.intervals.iter().enumerate() {
            assert!(k <= sa.k2_clamp, "interval {k} above clamp {}", sa.k2_clamp);
            assert!(k >= [2u64, 8][l], "interval {k} narrowed below base at level {l}");
        }
    }
    assert!(!sa.changes.is_empty(), "controller never adapted");
    // Fewer wide barriers => the adaptive timeline finishes no later.
    assert!(ra.makespan_seconds <= rs.makespan_seconds);
    // ... and the run still learns (chance for 5 classes is 0.2).
    assert!(ra.epochs.last().unwrap().train_loss.is_finite());
    assert!(ra.epochs.last().unwrap().test_acc > 0.3);
}

#[test]
fn warmup_run_is_dense_early() {
    let mut stat = quick_cfg();
    stat.set_levels(vec![2, 8]);
    stat.set_ks(vec![2, 8]);
    let mut warm = stat.clone();
    warm.schedule_policy = PolicyKind::Warmup { stage_steps: 8 };
    let rs = run_native(&stat);
    let rw = run_native(&warm);
    let total = |r: &RunRecord| {
        r.schedule.as_ref().unwrap().realized.iter().sum::<u64>()
    };
    assert!(total(&rw) > total(&rs), "warmup {} vs static {}", total(&rw), total(&rs));
    // By the end of the run the warmup has decayed to the base schedule.
    assert_eq!(rw.schedule.as_ref().unwrap().final_intervals, vec![2, 8]);
    assert!(rw.epochs.last().unwrap().train_loss.is_finite());
}

#[test]
fn checkpoint_policy_mismatch_fails_loudly() {
    use hier_avg::util::json::Json;
    let dir = std::env::temp_dir().join("hier_avg_policy_ckpt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ckpt.bin");
    let mut cfg = RunConfig::defaults("quickstart");
    cfg.backend = BackendKind::Native;
    cfg.p = 4;
    cfg.s = 2;
    cfg.k1 = 2;
    cfg.k2 = 4;
    cfg.epochs = 1;
    cfg.train_n = 256;
    cfg.test_n = 64;
    let layout = hier_avg::driver::layout_for(&cfg).unwrap();
    let params = vec![0.01f32; layout.total];
    let state = Json::parse(
        r#"{"offset": 0, "anchors": [], "base": [], "intervals": [], "ratio": [], "quiet": []}"#,
    )
    .unwrap();
    hier_avg::checkpoint::save_with_schedule(
        &path,
        "quickstart",
        &layout,
        &params,
        Some(("adaptive:0.25", &state)),
    )
    .unwrap();
    cfg.init_params = Some(path.to_string_lossy().into_owned());
    // Resuming under a different --schedule is rejected with an
    // actionable error naming both policies.
    let err = hier_avg::driver::run(&cfg).unwrap_err().to_string();
    assert!(err.contains("--schedule adaptive:0.25"), "unhelpful error: {err}");
    assert!(err.contains("static"), "unhelpful error: {err}");
    // The matching policy resumes and restores the controller state.
    cfg.schedule_policy = PolicyKind::parse("adaptive:0.25").unwrap();
    let rec = hier_avg::driver::run(&cfg).unwrap();
    assert_eq!(rec.schedule.as_ref().unwrap().policy, "adaptive:0.25");
}

#[test]
fn hier_topology_three_level_reduction_nests() {
    // After a level-1 reduction, members of each level-1 group agree; a
    // level-2 reduction then synchronizes everything.
    let topo = HierTopology::new(vec![2, 4, 8]).unwrap();
    let mut rng = Pcg32::seeded(3);
    let rows: Vec<Vec<f32>> =
        (0..8).map(|_| (0..33).map(|_| rng.next_normal()).collect()).collect();
    let mut replicas = ParamArena::from_rows(&rows);
    let mut red = Reducer::new(CostModel::default(), ReduceStrategy::Ring, 33);
    red.reduce_level(replicas.view_mut(), &topo, 1);
    assert_eq!(replicas.row(0), replicas.row(3));
    assert_eq!(replicas.row(4), replicas.row(7));
    assert_ne!(replicas.row(0), replicas.row(4));
    red.reduce_level(replicas.view_mut(), &topo, 2);
    for j in 1..8 {
        assert_eq!(replicas.row(0), replicas.row(j));
    }
}
