//! Integration tests for the sweep planner: search-space size and
//! validity, the K-AVG degeneration identity, modelled-vs-measured cost
//! parity against the real engine, and the SWEEP report schema.

use hier_avg::comm::{CollectiveKind, CostModel, ReduceStrategy};
use hier_avg::metrics::RunRecord;
use hier_avg::planner::{self, report, Candidate, ScoreCtx, SweepSpace};
use hier_avg::util::json::Json;

fn ctx(p: usize) -> ScoreCtx {
    ScoreCtx::for_model("quickstart", p, 20_000, ReduceStrategy::Ring, CostModel::default())
        .unwrap()
}

// ---------------------------------------------------------------------------
// Acceptance: the sweep ranks ≥ 20 candidate shapes for P ∈ {16, 64}
// ---------------------------------------------------------------------------

#[test]
fn sweep_ranks_at_least_20_candidates_for_p16_and_p64() {
    for p in [16usize, 64] {
        let space = SweepSpace::new(p).unwrap();
        let ranked = planner::rank(&space, &ctx(p)).unwrap();
        assert!(ranked.len() >= 20, "p={p}: only {} candidates ranked", ranked.len());
        // Fully ordered, finite, positive; depths span 2..=4.
        let mut depths = std::collections::BTreeSet::new();
        for w in ranked.windows(2) {
            assert!(w[0].score.time_to_target <= w[1].score.time_to_target, "p={p}");
        }
        for r in &ranked {
            assert!(r.score.time_to_target.is_finite() && r.score.time_to_target > 0.0);
            assert!(r.score.bound.is_finite() && r.score.bound > 0.0);
            assert_eq!(*r.candidate.levels.last().unwrap(), p);
            depths.insert(r.candidate.levels.len());
        }
        let expect: std::collections::BTreeSet<usize> = [2, 3, 4].into_iter().collect();
        assert_eq!(depths, expect, "p={p}");
    }
}

#[test]
fn ranking_is_deterministic() {
    let space = SweepSpace::new(16).unwrap();
    let a = planner::rank(&space, &ctx(16)).unwrap();
    let b = planner::rank(&space, &ctx(16)).unwrap();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.candidate, y.candidate);
        assert_eq!(x.score.time_to_target.to_bits(), y.score.time_to_target.to_bits());
    }
}

// ---------------------------------------------------------------------------
// Acceptance: with local averaging disabled, the top-ranked 2-level shape
// degenerates to the K-AVG baseline — structurally and bit-for-bit through
// the engine.
// ---------------------------------------------------------------------------

fn assert_records_identical(a: &RunRecord, b: &RunRecord) {
    assert_eq!(a.total_steps, b.total_steps);
    assert_eq!(a.epochs.len(), b.epochs.len());
    for (x, y) in a.epochs.iter().zip(&b.epochs) {
        assert_eq!(x.train_loss, y.train_loss);
        assert_eq!(x.train_acc, y.train_acc);
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits());
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits());
    }
    assert_eq!(a.comm, b.comm);
}

#[test]
fn top_candidate_without_local_averaging_is_kavg_baseline() {
    let p = 16usize;
    let mut space = SweepSpace::new(p).unwrap();
    space.local_averaging = false;
    let ranked = planner::rank(&space, &ctx(p)).unwrap();
    assert!(!ranked.is_empty());
    // Structurally: every candidate (top included) is the 2-level [1, P]
    // shape with a flat schedule — every learner its own cluster, no local
    // averaging events possible.
    let top = &ranked[0].candidate;
    assert_eq!(top.levels, vec![1, p]);
    let (k1, k2, s) = top.k1k2s();
    assert_eq!(k1, k2);
    assert_eq!(s, 1);

    // Bit-for-bit: the top candidate's validation run equals the legacy
    // (p, s=1, k1=k2=K) K-AVG encoding of the same schedule.
    let cfg_planner =
        planner::validation_config(top, "quickstart", CollectiveKind::Simulated).unwrap();
    let rec_planner = planner::validation_record(&cfg_planner).unwrap();

    let kavg = Candidate::with_default_links(vec![1, p], vec![k2, k2]).unwrap();
    let mut cfg_kavg =
        planner::validation_config(&kavg, "quickstart", CollectiveKind::Simulated).unwrap();
    // Rewrite through the legacy two-level mirror fields: no explicit
    // levels/ks, just (p, s, k1, k2) — the compatibility surface.
    cfg_kavg.levels = Vec::new();
    cfg_kavg.ks = Vec::new();
    cfg_kavg.s = 1;
    cfg_kavg.k1 = k2;
    cfg_kavg.k2 = k2;
    cfg_kavg.validate().unwrap();
    let rec_kavg = planner::validation_record(&cfg_kavg).unwrap();

    assert_records_identical(&rec_planner, &rec_kavg);
    assert_eq!(rec_planner.comm.local_reductions, 0, "K-AVG must never reduce locally");
    assert_eq!(
        rec_planner.comm.global_reductions,
        rec_planner.total_steps / k2,
        "global cadence must be the flat K-AVG interval"
    );
}

// ---------------------------------------------------------------------------
// Modelled cost vs the engine's accounting
// ---------------------------------------------------------------------------

#[test]
fn modelled_cost_matches_engine_accounting() {
    // Validate a 3-level candidate (with a rack-tier outermost level, so
    // all three link accounts are exercised) and check the closed-form
    // planner cost against the engine's per-run accounting.
    let mut cand = Candidate::with_default_links(vec![2, 4, 16], vec![2, 4, 8]).unwrap();
    *cand.links.last_mut().unwrap() = hier_avg::topology::LinkClass::RackFabric;
    let c = ctx(16);
    let v = planner::validate(&cand, &c, "quickstart", CollectiveKind::Simulated).unwrap();

    assert!(v.total_steps > 0);
    assert!(v.measured_comm_seconds > 0.0);
    let rel = v.delta_seconds.abs() / v.measured_comm_seconds.max(1e-30);
    assert!(
        rel < 1e-9,
        "modelled {} vs measured {} (rel {rel})",
        v.modelled_comm_seconds,
        v.measured_comm_seconds
    );
    // Byte accounting is integer arithmetic on both sides: exact.
    assert_eq!(v.modelled_comm_bytes, v.measured_comm_bytes);
    // Per-level parity as well.
    assert_eq!(v.modelled_level_seconds.len(), v.measured_level_seconds.len());
    for (l, (m, e)) in
        v.modelled_level_seconds.iter().zip(&v.measured_level_seconds).enumerate()
    {
        let rel = (m - e).abs() / e.abs().max(1e-30);
        assert!(rel < 1e-9 || (*m == 0.0 && *e == 0.0), "level {l}: {m} vs {e}");
    }
}

#[test]
fn modelled_cost_matches_engine_for_non_default_strategy() {
    // The validation run must be charged with the sweep's strategy, not
    // the config default (Ring) — otherwise the delta is spurious.
    let cand = Candidate::with_default_links(vec![4, 16], vec![2, 8]).unwrap();
    let c = ScoreCtx::for_model(
        "quickstart",
        16,
        20_000,
        ReduceStrategy::Naive,
        CostModel::default(),
    )
    .unwrap();
    let v = planner::validate(&cand, &c, "quickstart", CollectiveKind::Simulated).unwrap();
    assert!(v.measured_comm_seconds > 0.0);
    let rel = v.delta_seconds.abs() / v.measured_comm_seconds;
    assert!(rel < 1e-9, "naive-strategy delta: {rel}");
    assert_eq!(v.modelled_comm_bytes, v.measured_comm_bytes);
}

#[test]
fn validation_is_deterministic() {
    let cand = Candidate::with_default_links(vec![4, 16], vec![2, 8]).unwrap();
    let c = ctx(16);
    let a = planner::validate(&cand, &c, "quickstart", CollectiveKind::Simulated).unwrap();
    let b = planner::validate(&cand, &c, "quickstart", CollectiveKind::Simulated).unwrap();
    assert_eq!(a.measured_comm_seconds.to_bits(), b.measured_comm_seconds.to_bits());
    assert_eq!(a.final_train_loss.to_bits(), b.final_train_loss.to_bits());
    assert_eq!(a.total_steps, b.total_steps);
}

// ---------------------------------------------------------------------------
// Report schema
// ---------------------------------------------------------------------------

#[test]
fn sweep_report_schema_and_roundtrip() {
    let p = 16usize;
    let space = SweepSpace::new(p).unwrap();
    let c = ctx(p);
    let ranked = planner::rank(&space, &c).unwrap();
    let validations =
        planner::validate_top(&ranked, &c, "quickstart", 1, CollectiveKind::Simulated).unwrap();
    assert_eq!(validations.len(), 1);

    let dir = std::env::temp_dir().join("hier_avg_planner_test");
    let path = dir.join(format!("SWEEP_{p}.json"));
    report::write_sweep(&path, &space, &c, "quickstart", &ranked, &validations).unwrap();
    let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();

    assert_eq!(parsed.req("p").unwrap().as_usize().unwrap(), p);
    assert_eq!(parsed.req("model").unwrap().as_str().unwrap(), "quickstart");
    assert_eq!(parsed.req("horizon_steps").unwrap().as_usize().unwrap(), 20_000);
    assert!(parsed.req("k2_cap_condition_35").unwrap().as_usize().unwrap() >= 1);
    parsed.req("space").unwrap().req("k1_grid").unwrap().usize_arr().unwrap();

    let cands = parsed.req("candidates").unwrap().as_arr().unwrap();
    assert!(cands.len() >= 20);
    for (i, cand) in cands.iter().enumerate() {
        assert_eq!(cand.req("rank").unwrap().as_usize().unwrap(), i);
        let levels = cand.req("levels").unwrap().usize_arr().unwrap();
        let ks = cand.req("ks").unwrap().usize_arr().unwrap();
        let links = cand.req("links").unwrap().as_arr().unwrap();
        assert_eq!(levels.len(), ks.len());
        assert_eq!(levels.len(), links.len());
        assert_eq!(*levels.last().unwrap(), p);
        let score = cand.req("score").unwrap();
        for key in ["time_to_target", "comm_seconds", "compute_seconds", "bound"] {
            assert!(score.req(key).unwrap().as_f64().unwrap().is_finite(), "{key}");
        }
        score.req("condition_35").unwrap().as_bool().unwrap();
        let cost_levels = cand.req("cost_levels").unwrap().as_arr().unwrap();
        assert_eq!(cost_levels.len(), levels.len());
        // Only the validated prefix carries a validation block.
        assert_eq!(cand.get("validation").is_some(), i < 1, "candidate {i}");
    }
    let v = cands[0].req("validation").unwrap();
    assert!(v.req("total_steps").unwrap().as_usize().unwrap() > 0);
    let delta = v.req("delta_seconds").unwrap().as_f64().unwrap();
    let measured = v.req("measured_comm_seconds").unwrap().as_f64().unwrap();
    assert!(delta.abs() <= 1e-9 * measured.max(1.0), "delta {delta} measured {measured}");
}
