//! The heap/calendar event core vs the scan reference: bit-identity
//! across random topologies × heterogeneity specs, plus the large-P
//! timeline-only smoke the new core exists for.
//!
//! `ScanEventModel` (rust/src/sim/scan.rs) is the executable
//! specification — the legacy O(P)-per-step implementation kept
//! verbatim.  These tests drive both models over randomized shapes,
//! schedules, and het/straggler regimes and require the heap core's
//! timeline to reproduce the reference **bit for bit**: same clocks,
//! same busy/blocked/idle vectors, same stall attribution, same spike
//! counts.  Any intentional semantic change must be made to the
//! reference first; a diff here is a fast-path regression by definition.

use hier_avg::algorithms::{HierSchedule, StaticPolicy};
use hier_avg::sim::{
    drive_timeline, drive_timeline_policy, replay_timeline, replay_timeline_stats,
    EventCalendar, EventModel, ExecBreakdown, ExecModel, FaultPlan, FaultSpec, HetSpec,
    ScanEventModel,
};
use hier_avg::topology::HierTopology;
use hier_avg::util::rng::Pcg32;

fn assert_bitwise_eq(a: &ExecBreakdown, b: &ExecBreakdown, ctx: &str) {
    assert_eq!(a.model, b.model, "{ctx}: model name");
    assert_eq!(
        a.makespan_seconds.to_bits(),
        b.makespan_seconds.to_bits(),
        "{ctx}: makespan {} vs {}",
        a.makespan_seconds,
        b.makespan_seconds
    );
    assert_eq!(a.straggler_events, b.straggler_events, "{ctx}: straggler_events");
    for (name, xa, xb) in [
        ("busy", &a.busy_seconds, &b.busy_seconds),
        ("blocked", &a.blocked_seconds, &b.blocked_seconds),
        ("idle", &a.idle_seconds, &b.idle_seconds),
        ("level_stall", &a.level_stall_seconds, &b.level_stall_seconds),
        ("lost", &a.lost_seconds, &b.lost_seconds),
    ] {
        assert_eq!(xa.len(), xb.len(), "{ctx}: {name} length");
        for (j, (x, y)) in xa.iter().zip(xb.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: {name}[{j}] {x} vs {y}");
        }
    }
}

/// A random divisor chain over a random P, innermost first, last = P.
fn random_chain(rng: &mut Pcg32) -> Vec<usize> {
    let ps = [8usize, 12, 16, 24, 32, 48, 64];
    let p = ps[rng.next_below(ps.len() as u32) as usize];
    let n_levels = 2 + rng.next_below(3) as usize; // 2..=4
    let mut sizes = vec![p];
    for _ in 1..n_levels {
        let inner = sizes[0];
        let divs: Vec<usize> = (1..inner).filter(|d| inner % d == 0).collect();
        if divs.is_empty() {
            break;
        }
        sizes.insert(0, divs[rng.next_below(divs.len() as u32) as usize]);
    }
    sizes
}

/// A random non-decreasing interval chain for `n` levels.
fn random_intervals(rng: &mut Pcg32, n: usize) -> Vec<u64> {
    let mut ks = Vec::with_capacity(n);
    let mut k = 1 + rng.next_below(4) as u64;
    for _ in 0..n {
        ks.push(k);
        k += rng.next_below(9) as u64; // non-decreasing, not necessarily divisible
    }
    ks
}

#[test]
fn heap_core_matches_scan_reference_bitwise() {
    let mut rng = Pcg32::seeded(0xE7E_47);
    let hets = [0.0, 0.3, 1.1];
    let probs = [0.0, 0.05, 0.3];
    for case in 0..40 {
        let sizes = random_chain(&mut rng);
        let topo = HierTopology::new(sizes.clone()).unwrap();
        let ks = random_intervals(&mut rng, topo.n_levels());
        let sched = HierSchedule::new(ks.clone()).unwrap();
        let spec = HetSpec {
            het: hets[rng.next_below(3) as usize],
            straggler_prob: probs[rng.next_below(3) as usize],
            straggler_mult: 3.0,
            seed: 100 + case as u64,
        };
        let horizon = 50 + rng.next_below(251) as u64;
        let secs: Vec<f64> = (0..topo.n_levels()).map(|l| 1e-4 * (l + 1) as f64).collect();
        let ctx = format!(
            "case {case}: sizes={sizes:?} ks={ks:?} het={} prob={} horizon={horizon}",
            spec.het, spec.straggler_prob
        );

        let mut scan = ScanEventModel::new(topo.p(), topo.n_levels(), 1e-3, &spec);
        drive_timeline(&mut scan, &topo, &sched, horizon, &secs);
        let mut heap = EventModel::new(topo.p(), topo.n_levels(), 1e-3, &spec);
        drive_timeline(&mut heap, &topo, &sched, horizon, &secs);
        assert_eq!(scan.now().to_bits(), heap.now().to_bits(), "{ctx}: now()");
        assert_bitwise_eq(&scan.breakdown(), &heap.breakdown(), &ctx);

        // The per-step policy driver must agree with the calendar driver
        // on both models (same op sequence, batched differently).
        let mut heap2 = EventModel::new(topo.p(), topo.n_levels(), 1e-3, &spec);
        let mut policy = StaticPolicy::new();
        drive_timeline_policy(&mut heap2, &topo, &mut policy, &sched, horizon, &secs);
        assert_bitwise_eq(&scan.breakdown(), &heap2.breakdown(), &ctx);
    }
}

#[test]
fn heap_core_matches_scan_reference_under_faults() {
    // The elastic layer must not split the two cores: with an armed fault
    // plan, the heap model's timeline — lost-time ledger included — and
    // its membership-event counts reproduce the scan reference bit for
    // bit across random shapes and regimes.
    let mut rng = Pcg32::seeded(0xFA_17);
    for case in 0..20 {
        let sizes = random_chain(&mut rng);
        let topo = HierTopology::new(sizes.clone()).unwrap();
        let ks = random_intervals(&mut rng, topo.n_levels());
        let sched = HierSchedule::new(ks.clone()).unwrap();
        let spec = HetSpec {
            het: 0.4,
            straggler_prob: 0.05,
            straggler_mult: 3.0,
            seed: 500 + case as u64,
        };
        let plan = FaultPlan::Sampled(FaultSpec { prob: 0.02, mttr: 6 });
        let horizon = 50 + rng.next_below(151) as u64;
        let secs: Vec<f64> = (0..topo.n_levels()).map(|l| 1e-4 * (l + 1) as f64).collect();
        let ctx = format!("case {case}: sizes={sizes:?} ks={ks:?} horizon={horizon}");

        let mut scan = ScanEventModel::new(topo.p(), topo.n_levels(), 1e-3, &spec);
        scan.install_faults(spec.seed, &plan);
        drive_timeline(&mut scan, &topo, &sched, horizon, &secs);
        let mut heap = EventModel::new(topo.p(), topo.n_levels(), 1e-3, &spec);
        heap.install_faults(spec.seed, &plan);
        drive_timeline(&mut heap, &topo, &sched, horizon, &secs);
        assert_eq!(scan.now().to_bits(), heap.now().to_bits(), "{ctx}: now()");
        assert_bitwise_eq(&scan.breakdown(), &heap.breakdown(), &ctx);
        assert_eq!(scan.fault_counts(), heap.fault_counts(), "{ctx}: fault counts");

        // ... and through the per-step policy driver too.
        let mut heap2 = EventModel::new(topo.p(), topo.n_levels(), 1e-3, &spec);
        heap2.install_faults(spec.seed, &plan);
        let mut policy = StaticPolicy::new();
        drive_timeline_policy(&mut heap2, &topo, &mut policy, &sched, horizon, &secs);
        assert_bitwise_eq(&scan.breakdown(), &heap2.breakdown(), &ctx);
    }
}

#[test]
fn fault_timeline_conserves_per_learner_time() {
    // Every learner's ledger must close: with zero collective costs,
    // busy + blocked + lost + idle = makespan for each learner — a
    // preempted step's time lands in exactly one bucket (lost), never
    // two and never none.
    let topo = HierTopology::new(vec![4, 32]).unwrap();
    let sched = HierSchedule::new(vec![2, 8]).unwrap();
    let spec = HetSpec { het: 0.5, straggler_prob: 0.1, straggler_mult: 4.0, seed: 21 };
    let plan = FaultPlan::Sampled(FaultSpec { prob: 0.03, mttr: 8 });
    let secs = [0.0, 0.0];
    for scan_core in [true, false] {
        let b = if scan_core {
            let mut m = ScanEventModel::new(32, 2, 1e-3, &spec);
            m.install_faults(spec.seed, &plan);
            drive_timeline(&mut m, &topo, &sched, 256, &secs);
            let (pre, re) = m.fault_counts();
            assert!(pre > 0 && re > 0, "fault stream drew nothing");
            m.breakdown()
        } else {
            let mut m = EventModel::new(32, 2, 1e-3, &spec);
            m.install_faults(spec.seed, &plan);
            drive_timeline(&mut m, &topo, &sched, 256, &secs);
            m.breakdown()
        };
        let lost_total: f64 = b.lost_seconds.iter().sum();
        assert!(lost_total > 0.0, "no time was ever lost to preemption");
        for j in 0..32 {
            let total = b.busy_seconds[j]
                + b.blocked_seconds[j]
                + b.lost_seconds[j]
                + b.idle_seconds[j];
            assert!(
                (total - b.makespan_seconds).abs() <= 1e-9 * b.makespan_seconds,
                "learner {j} (scan={scan_core}): busy {} + blocked {} + lost {} + idle {} \
                 != makespan {}",
                b.busy_seconds[j],
                b.blocked_seconds[j],
                b.lost_seconds[j],
                b.idle_seconds[j],
                b.makespan_seconds
            );
        }
    }
}

#[test]
fn mid_run_queries_do_not_perturb_the_timeline() {
    // now()/clock_of flush lazily-advanced learners; interleaving them
    // mid-run must leave the final timeline bit-identical to the
    // reference (flushing is a pure reordering of the same FLOPs).
    let topo = HierTopology::new(vec![4, 16]).unwrap();
    let sched = HierSchedule::new(vec![2, 8]).unwrap();
    let spec = HetSpec { het: 0.6, straggler_prob: 0.2, straggler_mult: 4.0, seed: 77 };
    let secs = [1e-4, 1e-3];

    let mut scan = ScanEventModel::new(16, 2, 1e-3, &spec);
    let mut heap = EventModel::new(16, 2, 1e-3, &spec);
    for t in 1..=96u64 {
        scan.on_step();
        heap.on_step();
        if t % 7 == 0 {
            assert_eq!(scan.now().to_bits(), heap.now().to_bits(), "t={t}");
            // Flushing is idempotent: a second query sees the same clock.
            let c1 = heap.clock_of(3);
            let c2 = heap.clock_of(3);
            assert_eq!(c1.to_bits(), c2.to_bits());
        }
        if let Some(level) = sched.event_after(t) {
            let a = scan.on_reduction(&topo, level, secs[level]);
            let b = heap.on_reduction(&topo, level, secs[level]);
            assert_eq!(a.to_bits(), b.to_bits(), "stall at t={t}");
        }
        if t % 13 == 0 {
            assert_bitwise_eq(&scan.breakdown(), &heap.breakdown(), &format!("t={t}"));
        }
    }
    assert_bitwise_eq(&scan.breakdown(), &heap.breakdown(), "final");
}

#[test]
fn calendar_fires_exactly_the_schedule_events() {
    let mut rng = Pcg32::seeded(31);
    for case in 0..20 {
        let n = 2 + rng.next_below(3) as usize;
        let ks = random_intervals(&mut rng, n);
        let sched = HierSchedule::new(ks.clone()).unwrap();
        let horizon = 500u64;
        let mut cal = EventCalendar::new(&sched, horizon);
        let mut fired = 0u64;
        for t in 1..=horizon {
            if let Some(level) = sched.event_after(t) {
                assert_eq!(cal.next(), Some((t, level)), "case {case} ks={ks:?} t={t}");
                fired += 1;
            }
        }
        assert_eq!(cal.next(), None, "case {case}: calendar overran the horizon");
        let counts: u64 = sched.reduction_counts(horizon).iter().sum();
        assert_eq!(fired, counts, "case {case}");
    }
}

#[test]
fn timeline_only_smoke_at_p_100k() {
    // The acceptance smoke: a 100,000-learner straggler replay must be
    // feasible, monotone in virtual time, and conserve per-learner time:
    // busy + blocked + comm + idle = makespan for every learner.
    let p = 100_000;
    let topo = HierTopology::new(vec![100, p]).unwrap();
    let sched = HierSchedule::new(vec![4, 16]).unwrap();
    let spec = HetSpec { het: 0.5, straggler_prob: 0.05, straggler_mult: 4.0, seed: 9 };
    let horizon = 48u64;
    let secs = [1e-4, 1e-3];

    // Event times are monotone: now() never decreases across barrier
    // nodes (virtual time only moves forward).
    let mut model = EventModel::new(p, 2, 1e-3, &spec);
    let mut cal = EventCalendar::new(&sched, horizon);
    let mut done = 0u64;
    let mut prev = 0.0f64;
    while let Some((t, level)) = cal.next() {
        model.on_steps(t - done);
        done = t;
        model.on_reduction(&topo, level, secs[level]);
        let now = model.now();
        assert!(now >= prev, "virtual time went backwards: {now} < {prev} at t={t}");
        prev = now;
    }
    model.on_steps(horizon - done);
    assert!(model.now() >= prev);

    // Conservation: every learner pays every fired barrier's collective
    // cost (it is a member of exactly one group per level), so
    // clock_j = busy_j + blocked_j + comm and makespan = clock_j + idle_j.
    let b = replay_timeline(&topo, &sched, horizon, 1e-3, &secs, &spec);
    let counts = sched.reduction_counts(horizon);
    let comm: f64 = counts.iter().zip(secs.iter()).map(|(&c, &s)| c as f64 * s).sum();
    assert_eq!(b.busy_seconds.len(), p);
    assert!(b.makespan_seconds.is_finite() && b.makespan_seconds > 0.0);
    assert!(b.straggler_events > 0);
    for j in 0..p {
        let total = b.busy_seconds[j] + b.blocked_seconds[j] + comm + b.idle_seconds[j];
        assert!(
            (total - b.makespan_seconds).abs() <= 1e-9 * b.makespan_seconds,
            "learner {j}: busy {} + blocked {} + comm {comm} + idle {} != makespan {}",
            b.busy_seconds[j],
            b.blocked_seconds[j],
            b.idle_seconds[j],
            b.makespan_seconds
        );
    }

    // The no-allocation stats path agrees with the full breakdown.
    let s = replay_timeline_stats(&topo, &sched, horizon, 1e-3, &secs, &spec);
    assert_eq!(s.makespan_seconds.to_bits(), b.makespan_seconds.to_bits());
    assert_eq!(s.straggler_events, b.straggler_events);
    assert_eq!(s.steps, horizon);
    assert_eq!(s.reduction_events, counts.iter().sum::<u64>());
}

#[test]
fn homogeneous_heap_core_is_order_of_magnitude_cheap_at_p_1m() {
    // A 2-level million-learner homogeneous replay rides the shared step
    // node: no O(P) state, and the answer matches the closed form.
    let p = 1 << 20;
    let topo = HierTopology::new(vec![1 << 10, p]).unwrap();
    let sched = HierSchedule::new(vec![8, 64]).unwrap();
    let horizon = 4096u64;
    let secs = [1e-4, 1e-3];
    let s = replay_timeline_stats(&topo, &sched, horizon, 1e-3, &secs, &HetSpec::default());
    let counts = sched.reduction_counts(horizon);
    let expect = horizon as f64 * 1e-3
        + counts[0] as f64 * secs[0]
        + counts[1] as f64 * secs[1];
    assert!(
        (s.makespan_seconds - expect).abs() <= 1e-9 * expect,
        "{} vs {expect}",
        s.makespan_seconds
    );
    assert_eq!(s.blocked_seconds_total, 0.0);
    assert_eq!(s.straggler_events, 0);
    assert_eq!(s.reduction_events, counts.iter().sum::<u64>());
}
