//! Property tests as seeded randomized sweeps (proptest is unavailable in
//! this offline environment; each property draws hundreds of random cases
//! from a fixed-seed PCG and asserts an invariant, printing the failing
//! case on violation).

use hier_avg::algorithms::{HierAvgSchedule, ReduceEvent};
use hier_avg::comm::{CommStats, CostModel, ReduceStrategy, Reducer};
use hier_avg::optimizer::{LrSchedule, Sgd};
use hier_avg::params::{ParamArena, ParamEntry, ParamLayout};
use hier_avg::theory::{self, BoundParams};
use hier_avg::topology::{LinkClass, Topology};
use hier_avg::util::json::Json;
use hier_avg::util::rng::Pcg32;

const STRATEGIES: [ReduceStrategy; 3] =
    [ReduceStrategy::Naive, ReduceStrategy::Tree, ReduceStrategy::Ring];
const LINKS: [LinkClass; 3] =
    [LinkClass::IntraNode, LinkClass::InterNode, LinkClass::RackFabric];

/// A random bound regime; returns None when the draw violates δ ∈ (0,1).
fn random_bound_params(rng: &mut Pcg32) -> Option<BoundParams> {
    let p = BoundParams {
        l: 0.5 + rng.next_f64() * 20.0,
        m: 0.1 + rng.next_f64() * 5.0,
        mg: 0.1 + rng.next_f64() * 3.0,
        f_gap: 0.01 + rng.next_f64() * 100.0,
        gamma: 1e-4 + rng.next_f64() * 5e-3,
        b: 8.0 + rng.next_below(120) as f64,
        p: 2.0 + rng.next_below(126) as f64,
        delta_grad: rng.next_f64() * 3.0,
    };
    p.validate().ok().map(|_| p)
}

const CASES: usize = 300;

#[test]
fn prop_schedule_counts_equal_event_scan() {
    let mut rng = Pcg32::seeded(0xA11CE);
    for case in 0..CASES {
        let k1 = 1 + rng.next_below(16) as u64;
        let k2 = k1 + rng.next_below(48) as u64;
        let t = 1 + rng.next_below(2000) as u64;
        let s = HierAvgSchedule::new(k1, k2).unwrap();
        let (mut g, mut l) = (0u64, 0u64);
        for i in 1..=t {
            match s.event_after(i) {
                ReduceEvent::Global => g += 1,
                ReduceEvent::Local => l += 1,
                ReduceEvent::None => {}
            }
        }
        assert_eq!(
            s.reduction_counts(t),
            (g, l),
            "case {case}: k1={k1} k2={k2} t={t}"
        );
    }
}

#[test]
fn prop_schedule_global_subsumes_local() {
    // No step may be both; at multiples of k2 the event is always Global.
    let mut rng = Pcg32::seeded(0xBEE);
    for _ in 0..CASES {
        let k1 = 1 + rng.next_below(12) as u64;
        let k2 = k1 * (1 + rng.next_below(8) as u64);
        let s = HierAvgSchedule::new(k1, k2).unwrap();
        for t in 1..=(4 * k2) {
            let e = s.event_after(t);
            if t % k2 == 0 {
                assert_eq!(e, ReduceEvent::Global);
            } else if t % k1 == 0 {
                assert_eq!(e, ReduceEvent::Local);
            } else {
                assert_eq!(e, ReduceEvent::None);
            }
        }
    }
}

#[test]
fn prop_topology_partition() {
    // cluster_of is consistent with cluster_members and covers 0..P once.
    let mut rng = Pcg32::seeded(0x70_70);
    for _ in 0..CASES {
        let s = 1 + rng.next_below(8) as usize;
        let clusters = 1 + rng.next_below(16) as usize;
        let p = s * clusters;
        let topo = Topology::new(p, s).unwrap();
        let mut count = vec![0usize; p];
        for c in 0..topo.n_clusters() {
            for j in topo.cluster_members(c) {
                assert_eq!(topo.cluster_of(j), c);
                count[j] += 1;
            }
        }
        assert!(count.iter().all(|&c| c == 1));
    }
}

#[test]
fn prop_group_average_preserves_global_sum() {
    // Averaging any cluster preserves the global mean of all replicas
    // (conservation: reduction must neither create nor destroy mass).
    let mut rng = Pcg32::seeded(0x5EED5);
    for case in 0..100 {
        let s = 1 + rng.next_below(4) as usize;
        let clusters = 1 + rng.next_below(4) as usize;
        let p = s * clusters;
        let n = 1 + rng.next_below(64) as usize;
        let topo = Topology::new(p, s).unwrap();
        let rows: Vec<Vec<f32>> =
            (0..p).map(|_| (0..n).map(|_| rng.next_normal()).collect()).collect();
        let mut replicas = ParamArena::from_rows(&rows);
        let before: f64 = replicas.as_slice().iter().map(|&v| v as f64).sum();
        let mut red = Reducer::new(CostModel::default(), ReduceStrategy::Ring, n);
        red.local_average(replicas.view_mut(), &topo);
        let after: f64 = replicas.as_slice().iter().map(|&v| v as f64).sum();
        assert!(
            (before - after).abs() < 1e-3 * (1.0 + before.abs()),
            "case {case}: {before} -> {after}"
        );
    }
}

#[test]
fn prop_averaging_is_idempotent() {
    let mut rng = Pcg32::seeded(0x1D3);
    for _ in 0..100 {
        let p = 2 + rng.next_below(8) as usize;
        let n = 1 + rng.next_below(32) as usize;
        let topo = Topology::new(p, p).unwrap();
        let rows: Vec<Vec<f32>> =
            (0..p).map(|_| (0..n).map(|_| rng.next_normal()).collect()).collect();
        let mut replicas = ParamArena::from_rows(&rows);
        let mut red = Reducer::new(CostModel::default(), ReduceStrategy::Tree, n);
        red.global_average(replicas.view_mut(), &topo);
        let snapshot = replicas.clone();
        red.global_average(replicas.view_mut(), &topo);
        // Idempotent up to one rounding step: the mean is computed as
        // sum * (1/n), and n·a * (1/n) can be one ulp off a for n not a
        // power of two.
        for (r, s) in replicas.as_slice().iter().zip(snapshot.as_slice().iter()) {
            assert!(
                (r - s).abs() <= 2.0 * f32::EPSILON * s.abs().max(1.0),
                "{r} vs {s}"
            );
        }
    }
}

#[test]
fn prop_layout_roundtrip() {
    // Random layouts: slices tile the flat buffer exactly.
    let mut rng = Pcg32::seeded(0x1A_0);
    for _ in 0..CASES {
        let n_tensors = 1 + rng.next_below(8) as usize;
        let mut entries = Vec::new();
        let mut offset = 0usize;
        for i in 0..n_tensors {
            let r = 1 + rng.next_below(8) as usize;
            let c = 1 + rng.next_below(8) as usize;
            entries.push(ParamEntry {
                name: format!("t{i}"),
                shape: vec![r, c],
                offset,
                size: r * c,
            });
            offset += r * c;
        }
        let layout = ParamLayout::from_entries(entries).unwrap();
        let flat: Vec<f32> = (0..layout.total).map(|i| i as f32).collect();
        let mut covered = 0usize;
        for i in 0..layout.n_tensors() {
            let s = layout.slice(i, &flat);
            assert_eq!(s[0] as usize, covered);
            covered += s.len();
        }
        assert_eq!(covered, layout.total);
    }
}

#[test]
fn prop_sgd_momentum_zero_equals_plain() {
    let mut rng = Pcg32::seeded(0x0517);
    for _ in 0..CASES {
        let n = 1 + rng.next_below(32) as usize;
        let mut w1: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
        let mut w2 = w1.clone();
        let g: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
        let lr = rng.next_f32() * 0.1;
        Sgd::plain().apply(&mut w1, &g, lr);
        Sgd::new(0.0, 0.0, n).apply(&mut w2, &g, lr);
        assert_eq!(w1, w2);
    }
}

#[test]
fn prop_lr_schedules_positive_and_bounded() {
    let mut rng = Pcg32::seeded(0x77);
    for _ in 0..CASES {
        let peak = 0.001 + rng.next_f32();
        let total = 1 + rng.next_below(300) as usize;
        let scheds = [
            LrSchedule::Constant(peak),
            LrSchedule::StepDecay { initial: peak, milestones: vec![(total / 2, peak * 0.1)] },
            LrSchedule::Cosine { initial: peak, final_lr: peak * 0.01, total_epochs: total },
            LrSchedule::WarmupCosine {
                peak,
                final_lr: peak * 0.01,
                warmup_epochs: (total / 10).max(1),
                total_epochs: total,
            },
        ];
        for s in &scheds {
            for e in 0..total {
                let lr = s.lr_at(e);
                assert!(lr > 0.0 && lr <= peak * 1.0001, "{s:?} at {e}: {lr}");
            }
        }
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn gen(rng: &mut Pcg32, depth: usize) -> Json {
        match if depth == 0 { rng.next_below(4) } else { rng.next_below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_f32() < 0.5),
            2 => Json::Num((rng.next_f64() * 2e6).round() / 1e3 - 1e3),
            3 => Json::Str(
                (0..rng.next_below(12))
                    .map(|_| {
                        let c = rng.next_below(96) as u8 + 32;
                        c as char
                    })
                    .collect(),
            ),
            4 => Json::Arr((0..rng.next_below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.next_below(5) {
                    m.insert(format!("k{i}"), gen(rng, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    let mut rng = Pcg32::seeded(0x150);
    for case in 0..CASES {
        let v = gen(&mut rng, 3);
        let s = v.to_string();
        let p = Json::parse(&s).unwrap_or_else(|e| panic!("case {case}: {e}\n{s}"));
        assert_eq!(p, v, "case {case}: {s}");
        let pretty = Json::parse(&v.pretty()).unwrap();
        assert_eq!(pretty, v);
    }
}

#[test]
fn prop_thm35_monotonicity_random_regimes() {
    // Theorem 3.5 must hold for any valid parameter regime, not just the
    // defaults: bound ↑ in K1 (K1 ≥ 2), ↓ in S.
    let mut rng = Pcg32::seeded(0x7434);
    let mut tested = 0;
    for _ in 0..CASES {
        let p = BoundParams {
            l: 0.5 + rng.next_f64() * 20.0,
            m: 0.1 + rng.next_f64() * 5.0,
            mg: 1.0,
            f_gap: 0.01 + rng.next_f64() * 100.0,
            gamma: 1e-4 + rng.next_f64() * 5e-3,
            b: 8.0 + rng.next_below(120) as f64,
            p: 2.0 + rng.next_below(126) as f64,
            delta_grad: rng.next_f64() * 3.0,
        };
        if p.validate().is_err() {
            continue;
        }
        tested += 1;
        let k2 = 8 + 4 * rng.next_below(16) as u64;
        let n = 10 + rng.next_below(500) as u64;
        // monotone in K1
        let mut prev = theory::thm32_bound(&p, n, 2, k2, 4);
        let mut k1 = 4;
        while k1 <= k2 {
            let cur = theory::thm32_bound(&p, n, k1, k2, 4);
            assert!(cur >= prev - 1e-12, "k1={k1} k2={k2} {cur} < {prev}");
            prev = cur;
            k1 *= 2;
        }
        // monotone in S
        let mut prev = theory::thm32_bound(&p, n, 4, k2, 1);
        for s in [2u64, 4, 8, 16] {
            let cur = theory::thm32_bound(&p, n, 4, k2, s);
            assert!(cur <= prev + 1e-12, "s={s}");
            prev = cur;
        }
    }
    assert!(tested > CASES / 4, "too few valid regimes: {tested}");
}

#[test]
fn prop_thm36_holds_in_paper_range() {
    let mut rng = Pcg32::seeded(0x7436);
    let mut tested = 0;
    for _ in 0..CASES {
        let p = BoundParams {
            l: 0.5 + rng.next_f64() * 10.0,
            gamma: 1e-4 + rng.next_f64() * 3e-3,
            f_gap: 0.1 + rng.next_f64() * 50.0,
            ..BoundParams::default()
        };
        if p.validate().is_err() {
            continue;
        }
        tested += 1;
        let k = 2 + rng.next_below(63) as u64;
        let a = rng.next_f64() * 0.6;
        let t = 1000 + rng.next_below(100_000) as u64;
        let (h, x) = theory::thm36_pair(&p, t, k, a);
        assert!(h < x, "k={k} a={a:.3}: hier={h} kavg={x}");
    }
    assert!(tested > CASES / 4);
}

#[test]
fn prop_allreduce_seconds_monotone_in_bytes_and_participants() {
    // The planner's ranking depends on it: more bytes or more learners
    // never make a modelled allreduce cheaper, for every strategy on every
    // link tier.
    let mut rng = Pcg32::seeded(0xC0_57_01);
    let cm = CostModel::default();
    for case in 0..CASES {
        let n1 = 1 + rng.next_below(128) as usize;
        let n2 = n1 + rng.next_below(128) as usize;
        let b1 = 1 + rng.next_below(1 << 24) as usize;
        let b2 = b1 + rng.next_below(1 << 24) as usize;
        for link in LINKS {
            for s in STRATEGIES {
                let base = cm.allreduce_seconds(n1, b1, link, s);
                assert!(
                    base <= cm.allreduce_seconds(n2, b1, link, s) + 1e-15,
                    "case {case}: participants {n1}->{n2} {link:?} {s:?}"
                );
                assert!(
                    base <= cm.allreduce_seconds(n1, b2, link, s) + 1e-15,
                    "case {case}: bytes {b1}->{b2} {link:?} {s:?}"
                );
                assert!(base >= 0.0 && base.is_finite());
            }
        }
    }
}

#[test]
fn prop_link_tier_ordering() {
    // Identical payloads: rack-fabric cost ≥ inter-node ≥ intra-node (the
    // calibrated default tiers; strict once a reduction actually happens).
    let mut rng = Pcg32::seeded(0xC0_57_02);
    let cm = CostModel::default();
    for case in 0..CASES {
        let n = 2 + rng.next_below(255) as usize;
        let bytes = 1 + rng.next_below(1 << 26) as usize;
        for s in STRATEGIES {
            let intra = cm.allreduce_seconds(n, bytes, LinkClass::IntraNode, s);
            let inter = cm.allreduce_seconds(n, bytes, LinkClass::InterNode, s);
            let rack = cm.allreduce_seconds(n, bytes, LinkClass::RackFabric, s);
            assert!(
                intra < inter && inter < rack,
                "case {case}: n={n} bytes={bytes} {s:?}: {intra} / {inter} / {rack}"
            );
        }
    }
}

#[test]
fn prop_commstats_merge_associative() {
    // (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c).  Counts are u64 (exact); the seconds are
    // drawn as integer multiples of 2⁻⁸ far below 2⁵³ so every f64 sum is
    // exact and associativity holds bit-for-bit, not just approximately.
    let mut rng = Pcg32::seeded(0xC0_57_03);
    let draw = |rng: &mut Pcg32| CommStats {
        local_reductions: rng.next_below(1 << 20) as u64,
        global_reductions: rng.next_below(1 << 20) as u64,
        rack_reductions: rng.next_below(1 << 20) as u64,
        local_bytes: rng.next_below(1 << 30) as u64,
        global_bytes: rng.next_below(1 << 30) as u64,
        rack_bytes: rng.next_below(1 << 30) as u64,
        local_seconds: rng.next_below(1 << 24) as f64 / 256.0,
        global_seconds: rng.next_below(1 << 24) as f64 / 256.0,
        rack_seconds: rng.next_below(1 << 24) as f64 / 256.0,
    };
    for case in 0..CASES {
        let (a, b, c) = (draw(&mut rng), draw(&mut rng), draw(&mut rng));
        // left: (a ⊕ b) ⊕ c
        let mut left = a;
        left.merge(&b);
        left.merge(&c);
        // right: a ⊕ (b ⊕ c)
        let mut bc = b;
        bc.merge(&c);
        let mut right = a;
        right.merge(&bc);
        assert_eq!(left, right, "case {case}");
    }
}

#[test]
fn prop_optimal_k2_satisfies_condition_35() {
    // The planner invariant: with the K2 search capped at
    // max_k2_condition_35, the argmin the planner schedules always sits in
    // the regime where Theorem 3.4's bound is a guarantee.
    let mut rng = Pcg32::seeded(0x7434_35);
    let mut tested = 0;
    for case in 0..CASES {
        let Some(p) = random_bound_params(&mut rng) else { continue };
        let cap = theory::max_k2_condition_35(&p, 4096)
            .expect("validated params always admit K2 = 1");
        assert!(p.condition_35(cap), "case {case}: cap {cap} itself infeasible");
        if cap < 4096 {
            assert!(!p.condition_35(cap + 1), "case {case}: cap {cap} not maximal");
        }
        let k1 = 1 + rng.next_below(8) as u64;
        if k1 > cap {
            continue;
        }
        tested += 1;
        let t = 100 + rng.next_below(1_000_000) as u64;
        let s = 1 + rng.next_below(16) as u64;
        let k2 = theory::optimal_k2(&p, t, k1, s, cap);
        assert!(
            p.condition_35(k2),
            "case {case}: optimal K2 = {k2} violates (3.5) under cap {cap}"
        );
        assert!(k2 >= k1 && k2 <= cap && k2 % k1 == 0, "case {case}: k1={k1} k2={k2}");
    }
    assert!(tested > CASES / 4, "too few valid regimes: {tested}");
}

#[test]
fn prop_phi_monotone_in_k2() {
    // Φ(K1, K2, S) is non-decreasing in K2 on K2 ≥ K1 (and non-negative
    // there) — the planner's bound ordering over outer intervals relies on
    // the deviation term never rewarding a longer interval.
    let mut rng = Pcg32::seeded(0x7434_99);
    for case in 0..CASES {
        let k1 = 1 + rng.next_below(32) as u64;
        let s = 1 + rng.next_below(32) as u64;
        let mut prev = theory::phi(k1, k1, s);
        assert!(prev >= 0.0, "case {case}: phi({k1},{k1},{s}) = {prev} < 0");
        for dk in 1..=64u64 {
            let cur = theory::phi(k1, k1 + dk, s);
            assert!(
                cur >= prev - 1e-9,
                "case {case}: phi({k1},{},{s}) = {cur} < {prev}",
                k1 + dk
            );
            prev = cur;
        }
    }
}

#[test]
fn prop_thm34_bound_finite_positive() {
    // The planner divides and sorts by this bound: over any valid random
    // regime and any (T, K1 ≤ K2, S) grid point it must be a finite,
    // strictly positive number — never NaN, ∞, zero, or negative.
    let mut rng = Pcg32::seeded(0x7434_34);
    let mut tested = 0;
    for case in 0..CASES {
        let Some(p) = random_bound_params(&mut rng) else { continue };
        tested += 1;
        let t = 1 + rng.next_below(1_000_000) as u64;
        let k1 = 1 + rng.next_below(64) as u64;
        let k2 = k1 + rng.next_below(256) as u64;
        let s = 1 + rng.next_below(64) as u64;
        let b = theory::thm34_budget_bound(&p, t, k1, k2, s);
        assert!(
            b.is_finite() && b > 0.0,
            "case {case}: B(t={t}, k1={k1}, k2={k2}, s={s}) = {b}"
        );
    }
    assert!(tested > CASES / 4, "too few valid regimes: {tested}");
}

#[test]
fn prop_compressed_bytes_monotone_in_ratio_and_capped_at_dense() {
    // The planner ranks compressed twins by these numbers: a larger keep
    // ratio never shrinks the wire payload, and no spec ever prices above
    // its dense equivalent — for every strategy, link tier, participant
    // count, and parameter count, on both the byte and the seconds axes.
    use hier_avg::comm::Compression;
    let mut rng = Pcg32::seeded(0xC0_4412);
    let cm = CostModel::default();
    for case in 0..CASES {
        let n = 2 + rng.next_below(255) as usize;
        let n_params = 1 + rng.next_below(1 << 20) as usize;
        let r1 = (1 + rng.next_below(499)) as f64 / 1000.0; // 0.001 .. 0.499
        let r2 = (r1 + (1 + rng.next_below(500)) as f64 / 1000.0).min(1.0); // r1 < r2 <= 1
        let dense = Compression::None;
        let sparse_lo = Compression::TopK { ratio: r1, ef: true };
        let sparse_hi = Compression::TopK { ratio: r2, ef: true };
        assert!(sparse_lo.payload_bytes(n_params) <= sparse_hi.payload_bytes(n_params));
        assert_eq!(dense.payload_bytes(n_params), n_params * 4);
        for comp in [
            sparse_lo,
            sparse_hi,
            Compression::RandK { ratio: r1, ef: true },
            Compression::Q8 { ef: true },
            Compression::Q4 { ef: false },
        ] {
            assert!(comp.payload_bytes(n_params) <= n_params * 4, "case {case}: {comp:?}");
            for s in STRATEGIES {
                let cb = cm.compressed_allreduce_bytes(n, n_params, comp, s);
                let db = cm.compressed_allreduce_bytes(n, n_params, dense, s);
                assert!(cb <= db, "case {case}: {comp:?} {s:?}: {cb} > dense {db}");
                assert_eq!(db, cm.allreduce_bytes(n, n_params * 4, s));
                for link in LINKS {
                    let cs = cm.compressed_allreduce_seconds(n, n_params, comp, link, s);
                    let ds = cm.compressed_allreduce_seconds(n, n_params, dense, link, s);
                    assert!(cs.is_finite() && cs >= 0.0);
                    assert!(cs <= ds + 1e-15, "case {case}: {comp:?} {link:?} {s:?}");
                }
            }
            let lo = cm.compressed_allreduce_bytes(n, n_params, sparse_lo, ReduceStrategy::Ring);
            let hi = cm.compressed_allreduce_bytes(n, n_params, sparse_hi, ReduceStrategy::Ring);
            assert!(lo <= hi, "case {case}: ratio {r1} priced above {r2}");
        }
    }
}

#[test]
fn prop_cost_model_strategy_orderings() {
    // For any payload/participants: ring ≤ naive on bytes-dominated
    // payloads; tree ≤ naive always on rounds.
    let mut rng = Pcg32::seeded(0xC057);
    let cm = CostModel::default();
    for _ in 0..CASES {
        let n = 2 + rng.next_below(255) as usize;
        let bytes = 1 << (10 + rng.next_below(18)); // 1 KiB .. 128 MiB
        for link in
            [hier_avg::topology::LinkClass::IntraNode, hier_avg::topology::LinkClass::InterNode]
        {
            let naive = cm.allreduce_seconds(n, bytes, link, ReduceStrategy::Naive);
            let tree = cm.allreduce_seconds(n, bytes, link, ReduceStrategy::Tree);
            let ring = cm.allreduce_seconds(n, bytes, link, ReduceStrategy::Ring);
            assert!(tree <= naive + 1e-12);
            assert!(ring <= naive + 1e-12);
            assert!(naive >= 0.0 && tree >= 0.0 && ring >= 0.0);
        }
    }
}
