//! SIMD ≡ scalar-reference equality pins for the vectorized compute core
//! (ISSUE 9 tentpole): the three matmul orientations, the elementwise
//! comm kernels, and the quantizer — exact (`assert_eq!` on f32, i.e.
//! bitwise for non-NaN) across odd shapes, unaligned sub-slice offsets,
//! and both dispatch paths.
//!
//! The dispatched entry points (`matmul`, `add_assign`, …) follow
//! `util::simd::simd_enabled()`, so on an AVX2 host this suite pins the
//! vector path against the scalar reference; under `HIER_FORCE_SCALAR=1`
//! (the CI dual-dispatch job) it pins scalar ≡ scalar trivially while the
//! direct-AVX2 tests below keep exercising the vector code regardless of
//! the override.  See DESIGN.md §Performance for the summation-order
//! contract these tests enforce.

use hier_avg::native::linalg;
use hier_avg::util::rng::Pcg32;
use hier_avg::util::simd;

fn noisy(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| rng.next_normal()).collect()
}

/// Odd shapes straddling every tile boundary: scalar MR=4/NR=8/NR_T=4,
/// SIMD NR_S=16 and the Bᵀ pack width 8, and the KC=256 k-block.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (3, 5, 7),
    (4, 16, 16),
    (5, 17, 31),
    (8, 64, 48),
    (13, 300, 33),
    (2, 257, 19),
    (37, 23, 129),
    (6, 40, 272),
];

#[test]
fn matmul_simd_equals_scalar_reference() {
    for &(n, fi, fo) in SHAPES {
        let a = noisy(n * fi, 0x11 + n as u64);
        let b = noisy(fi * fo, 0x22 + fo as u64);
        let mut c = vec![0.0f32; n * fo];
        let mut cs = vec![0.0f32; n * fo];
        linalg::matmul(&a, &b, &mut c, n, fi, fo);
        linalg::matmul_scalar(&a, &b, &mut cs, n, fi, fo);
        assert_eq!(c, cs, "matmul shape ({n},{fi},{fo})");
    }
}

#[test]
fn matmul_at_b_simd_equals_scalar_reference() {
    for &(n, fi, fo) in SHAPES {
        let a = noisy(n * fi, 0x33 + n as u64);
        let b = noisy(n * fo, 0x44 + fo as u64);
        let mut c = vec![0.0f32; fi * fo];
        let mut cs = vec![0.0f32; fi * fo];
        linalg::matmul_at_b(&a, &b, &mut c, n, fi, fo);
        linalg::matmul_at_b_scalar(&a, &b, &mut cs, n, fi, fo);
        assert_eq!(c, cs, "at_b shape ({n},{fi},{fo})");
    }
}

#[test]
fn matmul_a_bt_simd_equals_scalar_reference() {
    for &(n, fi, fo) in SHAPES {
        let a = noisy(n * fo, 0x55 + n as u64);
        let b = noisy(fi * fo, 0x66 + fi as u64);
        let mut c = vec![0.0f32; n * fi];
        let mut cs = vec![0.0f32; n * fi];
        linalg::matmul_a_bt(&a, &b, &mut c, n, fo, fi);
        linalg::matmul_a_bt_scalar(&a, &b, &mut cs, n, fo, fi);
        assert_eq!(c, cs, "a_bt shape ({n},{fo},{fi})");
    }
}

#[test]
fn unaligned_operand_offsets_stay_exact() {
    // Sub-slice the operand buffers at every offset 0..8 so the SIMD
    // loads/stores hit all misalignments relative to a 32-byte boundary.
    let (n, fi, fo) = (5, 21, 35);
    let abuf = noisy(n * fi + 8, 0x77);
    let bbuf = noisy(fi * fo + 8, 0x88);
    for off in 0..8usize {
        let a = &abuf[off..off + n * fi];
        let b = &bbuf[off..off + fi * fo];
        let mut c = vec![0.0f32; n * fo];
        let mut cs = vec![0.0f32; n * fo];
        linalg::matmul(a, b, &mut c, n, fi, fo);
        linalg::matmul_scalar(a, b, &mut cs, n, fi, fo);
        assert_eq!(c, cs, "matmul offset {off}");
        let mut c = vec![0.0f32; n * fi];
        let mut cs = vec![0.0f32; n * fi];
        // (reinterpret the same buffers in the Bᵀ orientation)
        let a2 = &abuf[off..off + n * fi];
        let b2 = &bbuf[off..off + fi * fi];
        linalg::matmul_a_bt(a2, b2, &mut c, n, fi, fi);
        linalg::matmul_a_bt_scalar(a2, b2, &mut cs, n, fi, fi);
        assert_eq!(c, cs, "a_bt offset {off}");
    }
}

#[test]
fn elementwise_kernels_match_scalar_across_offsets() {
    let x = noisy(300, 0x99);
    let base = noisy(300, 0xAA);
    for off in 0..9usize {
        let mut a = base.clone();
        let mut b = base.clone();
        simd::add_assign(&mut a[off..], &x[off..]);
        simd::add_assign_scalar(&mut b[off..], &x[off..]);
        assert_eq!(a, b, "add_assign offset {off}");

        let mut a = base.clone();
        let mut b = base.clone();
        simd::scale_assign(&mut a[off..], 0.125);
        simd::scale_assign_scalar(&mut b[off..], 0.125);
        assert_eq!(a, b, "scale_assign offset {off}");

        assert_eq!(
            simd::max_abs(&x[off..]).to_bits(),
            simd::max_abs_scalar(&x[off..]).to_bits(),
            "max_abs offset {off}"
        );
    }
}

#[test]
fn quantizer_matches_scalar_on_adversarial_values() {
    // Exact half-step multiples are where vroundps's half-to-even would
    // diverge from f32::round's half-away-from-zero; the emulation and
    // the scalar path must agree bitwise on them.
    let mut acc: Vec<f32> = noisy(1000, 0xBB);
    for (i, v) in acc.iter_mut().enumerate().take(64) {
        *v = (i as f32 - 32.0) * 0.5; // …, -0.5, 0.0, 0.5, 1.0, 1.5, …
    }
    for levels in [127.0f32, 7.0] {
        let max_abs = simd::max_abs_scalar(&acc);
        let scale = max_abs / levels;
        let inv = 1.0 / scale;
        let (mut t1, mut e1) = (vec![0.0f32; acc.len()], vec![0.0f32; acc.len()]);
        let (mut t2, mut e2) = (vec![0.0f32; acc.len()], vec![0.0f32; acc.len()]);
        simd::quantize_split(&acc, &mut t1, &mut e1, inv, scale, levels);
        simd::quantize_split_scalar(&acc, &mut t2, &mut e2, inv, scale, levels);
        assert_eq!(t1, t2, "levels {levels}");
        assert_eq!(e1, e2, "levels {levels}");
    }
}

#[test]
fn dispatch_is_consistent_within_a_process() {
    // Whatever path simd_enabled() picks, repeated calls give identical
    // bits — determinism does not depend on the dispatch decision because
    // both paths share one summation order.
    let (n, fi, fo) = (9, 48, 37);
    let a = noisy(n * fi, 0xCC);
    let b = noisy(fi * fo, 0xDD);
    let mut c1 = vec![0.0f32; n * fo];
    let mut c2 = vec![0.0f32; n * fo];
    linalg::matmul(&a, &b, &mut c1, n, fi, fo);
    linalg::matmul(&a, &b, &mut c2, n, fi, fo);
    assert_eq!(c1, c2);
    // And the dispatch decision itself is well-formed: forced-scalar mode
    // reports SIMD off.
    if simd::force_scalar() {
        assert!(!simd::simd_enabled());
    }
}
