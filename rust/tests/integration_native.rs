//! Integration tests over the native backend: full training runs,
//! algorithm identities, and schedule/metric consistency — no artifacts
//! needed.

use hier_avg::config::{BackendKind, RunConfig};
use hier_avg::coordinator::Trainer;
use hier_avg::data::{ClassifyData, MixtureSpec};
use hier_avg::metrics::RunRecord;
use hier_avg::native::NativeMlp;
use hier_avg::optimizer::LrSchedule;
use hier_avg::util::rng::Pcg32;

/// Run the native trainer on a self-contained mixture task.
fn run_native(cfg: &RunConfig, dims: &[usize], batch: usize) -> RunRecord {
    let backend = NativeMlp::new(dims, batch, 64).unwrap();
    let data = ClassifyData::generate(MixtureSpec {
        dim: dims[0],
        classes: *dims.last().unwrap(),
        train_n: cfg.train_n,
        test_n: cfg.test_n,
        radius: cfg.radius,
        noise: cfg.noise,
        subclusters: 1,
        label_noise: 0.0,
        seed: cfg.seed ^ 0x5eed,
    });
    let mut rng = Pcg32::seeded(cfg.seed);
    let init = backend.init(&mut rng);
    Trainer::new(cfg, Box::new(backend), Box::new(data), init).unwrap().run().unwrap()
}

fn base_cfg() -> RunConfig {
    let mut cfg = RunConfig::defaults("native");
    cfg.backend = BackendKind::Native;
    cfg.p = 8;
    cfg.s = 4;
    cfg.k1 = 2;
    cfg.k2 = 8;
    cfg.epochs = 6;
    cfg.train_n = 2048;
    cfg.test_n = 512;
    cfg.lr = LrSchedule::Constant(0.1);
    cfg.noise = 0.8;
    cfg
}

const DIMS: &[usize] = &[24, 48, 6];

#[test]
fn hier_avg_trains_to_high_accuracy() {
    let cfg = base_cfg();
    let rec = run_native(&cfg, DIMS, 8);
    let last = rec.epochs.last().unwrap();
    assert!(last.test_acc > 0.8, "test_acc = {}", last.test_acc);
    assert!(last.train_loss < rec.epochs[0].train_loss * 0.7);
}

#[test]
fn kavg_equals_hier_with_degenerate_locals() {
    // K-AVG(K) == Hier-AVG(K1=K, K2=K) == Hier-AVG(S=1, K2=K): all three
    // must produce bit-identical trajectories for the same seed.
    let mut a = base_cfg();
    a.k1 = 8;
    a.k2 = 8;
    a.s = 4; // local avg coincides with global, so S irrelevant
    let mut b = base_cfg();
    b.k1 = 2;
    b.k2 = 8;
    b.s = 1; // S=1: local averaging is a no-op
    let mut c = base_cfg();
    c.k1 = 8;
    c.k2 = 8;
    c.s = 1;
    let ra = run_native(&a, DIMS, 8);
    let rb = run_native(&b, DIMS, 8);
    let rc = run_native(&c, DIMS, 8);
    for ((x, y), z) in ra.epochs.iter().zip(&rb.epochs).zip(&rc.epochs) {
        assert_eq!(x.train_loss, y.train_loss);
        assert_eq!(y.train_loss, z.train_loss);
        assert_eq!(x.test_acc, z.test_acc);
    }
}

#[test]
fn local_averaging_changes_trajectory() {
    // ... but with K1 < K2 and S > 1 the trajectory must differ from K-AVG.
    let hier = base_cfg();
    let mut kavg = base_cfg();
    kavg.k1 = 8;
    let rh = run_native(&hier, DIMS, 8);
    let rk = run_native(&kavg, DIMS, 8);
    assert_ne!(rh.epochs.last().unwrap().train_loss, rk.epochs.last().unwrap().train_loss);
    // and it must add local reductions
    assert!(rh.comm.local_reductions > 0);
    assert_eq!(rk.comm.local_reductions, 0);
}

#[test]
fn sync_sgd_is_hier_with_k_one() {
    let mut cfg = base_cfg();
    cfg.k1 = 1;
    cfg.k2 = 1;
    cfg.s = 1;
    let rec = run_native(&cfg, DIMS, 8);
    assert_eq!(rec.comm.global_reductions, rec.total_steps);
    assert!(rec.epochs.last().unwrap().test_acc > 0.8);
}

#[test]
fn larger_s_lowers_training_loss_here() {
    // Theorem 3.5 shape check on real training: S=4 should not train
    // slower than S=2 (same K1/K2/P, same data), measured at the tail.
    let mut s2 = base_cfg();
    s2.s = 2;
    s2.epochs = 8;
    let mut s4 = base_cfg();
    s4.s = 4;
    s4.epochs = 8;
    let r2 = run_native(&s2, DIMS, 8);
    let r4 = run_native(&s4, DIMS, 8);
    let tail = |r: &RunRecord| {
        let n = r.epochs.len();
        r.epochs[n - 2..].iter().map(|e| e.train_loss).sum::<f64>() / 2.0
    };
    // Allow a small tolerance: this is a stochastic ordering, not exact.
    assert!(
        tail(&r4) <= tail(&r2) * 1.10,
        "S=4 tail loss {} vs S=2 {}",
        tail(&r4),
        tail(&r2)
    );
}

#[test]
fn momentum_and_schedules_run() {
    let mut cfg = base_cfg();
    cfg.momentum = 0.9;
    cfg.weight_decay = 1e-4;
    cfg.lr = LrSchedule::WarmupCosine {
        peak: 0.05,
        final_lr: 0.001,
        warmup_epochs: 2,
        total_epochs: 6,
    };
    let rec = run_native(&cfg, DIMS, 8);
    assert!(rec.epochs.last().unwrap().test_acc > 0.7);
}

#[test]
fn eval_every_skips_intermediate_epochs() {
    let mut cfg = base_cfg();
    cfg.eval_every = 3;
    let rec = run_native(&cfg, DIMS, 8);
    assert!(rec.epochs[1].test_acc.is_nan());
    assert!(rec.epochs[0].test_acc.is_finite());
    assert!(rec.epochs.last().unwrap().test_acc.is_finite());
}

#[test]
fn comm_accounting_scales_with_frequency() {
    // Halving K2 should double global reductions (same steps).
    let mut hi = base_cfg();
    hi.k1 = 4;
    hi.k2 = 16;
    let mut lo = base_cfg();
    lo.k1 = 4;
    lo.k2 = 8;
    let rh = run_native(&hi, DIMS, 8);
    let rl = run_native(&lo, DIMS, 8);
    assert_eq!(rh.total_steps, rl.total_steps);
    assert_eq!(rl.comm.global_reductions, 2 * rh.comm.global_reductions);
    assert!(rl.comm.global_seconds > rh.comm.global_seconds);
}

#[test]
fn run_record_serializes() {
    let cfg = base_cfg();
    let rec = run_native(&cfg, DIMS, 8);
    let dir = std::env::temp_dir().join("hier_avg_itest");
    rec.write_json(&dir.join("r.json")).unwrap();
    rec.write_csv(&dir.join("r.csv")).unwrap();
    let parsed =
        hier_avg::util::json::Json::parse(&std::fs::read_to_string(dir.join("r.json")).unwrap())
            .unwrap();
    assert_eq!(
        parsed.req("epochs").unwrap().as_arr().unwrap().len(),
        rec.epochs.len()
    );
}

#[test]
fn warm_start_resumes_from_checkpoint() {
    // Train, save the averaged params, warm-start a second run: its first
    // epoch must start from a much better loss than a cold run's.
    let dir = std::env::temp_dir().join("hier_avg_warm_test");
    let ckpt = dir.join("warm.bin");

    let mut cfg = base_cfg();
    cfg.model = "quickstart".into();
    cfg.keep_final_params = true;
    let rec = hier_avg::driver::run(&cfg).unwrap();
    let params = rec.final_params.clone().unwrap();
    let layout = hier_avg::driver::layout_for(&cfg).unwrap();
    hier_avg::checkpoint::save(&ckpt, &cfg.model, &layout, &params).unwrap();

    let mut warm = cfg.clone();
    warm.keep_final_params = false;
    warm.init_params = Some(ckpt.to_string_lossy().to_string());
    warm.epochs = 2;
    let wrec = hier_avg::driver::run(&warm).unwrap();

    let mut cold = warm.clone();
    cold.init_params = None;
    let crec = hier_avg::driver::run(&cold).unwrap();

    assert!(
        wrec.epochs[0].train_loss < crec.epochs[0].train_loss * 0.7,
        "warm {} vs cold {}",
        wrec.epochs[0].train_loss,
        crec.epochs[0].train_loss
    );
}

#[test]
fn adaptive_k2_switches_frequency() {
    let mut cfg = base_cfg();
    cfg.k1 = 2;
    cfg.k2 = 16;
    cfg.epochs = 6;
    cfg.k2_schedule = vec![(3, 4)];
    let rec = run_native(&cfg, DIMS, 8);
    // steps/epoch = train_n / (P*B) = 2048 / 64 = 32.
    let spe = (cfg.train_n / (cfg.p * 8)) as u64;
    assert_eq!(rec.total_steps, spe * 6);
    // Epochs 0-2 at K2=16, epochs 3-5 at K2=4.
    let expect = 3 * spe / 16 + 3 * spe / 4;
    assert_eq!(rec.comm.global_reductions, expect);
}

#[test]
fn asgd_slower_than_hier_in_modelled_time() {
    // At the same sample budget ASGD's serialized server messages cost more
    // modelled time than Hier-AVG's amortized reductions.
    use hier_avg::algorithms::asgd::AsgdTrainer;
    let cfg = base_cfg();
    let hier = run_native(&cfg, DIMS, 8);

    let backend = NativeMlp::new(DIMS, 8, 64).unwrap();
    let data = ClassifyData::generate(MixtureSpec {
        dim: DIMS[0],
        classes: *DIMS.last().unwrap(),
        train_n: cfg.train_n,
        test_n: cfg.test_n,
        radius: cfg.radius,
        noise: cfg.noise,
        subclusters: 1,
        label_noise: 0.0,
        seed: cfg.seed ^ 0x5eed,
    });
    let mut rng = Pcg32::seeded(cfg.seed);
    let init = backend.init(&mut rng);
    let mut asgd = AsgdTrainer::new(&cfg, Box::new(backend), Box::new(data), init, 1).unwrap();
    let arec = asgd.run().unwrap();
    assert!(
        arec.comm.global_seconds > hier.comm.total_seconds(),
        "asgd comm {} vs hier {}",
        arec.comm.global_seconds,
        hier.comm.total_seconds()
    );
    // both still learn
    assert!(arec.epochs.last().unwrap().test_acc > 0.7);
}
