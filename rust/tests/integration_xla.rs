//! Integration tests over the XLA/PJRT path: artifact loading, gradient
//! parity against the native backend, the Pallas group-average artifact,
//! and short end-to-end training runs.
//!
//! Feature-gating audit (kept true by CI's build matrix):
//!
//! - **without `--features xla`** (the default): `runtime::xla_backend`
//!   resolves to the stub in `runtime/xla_stub.rs`, whose public surface
//!   (XlaRuntime / XlaBackend / XlaGroupAvg / XlaSgdUpdate) mirrors the
//!   real module, so this file compiles unchanged and every test skips
//!   cleanly — either at the manifest probe below or at the stub's
//!   fail-fast constructor (pinned by `stub_runtime_fails_fast…`).
//! - **with `--features xla`**: the real `runtime/xla_backend.rs`
//!   compiles against the `xla` dependency (the type-checking shim in
//!   `third_party/xla-rs`, or the vendored PJRT bindings when present).
//!   CI runs this leg build-only (`cargo test --features xla --no-run`).
//!
//! Either way, these tests require `make artifacts` to do real work; they
//! skip (with a message) when the artifacts directory is absent so
//! `cargo test` stays green on a fresh checkout.

use hier_avg::backend::{StepBackend, StepOut};
use hier_avg::config::{BackendKind, RunConfig};
use hier_avg::data::{BatchBuf, ClassifyData, DataSource, MixtureSpec};
use hier_avg::driver;
use hier_avg::native::NativeMlp;
use hier_avg::optimizer::LrSchedule;
use hier_avg::params::{ParamArena, Rows, RowsMut};
use hier_avg::runtime::{Manifest, XlaBackend};
use hier_avg::runtime::xla_backend::XlaGroupAvg;
use hier_avg::util::rng::Pcg32;

fn manifest() -> Option<Manifest> {
    match Manifest::load_default() {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping XLA test (artifacts missing): {e}");
            None
        }
    }
}

/// Without the `xla` feature the stub runtime must fail fast at
/// construction with the vendoring hint — never pretend to execute.
#[cfg(not(feature = "xla"))]
#[test]
fn stub_runtime_fails_fast_with_vendoring_hint() {
    let err = hier_avg::runtime::XlaRuntime::cpu().unwrap_err().to_string();
    assert!(err.contains("xla"), "unhelpful stub error: {err}");
    let err2 = hier_avg::runtime::XlaRuntime::cpu_shared().unwrap_err().to_string();
    assert!(err2.contains("xla"), "unhelpful stub error: {err2}");
}

#[test]
fn quickstart_trains_with_xla() {
    if manifest().is_none() {
        return;
    }
    let mut cfg = RunConfig::defaults("quickstart");
    cfg.backend = BackendKind::Xla;
    cfg.p = 4;
    cfg.s = 2;
    cfg.k1 = 2;
    cfg.k2 = 4;
    cfg.epochs = 3;
    cfg.train_n = 2048;
    cfg.test_n = 256;
    cfg.lr = LrSchedule::Constant(0.1);
    // Easy single-cluster mixture: this test checks the XLA plumbing, not
    // optimization difficulty.
    cfg.subclusters = 1;
    cfg.label_noise = 0.0;
    let rec = driver::run(&cfg).unwrap();
    let last = rec.epochs.last().unwrap();
    assert!(last.test_acc > 0.8, "test_acc = {}", last.test_acc);
    assert!(last.train_loss < rec.epochs[0].train_loss);
}

/// The core cross-validation: the AOT-lowered JAX+Pallas train step and the
/// hand-written Rust backprop must produce the same gradients on the same
/// parameters and batch.
#[test]
fn xla_and_native_gradients_agree() {
    let Some(m) = manifest() else { return };
    let entry = m.model("quickstart").unwrap().clone();
    let (dims, batch, eval_b) = driver::model_dims("quickstart").unwrap();
    let mut xla = XlaBackend::load(&m, "quickstart", 1).unwrap();
    let mut native = NativeMlp::new(dims, batch, eval_b).unwrap();

    // Shared params: the artifact init blob, remapped into each layout.
    let blob = m.load_init(&entry).unwrap();
    let native_init = driver::remap_by_name(&entry.layout, &blob, native.layout()).unwrap();

    // Shared batch.
    let data = ClassifyData::generate(MixtureSpec {
        dim: dims[0],
        classes: *dims.last().unwrap(),
        train_n: 256,
        test_n: 64,
        radius: 1.0,
        noise: 1.0,
        subclusters: 1,
        label_noise: 0.0,
        seed: 7,
    });
    let mut rng = Pcg32::seeded(3);
    let mut buf = BatchBuf::default();
    data.fill_train(&mut rng, batch, &mut buf);

    // XLA grads (manifest layout).
    let mut gx = vec![0.0f32; entry.layout.total];
    let mut outs = vec![StepOut::default()];
    xla.grads(Rows::single(&blob), &buf, RowsMut::single(&mut gx), &mut outs).unwrap();

    // Native grads (native layout).
    let mut gn = vec![0.0f32; native.n_params()];
    let mut nouts = vec![StepOut::default()];
    native
        .grads(Rows::single(&native_init), &buf, RowsMut::single(&mut gn), &mut nouts)
        .unwrap();

    // Compare in the native layout.
    let gx_native = driver::remap_by_name(&entry.layout, &gx, native.layout()).unwrap();
    let mut max_abs = 0.0f32;
    let mut max_rel = 0.0f32;
    for (a, b) in gx_native.iter().zip(&gn) {
        let abs = (a - b).abs();
        max_abs = max_abs.max(abs);
        max_rel = max_rel.max(abs / (a.abs().max(b.abs()).max(1e-3)));
    }
    assert!(
        max_abs < 2e-4 && max_rel < 2e-2,
        "gradient mismatch: max_abs={max_abs} max_rel={max_rel}"
    );
    assert!(
        (outs[0].loss - nouts[0].loss).abs() < 1e-4,
        "loss mismatch: xla={} native={}",
        outs[0].loss,
        nouts[0].loss
    );
    assert_eq!(outs[0].ncorrect, nouts[0].ncorrect);
}

#[test]
fn xla_eval_matches_native() {
    let Some(m) = manifest() else { return };
    let entry = m.model("quickstart").unwrap().clone();
    let (dims, batch, eval_b) = driver::model_dims("quickstart").unwrap();
    let mut xla = XlaBackend::load(&m, "quickstart", 1).unwrap();
    let mut native = NativeMlp::new(dims, batch, eval_b).unwrap();
    let blob = m.load_init(&entry).unwrap();
    let native_init = driver::remap_by_name(&entry.layout, &blob, native.layout()).unwrap();

    let data = ClassifyData::generate(MixtureSpec {
        dim: dims[0],
        classes: *dims.last().unwrap(),
        train_n: 256,
        test_n: eval_b,
        radius: 1.0,
        noise: 1.0,
        subclusters: 1,
        label_noise: 0.0,
        seed: 11,
    });
    let mut buf = BatchBuf::default();
    assert_eq!(data.fill_eval(0, eval_b, &mut buf), eval_b);
    let (lx, cx) = xla.eval_batch_stats(&blob, &buf, eval_b).unwrap();
    let (ln, cn) = native.eval_batch_stats(&native_init, &buf, eval_b).unwrap();
    assert!((lx - ln).abs() / ln.abs().max(1.0) < 1e-3, "xla={lx} native={ln}");
    assert_eq!(cx, cn);
}

#[test]
fn stacked_variant_matches_singleton() {
    // The P=4 stacked artifact must produce the same per-learner grads as
    // four singleton dispatches.
    let Some(m) = manifest() else { return };
    let entry = m.model("quickstart").unwrap().clone();
    let batch = entry.batch;
    let mut xla1 = XlaBackend::load(&m, "quickstart", 1).unwrap();
    let mut xla4 = XlaBackend::load(&m, "quickstart", 4).unwrap();
    assert_eq!(xla4.train_p(), 4);

    let blob = m.load_init(&entry).unwrap();
    // Give each learner slightly different params.
    let mut replicas = ParamArena::replicated(&blob, 4);
    for j in 0..4 {
        for v in replicas.row_mut(j).iter_mut() {
            *v += 0.01 * (j as f32);
        }
    }
    let data = ClassifyData::generate(MixtureSpec {
        dim: entry.input_dim().unwrap(),
        classes: entry.classes().unwrap(),
        train_n: 512,
        test_n: 64,
        radius: 1.0,
        noise: 1.0,
        subclusters: 1,
        label_noise: 0.0,
        seed: 5,
    });
    let mut rng = Pcg32::seeded(9);
    let mut buf = BatchBuf::default();
    for _ in 0..4 {
        data.fill_train(&mut rng, batch, &mut buf);
    }

    let mut g4 = ParamArena::zeroed(4, entry.layout.total);
    let mut o4 = vec![StepOut::default(); 4];
    xla4.grads(replicas.view(), &buf, g4.view_mut(), &mut o4).unwrap();

    let mut g1 = ParamArena::zeroed(4, entry.layout.total);
    let mut o1 = vec![StepOut::default(); 4];
    // Chunked through the P=1 artifact (XlaBackend loops 4 chunks).
    xla1.grads(replicas.view(), &buf, g1.view_mut(), &mut o1).unwrap();

    for j in 0..4 {
        assert!((o4[j].loss - o1[j].loss).abs() < 1e-5, "learner {j} loss");
        let max_abs = g4
            .row(j)
            .iter()
            .zip(g1.row(j))
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_abs < 1e-4, "learner {j}: max grad diff {max_abs}");
    }
}

#[test]
fn pallas_group_avg_artifact_matches_native_mean() {
    let Some(m) = manifest() else { return };
    let mut avg = match XlaGroupAvg::load(&m, 4) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let mut rng = Pcg32::seeded(21);
    let n = 10_000usize; // not a multiple of the chunk: exercises the tail
    let shards: Vec<Vec<f32>> =
        (0..4).map(|_| (0..n).map(|_| rng.next_normal()).collect()).collect();
    let refs: Vec<&[f32]> = shards.iter().map(|s| s.as_slice()).collect();
    let mut out = vec![0.0f32; n];
    avg.average(&refs, &mut out).unwrap();
    for i in 0..n {
        let expect = (shards[0][i] + shards[1][i] + shards[2][i] + shards[3][i]) / 4.0;
        assert!((out[i] - expect).abs() < 1e-5, "i={i}");
    }
}

#[test]
fn lm_artifact_runs_and_learns_a_little() {
    let Some(m) = manifest() else { return };
    if m.model("lm_small").is_err() {
        return;
    }
    let mut cfg = RunConfig::defaults("lm_small");
    cfg.backend = BackendKind::Xla;
    cfg.p = 4;
    cfg.s = 2;
    cfg.k1 = 2;
    cfg.k2 = 4;
    cfg.epochs = 2;
    cfg.train_n = 512; // 16 steps/epoch at P=4, B=8
    cfg.test_n = 64;
    cfg.lr = LrSchedule::Constant(0.3);
    cfg.record_steps = true;
    let rec = driver::run(&cfg).unwrap();
    let first = rec.step_loss.first().copied().unwrap();
    let last = rec.epochs.last().unwrap();
    assert!(
        last.train_loss < first as f64,
        "LM loss should drop: first step {first}, last epoch {}",
        last.train_loss
    );
    // token-level accuracy should beat uniform chance (1/256)
    assert!(last.test_acc > 0.01, "acc = {}", last.test_acc);
}

#[test]
fn pallas_sgd_update_artifact_matches_native() {
    let Some(m) = manifest() else { return };
    let mut upd = match hier_avg::runtime::xla_backend::XlaSgdUpdate::load(&m) {
        Ok(u) => u,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let mut rng = Pcg32::seeded(33);
    let n = 9_000usize; // exercises the padded tail
    let mut w: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
    let g: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
    let mut expect = w.clone();
    hier_avg::optimizer::Sgd::plain().apply(&mut expect, &g, 0.05);
    upd.apply(&mut w, &g, 0.05).unwrap();
    for (a, b) in w.iter().zip(&expect) {
        assert!((a - b).abs() < 1e-6);
    }
}
