//! Golden-trace regression suite: the planner's validation runs, pinned
//! bit-for-bit.
//!
//! One small deterministic run per collective engine (simulated, sharded,
//! pooled) is serialized through `RunRecord::to_golden_json` (wall-clock
//! stripped, reduction trace included) and compared against the committed
//! JSON under `rust/tests/golden/`.  Any change to training numerics, the
//! schedule, the cost model, or the serialization shows up as a diff.
//! The `validation_event_*` set repeats the scenario under `--exec event`
//! (homogeneous — byte-equal to lockstep except the model name, which
//! `event_homogeneous_is_bit_identical_to_lockstep` enforces directly)
//! plus one heterogeneous straggler pin.
//!
//! Blessing: set `GOLDEN_BLESS=1` to regenerate the files (they are also
//! written automatically when missing, so a fresh checkout bootstraps
//! itself); commit the result.  CI additionally runs this suite twice
//! (bless, then verify) to prove run-to-run determinism on its own host.
//!
//! The configs come from `planner::validation_config` — the exact
//! scenario generator `sweep --validate-top` uses — so these goldens also
//! prove the planner's validation runs are identical across
//! `--collective simulated|sharded|pooled`.

use std::path::PathBuf;

use hier_avg::comm::CollectiveKind;
use hier_avg::metrics::RunRecord;
use hier_avg::planner::{self, Candidate};
use hier_avg::sim::ExecKind;
use hier_avg::util::json::Json;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden")
}

/// The fixed scenario all three goldens share: a 3-level hierarchy at
/// P = 8 so every tier (intra / inter) fires within the short run.
fn golden_candidate() -> Candidate {
    Candidate::with_default_links(vec![2, 4, 8], vec![2, 4, 8]).unwrap()
}

fn run_with(collective: CollectiveKind) -> RunRecord {
    run_with_exec(collective, ExecKind::Lockstep)
}

fn run_with_exec(collective: CollectiveKind, exec: ExecKind) -> RunRecord {
    let mut cfg =
        planner::validation_config(&golden_candidate(), "quickstart", collective).unwrap();
    cfg.exec = exec;
    cfg.validate().unwrap();
    planner::validation_record(&cfg).unwrap()
}

/// The heterogeneous scenario pinned by the straggler golden: the same
/// topology/schedule under the event model with a rate ramp + seeded
/// spikes.  Parameters must stay bit-identical to the homogeneous runs —
/// heterogeneity is a time model only.
fn run_straggler() -> RunRecord {
    let mut cfg = planner::validation_config(
        &golden_candidate(),
        "quickstart",
        CollectiveKind::Simulated,
    )
    .unwrap();
    cfg.exec = ExecKind::Event;
    cfg.het = 0.25;
    cfg.straggler_prob = 0.1;
    cfg.straggler_mult = 4.0;
    cfg.validate().unwrap();
    planner::validation_record(&cfg).unwrap()
}

/// The elastic scenario pinned by the faults golden: the straggler
/// scenario with the fault layer armed — seeded spot preemptions (hazard
/// 0.1 per live learner-step, repair after 4 virtual steps), survivor
/// reductions, checkpoint re-entries.  Every membership event and every
/// reweighted average is a pure function of the seeded timeline and must
/// stay byte-stable.
fn run_faults() -> RunRecord {
    let mut cfg = planner::validation_config(
        &golden_candidate(),
        "quickstart",
        CollectiveKind::Simulated,
    )
    .unwrap();
    cfg.exec = ExecKind::Event;
    cfg.het = 0.25;
    cfg.straggler_prob = 0.1;
    cfg.straggler_mult = 4.0;
    cfg.faults = Some(hier_avg::sim::parse_faults("0.1:4").unwrap());
    cfg.validate().unwrap();
    planner::validation_record(&cfg).unwrap()
}

/// The golden JSON with the execution-model *name* neutralized: the
/// determinism contract says a homogeneous event run matches lockstep on
/// every byte of the golden view except `exec.model` itself.
fn neutralize_exec_model(mut j: Json) -> Json {
    if let Json::Obj(ref mut root) = j {
        if let Some(Json::Obj(exec)) = root.get_mut("exec") {
            exec.insert("model".to_string(), Json::Str("-".to_string()));
        }
    }
    j
}

/// Compare `rec` against the committed golden `name`.json, blessing it
/// when missing or when `GOLDEN_BLESS=1`.  `GOLDEN_REQUIRE=1` turns a
/// missing golden into a hard failure instead of a bootstrap bless — the
/// knob CI uses to surface "the cross-commit pin is not in the tree yet"
/// rather than silently re-blessing forever.
fn check_golden(name: &str, rec: &RunRecord) {
    let dir = golden_dir();
    let path = dir.join(format!("{name}.json"));
    let actual = rec.to_golden_json().pretty() + "\n";
    let env_on = |k: &str| std::env::var(k).map(|v| v == "1").unwrap_or(false);
    let bless = env_on("GOLDEN_BLESS");
    if !bless && env_on("GOLDEN_REQUIRE") && !path.exists() {
        panic!(
            "golden trace {} is not committed (GOLDEN_REQUIRE=1): run \
             `GOLDEN_BLESS=1 cargo test --test golden_trace` and commit the file \
             (or download CI's golden-traces artifact)",
            path.display()
        );
    }
    if bless || !path.exists() {
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(&path, &actual).unwrap();
        eprintln!(
            "blessed golden trace {} — commit it to pin the behaviour",
            path.display()
        );
        return;
    }
    let stored = std::fs::read_to_string(&path).unwrap();
    let stored_json = Json::parse(&stored)
        .unwrap_or_else(|e| panic!("{} is not valid JSON: {e}", path.display()));
    let actual_json = Json::parse(&actual).unwrap();
    assert_eq!(
        stored_json,
        actual_json,
        "golden trace {name} drifted from {}.\nIf the change is intentional, regenerate with \
         `GOLDEN_BLESS=1 cargo test --test golden_trace` and commit the new file.",
        path.display()
    );
}

#[test]
fn golden_trace_simulated() {
    check_golden("validation_simulated", &run_with(CollectiveKind::Simulated));
}

#[test]
fn golden_trace_sharded() {
    check_golden("validation_sharded", &run_with(CollectiveKind::Sharded { threads: 3 }));
}

#[test]
fn golden_trace_pooled() {
    check_golden("validation_pooled", &run_with(CollectiveKind::Pooled { threads: 2 }));
}

#[test]
fn golden_trace_event_simulated() {
    check_golden(
        "validation_event_simulated",
        &run_with_exec(CollectiveKind::Simulated, ExecKind::Event),
    );
}

#[test]
fn golden_trace_event_sharded() {
    check_golden(
        "validation_event_sharded",
        &run_with_exec(CollectiveKind::Sharded { threads: 3 }, ExecKind::Event),
    );
}

#[test]
fn golden_trace_event_pooled() {
    check_golden(
        "validation_event_pooled",
        &run_with_exec(CollectiveKind::Pooled { threads: 2 }, ExecKind::Event),
    );
}

/// Pins the heterogeneous timeline itself: per-level stall attribution,
/// busy/blocked/idle breakdown, and straggler spikes are all seeded and
/// must stay byte-stable.
#[test]
fn golden_trace_event_straggler() {
    check_golden("validation_event_straggler", &run_straggler());
}

/// Pins the adaptive schedule controller end to end: the same straggler
/// scenario under `--schedule adaptive` — every widening decision, the
/// realized per-level counts, the interval trajectory, and the
/// serialized controller state are pure functions of the seeded timeline
/// and must stay byte-stable.
#[test]
fn golden_trace_adaptive_straggler() {
    let mut cfg = planner::validation_config(
        &golden_candidate(),
        "quickstart",
        CollectiveKind::Simulated,
    )
    .unwrap();
    cfg.schedule_policy =
        hier_avg::algorithms::PolicyKind::Adaptive { target: 0.05, gain: 1.0 };
    cfg.exec = ExecKind::Event;
    cfg.het = 0.25;
    cfg.straggler_prob = 0.1;
    cfg.straggler_mult = 4.0;
    cfg.validate().unwrap();
    let rec = planner::validation_record(&cfg).unwrap();
    assert_eq!(rec.schedule.as_ref().unwrap().policy, "adaptive:0.05");
    check_golden("validation_adaptive_straggler", &rec);
}

/// Pins the elastic-membership layer end to end: the preemption trace,
/// survivor-reduction parameter math, warm-sync re-entries, and the
/// faults accounting block must all stay byte-stable.
#[test]
fn golden_trace_faults_simulated() {
    check_golden("validation_faults_simulated", &run_faults());
}

/// The fault scenario genuinely exercises the elastic machinery — and
/// still trains: losses stay finite through every preemption and
/// recovery.
#[test]
fn fault_run_reports_membership_events() {
    let rec = run_faults();
    let f = rec.faults.as_ref().expect("fault-armed run must carry a faults block");
    assert!(f.preemptions > 0, "hazard 0.1 over the run fired no preemption");
    assert!(f.reentries > 0, "no learner recovered within the run");
    assert_eq!(f.checkpoint_restores, f.reentries, "every re-entry restores");
    assert!(f.survivor_reductions > 0, "no barrier ever degraded");
    assert!(f.lost_seconds > 0.0);
    assert!(f.membership_epoch >= f.preemptions.min(f.reentries));
    for e in &rec.epochs {
        assert!(e.train_loss.is_finite() && e.test_loss.is_finite(), "loss diverged");
    }
}

/// The fault layer's determinism contract: `--faults 0` arms the layer
/// (membership machinery installed, zero events drawn) and is
/// bit-identical to the plain event run on every golden byte except the
/// faults block itself — across all three collectives.
#[test]
fn zero_fault_run_is_bit_identical_to_plain_event() {
    for collective in [
        CollectiveKind::Simulated,
        CollectiveKind::Sharded { threads: 3 },
        CollectiveKind::Pooled { threads: 2 },
    ] {
        let plain = run_with_exec(collective, ExecKind::Event);
        let mut cfg =
            planner::validation_config(&golden_candidate(), "quickstart", collective)
                .unwrap();
        cfg.exec = ExecKind::Event;
        cfg.faults = Some(hier_avg::sim::parse_faults("0").unwrap());
        cfg.validate().unwrap();
        let mut armed = planner::validation_record(&cfg).unwrap();
        let f = armed.faults.take().expect("armed run must carry a faults block");
        assert_eq!(
            (f.preemptions, f.reentries, f.checkpoint_restores, f.migrations),
            (0, 0, 0, 0),
            "--faults 0 drew a membership event ({collective:?})"
        );
        assert_eq!(f.survivor_reductions, 0);
        assert_eq!(f.lost_seconds, 0.0);
        assert_eq!(f.membership_epoch, 0);
        // With the (all-zero) faults block stripped, every byte matches.
        assert_eq!(
            plain.to_golden_json().pretty(),
            armed.to_golden_json().pretty(),
            "--faults 0 perturbed the event run ({collective:?})"
        );
    }
}

/// The load-bearing invariant of the execution-model layer: with
/// homogeneous compute times, `--exec event` reproduces lockstep **bit
/// for bit** — parameters, reduction trace, comm bytes, epoch curves, and
/// the timeline breakdown — across all three collectives.  The only
/// permitted difference in the golden view is the model's own name.
#[test]
fn event_homogeneous_is_bit_identical_to_lockstep() {
    for collective in [
        CollectiveKind::Simulated,
        CollectiveKind::Sharded { threads: 3 },
        CollectiveKind::Pooled { threads: 2 },
    ] {
        let lockstep = run_with_exec(collective, ExecKind::Lockstep);
        let event = run_with_exec(collective, ExecKind::Event);
        assert_eq!(
            neutralize_exec_model(lockstep.to_golden_json()).pretty(),
            neutralize_exec_model(event.to_golden_json()).pretty(),
            "homogeneous event run drifted from lockstep ({collective:?})"
        );
    }
}

/// Heterogeneity never touches the parameter path: a straggler-ridden
/// event run produces the same training curves, trace steps/kinds, and
/// comm account as lockstep — only the time fields move.
#[test]
fn straggler_run_training_numerics_match_lockstep() {
    let lockstep = run_with(CollectiveKind::Simulated);
    let strag = run_straggler();
    assert_eq!(lockstep.total_steps, strag.total_steps);
    for (x, y) in lockstep.epochs.iter().zip(&strag.epochs) {
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits());
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits());
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits());
    }
    assert_eq!(lockstep.comm, strag.comm);
    assert_eq!(lockstep.trace.len(), strag.trace.len());
    for (a, b) in lockstep.trace.iter().zip(&strag.trace) {
        assert_eq!((a.step, a.kind), (b.step, b.kind));
        assert_eq!(a.seconds.to_bits(), b.seconds.to_bits());
    }
    // ... while the timeline actually stretched.
    assert!(strag.makespan_seconds > lockstep.makespan_seconds);
    assert!(strag.straggler_events > 0);
    assert!(strag.level_stall_seconds.iter().sum::<f64>() > 0.0);
}

/// The compression layer's determinism contract: an explicit
/// `--compress none` builds no wrapper and reproduces the committed dense
/// goldens byte for byte — across all three collectives and both
/// execution models.  (Pinned against the in-process baseline rather
/// than the files so the guarantee holds even before a golden is
/// committed; `check_golden` above covers the file half.)
#[test]
fn compress_none_is_bit_identical_to_dense() {
    use hier_avg::comm::Compression;
    for collective in [
        CollectiveKind::Simulated,
        CollectiveKind::Sharded { threads: 3 },
        CollectiveKind::Pooled { threads: 2 },
    ] {
        for exec in [ExecKind::Lockstep, ExecKind::Event] {
            let dense = run_with_exec(collective, exec);
            let mut cfg =
                planner::validation_config(&golden_candidate(), "quickstart", collective)
                    .unwrap();
            cfg.exec = exec;
            cfg.compress = Compression::parse("none").unwrap();
            cfg.validate().unwrap();
            let none = planner::validation_record(&cfg).unwrap();
            assert!(none.compression.is_none(), "--compress none emitted a compression block");
            assert_eq!(
                dense.to_golden_json().pretty(),
                none.to_golden_json().pretty(),
                "--compress none perturbed the dense run ({collective:?}, {exec:?})"
            );
        }
    }
}

/// ... and a *non*-none spec moves strictly fewer bytes while still
/// training to finite losses under the golden scenario — so the dense
/// identity above is not vacuous.
#[test]
fn compressed_golden_scenario_trains_and_saves_bytes() {
    use hier_avg::comm::Compression;
    let mut cfg = planner::validation_config(
        &golden_candidate(),
        "quickstart",
        CollectiveKind::Simulated,
    )
    .unwrap();
    cfg.compress = Compression::parse("topk:0.1").unwrap();
    cfg.validate().unwrap();
    let rec = planner::validation_record(&cfg).unwrap();
    let c = rec.compression.as_ref().expect("compressed run must carry a compression block");
    assert_eq!(c.spec, "topk:0.1");
    assert!(c.compressed_bytes < c.dense_bytes);
    assert!(c.payload_bytes < c.dense_payload_bytes);
    for e in &rec.epochs {
        assert!(e.train_loss.is_finite() && e.test_loss.is_finite(), "loss diverged");
    }
}

/// The three collectives must produce the same golden bytes — the
/// cross-engine half of the regression holds even before any file is
/// committed, and proves the planner's validation runs are bit-identical
/// across `--collective simulated|sharded|pooled`.
#[test]
fn golden_identical_across_collectives() {
    let sim = run_with(CollectiveKind::Simulated).to_golden_json().pretty();
    let sh = run_with(CollectiveKind::Sharded { threads: 3 }).to_golden_json().pretty();
    let po = run_with(CollectiveKind::Pooled { threads: 2 }).to_golden_json().pretty();
    assert_eq!(sim, sh, "sharded validation run drifted from simulated");
    assert_eq!(sim, po, "pooled validation run drifted from simulated");
}

/// Same config, run twice in one process: byte-identical golden JSON
/// (run-to-run determinism, independent of the committed files).
#[test]
fn golden_run_to_run_deterministic() {
    let a = run_with(CollectiveKind::Simulated).to_golden_json().pretty();
    let b = run_with(CollectiveKind::Simulated).to_golden_json().pretty();
    assert_eq!(a, b);
}

/// The golden scenario exercises every level: trace events for all three
/// tiers, per-level accounts filled, and counts matching the schedule.
#[test]
fn golden_scenario_covers_all_levels() {
    let rec = run_with(CollectiveKind::Simulated);
    assert!(rec.total_steps >= 16, "run too short to fire the outer tier");
    assert_eq!(rec.comm_levels.len(), 3);
    for (l, ls) in rec.comm_levels.iter().enumerate() {
        assert!(ls.reductions > 0, "level {l} never reduced");
        assert!(ls.seconds > 0.0, "level {l} free");
    }
    let kinds: std::collections::BTreeSet<char> =
        rec.trace.iter().map(|t| t.kind).collect();
    let expect: std::collections::BTreeSet<char> = ['L', '1', 'G'].into_iter().collect();
    assert_eq!(kinds, expect);
}
