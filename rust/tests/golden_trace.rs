//! Golden-trace regression suite: the planner's validation runs, pinned
//! bit-for-bit.
//!
//! One small deterministic run per collective engine (simulated, sharded,
//! pooled) is serialized through `RunRecord::to_golden_json` (wall-clock
//! stripped, reduction trace included) and compared against the committed
//! JSON under `rust/tests/golden/`.  Any change to training numerics, the
//! schedule, the cost model, or the serialization shows up as a diff.
//!
//! Blessing: set `GOLDEN_BLESS=1` to regenerate the files (they are also
//! written automatically when missing, so a fresh checkout bootstraps
//! itself); commit the result.  CI additionally runs this suite twice
//! (bless, then verify) to prove run-to-run determinism on its own host.
//!
//! The configs come from `planner::validation_config` — the exact
//! scenario generator `sweep --validate-top` uses — so these goldens also
//! prove the planner's validation runs are identical across
//! `--collective simulated|sharded|pooled`.

use std::path::PathBuf;

use hier_avg::comm::CollectiveKind;
use hier_avg::metrics::RunRecord;
use hier_avg::planner::{self, Candidate};
use hier_avg::util::json::Json;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden")
}

/// The fixed scenario all three goldens share: a 3-level hierarchy at
/// P = 8 so every tier (intra / inter) fires within the short run.
fn golden_candidate() -> Candidate {
    Candidate::with_default_links(vec![2, 4, 8], vec![2, 4, 8]).unwrap()
}

fn run_with(collective: CollectiveKind) -> RunRecord {
    let cfg = planner::validation_config(&golden_candidate(), "quickstart", collective).unwrap();
    planner::validation_record(&cfg).unwrap()
}

/// Compare `rec` against the committed golden `name`.json, blessing it
/// when missing or when `GOLDEN_BLESS=1`.  `GOLDEN_REQUIRE=1` turns a
/// missing golden into a hard failure instead of a bootstrap bless — the
/// knob CI uses to surface "the cross-commit pin is not in the tree yet"
/// rather than silently re-blessing forever.
fn check_golden(name: &str, rec: &RunRecord) {
    let dir = golden_dir();
    let path = dir.join(format!("{name}.json"));
    let actual = rec.to_golden_json().pretty() + "\n";
    let env_on = |k: &str| std::env::var(k).map(|v| v == "1").unwrap_or(false);
    let bless = env_on("GOLDEN_BLESS");
    if !bless && env_on("GOLDEN_REQUIRE") && !path.exists() {
        panic!(
            "golden trace {} is not committed (GOLDEN_REQUIRE=1): run \
             `GOLDEN_BLESS=1 cargo test --test golden_trace` and commit the file \
             (or download CI's golden-traces artifact)",
            path.display()
        );
    }
    if bless || !path.exists() {
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(&path, &actual).unwrap();
        eprintln!(
            "blessed golden trace {} — commit it to pin the behaviour",
            path.display()
        );
        return;
    }
    let stored = std::fs::read_to_string(&path).unwrap();
    let stored_json = Json::parse(&stored)
        .unwrap_or_else(|e| panic!("{} is not valid JSON: {e}", path.display()));
    let actual_json = Json::parse(&actual).unwrap();
    assert_eq!(
        stored_json,
        actual_json,
        "golden trace {name} drifted from {}.\nIf the change is intentional, regenerate with \
         `GOLDEN_BLESS=1 cargo test --test golden_trace` and commit the new file.",
        path.display()
    );
}

#[test]
fn golden_trace_simulated() {
    check_golden("validation_simulated", &run_with(CollectiveKind::Simulated));
}

#[test]
fn golden_trace_sharded() {
    check_golden("validation_sharded", &run_with(CollectiveKind::Sharded { threads: 3 }));
}

#[test]
fn golden_trace_pooled() {
    check_golden("validation_pooled", &run_with(CollectiveKind::Pooled { threads: 2 }));
}

/// The three collectives must produce the same golden bytes — the
/// cross-engine half of the regression holds even before any file is
/// committed, and proves the planner's validation runs are bit-identical
/// across `--collective simulated|sharded|pooled`.
#[test]
fn golden_identical_across_collectives() {
    let sim = run_with(CollectiveKind::Simulated).to_golden_json().pretty();
    let sh = run_with(CollectiveKind::Sharded { threads: 3 }).to_golden_json().pretty();
    let po = run_with(CollectiveKind::Pooled { threads: 2 }).to_golden_json().pretty();
    assert_eq!(sim, sh, "sharded validation run drifted from simulated");
    assert_eq!(sim, po, "pooled validation run drifted from simulated");
}

/// Same config, run twice in one process: byte-identical golden JSON
/// (run-to-run determinism, independent of the committed files).
#[test]
fn golden_run_to_run_deterministic() {
    let a = run_with(CollectiveKind::Simulated).to_golden_json().pretty();
    let b = run_with(CollectiveKind::Simulated).to_golden_json().pretty();
    assert_eq!(a, b);
}

/// The golden scenario exercises every level: trace events for all three
/// tiers, per-level accounts filled, and counts matching the schedule.
#[test]
fn golden_scenario_covers_all_levels() {
    let rec = run_with(CollectiveKind::Simulated);
    assert!(rec.total_steps >= 16, "run too short to fire the outer tier");
    assert_eq!(rec.comm_levels.len(), 3);
    for (l, ls) in rec.comm_levels.iter().enumerate() {
        assert!(ls.reductions > 0, "level {l} never reduced");
        assert!(ls.seconds > 0.0, "level {l} free");
    }
    let kinds: std::collections::BTreeSet<char> =
        rec.trace.iter().map(|t| t.kind).collect();
    let expect: std::collections::BTreeSet<char> = ['L', '1', 'G'].into_iter().collect();
    assert_eq!(kinds, expect);
}
