//! Arena step-pipeline invariants: the pool-parallel step path (flat
//! learner arena + pooled fill/apply/loss-reduction) must be bit-identical
//! to the serial reference path (`pool_threads = 0`) across topologies,
//! collectives, exec models, compression, and the fault layer — the
//! executable form of the "no golden re-bless" contract (DESIGN.md
//! §Memory layout).

use hier_avg::backend::StepBackend;
use hier_avg::comm::{CollectiveKind, Compression};
use hier_avg::config::{BackendKind, RunConfig};
use hier_avg::coordinator::{engine::tree_sum, sim_step_seconds, Engine, Trainer};
use hier_avg::data::{ClassifyData, MixtureSpec};
use hier_avg::exec::shared_pool;
use hier_avg::metrics::RunRecord;
use hier_avg::native::NativeMlp;
use hier_avg::optimizer::LrSchedule;
use hier_avg::sim::{parse_faults, ExecKind};
use hier_avg::util::rng::Pcg32;

const DIMS: &[usize] = &[8, 12, 4];
const BATCH: usize = 4;

fn base_cfg(p: usize, s: usize) -> RunConfig {
    let mut cfg = RunConfig::defaults("arena-test");
    cfg.backend = BackendKind::Native;
    cfg.p = p;
    cfg.s = s;
    cfg.k1 = 2;
    cfg.k2 = 4;
    cfg.epochs = 2;
    cfg.train_n = 256;
    cfg.test_n = 64;
    cfg.lr = LrSchedule::Constant(0.1);
    cfg.momentum = 0.9;
    cfg.weight_decay = 1e-4;
    cfg.noise = 0.8;
    cfg.keep_final_params = true;
    cfg.quiet = true;
    cfg
}

fn run(cfg: &RunConfig) -> RunRecord {
    let backend = NativeMlp::new(DIMS, BATCH, 32).unwrap();
    let data = ClassifyData::generate(MixtureSpec {
        dim: DIMS[0],
        classes: *DIMS.last().unwrap(),
        train_n: cfg.train_n,
        test_n: cfg.test_n,
        radius: cfg.radius,
        noise: cfg.noise,
        subclusters: 1,
        label_noise: 0.0,
        seed: cfg.seed ^ 0x5eed,
    });
    let mut rng = Pcg32::seeded(cfg.seed);
    let init = backend.init(&mut rng);
    Trainer::new(cfg, Box::new(backend), Box::new(data), init).unwrap().run().unwrap()
}

/// Pooled run == serial-reference run, bit for bit (losses, accuracies,
/// and final mean parameters), across the full config matrix the goldens
/// span: topology shapes × all three collectives × both exec models.
#[test]
fn pooled_step_pipeline_bit_identical_across_matrix() {
    let mut seed_rng = Pcg32::seeded(0xA11E7);
    for &(p, s) in &[(4usize, 2usize), (8, 4), (16, 4)] {
        for collective in [
            CollectiveKind::Simulated,
            CollectiveKind::Sharded { threads: 2 },
            CollectiveKind::Pooled { threads: 0 },
        ] {
            for exec in [ExecKind::Lockstep, ExecKind::Event] {
                let mut cfg = base_cfg(p, s);
                cfg.seed = 1 + (seed_rng.next_u32() as u64 % 1000);
                cfg.collective = collective;
                cfg.exec = exec;
                cfg.pool_threads = 0;
                let serial = run(&cfg);
                cfg.pool_threads = 4;
                let pooled = run(&cfg);
                let label = format!("p{p}/s{s}/{collective:?}/{exec:?}");
                for (a, b) in serial.epochs.iter().zip(&pooled.epochs) {
                    assert_eq!(a.train_loss, b.train_loss, "{label}: train_loss");
                    assert_eq!(a.test_acc, b.test_acc, "{label}: test_acc");
                }
                assert_eq!(
                    serial.final_params, pooled.final_params,
                    "{label}: final params must match bitwise"
                );
            }
        }
    }
}

/// Compression (error feedback carries state across barriers) composes
/// with the pooled pipeline without perturbing a single bit.
#[test]
fn pooled_pipeline_bit_identical_under_compression() {
    let mut cfg = base_cfg(8, 4);
    cfg.compress = Compression::parse("q8").unwrap();
    cfg.pool_threads = 0;
    let serial = run(&cfg);
    cfg.pool_threads = 4;
    let pooled = run(&cfg);
    for (a, b) in serial.epochs.iter().zip(&pooled.epochs) {
        assert_eq!(a.train_loss, b.train_loss, "compressed: train_loss");
    }
    assert_eq!(serial.final_params, pooled.final_params, "compressed: final params");
}

/// Build an engine + backend pair for direct step-level driving.
fn mk_engine<'a>(
    cfg: &'a RunConfig,
) -> (Engine<'a>, NativeMlp, ClassifyData) {
    let backend = NativeMlp::new(DIMS, BATCH, 32).unwrap();
    let data = ClassifyData::generate(MixtureSpec {
        dim: DIMS[0],
        classes: *DIMS.last().unwrap(),
        train_n: 256,
        test_n: 64,
        radius: 1.0,
        noise: 0.8,
        subclusters: 1,
        label_noise: 0.0,
        seed: 0x5eed,
    });
    let init = backend.init(&mut Pcg32::seeded(cfg.seed));
    let n_params = backend.n_params();
    let step_secs = sim_step_seconds(BATCH, n_params);
    let policy = cfg.schedule_policy.build(cfg.k2_clamp(BATCH), step_secs, cfg.p);
    let engine = Engine::new(cfg, n_params, &init, step_secs, policy).unwrap();
    (engine, backend, data)
}

/// While a learner is preempted, its arena row is frozen: pooled steps
/// must not move a single byte of it (the apply loop skips dead rows, and
/// no reduction fires inside the outage window under K1 = 8).
#[test]
fn preempted_rows_byte_stable_across_pooled_steps() {
    let mut cfg = base_cfg(8, 4);
    cfg.k1 = 8;
    cfg.k2 = 64;
    cfg.pool_threads = 4;
    // Learner 1 goes down at step 3 for 4 steps (down through steps 3..7).
    cfg.faults = Some(parse_faults("trace:3@1x4").unwrap());
    let (mut engine, mut backend, data) = mk_engine(&cfg);
    let sched = cfg.hier_schedule_at(0).unwrap();
    for _ in 0..4 {
        engine.step(&mut backend, &data, 0.1, &sched).unwrap();
    }
    // After step index 3 (the 4th step) the outage is active.
    let frozen: Vec<u32> =
        engine.learners.replicas.row(1).iter().map(|v| v.to_bits()).collect();
    for _ in 0..3 {
        engine.step(&mut backend, &data, 0.1, &sched).unwrap();
        let now: Vec<u32> =
            engine.learners.replicas.row(1).iter().map(|v| v.to_bits()).collect();
        assert_eq!(frozen, now, "preempted learner's arena row moved during outage");
    }
}

/// A scripted outage + re-entry restore produces the same bits under the
/// pooled pipeline as under the serial reference.
#[test]
fn reentry_restore_bit_identical_pooled_vs_serial() {
    let run_faulty = |pool_threads: usize| {
        let mut cfg = base_cfg(8, 4);
        cfg.pool_threads = pool_threads;
        cfg.faults = Some(parse_faults("trace:3@1x4").unwrap());
        let (mut engine, mut backend, data) = mk_engine(&cfg);
        let sched = cfg.hier_schedule_at(0).unwrap();
        for _ in 0..16 {
            engine.step(&mut backend, &data, 0.1, &sched).unwrap();
        }
        let mut mean = vec![0.0f32; backend.n_params()];
        engine.mean_params(&mut mean);
        (mean, engine.learners.replicas.clone())
    };
    let (serial_mean, serial_arena) = run_faulty(0);
    let (pooled_mean, pooled_arena) = run_faulty(4);
    assert_eq!(serial_mean, pooled_mean, "post-outage mean params diverged");
    assert_eq!(serial_arena, pooled_arena, "post-outage learner arenas diverged");
}

/// `tree_sum` is the one summation shape both step paths share: at or
/// below the block width it IS the legacy ascending fold, and above it
/// the pooled call agrees bitwise with the serial call (fixed-shape
/// blocks, thread-count-independent).
#[test]
fn tree_sum_matches_legacy_fold_and_is_pool_invariant() {
    let mut rng = Pcg32::seeded(9);
    for &n in &[0usize, 1, 17, 255, 256] {
        let vals: Vec<f64> =
            (0..n).map(|_| rng.next_normal() as f64 * 3.7).collect();
        let legacy: f64 = vals.iter().sum();
        assert_eq!(legacy.to_bits(), tree_sum(&vals, None).to_bits(), "n={n}");
    }
    let pool = shared_pool(4);
    for &n in &[257usize, 1000, 4096, 5000] {
        let vals: Vec<f64> =
            (0..n).map(|_| rng.next_normal() as f64 * 3.7).collect();
        let serial = tree_sum(&vals, None);
        let pooled = tree_sum(&vals, Some(&*pool));
        assert_eq!(serial.to_bits(), pooled.to_bits(), "n={n}");
    }
}
