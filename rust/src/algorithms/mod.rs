//! Averaging schedules: when, after each local SGD step, does a learner
//! reduce — and at which tier of the hierarchy?
//!
//! `HierAvgSchedule { k1, k2 }` is Algorithm 1 of the paper.  It reproduces
//! the classical synchronous variants exactly (paper §3.1):
//!
//! - `K2 = K1 = 1, S = 1`  ⇒ synchronous parallel SGD (Zinkevich et al.)
//! - `K1 = K2` or `S = 1`  ⇒ K-AVG (Zhou & Cong 2018) with K = K2
//!
//! [`HierSchedule`] generalizes it to per-level intervals
//! `K = [k_1 ≤ k_2 ≤ … ≤ k_L]` over an N-level [`crate::topology::HierTopology`]:
//! after step t the *outermost* level whose interval divides t reduces
//! (subsuming every inner boundary that coincides), exactly as the paper's
//! global boundary subsumes the local one.  `HierSchedule::two_level(k1, k2)`
//! reproduces `HierAvgSchedule` bit-for-bit — enforced by tests here and
//! property tests in rust/tests/hierarchy.rs.

pub mod asgd;
pub mod policy;

pub use policy::{
    AdaptivePolicy, PolicyKind, ScheduleChange, SchedulePolicy, ScheduleSummary, StaticPolicy,
    WarmupPolicy,
};

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceEvent {
    /// Keep running local SGD.
    None,
    /// Average within each local cluster (line "local averaging" of Alg. 1).
    Local,
    /// Average across all P learners (line "global averaging" of Alg. 1).
    Global,
}

/// The Hier-AVG schedule.  `k1` = local averaging interval, `k2` = global
/// averaging interval.  The paper's *analysis* assumes `k2 = β·k1` with
/// integer β (§3.1), but notes the implementation "can be implemented at
/// the practitioner's will"; like the paper's own ImageNet run
/// (K2=43, K1=20) we accept any `k1 ≤ k2` and expose
/// [`HierAvgSchedule::is_integer_beta`] for analysis-faithful checks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HierAvgSchedule {
    pub k1: u64,
    pub k2: u64,
}

impl HierAvgSchedule {
    pub fn new(k1: u64, k2: u64) -> Result<HierAvgSchedule> {
        if k1 == 0 || k2 == 0 {
            bail!("K1 and K2 must be >= 1 (got K1={k1}, K2={k2})");
        }
        if k2 < k1 {
            bail!("K2 must be >= K1 (got K1={k1}, K2={k2})");
        }
        Ok(HierAvgSchedule { k1, k2 })
    }

    /// Whether the analysis assumption K2 = β·K1 (β integer) holds.
    pub fn is_integer_beta(&self) -> bool {
        self.k2 % self.k1 == 0
    }

    /// K-AVG with interval K: local averaging degenerates.
    pub fn k_avg(k: u64) -> Result<HierAvgSchedule> {
        HierAvgSchedule::new(k, k)
    }

    /// Synchronous parallel SGD: global reduction after every step.
    pub fn sync_sgd() -> HierAvgSchedule {
        HierAvgSchedule { k1: 1, k2: 1 }
    }

    /// β = K2 / K1: local averaging rounds per global interval.
    pub fn beta(&self) -> u64 {
        self.k2 / self.k1
    }

    /// The reduction event after completing step `t` (1-based: the t-th
    /// local SGD step just finished).  A global boundary subsumes the local
    /// one that coincides with it.
    pub fn event_after(&self, t: u64) -> ReduceEvent {
        debug_assert!(t >= 1);
        if t % self.k2 == 0 {
            ReduceEvent::Global
        } else if t % self.k1 == 0 {
            ReduceEvent::Local
        } else {
            ReduceEvent::None
        }
    }

    /// Number of global / local reductions incurred over `t` steps.
    /// (A step that is a multiple of both intervals counts only as global.)
    pub fn reduction_counts(&self, t: u64) -> (u64, u64) {
        let global = t / self.k2;
        let both = t / lcm(self.k1, self.k2);
        (global, t / self.k1 - both)
    }
}

/// Per-level averaging intervals for an N-level hierarchy.
///
/// `intervals[l]` is the number of local SGD steps between reductions at
/// level `l` (0 = innermost, last = outermost/global).  Intervals are
/// non-decreasing outward, mirroring the paper's `K1 ≤ K2`.  Identities:
///
/// - all intervals 1 (and every group size 1 below the top) ⇒ sync SGD;
/// - `[k, k]` ⇒ K-AVG with interval k (inner boundaries always subsumed);
/// - `[k1, k2]` ⇒ the paper's `HierAvgSchedule { k1, k2 }`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HierSchedule {
    intervals: Vec<u64>,
}

impl HierSchedule {
    pub fn new(intervals: Vec<u64>) -> Result<HierSchedule> {
        if intervals.is_empty() {
            bail!("schedule needs at least one interval");
        }
        if intervals.len() > crate::topology::MAX_LEVELS {
            bail!(
                "schedule has {} levels (max {})",
                intervals.len(),
                crate::topology::MAX_LEVELS
            );
        }
        for (l, &k) in intervals.iter().enumerate() {
            if k == 0 {
                bail!("interval at level {l} must be >= 1");
            }
        }
        for l in 0..intervals.len() - 1 {
            if intervals[l] > intervals[l + 1] {
                bail!(
                    "intervals must be non-decreasing outward (K1 <= K2 <= ...): \
                     level {l} has {} > {}",
                    intervals[l],
                    intervals[l + 1]
                );
            }
        }
        Ok(HierSchedule { intervals })
    }

    /// The paper's two-level schedule.
    pub fn two_level(k1: u64, k2: u64) -> Result<HierSchedule> {
        let legacy = HierAvgSchedule::new(k1, k2)?;
        Ok(HierSchedule::from(legacy))
    }

    pub fn n_levels(&self) -> usize {
        self.intervals.len()
    }

    pub fn intervals(&self) -> &[u64] {
        &self.intervals
    }

    /// Whether every interval divides the next (the analysis-faithful
    /// integer-β chain; cf. `HierAvgSchedule::is_integer_beta`).
    pub fn is_integer_chain(&self) -> bool {
        self.intervals.windows(2).all(|w| w[1] % w[0] == 0)
    }

    /// The level that reduces after completing step `t` (1-based), if any:
    /// the outermost level whose interval divides t, subsuming all inner
    /// boundaries that coincide with it (the one shared rule in
    /// [`policy::fire_level`], so the static table and the policy layer's
    /// phase-anchored tables cannot drift).
    pub fn event_after(&self, t: u64) -> Option<usize> {
        debug_assert!(t >= 1);
        policy::fire_level(&self.intervals, t)
    }

    /// Number of reduction events per level over `t` steps.  A step on
    /// several boundaries counts only for the outermost level (matching
    /// [`HierSchedule::event_after`]); computed by inclusion–exclusion
    /// rather than an O(t) scan.
    pub fn reduction_counts(&self, t: u64) -> Vec<u64> {
        let n = self.intervals.len();
        (0..n)
            .map(|lev| {
                // Multiples of k[lev] that are multiples of no outer
                // interval: inclusion–exclusion over subsets of the outer
                // levels on the lcm.
                let outers = &self.intervals[lev + 1..];
                let mut count: i64 = 0;
                for mask in 0u32..(1u32 << outers.len()) {
                    let mut m = Some(self.intervals[lev]);
                    for (i, &o) in outers.iter().enumerate() {
                        if mask >> i & 1 == 1 {
                            m = m.and_then(|v| lcm_capped(v, o, t));
                        }
                    }
                    let term = m.map_or(0, |v| (t / v) as i64);
                    if mask.count_ones() % 2 == 0 {
                        count += term;
                    } else {
                        count -= term;
                    }
                }
                count.max(0) as u64
            })
            .collect()
    }
}

impl From<HierAvgSchedule> for HierSchedule {
    fn from(s: HierAvgSchedule) -> HierSchedule {
        HierSchedule { intervals: vec![s.k1, s.k2] }
    }
}

fn gcd(a: u64, b: u64) -> u64 {
    if b == 0 { a } else { gcd(b, a % b) }
}

fn lcm(a: u64, b: u64) -> u64 {
    a / gcd(a, b) * b
}

/// lcm(a, b), or None when it exceeds `cap` (such a period contributes no
/// multiples within the horizon; the u128 widening avoids overflow).
fn lcm_capped(a: u64, b: u64, cap: u64) -> Option<u64> {
    let l = (a / gcd(a, b)) as u128 * b as u128;
    if l > cap as u128 { None } else { Some(l as u64) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validates() {
        assert!(HierAvgSchedule::new(0, 4).is_err());
        assert!(HierAvgSchedule::new(4, 0).is_err());
        assert!(HierAvgSchedule::new(8, 4).is_err());
        // Non-integer β is accepted (paper's own ImageNet run uses 43/20)
        // but flagged for the analysis.
        let ragged = HierAvgSchedule::new(3, 8).unwrap();
        assert!(!ragged.is_integer_beta());
        assert!(HierAvgSchedule::new(4, 32).unwrap().is_integer_beta());
    }

    #[test]
    fn ragged_counts_match_events() {
        let s = HierAvgSchedule::new(20, 43).unwrap();
        let t = 10_000;
        let (mut g, mut l) = (0, 0);
        for i in 1..=t {
            match s.event_after(i) {
                ReduceEvent::Global => g += 1,
                ReduceEvent::Local => l += 1,
                ReduceEvent::None => {}
            }
        }
        assert_eq!(s.reduction_counts(t), (g, l));
    }

    #[test]
    fn hier_schedule_pattern() {
        let s = HierAvgSchedule::new(2, 6).unwrap();
        let events: Vec<_> = (1..=12).map(|t| s.event_after(t)).collect();
        use ReduceEvent::*;
        assert_eq!(
            events,
            vec![None, Local, None, Local, None, Global, None, Local, None, Local, None, Global]
        );
    }

    #[test]
    fn k_avg_identity() {
        // K1 == K2: no pure-local events ever fire.
        let s = HierAvgSchedule::k_avg(4).unwrap();
        for t in 1..=64 {
            assert_ne!(s.event_after(t), ReduceEvent::Local);
            assert_eq!(s.event_after(t) == ReduceEvent::Global, t % 4 == 0);
        }
    }

    #[test]
    fn sync_sgd_identity() {
        let s = HierAvgSchedule::sync_sgd();
        for t in 1..=16 {
            assert_eq!(s.event_after(t), ReduceEvent::Global);
        }
    }

    #[test]
    fn reduction_counts_match_events() {
        let s = HierAvgSchedule::new(4, 32).unwrap();
        let t = 1000;
        let (mut g, mut l) = (0, 0);
        for i in 1..=t {
            match s.event_after(i) {
                ReduceEvent::Global => g += 1,
                ReduceEvent::Local => l += 1,
                ReduceEvent::None => {}
            }
        }
        assert_eq!(s.reduction_counts(t), (g, l));
    }

    #[test]
    fn hier_schedule_two_level_matches_legacy() {
        for (k1, k2) in [(1u64, 1u64), (2, 6), (4, 32), (20, 43), (3, 8)] {
            let legacy = HierAvgSchedule::new(k1, k2).unwrap();
            let hier = HierSchedule::two_level(k1, k2).unwrap();
            for t in 1..=200 {
                let expect = match legacy.event_after(t) {
                    ReduceEvent::Global => Some(1),
                    ReduceEvent::Local => Some(0),
                    ReduceEvent::None => None,
                };
                assert_eq!(hier.event_after(t), expect, "k1={k1} k2={k2} t={t}");
            }
            let (g, l) = legacy.reduction_counts(10_000);
            assert_eq!(hier.reduction_counts(10_000), vec![l, g]);
        }
    }

    #[test]
    fn hier_schedule_validates() {
        assert!(HierSchedule::new(vec![]).is_err());
        assert!(HierSchedule::new(vec![0, 4]).is_err());
        assert!(HierSchedule::new(vec![8, 4]).is_err());
        assert!(HierSchedule::new(vec![2, 4, 3]).is_err());
        let s = HierSchedule::new(vec![2, 4, 16]).unwrap();
        assert!(s.is_integer_chain());
        assert!(!HierSchedule::new(vec![2, 3, 7]).unwrap().is_integer_chain());
    }

    #[test]
    fn hier_schedule_three_level_counts_match_scan() {
        for intervals in [vec![2u64, 4, 16], vec![2, 3, 7], vec![1, 1, 1], vec![5, 5, 10]] {
            let s = HierSchedule::new(intervals.clone()).unwrap();
            let t = 2_000u64;
            let mut scan = vec![0u64; s.n_levels()];
            for i in 1..=t {
                if let Some(lev) = s.event_after(i) {
                    scan[lev] += 1;
                }
            }
            assert_eq!(s.reduction_counts(t), scan, "intervals {intervals:?}");
        }
    }

    #[test]
    fn hier_schedule_outermost_subsumes() {
        let s = HierSchedule::new(vec![2, 4, 8]).unwrap();
        assert_eq!(s.event_after(8), Some(2));
        assert_eq!(s.event_after(4), Some(1));
        assert_eq!(s.event_after(2), Some(0));
        assert_eq!(s.event_after(3), None);
        // equal intervals: the inner level never fires on its own
        let dup = HierSchedule::new(vec![4, 4]).unwrap();
        let counts = dup.reduction_counts(1000);
        assert_eq!(counts[0], 0);
        assert_eq!(counts[1], 250);
    }

    #[test]
    fn paper_comparison_counts() {
        // §4.3: K2 = 2*K_opt halves the number of global reductions vs
        // K-AVG at K_opt over the same number of steps.
        let kavg = HierAvgSchedule::k_avg(32).unwrap();
        let hier = HierAvgSchedule::new(4, 64).unwrap();
        let t = 12800;
        assert_eq!(kavg.reduction_counts(t).0, 2 * hier.reduction_counts(t).0);
    }
}
