//! Averaging schedules: when, after each local SGD step, does a learner
//! reduce — locally (within its cluster of S) or globally (all P)?
//!
//! `HierAvgSchedule { k1, k2 }` is Algorithm 1 of the paper.  It reproduces
//! the classical synchronous variants exactly (paper §3.1):
//!
//! - `K2 = K1 = 1, S = 1`  ⇒ synchronous parallel SGD (Zinkevich et al.)
//! - `K1 = K2` or `S = 1`  ⇒ K-AVG (Zhou & Cong 2018) with K = K2
//!
//! Both identities are enforced by tests here and property tests in
//! rust/tests/proptests.rs.

pub mod asgd;

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceEvent {
    /// Keep running local SGD.
    None,
    /// Average within each local cluster (line "local averaging" of Alg. 1).
    Local,
    /// Average across all P learners (line "global averaging" of Alg. 1).
    Global,
}

/// The Hier-AVG schedule.  `k1` = local averaging interval, `k2` = global
/// averaging interval.  The paper's *analysis* assumes `k2 = β·k1` with
/// integer β (§3.1), but notes the implementation "can be implemented at
/// the practitioner's will"; like the paper's own ImageNet run
/// (K2=43, K1=20) we accept any `k1 ≤ k2` and expose
/// [`HierAvgSchedule::is_integer_beta`] for analysis-faithful checks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HierAvgSchedule {
    pub k1: u64,
    pub k2: u64,
}

impl HierAvgSchedule {
    pub fn new(k1: u64, k2: u64) -> Result<HierAvgSchedule> {
        if k1 == 0 || k2 == 0 {
            bail!("K1 and K2 must be >= 1 (got K1={k1}, K2={k2})");
        }
        if k2 < k1 {
            bail!("K2 must be >= K1 (got K1={k1}, K2={k2})");
        }
        Ok(HierAvgSchedule { k1, k2 })
    }

    /// Whether the analysis assumption K2 = β·K1 (β integer) holds.
    pub fn is_integer_beta(&self) -> bool {
        self.k2 % self.k1 == 0
    }

    /// K-AVG with interval K: local averaging degenerates.
    pub fn k_avg(k: u64) -> Result<HierAvgSchedule> {
        HierAvgSchedule::new(k, k)
    }

    /// Synchronous parallel SGD: global reduction after every step.
    pub fn sync_sgd() -> HierAvgSchedule {
        HierAvgSchedule { k1: 1, k2: 1 }
    }

    /// β = K2 / K1: local averaging rounds per global interval.
    pub fn beta(&self) -> u64 {
        self.k2 / self.k1
    }

    /// The reduction event after completing step `t` (1-based: the t-th
    /// local SGD step just finished).  A global boundary subsumes the local
    /// one that coincides with it.
    pub fn event_after(&self, t: u64) -> ReduceEvent {
        debug_assert!(t >= 1);
        if t % self.k2 == 0 {
            ReduceEvent::Global
        } else if t % self.k1 == 0 {
            ReduceEvent::Local
        } else {
            ReduceEvent::None
        }
    }

    /// Number of global / local reductions incurred over `t` steps.
    /// (A step that is a multiple of both intervals counts only as global.)
    pub fn reduction_counts(&self, t: u64) -> (u64, u64) {
        let global = t / self.k2;
        let both = t / lcm(self.k1, self.k2);
        (global, t / self.k1 - both)
    }
}

fn gcd(a: u64, b: u64) -> u64 {
    if b == 0 { a } else { gcd(b, a % b) }
}

fn lcm(a: u64, b: u64) -> u64 {
    a / gcd(a, b) * b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validates() {
        assert!(HierAvgSchedule::new(0, 4).is_err());
        assert!(HierAvgSchedule::new(4, 0).is_err());
        assert!(HierAvgSchedule::new(8, 4).is_err());
        // Non-integer β is accepted (paper's own ImageNet run uses 43/20)
        // but flagged for the analysis.
        let ragged = HierAvgSchedule::new(3, 8).unwrap();
        assert!(!ragged.is_integer_beta());
        assert!(HierAvgSchedule::new(4, 32).unwrap().is_integer_beta());
    }

    #[test]
    fn ragged_counts_match_events() {
        let s = HierAvgSchedule::new(20, 43).unwrap();
        let t = 10_000;
        let (mut g, mut l) = (0, 0);
        for i in 1..=t {
            match s.event_after(i) {
                ReduceEvent::Global => g += 1,
                ReduceEvent::Local => l += 1,
                ReduceEvent::None => {}
            }
        }
        assert_eq!(s.reduction_counts(t), (g, l));
    }

    #[test]
    fn hier_schedule_pattern() {
        let s = HierAvgSchedule::new(2, 6).unwrap();
        let events: Vec<_> = (1..=12).map(|t| s.event_after(t)).collect();
        use ReduceEvent::*;
        assert_eq!(
            events,
            vec![None, Local, None, Local, None, Global, None, Local, None, Local, None, Global]
        );
    }

    #[test]
    fn k_avg_identity() {
        // K1 == K2: no pure-local events ever fire.
        let s = HierAvgSchedule::k_avg(4).unwrap();
        for t in 1..=64 {
            assert_ne!(s.event_after(t), ReduceEvent::Local);
            assert_eq!(s.event_after(t) == ReduceEvent::Global, t % 4 == 0);
        }
    }

    #[test]
    fn sync_sgd_identity() {
        let s = HierAvgSchedule::sync_sgd();
        for t in 1..=16 {
            assert_eq!(s.event_after(t), ReduceEvent::Global);
        }
    }

    #[test]
    fn reduction_counts_match_events() {
        let s = HierAvgSchedule::new(4, 32).unwrap();
        let t = 1000;
        let (mut g, mut l) = (0, 0);
        for i in 1..=t {
            match s.event_after(i) {
                ReduceEvent::Global => g += 1,
                ReduceEvent::Local => l += 1,
                ReduceEvent::None => {}
            }
        }
        assert_eq!(s.reduction_counts(t), (g, l));
    }

    #[test]
    fn paper_comparison_counts() {
        // §4.3: K2 = 2*K_opt halves the number of global reductions vs
        // K-AVG at K_opt over the same number of steps.
        let kavg = HierAvgSchedule::k_avg(32).unwrap();
        let hier = HierAvgSchedule::new(4, 64).unwrap();
        let t = 12800;
        assert_eq!(kavg.reduction_counts(t).0, 2 * hier.reduction_counts(t).0);
    }
}
