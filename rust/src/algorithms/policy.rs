//! The schedule-policy layer: *who decides* when each hierarchy tier
//! reduces.
//!
//! [`crate::algorithms::HierSchedule`] is a passive interval table; this
//! module promotes the decision into a first-class [`SchedulePolicy`]
//! trait so the reduction cadence can react to observed runtime
//! conditions.  Three implementations:
//!
//! - [`StaticPolicy`] — delegates every decision to the epoch's base
//!   `HierSchedule`, bit-for-bit identical to the pre-policy engine (the
//!   load-bearing invariant; golden- and property-tested).
//! - [`AdaptivePolicy`] — the online straggler-aware K2 controller: after
//!   every fired reduction it observes the barrier stall the event
//!   timeline attributed to that tier and the modelled collective cost,
//!   and widens (doubles) a tier's interval when the stall eats more than
//!   `target` of the tier's compute budget, narrowing back toward the
//!   base schedule when the signal fades.  Widening the outermost
//!   interval is capped at [`crate::theory::max_k2_condition_35`] so
//!   *adaptation* never leaves the regime where Theorem 3.4's bound is a
//!   guarantee (a base schedule the user already configured past the
//!   clamp is adopted verbatim, exactly as a static run would — the
//!   controller then simply cannot widen further), and no interval ever
//!   narrows below the base schedule, so realized global reductions
//!   never exceed the static run's.  With `gain = 0` the controller is
//!   neutral: decisions short-circuit to the base schedule and the
//!   policy is bit-identical to [`StaticPolicy`].
//! - [`WarmupPolicy`] — Adaptive-Periodic-Averaging shape (Jiang &
//!   Agrawal 2020): dense early averaging decaying to the base schedule.
//!   During stage `s` (each stage is `stage_steps` steps) every interval
//!   is capped at `2^s`, so training starts near sync-SGD and relaxes to
//!   the configured sparse schedule.
//!
//! **Determinism rule** (DESIGN.md §Schedule policies): a policy's only
//! inputs are the step counter, the base schedule, and the *seeded*
//! virtual timeline's stall/comm attribution — never the wall clock — so
//! replaying the same seeded timeline reproduces every decision exactly.
//! This is what lets the planner rank adaptive candidates by pure replay
//! ([`crate::sim::drive_timeline_policy`]) and lets a checkpointed
//! controller resume bit-identically.

use anyhow::{anyhow, bail, Result};

use crate::algorithms::HierSchedule;
use crate::util::json::Json;

/// Upper cap fed to [`crate::theory::max_k2_condition_35`] when deriving
/// the adaptive controller's clamp: far above any practical interval, so
/// the binding constraint is condition (3.5) itself.
pub const K2_CLAMP_CAP: u64 = 1 << 20;

/// Default stall-to-compute ratio above which the adaptive controller
/// widens a tier's interval (`--schedule adaptive` with no target).
pub const DEFAULT_ADAPTIVE_TARGET: f64 = 0.25;

/// Default steps per warmup stage (`--schedule warmup` with no length).
pub const DEFAULT_WARMUP_STAGE_STEPS: u64 = 64;

/// The level (if any) that fires `rel` steps into the current phase of
/// `intervals`: the outermost level whose interval divides `rel`,
/// subsuming inner boundaries.  This is THE subsumption rule —
/// [`HierSchedule::event_after`] delegates here, so the static table and
/// the phase-anchored policy tables can never drift apart.
pub(crate) fn fire_level(intervals: &[u64], rel: u64) -> Option<usize> {
    (0..intervals.len()).rev().find(|&l| rel % intervals[l] == 0)
}

/// Reject a restored interval table that violates the invariants the
/// live controller maintains (missing, length-mismatched, zero,
/// non-monotone, or below-base entries) — the sidecar is editable JSON,
/// and a run must fail loudly rather than fire from a corrupt table.
fn check_restored_table(what: &str, base: &[u64], current: &[u64]) -> Result<()> {
    if base.len() != current.len() {
        bail!("{what} state is inconsistent: {} base / {} current entries", base.len(), current.len());
    }
    for (l, (&b, &c)) in base.iter().zip(current).enumerate() {
        if b == 0 || c == 0 {
            bail!("{what} state is inconsistent: zero interval at level {l}");
        }
    }
    for w in current.windows(2) {
        if w[0] > w[1] {
            bail!(
                "{what} state is inconsistent: intervals {current:?} are not \
                 non-decreasing outward"
            );
        }
    }
    for w in base.windows(2) {
        if w[0] > w[1] {
            bail!(
                "{what} state is inconsistent: base {base:?} is not non-decreasing outward"
            );
        }
    }
    Ok(())
}

/// One interval-table change: `intervals` took effect for steps
/// `>= step` (the trajectory entry the metrics/JSON `schedule` block
/// records).
#[derive(Debug, Clone, PartialEq)]
pub struct ScheduleChange {
    pub step: u64,
    pub intervals: Vec<u64>,
}

/// Which schedule policy a run uses (`--schedule`, config key
/// `"schedule"`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PolicyKind {
    /// The base `HierSchedule`, verbatim (the default).
    Static,
    /// Online straggler-aware controller.  `target` is the
    /// stall-to-compute ratio that triggers widening; `gain` the EWMA
    /// weight of each new observation (0 disables adaptation entirely —
    /// the neutral controller, bit-identical to `Static`).
    Adaptive { target: f64, gain: f64 },
    /// Dense-to-sparse warmup; `stage_steps` steps per doubling stage.
    Warmup { stage_steps: u64 },
}

impl PolicyKind {
    /// Parse the CLI/config spelling:
    /// `static | adaptive[:target[:gain]] | warmup[:steps]`.
    pub fn parse(s: &str) -> Result<PolicyKind> {
        let mut parts = s.split(':');
        let name = parts.next().unwrap_or("");
        let kind = match name {
            "static" => {
                if parts.next().is_some() {
                    bail!("--schedule static takes no parameter (got {s:?})");
                }
                PolicyKind::Static
            }
            "adaptive" => {
                let target = match parts.next() {
                    None => DEFAULT_ADAPTIVE_TARGET,
                    Some(t) => t.trim().parse().map_err(|e| {
                        anyhow!(
                            "invalid --schedule adaptive target {t:?}: {e} \
                             (expected adaptive[:target[:gain]], e.g. adaptive:0.25)"
                        )
                    })?,
                };
                let gain = match parts.next() {
                    None => 1.0,
                    Some(g) => g.trim().parse().map_err(|e| {
                        anyhow!(
                            "invalid --schedule adaptive gain {g:?}: {e} \
                             (expected adaptive[:target[:gain]], e.g. adaptive:0.25:1)"
                        )
                    })?,
                };
                if parts.next().is_some() {
                    bail!("--schedule adaptive takes at most target:gain (got {s:?})");
                }
                PolicyKind::Adaptive { target, gain }
            }
            "warmup" => {
                let stage_steps = match parts.next() {
                    None => DEFAULT_WARMUP_STAGE_STEPS,
                    Some(k) => k.trim().parse().map_err(|e| {
                        anyhow!(
                            "invalid --schedule warmup stage length {k:?}: {e} \
                             (expected warmup[:steps], e.g. warmup:64)"
                        )
                    })?,
                };
                if parts.next().is_some() {
                    bail!("--schedule warmup takes at most one parameter (got {s:?})");
                }
                PolicyKind::Warmup { stage_steps }
            }
            other => bail!(
                "unknown schedule policy {other:?} \
                 (static | adaptive[:target[:gain]] | warmup[:steps])"
            ),
        };
        kind.validate()?;
        Ok(kind)
    }

    /// Reject out-of-range parameters with actionable errors (also run by
    /// `RunConfig::validate` for programmatically-built configs).
    pub fn validate(&self) -> Result<()> {
        match *self {
            PolicyKind::Static => Ok(()),
            PolicyKind::Adaptive { target, gain } => {
                if !target.is_finite() || target <= 0.0 {
                    bail!(
                        "adaptive schedule target must be a finite ratio > 0 (got {target}): \
                         it is the fraction of a tier's compute budget lost to barrier \
                         stall above which the tier's interval widens"
                    );
                }
                if !gain.is_finite() || gain < 0.0 {
                    bail!(
                        "adaptive schedule gain must be finite and >= 0 (got {gain}): \
                         it is the EWMA weight of each stall observation (0 disables \
                         adaptation — the neutral controller)"
                    );
                }
                Ok(())
            }
            PolicyKind::Warmup { stage_steps } => {
                if stage_steps == 0 {
                    bail!(
                        "warmup stage length must be >= 1 step (got 0): each stage \
                         doubles the interval cap until the base schedule is reached"
                    );
                }
                Ok(())
            }
        }
    }

    /// Bare policy name (stable; used in labels and banners).
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Static => "static",
            PolicyKind::Adaptive { .. } => "adaptive",
            PolicyKind::Warmup { .. } => "warmup",
        }
    }

    /// Canonical spec string: `PolicyKind::parse(spec())` roundtrips, and
    /// the checkpoint sidecar compares specs to reject cross-policy
    /// resumes.
    pub fn spec(&self) -> String {
        match *self {
            PolicyKind::Static => "static".to_string(),
            PolicyKind::Adaptive { target, gain } => {
                if gain == 1.0 {
                    format!("adaptive:{target}")
                } else {
                    format!("adaptive:{target}:{gain}")
                }
            }
            PolicyKind::Warmup { stage_steps } => format!("warmup:{stage_steps}"),
        }
    }

    /// Build the policy for a run.  `k2_clamp` bounds what the adaptive
    /// controller may *widen* the outermost interval to (condition (3.5);
    /// the configured base schedule itself is never altered);
    /// `step_seconds`/`p` normalize its stall observations into a
    /// fraction of the cluster's compute budget.  Static and warmup
    /// policies ignore all three.
    pub fn build(
        &self,
        k2_clamp: u64,
        step_seconds: f64,
        p: usize,
    ) -> Box<dyn SchedulePolicy> {
        match *self {
            PolicyKind::Static => Box::new(StaticPolicy::new()),
            PolicyKind::Adaptive { target, gain } => {
                Box::new(AdaptivePolicy::new(target, gain, k2_clamp, step_seconds, p))
            }
            PolicyKind::Warmup { stage_steps } => Box::new(WarmupPolicy::new(stage_steps)),
        }
    }
}

impl Default for PolicyKind {
    fn default() -> PolicyKind {
        PolicyKind::Static
    }
}

/// What the metrics layer records about a run's schedule decisions
/// (`RunRecord.schedule` → the JSON `schedule` block).
#[derive(Debug, Clone, PartialEq)]
pub struct ScheduleSummary {
    /// Canonical policy spec (`PolicyKind::spec`).
    pub policy: String,
    /// Per-level realized reduction events (decisions the policy actually
    /// fired, outermost-subsumed — the engine counts them).
    pub realized: Vec<u64>,
    /// The interval table in effect at the end of the run.
    pub final_intervals: Vec<u64>,
    /// The condition-(3.5) clamp the run's controller was bounded by.
    pub k2_clamp: u64,
    /// Interval trajectory: every table change, in step order.
    pub changes: Vec<ScheduleChange>,
    /// Serializable controller state (the checkpoint sidecar stores this
    /// so a resumed run continues the controller exactly).
    pub state: Json,
}

/// A per-step, per-level reduction decider the engine consults instead of
/// reading the static interval table directly.
///
/// Contract: the engine calls [`SchedulePolicy::decide`] once per
/// completed step with the epoch's base schedule, then — iff a level
/// fired — [`SchedulePolicy::observe`] with the barrier stall the
/// execution model attributed to that event and the modelled collective
/// seconds.  Feedback is a pure function of the seeded timeline (never
/// wall clock), so identical replays make identical decisions.
pub trait SchedulePolicy: std::fmt::Debug + Send {
    /// `PolicyKind::name()` of this policy.
    fn name(&self) -> &'static str;

    /// Which level (if any) reduces after completing step `t` (1-based),
    /// given the config's base schedule for the current epoch.  The
    /// outermost eligible level wins, subsuming inner boundaries — the
    /// same convention as [`HierSchedule::event_after`].
    fn decide(&mut self, t: u64, base: &HierSchedule) -> Option<usize>;

    /// Feedback for the reduction that `decide` fired at step `t`:
    /// `stall_seconds` is the barrier wait the execution model attributed
    /// to this event (zero under lockstep), `comm_seconds` one symmetric
    /// group's modelled collective cost.
    fn observe(&mut self, _t: u64, _level: usize, _stall_seconds: f64, _comm_seconds: f64) {}

    /// Culprit feedback, delivered only when the elastic fault layer
    /// (`--faults`) is active: `learner` is the participant the whole
    /// barrier at step `t` waited for (the timeline's globally latest
    /// arrival across the reduction).  Like [`SchedulePolicy::observe`],
    /// a pure function of the seeded timeline, so replays reproduce
    /// every migration.  Default: ignored.
    fn observe_culprit(
        &mut self,
        _t: u64,
        _level: usize,
        _learner: usize,
        _stall_seconds: f64,
        _comm_seconds: f64,
    ) {
    }

    /// Drain a pending membership decision: a learner the policy wants
    /// migrated out of its sub-top group (it then barriers only at the
    /// outermost level) instead of widening everyone's interval around
    /// one persistently slow machine.  The engine polls this after every
    /// reduction and applies at most one migration per poll.  Default:
    /// never migrates.
    fn take_migration(&mut self) -> Option<usize> {
        None
    }

    /// Learners this policy has already migrated to outermost-only
    /// cadence (granted via [`SchedulePolicy::take_migration`], including
    /// migrations restored from a checkpoint).  The engine re-applies
    /// these as detachments when a resumed run rebuilds its fault
    /// runtime, so a warm restart does not silently re-attach a learner
    /// the saving run had already given up on.  Default: none.
    fn migrated_learners(&self) -> Vec<usize> {
        Vec::new()
    }

    /// The interval table currently in effect (the base schedule's, for
    /// policies that never deviate from it).
    fn intervals(&self, base: &HierSchedule) -> Vec<u64>;

    /// Every interval-table change so far (empty for a static policy).
    fn changes(&self) -> &[ScheduleChange] {
        &[]
    }

    /// Serializable controller state.  [`SchedulePolicy::restore`] must
    /// accept exactly what this produced; the checkpoint sidecar stores
    /// it so a resumed run continues the controller bit-identically.
    fn state(&self) -> Json;

    /// Restore state previously produced by [`SchedulePolicy::state`] on
    /// a policy of the same kind.
    fn restore(&mut self, state: &Json) -> Result<()>;
}

// ---------------------------------------------------------------------------
// StaticPolicy
// ---------------------------------------------------------------------------

/// The base schedule, verbatim: `decide` is exactly
/// [`HierSchedule::event_after`], so an engine driven by this policy is
/// bit-identical to the pre-policy engine.
#[derive(Debug, Clone, Default)]
pub struct StaticPolicy;

impl StaticPolicy {
    pub fn new() -> StaticPolicy {
        StaticPolicy
    }
}

impl SchedulePolicy for StaticPolicy {
    fn name(&self) -> &'static str {
        "static"
    }

    fn decide(&mut self, t: u64, base: &HierSchedule) -> Option<usize> {
        base.event_after(t)
    }

    fn intervals(&self, base: &HierSchedule) -> Vec<u64> {
        base.intervals().to_vec()
    }

    fn state(&self) -> Json {
        Json::obj()
    }

    fn restore(&mut self, _state: &Json) -> Result<()> {
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// AdaptivePolicy
// ---------------------------------------------------------------------------

/// The online straggler-aware K2 controller (module docs for the control
/// law; DESIGN.md §Schedule policies for the contract).
#[derive(Debug, Clone)]
pub struct AdaptivePolicy {
    /// Widening threshold: stall / (P · interval · step_seconds).
    pub target: f64,
    /// EWMA weight per observation; 0 = neutral (≡ static).
    pub gain: f64,
    /// Condition-(3.5) ceiling on outermost-interval *widening* (the
    /// configured base is adopted verbatim even when it sits past it).
    pub k2_clamp: u64,
    step_seconds: f64,
    p: usize,
    /// Steps completed by previous (checkpointed) runs: decisions use
    /// `t + offset` so a resumed controller continues its own timeline.
    offset: u64,
    /// Highest absolute step seen (for the next checkpoint's offset).
    last_t: u64,
    /// Base-schedule snapshot the current table derives from.
    base: Vec<u64>,
    /// The interval table currently in effect (empty until first decide).
    current: Vec<u64>,
    /// Per-level phase anchor: level `l` fires when
    /// `(t_abs − anchors[l]) % current[l] == 0`.  Only the level whose
    /// interval changed re-anchors — adapting an inner tier must never
    /// shift (let alone starve) the outer tiers' cadence.
    anchors: Vec<u64>,
    /// EWMA stall-to-compute ratio per level.
    ratio: Vec<f64>,
    /// Consecutive deep-quiet observations per level (the narrowing
    /// hysteresis: with `gain = 1` the EWMA is just the last observation,
    /// so a single quiet barrier right after a widening must not undo
    /// it).
    quiet: Vec<u32>,
    changes: Vec<ScheduleChange>,
    /// The learner the last expensive barrier waited for (fault layer
    /// only; see [`SchedulePolicy::observe_culprit`]).
    last_culprit: Option<usize>,
    /// Consecutive expensive barriers blamed on `last_culprit`.
    culprit_streak: u32,
    /// A migration decided but not yet drained by the engine.
    pending_migration: Option<usize>,
    /// Learners already migrated (never migrated twice).
    migrated: Vec<bool>,
    /// Migrations granted so far, capped at `max(1, P/16)` so the policy
    /// degrades groups, never dissolves them.
    migrations_done: usize,
}

/// Consecutive observations below a quarter of the target a tier must
/// see before it narrows (damping against widen/narrow ping-pong under
/// stochastic spikes).
const NARROW_STREAK: u32 = 3;

/// Consecutive expensive barriers one learner must be blamed for before
/// the controller migrates it out of its sub-top group.  High enough
/// that a single straggler spike (or a just-repaired machine paying its
/// restore surcharge) never triggers a migration; a persistent EWMA
/// stall does.
pub const MIGRATE_STREAK: u32 = 4;

impl AdaptivePolicy {
    pub fn new(
        target: f64,
        gain: f64,
        k2_clamp: u64,
        step_seconds: f64,
        p: usize,
    ) -> AdaptivePolicy {
        AdaptivePolicy {
            target,
            gain,
            k2_clamp: k2_clamp.max(1),
            step_seconds,
            p: p.max(1),
            offset: 0,
            last_t: 0,
            base: Vec::new(),
            current: Vec::new(),
            anchors: Vec::new(),
            ratio: Vec::new(),
            quiet: Vec::new(),
            changes: Vec::new(),
            last_culprit: None,
            culprit_streak: 0,
            pending_migration: None,
            migrated: vec![false; p.max(1)],
            migrations_done: 0,
        }
    }

    /// Migration budget: at most one learner per 16, and always at least
    /// one, so a persistent straggler can be detached even in a tiny
    /// fleet but groups are degraded, never dissolved.
    fn migration_cap(&self) -> usize {
        (self.p / 16).max(1)
    }

    /// (Re)derive the working table from the base schedule: on the first
    /// decide, and whenever the base changes (the per-epoch `k2_schedule`
    /// path).  The base is adopted *verbatim* — the condition-(3.5) clamp
    /// bounds only what the controller may widen to ([`Self::widen_cap`]),
    /// never the user's configured schedule, so an adaptive run starts
    /// from exactly the static table and can only thin it out.  A mid-run
    /// base change discards the adapted table (the controller's phase and
    /// ratios are about the old cadence), re-anchors, and is recorded in
    /// the trajectory so the emitted `adaptations` always reflect what
    /// actually ran.
    fn sync_base(&mut self, t_abs: u64, base: &HierSchedule) {
        if self.base == base.intervals() {
            return;
        }
        let first = self.base.is_empty();
        self.base = base.intervals().to_vec();
        self.current = self.base.clone();
        self.ratio = vec![0.0; self.base.len()];
        self.quiet = vec![0; self.base.len()];
        if first {
            // Legacy phase: every level counts from step 0, exactly like
            // the static modulo rule.
            self.anchors = vec![0; self.base.len()];
        } else {
            // Per-epoch rewrite (k2_schedule): restart every phase at the
            // previous step so the new table fires on its own cadence,
            // and log the reset as a trajectory entry.
            self.anchors = vec![t_abs - 1; self.base.len()];
            self.changes
                .push(ScheduleChange { step: t_abs, intervals: self.current.clone() });
        }
    }

    /// Highest value level `l` may widen to: *half* the next-outer
    /// interval, or — at the outermost level — the condition-(3.5)
    /// clamp.  The half keeps an inner tier strictly inside its outer
    /// neighbour: a tier widened to equality would be fully subsumed
    /// (outermost wins), never fire, never observe, and so never be able
    /// to narrow back when the stall fades.  A base schedule already
    /// past the clamp is the user's choice (exactly as in a static run):
    /// widening is then simply impossible, never a silent narrowing
    /// below the configured table.
    fn widen_cap(&self, level: usize) -> u64 {
        if level + 1 < self.current.len() {
            self.current[level + 1] / 2
        } else {
            self.k2_clamp.max(*self.base.last().unwrap())
        }
    }

    /// Lowest value level `l` may narrow to: never below the base
    /// interval, and never below the level just inside it.
    fn floor(&self, level: usize) -> u64 {
        let base = self.base[level];
        if level == 0 {
            base
        } else {
            base.max(self.current[level - 1])
        }
    }

    /// Log an adaptation of `level` and re-anchor *that level only*: the
    /// other tiers — in particular the outermost — keep their cadence.
    fn record_change(&mut self, t_abs: u64, level: usize) {
        self.anchors[level] = t_abs;
        self.changes.push(ScheduleChange { step: t_abs, intervals: self.current.clone() });
    }
}

impl SchedulePolicy for AdaptivePolicy {
    fn name(&self) -> &'static str {
        "adaptive"
    }

    fn decide(&mut self, t: u64, base: &HierSchedule) -> Option<usize> {
        if self.gain == 0.0 {
            // Neutral controller: no state, no phase tracking — literally
            // the static decision (the zero-gain ≡ static property test
            // rides on this being the identical code path).
            return base.event_after(t);
        }
        let t_abs = t + self.offset;
        self.last_t = t_abs;
        self.sync_base(t_abs, base);
        // Outermost-wins over per-level phases: level l is due when its
        // own counter hits its interval; an outer due subsumes inner
        // ones, exactly the `fire_level` convention (which this equals
        // whenever all anchors coincide — e.g. before any adaptation).
        (0..self.current.len()).rev().find(|&l| {
            debug_assert!(self.anchors[l] < t_abs, "decide at or before an anchor");
            (t_abs - self.anchors[l]) % self.current[l] == 0
        })
    }

    fn observe(&mut self, t: u64, level: usize, stall_seconds: f64, comm_seconds: f64) {
        if self.gain == 0.0 || level >= self.current.len() {
            return;
        }
        let t_abs = t + self.offset;
        // Stall as a fraction of the cluster's compute budget over the
        // tier's interval: scale-free in model size and step cost, so one
        // target works across workloads.
        let budget =
            (self.p as f64 * self.current[level] as f64 * self.step_seconds).max(1e-300);
        let r = stall_seconds / budget;
        let w = self.gain.min(1.0);
        self.ratio[level] = (1.0 - w) * self.ratio[level] + w * r;
        // Narrowing hysteresis: count consecutive deep-quiet barriers.
        if r < 0.25 * self.target {
            self.quiet[level] = self.quiet[level].saturating_add(1);
        } else {
            self.quiet[level] = 0;
        }
        if self.ratio[level] > self.target {
            // Barriers at this tier are expensive: halve their frequency,
            // staying inside the outer level's interval (or the theory
            // clamp at the outermost level).  The EWMA is re-seeded at
            // the neutral midpoint (not zero) so the next observation is
            // judged from indifference, not from a fake all-clear.
            let widened = self.current[level].saturating_mul(2).min(self.widen_cap(level));
            if widened > self.current[level] {
                self.current[level] = widened;
                self.ratio[level] = 0.5 * self.target;
                self.quiet[level] = 0;
                self.record_change(t_abs, level);
            }
        } else if self.ratio[level] < 0.25 * self.target
            && self.quiet[level] >= NARROW_STREAK
            && self.current[level] > self.floor(level)
        {
            // The stall signal faded — for NARROW_STREAK consecutive
            // barriers, so one quiet observation cannot ping-pong a
            // widening — relax back toward the base schedule, but only
            // where the tier's collective cost fits inside the narrowed
            // interval's compute budget (the comm-cost half of the
            // feedback: never narrow a tier into a comm-bound regime
            // just because its barriers stopped stalling).
            let narrowed = (self.current[level] / 2).max(self.floor(level));
            let narrowed_budget =
                (self.p as f64 * narrowed as f64 * self.step_seconds).max(1e-300);
            if narrowed < self.current[level] && comm_seconds <= narrowed_budget {
                self.current[level] = narrowed;
                self.quiet[level] = 0;
                self.record_change(t_abs, level);
            }
        }
    }

    fn observe_culprit(
        &mut self,
        _t: u64,
        level: usize,
        learner: usize,
        stall_seconds: f64,
        _comm_seconds: f64,
    ) {
        if self.gain == 0.0 || learner >= self.migrated.len() {
            return; // the neutral controller adapts nothing, membership included
        }
        // A culprit only counts while its barrier actually hurts: the
        // same target threshold `observe` widens on, against the tier's
        // current interval budget.
        let interval = self.current.get(level).copied().unwrap_or(1).max(1);
        let budget =
            (self.p as f64 * interval as f64 * self.step_seconds).max(1e-300);
        if stall_seconds <= self.target * budget {
            // Quiet barrier: whoever was accumulating blame is forgiven.
            self.last_culprit = None;
            self.culprit_streak = 0;
            return;
        }
        if self.last_culprit == Some(learner) {
            self.culprit_streak = self.culprit_streak.saturating_add(1);
        } else {
            self.last_culprit = Some(learner);
            self.culprit_streak = 1;
        }
        if self.culprit_streak >= MIGRATE_STREAK
            && !self.migrated[learner]
            && self.migrations_done < self.migration_cap()
            && self.pending_migration.is_none()
        {
            // Persistent straggler: move *it* to the outermost-only
            // cadence instead of widening every learner's interval
            // around it.
            self.migrated[learner] = true;
            self.migrations_done += 1;
            self.pending_migration = Some(learner);
            self.last_culprit = None;
            self.culprit_streak = 0;
        }
    }

    fn take_migration(&mut self) -> Option<usize> {
        self.pending_migration.take()
    }

    fn migrated_learners(&self) -> Vec<usize> {
        (0..self.migrated.len()).filter(|&l| self.migrated[l]).collect()
    }

    fn intervals(&self, base: &HierSchedule) -> Vec<u64> {
        if self.current.is_empty() {
            base.intervals().to_vec()
        } else {
            self.current.clone()
        }
    }

    fn changes(&self) -> &[ScheduleChange] {
        &self.changes
    }

    // The `migration` sub-object is emitted only once the controller has
    // actually touched membership (a migration granted, a streak in
    // flight): a fault-free or pre-migration run serializes exactly the
    // pre-elastic schema, so those sidecars — and the adaptive goldens —
    // stay byte-stable.  Omitting it when non-default would silently
    // reset detachment decisions on warm restart (the learner would be
    // re-attached and the streak forgotten), so it is always written the
    // moment there is anything to lose.
    fn state(&self) -> Json {
        let mut o = Json::obj();
        o.set("offset", Json::from(self.last_t.max(self.offset) as usize))
            .set(
                "anchors",
                Json::Arr(self.anchors.iter().map(|&a| Json::from(a as usize)).collect()),
            )
            .set(
                "base",
                Json::Arr(self.base.iter().map(|&k| Json::from(k as usize)).collect()),
            )
            .set(
                "intervals",
                Json::Arr(self.current.iter().map(|&k| Json::from(k as usize)).collect()),
            )
            .set("ratio", Json::from_f64_slice(&self.ratio))
            .set(
                "quiet",
                Json::Arr(self.quiet.iter().map(|&q| Json::from(q as usize)).collect()),
            );
        if self.migrations_done > 0
            || self.culprit_streak > 0
            || self.pending_migration.is_some()
        {
            let mut m = Json::obj();
            m.set("done", Json::from(self.migrations_done)).set(
                "migrated",
                Json::Arr(
                    self.migrated_learners().into_iter().map(Json::from).collect(),
                ),
            );
            m.set("streak", Json::from(self.culprit_streak as usize));
            if let Some(c) = self.last_culprit {
                m.set("culprit", Json::from(c));
            }
            if let Some(p) = self.pending_migration {
                m.set("pending", Json::from(p));
            }
            o.set("migration", m);
        }
        o
    }

    fn restore(&mut self, state: &Json) -> Result<()> {
        self.offset = state.req("offset")?.as_usize()? as u64;
        self.anchors = state
            .req("anchors")?
            .usize_arr()?
            .into_iter()
            .map(|a| a as u64)
            .collect();
        self.base = state
            .req("base")?
            .usize_arr()?
            .into_iter()
            .map(|k| k as u64)
            .collect();
        self.current = state
            .req("intervals")?
            .usize_arr()?
            .into_iter()
            .map(|k| k as u64)
            .collect();
        self.ratio =
            state.req("ratio")?.as_arr()?.iter().map(|v| v.as_f64()).collect::<Result<_>>()?;
        self.quiet = state
            .req("quiet")?
            .usize_arr()?
            .into_iter()
            .map(|q| q.min(u32::MAX as usize) as u32)
            .collect();
        if self.base.len() != self.ratio.len()
            || self.base.len() != self.anchors.len()
            || self.base.len() != self.quiet.len()
        {
            bail!(
                "adaptive controller state is inconsistent: {} base / {} ratio / {} anchor \
                 / {} quiet entries",
                self.base.len(),
                self.ratio.len(),
                self.anchors.len(),
                self.quiet.len()
            );
        }
        if !self.base.is_empty() {
            // The sidecar is editable JSON: re-check every invariant the
            // live controller maintains, so a resumed run can never fire
            // from a table the emitted schedule block would misreport.
            check_restored_table("adaptive controller", &self.base, &self.current)?;
            for (l, (&b, &c)) in self.base.iter().zip(&self.current).enumerate() {
                if c < b {
                    bail!(
                        "adaptive controller state is inconsistent: interval {c} below the \
                         base {b} at level {l} (the controller never narrows below base)"
                    );
                }
            }
            let outer = *self.current.last().unwrap();
            let cap = self.k2_clamp.max(*self.base.last().unwrap());
            if outer > cap {
                bail!(
                    "adaptive controller state is inconsistent: outermost interval {outer} \
                     above the condition-(3.5) widening cap {cap}"
                );
            }
            if self.ratio.iter().any(|r| !r.is_finite() || *r < 0.0) {
                bail!(
                    "adaptive controller state is inconsistent: stall/compute ratios must \
                     be finite and >= 0 (got {:?})",
                    self.ratio
                );
            }
        } else if !self.current.is_empty() {
            bail!(
                "adaptive controller state is inconsistent: {} current entries with no base",
                self.current.len()
            );
        }
        if let Some(&a) = self.anchors.iter().find(|&&a| a > self.offset) {
            bail!(
                "adaptive controller state is inconsistent: anchor step {a} past the {} \
                 steps the saving run completed",
                self.offset
            );
        }
        // Migration bookkeeping: absent in pre-elastic sidecars (and in
        // any run that never touched membership) — restore to the
        // all-clear default.  When present, every invariant the live
        // controller maintains is re-checked, because a warm restart
        // acts on this table (the engine re-detaches `migrated`): a
        // corrupt sidecar must fail loudly, never silently re-attach or
        // over-migrate.
        self.last_culprit = None;
        self.culprit_streak = 0;
        self.pending_migration = None;
        self.migrated = vec![false; self.p];
        self.migrations_done = 0;
        if let Some(m) = state.get("migration") {
            let done = m.req("done")?.as_usize()?;
            let migrated = m.req("migrated")?.usize_arr()?;
            if migrated.len() != done {
                bail!(
                    "adaptive migration state is inconsistent: done = {done} but {} \
                     migrated learners listed",
                    migrated.len()
                );
            }
            if done > self.migration_cap() {
                bail!(
                    "adaptive migration state is inconsistent: {done} migrations past \
                     the cap of {} for P = {}",
                    self.migration_cap(),
                    self.p
                );
            }
            for w in migrated.windows(2) {
                if w[0] >= w[1] {
                    bail!(
                        "adaptive migration state is inconsistent: migrated learners \
                         {migrated:?} are not strictly increasing"
                    );
                }
            }
            for &l in &migrated {
                if l >= self.p {
                    bail!(
                        "adaptive migration state is inconsistent: migrated learner {l} \
                         out of range for P = {}",
                        self.p
                    );
                }
                self.migrated[l] = true;
            }
            self.migrations_done = done;
            let streak = m.req("streak")?.as_usize()?;
            self.culprit_streak = streak.min(u32::MAX as usize) as u32;
            self.last_culprit = match m.get("culprit") {
                Some(c) => Some(c.as_usize()?),
                None => None,
            };
            match (streak > 0, self.last_culprit) {
                (true, None) => bail!(
                    "adaptive migration state is inconsistent: a culprit streak of \
                     {streak} with no culprit learner"
                ),
                (false, Some(c)) => bail!(
                    "adaptive migration state is inconsistent: culprit learner {c} \
                     with a zero streak"
                ),
                _ => {}
            }
            if let Some(c) = self.last_culprit {
                if c >= self.p {
                    bail!(
                        "adaptive migration state is inconsistent: culprit learner {c} \
                         out of range for P = {}",
                        self.p
                    );
                }
            }
            self.pending_migration = match m.get("pending") {
                Some(pm) => Some(pm.as_usize()?),
                None => None,
            };
            if let Some(pm) = self.pending_migration {
                if pm >= self.p || !self.migrated[pm] {
                    bail!(
                        "adaptive migration state is inconsistent: pending migration \
                         {pm} is not among the migrated learners {migrated:?}"
                    );
                }
            }
        }
        self.last_t = self.offset;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// WarmupPolicy
// ---------------------------------------------------------------------------

/// Dense-to-sparse warmup: during stage `s` (steps `s·L+1 ..= (s+1)·L`
/// with `L = stage_steps`) every base interval is capped at `2^s`, so
/// the run starts near synchronous SGD and decays to the configured
/// schedule — the Adaptive-Periodic-Averaging shape.
#[derive(Debug, Clone)]
pub struct WarmupPolicy {
    pub stage_steps: u64,
    offset: u64,
    last_t: u64,
    /// Stage index the current table was built for (the per-step path is
    /// one division + compare; the table is rebuilt only on a stage or
    /// base change — the layer must cost ~0 vs static).
    stage: u64,
    base: Vec<u64>,
    current: Vec<u64>,
    anchor: u64,
    changes: Vec<ScheduleChange>,
}

impl WarmupPolicy {
    pub fn new(stage_steps: u64) -> WarmupPolicy {
        WarmupPolicy {
            stage_steps: stage_steps.max(1),
            offset: 0,
            last_t: 0,
            stage: 0,
            base: Vec::new(),
            current: Vec::new(),
            anchor: 0,
            changes: Vec::new(),
        }
    }
}

impl SchedulePolicy for WarmupPolicy {
    fn name(&self) -> &'static str {
        "warmup"
    }

    fn decide(&mut self, t: u64, base: &HierSchedule) -> Option<usize> {
        let t_abs = t + self.offset;
        self.last_t = t_abs;
        let stage = t_abs.saturating_sub(1) / self.stage_steps;
        if self.current.is_empty() || stage != self.stage || self.base != base.intervals() {
            let first = self.current.is_empty();
            self.stage = stage;
            self.base = base.intervals().to_vec();
            let cap = if stage >= 63 { u64::MAX } else { 1u64 << stage };
            let target: Vec<u64> = self.base.iter().map(|&k| k.min(cap)).collect();
            if target != self.current {
                self.current = target;
                // Phase re-anchors at the stage boundary (the previous
                // step), so `rel` restarts at 1 for this step.  The
                // initial table is recorded only when it actually
                // deviates from the base.
                self.anchor = t_abs - 1;
                if !first || self.current != self.base {
                    self.changes
                        .push(ScheduleChange { step: t_abs, intervals: self.current.clone() });
                }
            }
        }
        let rel = t_abs - self.anchor;
        fire_level(&self.current, rel)
    }

    fn intervals(&self, base: &HierSchedule) -> Vec<u64> {
        if self.current.is_empty() {
            base.intervals().to_vec()
        } else {
            self.current.clone()
        }
    }

    fn changes(&self) -> &[ScheduleChange] {
        &self.changes
    }

    fn state(&self) -> Json {
        let mut o = Json::obj();
        o.set("offset", Json::from(self.last_t.max(self.offset) as usize))
            .set("anchor", Json::from(self.anchor as usize))
            .set(
                "base",
                Json::Arr(self.base.iter().map(|&k| Json::from(k as usize)).collect()),
            )
            .set(
                "intervals",
                Json::Arr(self.current.iter().map(|&k| Json::from(k as usize)).collect()),
            );
        o
    }

    fn restore(&mut self, state: &Json) -> Result<()> {
        self.offset = state.req("offset")?.as_usize()? as u64;
        self.anchor = state.req("anchor")?.as_usize()? as u64;
        self.base = state
            .req("base")?
            .usize_arr()?
            .into_iter()
            .map(|k| k as u64)
            .collect();
        self.current = state
            .req("intervals")?
            .usize_arr()?
            .into_iter()
            .map(|k| k as u64)
            .collect();
        if !self.base.is_empty() {
            check_restored_table("warmup policy", &self.base, &self.current)?;
            // Warmup only ever caps the base downward.
            for (l, (&b, &c)) in self.base.iter().zip(&self.current).enumerate() {
                if c > b {
                    bail!(
                        "warmup policy state is inconsistent: interval {c} above the base \
                         {b} at level {l} (warmup only caps the base downward)"
                    );
                }
            }
        }
        if self.anchor > self.offset {
            bail!(
                "warmup policy state is inconsistent: anchor step {} past the {} steps \
                 the saving run completed",
                self.anchor,
                self.offset
            );
        }
        self.last_t = self.offset;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(ks: &[u64]) -> HierSchedule {
        HierSchedule::new(ks.to_vec()).unwrap()
    }

    #[test]
    fn parse_and_spec_roundtrip() {
        for s in ["static", "adaptive", "adaptive:0.5", "adaptive:0.5:0", "warmup", "warmup:32"]
        {
            let k = PolicyKind::parse(s).unwrap();
            let k2 = PolicyKind::parse(&k.spec()).unwrap();
            assert_eq!(k, k2, "spec {s:?} did not roundtrip");
        }
        assert_eq!(PolicyKind::parse("static").unwrap(), PolicyKind::Static);
        assert_eq!(
            PolicyKind::parse("adaptive").unwrap(),
            PolicyKind::Adaptive { target: DEFAULT_ADAPTIVE_TARGET, gain: 1.0 }
        );
        assert_eq!(
            PolicyKind::parse("warmup:8").unwrap(),
            PolicyKind::Warmup { stage_steps: 8 }
        );
    }

    #[test]
    fn parse_rejects_garbage_with_context() {
        for bad in [
            "static:1",
            "adaptive:lots",
            "adaptive:0",
            "adaptive:-1",
            "adaptive:0.5:-2",
            "adaptive:0.5:1:9",
            "warmup:0",
            "warmup:soon",
            "",
        ] {
            assert!(PolicyKind::parse(bad).is_err(), "{bad:?} should be rejected");
        }
        let err = PolicyKind::parse("adaptivee").unwrap_err().to_string();
        assert!(err.contains("static | adaptive"), "unhelpful error: {err}");
    }

    #[test]
    fn static_policy_matches_base_schedule() {
        let base = sched(&[2, 6]);
        let mut p = StaticPolicy::new();
        for t in 1..=200 {
            assert_eq!(p.decide(t, &base), base.event_after(t));
        }
        assert!(p.changes().is_empty());
        assert_eq!(p.intervals(&base), vec![2, 6]);
    }

    #[test]
    fn zero_gain_adaptive_is_the_static_decision_stream() {
        let base = sched(&[2, 3, 7]);
        let mut a = AdaptivePolicy::new(0.25, 0.0, 1_000, 1e-3, 8);
        let mut s = StaticPolicy::new();
        for t in 1..=500 {
            let d = a.decide(t, &base);
            assert_eq!(d, s.decide(t, &base), "t={t}");
            if let Some(level) = d {
                // Feedback must be inert too.
                a.observe(t, level, 123.0, 1e-6);
            }
        }
        assert!(a.changes().is_empty());
        assert_eq!(a.intervals(&base), base.intervals().to_vec());
    }

    #[test]
    fn adaptive_widens_under_stall_and_respects_clamp() {
        let base = sched(&[2, 8]);
        let clamp = 32;
        let step = 1e-3;
        let p = 8;
        let mut pol = AdaptivePolicy::new(0.25, 1.0, clamp, step, p);
        let mut fired = vec![0u64; 2];
        for t in 1..=2_000u64 {
            if let Some(level) = pol.decide(t, &base) {
                fired[level] += 1;
                // Synthetic heavy stall: half the cluster's interval
                // budget lost at every barrier.
                let budget = p as f64 * pol.intervals(&base)[level] as f64 * step;
                pol.observe(t, level, 0.5 * budget, 1e-6);
            }
        }
        let current = pol.intervals(&base);
        assert_eq!(current[1], clamp, "outermost did not widen to the clamp: {current:?}");
        assert!(current[0] >= 2 && current[0] <= current[1], "chain broken: {current:?}");
        assert!(!pol.changes().is_empty());
        for c in pol.changes() {
            assert!(*c.intervals.last().unwrap() <= clamp);
            for w in c.intervals.windows(2) {
                assert!(w[0] <= w[1], "non-monotone table {:?}", c.intervals);
            }
        }
        // Fewer global events than the static schedule would have fired.
        assert!(fired[1] < 2_000 / 8, "global tier did not thin out: {fired:?}");
    }

    #[test]
    fn adaptive_event_gaps_never_shrink_below_base() {
        // The invariant the CI smoke asserts from the JSON: realized
        // global reductions <= static's, guaranteed because intervals
        // never narrow below base and phase restarts only stretch gaps.
        let base = sched(&[2, 8]);
        let mut pol = AdaptivePolicy::new(0.25, 1.0, 64, 1e-3, 8);
        let mut last_global = 0u64;
        let mut globals = 0u64;
        let horizon = 4_000u64;
        for t in 1..=horizon {
            if let Some(level) = pol.decide(t, &base) {
                if level == 1 {
                    assert!(t - last_global >= 8, "gap {} at t={t}", t - last_global);
                    last_global = t;
                    globals += 1;
                }
                // Alternate heavy and zero stall so the controller both
                // widens and narrows over the run.
                let stall = if (t / 512) % 2 == 0 { 1.0 } else { 0.0 };
                pol.observe(t, level, stall, 1e-6);
            }
        }
        assert!(globals <= horizon / 8);
        // The floor holds even after narrowing cycles.
        assert!(pol.intervals(&base)[1] >= 8);
    }

    #[test]
    fn base_beyond_clamp_is_adopted_verbatim_never_densified() {
        // A user schedule already past the condition-(3.5) clamp is the
        // user's choice, exactly as in a static run: the controller must
        // neither densify it down to the clamp (that would fire MORE
        // global reductions than static) nor widen past it.
        let base = sched(&[2, 512]);
        let mut pol = AdaptivePolicy::new(0.25, 1.0, 14, 1e-3, 8);
        let mut globals = 0u64;
        for t in 1..=2_048u64 {
            if let Some(level) = pol.decide(t, &base) {
                if level == 1 {
                    globals += 1;
                }
                // Heavy stall at every barrier.
                pol.observe(t, level, 1.0, 1e-6);
            }
        }
        assert_eq!(pol.intervals(&base)[1], 512);
        assert!(globals <= 2_048 / 512, "adaptive fired {globals} global reductions");
    }

    #[test]
    fn mid_run_base_change_is_recorded_and_reanchors() {
        // The per-epoch k2_schedule path swaps the base schedule under a
        // live controller: the reset must land in the trajectory (the
        // emitted `adaptations` always reflect what actually ran) and
        // the new table fires on a fresh phase.
        let a = sched(&[2, 8]);
        let b = sched(&[2, 4]);
        let mut pol = AdaptivePolicy::new(0.25, 1.0, 64, 1e-3, 8);
        for t in 1..=64u64 {
            if let Some(level) = pol.decide(t, &a) {
                pol.observe(t, level, 1.0, 1e-6); // heavy stall: widens
            }
        }
        assert!(pol.intervals(&a)[1] > 8, "setup never widened");
        let n_before = pol.changes().len();
        pol.decide(65, &b);
        assert_eq!(pol.changes().len(), n_before + 1, "base reset not recorded");
        let last = pol.changes().last().unwrap();
        assert_eq!((last.step, last.intervals.clone()), (65, vec![2, 4]));
        assert_eq!(pol.intervals(&b), vec![2, 4]);
        // Fresh phase: the first firing of the new table is 4 steps in.
        let mut next_global = None;
        for t in 65..=80u64 {
            if t > 65 {
                if pol.decide(t, &b) == Some(1) && next_global.is_none() {
                    next_global = Some(t);
                }
            }
        }
        assert_eq!(next_global, Some(68));
    }

    #[test]
    fn adaptive_state_roundtrips_and_resumes() {
        let base = sched(&[2, 8]);
        let mut a = AdaptivePolicy::new(0.25, 1.0, 64, 1e-3, 8);
        for t in 1..=300u64 {
            if let Some(level) = a.decide(t, &base) {
                a.observe(t, level, 0.8 * 8.0 * 8.0 * 1e-3, 1e-6);
            }
        }
        let state = a.state();
        let mut b = AdaptivePolicy::new(0.25, 1.0, 64, 1e-3, 8);
        b.restore(&state).unwrap();
        // The resumed policy continues the original's decision stream:
        // driving the original further must match the restored copy
        // driven from t = 1.
        for t in 1..=200u64 {
            let da = a.decide(300 + t, &base);
            let db = b.decide(t, &base);
            assert_eq!(da, db, "t={t}");
            if let Some(level) = da {
                a.observe(300 + t, level, 0.0, 1e-6);
                b.observe(t, level, 0.0, 1e-6);
            }
        }
        assert_eq!(a.intervals(&base), b.intervals(&base));
        // Corrupt state is rejected.
        let mut broken = AdaptivePolicy::new(0.25, 1.0, 64, 1e-3, 8);
        assert!(broken.restore(&Json::obj()).is_err());
    }

    #[test]
    fn migration_state_roundtrips_through_the_sidecar() {
        // PR 7 regression: migration bookkeeping must survive a warm
        // restart — a resumed controller that forgot its detachments
        // would re-attach the straggler and re-burn a migration slot on
        // it.
        let base = sched(&[2, 8]);
        let p = 32;
        let step = 1e-3;
        let mut a = AdaptivePolicy::new(0.25, 1.0, 64, step, p);
        for i in 0..(MIGRATE_STREAK as u64 + 2) {
            let t = (i + 1) * 8;
            let level = a.decide(t, &base).unwrap();
            let budget = p as f64 * a.intervals(&base)[level] as f64 * step;
            a.observe_culprit(t, level, 7, budget, 1e-6);
        }
        assert_eq!(a.take_migration(), Some(7));
        assert_eq!(a.migrated_learners(), vec![7]);
        // Build a fresh streak (not yet a migration) so the in-flight
        // counters roundtrip too.
        let t = 100 * 8;
        let level = a.decide(t, &base).unwrap();
        let budget = p as f64 * a.intervals(&base)[level] as f64 * step;
        a.observe_culprit(t, level, 19, budget, 1e-6);

        let state = a.state();
        let m = state.req("migration").unwrap();
        assert_eq!(m.req("done").unwrap().as_usize().unwrap(), 1);
        assert_eq!(m.req("migrated").unwrap().usize_arr().unwrap(), vec![7]);
        assert_eq!(m.req("streak").unwrap().as_usize().unwrap(), 1);
        assert_eq!(m.req("culprit").unwrap().as_usize().unwrap(), 19);

        let mut b = AdaptivePolicy::new(0.25, 1.0, 64, step, p);
        b.restore(&state).unwrap();
        assert_eq!(b.migrated_learners(), vec![7], "detachments lost on restore");
        // The restored streak continues: learner 19 needs only the
        // remaining expensive barriers, same as the original.
        let mut granted = (None, None);
        for (who, pol) in [(0, &mut a), (1, &mut b)] {
            for i in 0..MIGRATE_STREAK as u64 {
                let t = (200 + i + 1) * 8;
                let level = pol.decide(t, &base).unwrap();
                let budget = p as f64 * pol.intervals(&base)[level] as f64 * step;
                pol.observe_culprit(t, level, 19, budget, 1e-6);
                if let Some(g) = pol.take_migration() {
                    let slot = if who == 0 { &mut granted.0 } else { &mut granted.1 };
                    assert!(slot.is_none());
                    *slot = Some((i, g));
                }
            }
        }
        assert_eq!(granted.0, granted.1, "restored streak diverged from the original");
        assert!(granted.0.is_some(), "setup: streak never completed");
        // A migration pending (granted, not yet drained by the engine)
        // also survives.
        let state = a.state();
        assert_eq!(state.req("migration").unwrap().req("done").unwrap().as_usize().unwrap(), 2);
        let mut c = AdaptivePolicy::new(0.25, 1.0, 64, step, p);
        // a's pending was drained in the loop above; fabricate one via a
        // fresh grant on a restored copy instead.
        c.restore(&state).unwrap();
        assert_eq!(c.migrated_learners(), vec![7, 19]);
        assert_eq!(c.take_migration(), None, "no pending migration was saved");
        // Legacy sidecar (no migration block) restores to the all-clear
        // default — pre-elastic checkpoints stay loadable.
        let legacy = match a.state() {
            Json::Obj(mut kvs) => {
                kvs.remove("migration");
                Json::Obj(kvs)
            }
            other => other,
        };
        let mut d = AdaptivePolicy::new(0.25, 1.0, 64, step, p);
        d.restore(&legacy).unwrap();
        assert!(d.migrated_learners().is_empty());
    }

    #[test]
    fn pending_migration_roundtrips() {
        let base = sched(&[2, 8]);
        let p = 32;
        let step = 1e-3;
        let mut a = AdaptivePolicy::new(0.25, 1.0, 64, step, p);
        for i in 0..(MIGRATE_STREAK as u64 + 2) {
            let t = (i + 1) * 8;
            let level = a.decide(t, &base).unwrap();
            let budget = p as f64 * a.intervals(&base)[level] as f64 * step;
            a.observe_culprit(t, level, 7, budget, 1e-6);
        }
        // NOT drained: the checkpoint fired between the grant and the
        // engine's poll.
        let state = a.state();
        assert_eq!(state.req("migration").unwrap().req("pending").unwrap().as_usize().unwrap(), 7);
        let mut b = AdaptivePolicy::new(0.25, 1.0, 64, step, p);
        b.restore(&state).unwrap();
        assert_eq!(b.take_migration(), Some(7), "pending migration lost on restore");
        assert_eq!(b.take_migration(), None);
    }

    #[test]
    fn restore_rejects_corrupt_migration_state() {
        let table = r#""anchors": [8, 0], "base": [2, 8], "intervals": [2, 8], "ratio": [0, 0], "quiet": [0, 0]"#;
        let cases = [
            // done disagrees with the migrated list
            r#"{"done": 2, "migrated": [7], "streak": 0}"#,
            // past the cap (P = 32 -> cap 2)
            r#"{"done": 3, "migrated": [3, 7, 9], "streak": 0}"#,
            // out-of-range learner
            r#"{"done": 1, "migrated": [99], "streak": 0}"#,
            // duplicate / unsorted list
            r#"{"done": 2, "migrated": [7, 7], "streak": 0}"#,
            // a streak with no culprit
            r#"{"done": 0, "migrated": [], "streak": 3}"#,
            // a culprit with no streak
            r#"{"done": 0, "migrated": [], "streak": 0, "culprit": 7}"#,
            // out-of-range culprit
            r#"{"done": 0, "migrated": [], "streak": 2, "culprit": 99}"#,
            // pending not among the migrated
            r#"{"done": 1, "migrated": [7], "streak": 0, "pending": 9}"#,
            // missing required field
            r#"{"done": 1, "migrated": [7]}"#,
        ];
        for m in cases {
            let s = format!(r#"{{"offset": 10, {table}, "migration": {m}}}"#);
            let state = Json::parse(&s).unwrap();
            let mut pol = AdaptivePolicy::new(0.25, 1.0, 64, 1e-3, 32);
            assert!(pol.restore(&state).is_err(), "accepted corrupt migration state {m}");
        }
        // The same table with a consistent block is accepted (the harness
        // above is testing the block, not the table).
        let ok = format!(
            r#"{{"offset": 10, {table}, "migration": {{"done": 1, "migrated": [7], "streak": 2, "culprit": 9}}}}"#
        );
        let mut pol = AdaptivePolicy::new(0.25, 1.0, 64, 1e-3, 32);
        pol.restore(&Json::parse(&ok).unwrap()).unwrap();
        assert_eq!(pol.migrated_learners(), vec![7]);
    }

    #[test]
    fn restore_rejects_tables_that_violate_controller_invariants() {
        // The sidecar is editable JSON: a resumed run must fail loudly
        // rather than fire from a table the schedule block would
        // misreport.
        let cases = [
            // non-monotone current
            r#"{"offset": 10, "anchors": [8, 0], "base": [2, 8], "intervals": [16, 8], "ratio": [0, 0], "quiet": [0, 0]}"#,
            // below base
            r#"{"offset": 10, "anchors": [8, 0], "base": [2, 8], "intervals": [2, 4], "ratio": [0, 0], "quiet": [0, 0]}"#,
            // outermost above the widening cap (clamp 64, base 8)
            r#"{"offset": 10, "anchors": [8, 0], "base": [2, 8], "intervals": [2, 512], "ratio": [0, 0], "quiet": [0, 0]}"#,
            // zero interval
            r#"{"offset": 10, "anchors": [8, 0], "base": [2, 8], "intervals": [0, 8], "ratio": [0, 0], "quiet": [0, 0]}"#,
            // negative EWMA ratio
            r#"{"offset": 10, "anchors": [8, 0], "base": [2, 8], "intervals": [2, 8], "ratio": [0, -1], "quiet": [0, 0]}"#,
            // an anchor past the saved run's steps
            r#"{"offset": 10, "anchors": [8, 99], "base": [2, 8], "intervals": [2, 8], "ratio": [0, 0], "quiet": [0, 0]}"#,
            // anchors/quiet arity drift
            r#"{"offset": 10, "anchors": [8], "base": [2, 8], "intervals": [2, 8], "ratio": [0, 0], "quiet": [0, 0]}"#,
            // current with no base
            r#"{"offset": 0, "anchors": [], "base": [], "intervals": [2, 8], "ratio": [], "quiet": []}"#,
        ];
        for s in cases {
            let state = Json::parse(s).unwrap();
            let mut pol = AdaptivePolicy::new(0.25, 1.0, 64, 1e-3, 8);
            assert!(pol.restore(&state).is_err(), "accepted corrupt state {s}");
        }
        // Warmup: an interval above the base is impossible for a policy
        // that only caps downward.
        let state = Json::parse(
            r#"{"offset": 10, "anchor": 8, "base": [2, 8], "intervals": [2, 16]}"#,
        )
        .unwrap();
        let mut w = WarmupPolicy::new(8);
        assert!(w.restore(&state).is_err());
    }

    #[test]
    fn default_policies_ignore_culprit_feedback() {
        let base = sched(&[2, 8]);
        let mut s = StaticPolicy::new();
        let mut w = WarmupPolicy::new(8);
        for t in 1..=64u64 {
            s.decide(t, &base);
            w.decide(t, &base);
            s.observe_culprit(t, 1, 3, 1e9, 1e-6);
            w.observe_culprit(t, 1, 3, 1e9, 1e-6);
        }
        assert_eq!(s.take_migration(), None);
        assert_eq!(w.take_migration(), None);
        // The neutral (zero-gain) adaptive controller is inert here too.
        let mut n = AdaptivePolicy::new(0.25, 0.0, 64, 1e-3, 8);
        for t in 1..=64u64 {
            n.decide(t, &base);
            n.observe_culprit(t, 1, 3, 1e9, 1e-6);
        }
        assert_eq!(n.take_migration(), None);
    }

    #[test]
    fn adaptive_migrates_persistent_culprit_and_respects_cap() {
        let base = sched(&[2, 8]);
        let p = 32; // cap = max(1, 32/16) = 2 migrations
        let step = 1e-3;
        let mut pol = AdaptivePolicy::new(0.25, 1.0, 64, step, p);
        let mut migrated = Vec::new();
        // Three learners take turns being the persistent culprit; only
        // the first two fit the migration budget.
        for (round, culprit) in [(0u64, 7usize), (1, 19), (2, 28)] {
            for i in 0..(MIGRATE_STREAK as u64 + 2) {
                let t = round * 800 + (i + 1) * 8; // every global boundary
                let level = pol.decide(t, &base).expect("global fires on its interval");
                assert_eq!(level, 1);
                let budget = p as f64 * pol.intervals(&base)[level] as f64 * step;
                // Well past target × budget: an expensive barrier.
                pol.observe_culprit(t, level, culprit, budget, 1e-6);
                if let Some(m) = pol.take_migration() {
                    migrated.push(m);
                }
            }
        }
        assert_eq!(migrated, vec![7, 19], "cap of 2 not honoured");
        // A quiet barrier resets the streak: intermittent blame never
        // triggers a migration even with budget left.
        let mut pol = AdaptivePolicy::new(0.25, 1.0, 64, step, p);
        for i in 0..20u64 {
            let t = (i + 1) * 8;
            let level = pol.decide(t, &base).unwrap();
            let budget = p as f64 * pol.intervals(&base)[level] as f64 * step;
            let stall = if i % 2 == 0 { budget } else { 0.0 };
            pol.observe_culprit(t, level, 7, stall, 1e-6);
            assert_eq!(pol.take_migration(), None, "migrated at t={t}");
        }
        // ... and a learner is never migrated twice.
        let mut pol = AdaptivePolicy::new(0.25, 1.0, 64, step, p);
        let mut count = 0;
        for i in 0..40u64 {
            let t = (i + 1) * 8;
            let level = pol.decide(t, &base).unwrap();
            let budget = p as f64 * pol.intervals(&base)[level] as f64 * step;
            pol.observe_culprit(t, level, 7, budget, 1e-6);
            if pol.take_migration().is_some() {
                count += 1;
            }
        }
        assert_eq!(count, 1, "learner 7 migrated more than once");
    }

    #[test]
    fn warmup_is_dense_early_and_decays_to_base() {
        let base = sched(&[4, 16]);
        let mut w = WarmupPolicy::new(8);
        // Stage 0: cap 1 — a (global) reduction after every step.
        for t in 1..=8u64 {
            assert_eq!(w.decide(t, &base), Some(1), "t={t}");
        }
        assert_eq!(w.intervals(&base), vec![1, 1]);
        // Stage 2: cap 4 — the inner tier is at base, outer still capped.
        for t in 17..=24u64 {
            w.decide(t, &base);
        }
        assert_eq!(w.intervals(&base), vec![4, 4]);
        // Far past warmup: the base schedule, and no further changes.
        for t in 25..=200u64 {
            w.decide(t, &base);
        }
        assert_eq!(w.intervals(&base), base.intervals().to_vec());
        let n_changes = w.changes().len();
        for t in 201..=400u64 {
            w.decide(t, &base);
        }
        assert_eq!(w.changes().len(), n_changes, "changes after warmup completed");
        // The trajectory starts at the dense table.
        assert_eq!(w.changes()[0].step, 1);
        assert_eq!(w.changes()[0].intervals, vec![1, 1]);
    }

    #[test]
    fn warmup_state_roundtrips() {
        let base = sched(&[4, 16]);
        let mut a = WarmupPolicy::new(8);
        for t in 1..=20u64 {
            a.decide(t, &base);
        }
        let mut b = WarmupPolicy::new(8);
        b.restore(&a.state()).unwrap();
        for t in 1..=50u64 {
            assert_eq!(b.decide(t, &base), a.decide(20 + t, &base), "t={t}");
        }
    }

    #[test]
    fn build_dispatches_by_kind() {
        for (spec, name) in
            [("static", "static"), ("adaptive:0.5", "adaptive"), ("warmup:8", "warmup")]
        {
            let kind = PolicyKind::parse(spec).unwrap();
            let policy = kind.build(100, 1e-3, 8);
            assert_eq!(policy.name(), name);
        }
    }
}
