//! Simulated asynchronous SGD with a (sharded) parameter server — the
//! baseline family the paper's introduction argues against (Recht et al.
//! 2011; Dean et al. 2012; Li et al. 2014).
//!
//! Execution model: workers compute gradients against the parameter copy
//! they last *fetched*; the server applies gradient pushes one at a time.
//! With P workers pushing round-robin, a gradient is applied `P−1` ticks
//! after its fetch — the classic staleness-∝-P behaviour (§1: "the
//! staleness of gradients ... is proportional to the number of learners").
//! The server's serialization is also what limits throughput: every push +
//! pull crosses the inter-node link and queues at the server, so modelled
//! time grows linearly in P while Hier-AVG's reductions amortize over K2
//! steps.  `repro asgd` reproduces that comparison.

use std::time::Instant;

use anyhow::Result;

use crate::backend::{StepBackend, StepOut};
use crate::comm::CostModel;
use crate::config::RunConfig;
use crate::data::{BatchBuf, DataSource};
use crate::metrics::{EpochStats, RunRecord};
use crate::optimizer::Sgd;
use crate::params::{FlatParams, Rows, RowsMut};
use crate::topology::LinkClass;
use crate::util::rng::Pcg32;

pub struct AsgdTrainer<'a> {
    pub cfg: &'a RunConfig,
    pub backend: Box<dyn StepBackend>,
    pub data: Box<dyn DataSource>,
    pub init: FlatParams,
    /// Server shards (Li et al. 2014): pushes to distinct shards proceed
    /// concurrently; bytes per message shrink accordingly.
    pub shards: usize,
}

impl<'a> AsgdTrainer<'a> {
    pub fn new(
        cfg: &'a RunConfig,
        backend: Box<dyn StepBackend>,
        data: Box<dyn DataSource>,
        init: FlatParams,
        shards: usize,
    ) -> Result<AsgdTrainer<'a>> {
        anyhow::ensure!(shards >= 1, "shards must be >= 1");
        anyhow::ensure!(
            init.len() == backend.n_params(),
            "init/backend parameter count mismatch"
        );
        Ok(AsgdTrainer { cfg, backend, data, init, shards })
    }

    /// Server ticks per epoch: the same sample budget as the synchronous
    /// trainers (train_n samples per epoch; each tick consumes one
    /// mini-batch of B).
    pub fn ticks_per_epoch(&self) -> usize {
        (self.data.train_n() / self.backend.train_batch()).max(1)
    }

    pub fn run(&mut self) -> Result<RunRecord> {
        let cfg = self.cfg;
        let p = cfg.p;
        let b = self.backend.train_batch();
        let n = self.backend.n_params();
        let cost: &CostModel = &cfg.cost;

        // Server state + per-worker stale snapshots.
        let mut server: FlatParams = self.init.clone();
        let mut snapshots: Vec<FlatParams> = vec![self.init.clone(); p];
        let mut opt = Sgd::new(cfg.momentum, cfg.weight_decay, n);

        let mut root = Pcg32::new(cfg.seed, 0x41534744); // "ASGD"
        let mut rngs: Vec<Pcg32> = (0..p).map(|j| root.fork(j as u64)).collect();

        let mut record = RunRecord {
            label: format!("asgd-{}-p{}", cfg.model, p),
            // ASGD's own overlap model is neither lockstep nor the event
            // engine; name it so the JSON `exec` block is self-describing.
            exec_model: "asgd".to_string(),
            ..Default::default()
        };
        let tpe = self.ticks_per_epoch();
        // Modelled compute: each worker's fwd+bwd overlaps with others, so
        // per *round* of P ticks one step-time elapses; the server
        // serializes the message handling on top of that.
        const DEVICE_FLOPS: f64 = 10.6e12;
        let step_secs = 6.0 * b as f64 * n as f64 / DEVICE_FLOPS;
        let msg_bytes = n * 4 / self.shards;
        // push (grad) + pull (params): two inter-node messages, queued at
        // the server => serialized across workers within a round.
        let msg_secs = 2.0 * (cost.alpha_inter + msg_bytes as f64 * cost.beta_inter);

        let mut batch = BatchBuf::default();
        let mut grads = vec![0.0f32; n];
        let mut outs = vec![StepOut::default()];
        let units = self.backend.units_per_row() as f64;
        let started = Instant::now();
        let mut ticks: u64 = 0;

        for epoch in 0..cfg.epochs {
            let lr = cfg.lr.lr_at(epoch);
            let mut ep_loss = 0.0f64;
            let mut ep_correct = 0.0f64;
            for tick in 0..tpe {
                let j = tick % p; // round-robin pusher
                batch.clear();
                self.data.fill_train(&mut rngs[j], b, &mut batch);
                // Gradient at the STALE snapshot (fetched ~P-1 ticks ago).
                self.backend.grads(
                    Rows::single(&snapshots[j]),
                    &batch,
                    RowsMut::single(&mut grads),
                    &mut outs,
                )?;
                // Server applies, worker pulls fresh params.
                opt.apply(&mut server, &grads, lr);
                snapshots[j].copy_from_slice(&server);
                ticks += 1;
                record.comm.global_reductions += 1;
                record.comm.global_bytes += 2 * msg_bytes as u64;
                record.comm.global_seconds += msg_secs;
                ep_loss += outs[0].loss as f64;
                ep_correct += outs[0].ncorrect as f64;
                if cfg.record_steps {
                    record.step_loss.push(outs[0].loss);
                }
            }
            // P workers compute concurrently: tpe ticks = tpe/P rounds.
            record.sim_compute_seconds += (tpe as f64 / p as f64) * step_secs;

            let (test_loss, test_acc) = if epoch % cfg.eval_every.max(1) == 0
                || epoch + 1 == cfg.epochs
            {
                evaluate(self.backend.as_mut(), self.data.as_ref(), &server)?
            } else {
                (f64::NAN, f64::NAN)
            };
            record.epochs.push(EpochStats {
                epoch,
                train_loss: ep_loss / tpe as f64,
                train_acc: ep_correct / (tpe * b) as f64 / units,
                test_loss,
                test_acc,
                sim_seconds: record.sim_compute_seconds + record.comm.total_seconds(),
                wall_seconds: started.elapsed().as_secs_f64(),
            });
        }
        record.total_steps = ticks;
        record.makespan_seconds = record.sim_compute_seconds + record.comm.total_seconds();
        Ok(record)
    }
}

/// Shared eval helper (same contract as `Trainer::evaluate`).
pub fn evaluate(
    backend: &mut dyn StepBackend,
    data: &dyn DataSource,
    params: &FlatParams,
) -> Result<(f64, f64)> {
    let eb = backend.eval_batch();
    let units = backend.units_per_row() as f64;
    let n_batches = data.eval_n() / eb;
    anyhow::ensure!(n_batches > 0, "eval set smaller than eval batch");
    let mut buf = BatchBuf::default();
    let (mut sum_loss, mut ncorrect) = (0.0f64, 0.0f64);
    for i in 0..n_batches {
        buf.clear();
        data.fill_eval(i * eb, eb, &mut buf);
        let (l, c) = backend.eval_batch_stats(params, &buf, eb)?;
        sum_loss += l as f64;
        ncorrect += c as f64;
    }
    let rows = (n_batches * eb) as f64;
    Ok((sum_loss / (rows * units), ncorrect / (rows * units)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BackendKind;
    use crate::data::{ClassifyData, MixtureSpec};
    use crate::native::NativeMlp;

    fn mk(cfg: &RunConfig) -> AsgdTrainer<'_> {
        let backend = NativeMlp::new(&[16, 32, 4], 8, 32).unwrap();
        let data = ClassifyData::generate(MixtureSpec {
            dim: 16,
            classes: 4,
            train_n: cfg.train_n,
            test_n: cfg.test_n,
            radius: 1.0,
            noise: 0.6,
            subclusters: 1,
            label_noise: 0.0,
            seed: 5,
        });
        let mut rng = Pcg32::seeded(cfg.seed);
        let init = backend.init(&mut rng);
        AsgdTrainer::new(cfg, Box::new(backend), Box::new(data), init, 1).unwrap()
    }

    fn cfg() -> RunConfig {
        let mut cfg = RunConfig::defaults("asgd-test");
        cfg.backend = BackendKind::Native;
        cfg.p = 4;
        cfg.epochs = 4;
        cfg.train_n = 1024;
        cfg.test_n = 128;
        cfg.lr = crate::optimizer::LrSchedule::Constant(0.05);
        cfg
    }

    #[test]
    fn asgd_learns_despite_staleness() {
        let cfg = cfg();
        let rec = mk(&cfg).run().unwrap();
        let last = rec.epochs.last().unwrap();
        assert!(last.test_acc > 0.8, "acc = {}", last.test_acc);
        assert!(last.train_loss < rec.epochs[0].train_loss);
    }

    #[test]
    fn asgd_message_count_is_per_tick() {
        let cfg = cfg();
        let mut t = mk(&cfg);
        let tpe = t.ticks_per_epoch();
        let rec = t.run().unwrap();
        assert_eq!(rec.total_steps, (tpe * cfg.epochs) as u64);
        assert_eq!(rec.comm.global_reductions, rec.total_steps);
    }

    #[test]
    fn asgd_deterministic() {
        let cfg = cfg();
        let a = mk(&cfg).run().unwrap();
        let b = mk(&cfg).run().unwrap();
        assert_eq!(a.epochs.last().unwrap().train_loss, b.epochs.last().unwrap().train_loss);
    }

    #[test]
    fn sharding_cuts_message_time() {
        let cfg = cfg();
        let mut one = mk(&cfg);
        one.shards = 1;
        let r1 = one.run().unwrap();
        let mut four = mk(&cfg);
        four.shards = 4;
        let r4 = four.run().unwrap();
        assert!(r4.comm.global_seconds < r1.comm.global_seconds);
    }
}
