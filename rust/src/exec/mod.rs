//! The executor layer: a persistent, work-stealing-free worker pool for
//! the two wall-clock hot paths — group reductions (`comm::collective::
//! PooledCollective`) and the native backend's per-step lane fan-out
//! (`native::ParallelNativeMlp`).
//!
//! Before this layer existed both paths paid a full `std::thread::scope`
//! spawn + join per call (one per reduction, one per training step).  A
//! [`WorkerPool`] instead parks long-lived threads on a condvar and wakes
//! them per dispatch, which replaces thread creation (~tens of µs each)
//! with a notify/wait round-trip (~single-digit µs total).
//!
//! ## Determinism contract
//!
//! The pool never splits, reorders, or steals work: the caller defines an
//! indexed task list and every task index is executed exactly once, with a
//! *static* index→thread assignment (`index % slots`).  Because each
//! task's output depends only on its own index (callers hand tasks
//! disjoint output chunks computed from `(len, slots)` with the same
//! ceil-div math the old scoped-thread paths used), results are
//! bit-identical across runs, thread counts, and oversubscription — the
//! same contract `ShardedCollective` established, now without per-call
//! spawns.  See DESIGN.md §"The executor layer".
//!
//! ## Ownership
//!
//! Pools are process-wide and come from [`shared_pool`]: one pool per
//! resolved thread count, shared by every subsystem that asks for that
//! size (so the collective and the native backend of one run dispatch
//! onto the *same* threads instead of oversubscribing the host twice).
//! Concurrent `run` calls on one pool are serialized internally, so
//! sharing is safe from any thread.

use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// A dispatched batch of indexed tasks, lifetime-erased for the worker
/// threads.  `run` blocks until every worker has finished its share, so
/// the erased borrow can never outlive the data it points into.
#[derive(Clone, Copy)]
struct Job {
    f: &'static (dyn Fn(usize) + Sync),
    n_tasks: usize,
    /// Total execution slots (worker threads + the calling thread).
    slots: usize,
}

struct State {
    /// Bumped once per dispatch; workers run each generation exactly once.
    generation: u64,
    job: Option<Job>,
    /// Task count of the current generation, kept OUTSIDE `job` so a
    /// non-participating worker that wakes late — after `run` has already
    /// returned and cleared `job` — can still decide "no indices for my
    /// slot" without touching the cleared job.  (Participants can never be
    /// late: `run` blocks until every one of them has finished.)
    n_tasks: usize,
    /// Participating workers still executing the current generation.
    active: usize,
    /// Set when a worker-side task panicked (re-raised by the caller).
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between dispatches.
    work_cv: Condvar,
    /// The dispatching thread waits here for `active == 0`.
    done_cv: Condvar,
}


/// Locks ignoring poisoning: every panic in pool code is confined to the
/// catch_unwind blocks around task execution, so state behind these locks
/// is always consistent; a poisoned flag would only turn one reported
/// panic into a cascade.
fn lock_ignore_poison<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// The shard-range plan for one `(len, chunk_len)` split on a pool of a
/// given size: the boundaries `run_chunks_mut` dispatches and the static
/// chunk→slot affinity implied by the pool's `index % slots` assignment.
/// Cached on the pool (single entry, keyed by `(len, chunk_len, slots)`)
/// so the engine's per-step fan-out, the collectives' reductions, and
/// first-touch initialization all reuse *identical* ranges without
/// re-deriving them.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ChunkPlan {
    pub len: usize,
    pub chunk_len: usize,
    pub slots: usize,
    /// Chunk `i` covers `ranges[i].0 .. ranges[i].1` — the same
    /// boundaries as `slice::chunks_mut(chunk_len)`.
    pub ranges: Vec<(usize, usize)>,
}

impl ChunkPlan {
    fn build(len: usize, chunk_len: usize, slots: usize) -> ChunkPlan {
        let n_chunks = len.div_ceil(chunk_len);
        let ranges = (0..n_chunks)
            .map(|i| {
                let start = i * chunk_len;
                (start, (start + chunk_len).min(len))
            })
            .collect();
        ChunkPlan { len, chunk_len, slots, ranges }
    }

    /// The execution slot chunk `i` always runs on (slot 0 = the calling
    /// thread).  Stable across dispatches for a fixed plan — the basis of
    /// the shard→slot affinity and of first-touch placement.
    pub fn slot_of(&self, chunk: usize) -> usize {
        chunk % self.slots
    }
}

/// A fixed-size pool of parked OS threads executing indexed task batches.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Serializes whole dispatches so a pool can be shared across callers.
    run_lock: Mutex<()>,
    slots: usize,
    /// Single-entry [`ChunkPlan`] cache (hot paths re-split the same
    /// buffer length every step/reduction).
    plan: Mutex<Option<Arc<ChunkPlan>>>,
}

impl WorkerPool {
    /// A pool with `threads` total execution slots (the calling thread
    /// counts as slot 0, so `threads - 1` OS threads are spawned).
    /// `threads == 0` resolves to the host's available parallelism.
    /// Counts above the hardware parallelism are allowed (oversubscription
    /// changes scheduling, never results).
    pub fn new(threads: usize) -> WorkerPool {
        let slots = resolve_threads(threads);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                generation: 0,
                job: None,
                n_tasks: 0,
                active: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (1..slots)
            .map(|w| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("hier-avg-pool-{w}"))
                    .spawn(move || worker_loop(&shared, w))
                    .expect("spawning pool worker")
            })
            .collect();
        WorkerPool { shared, handles, run_lock: Mutex::new(()), slots, plan: Mutex::new(None) }
    }

    /// The cached shard-range plan for splitting `len` elements into
    /// `chunk_len`-sized chunks on this pool.  Rebuilt only when the key
    /// `(len, chunk_len, slots)` changes; `run_chunks_mut` and
    /// [`WorkerPool::first_touch`] both dispatch from it, so affinity and
    /// page placement always agree on the boundaries.
    pub fn chunk_plan(&self, len: usize, chunk_len: usize) -> Arc<ChunkPlan> {
        assert!(chunk_len > 0, "chunk_len must be positive");
        let mut cached = lock_ignore_poison(&self.plan);
        if let Some(p) = cached.as_ref() {
            if p.len == len && p.chunk_len == chunk_len && p.slots == self.slots {
                return Arc::clone(p);
            }
        }
        let plan = Arc::new(ChunkPlan::build(len, chunk_len, self.slots));
        *cached = Some(Arc::clone(&plan));
        plan
    }

    /// Total execution slots (worker threads + the caller).
    pub fn threads(&self) -> usize {
        self.slots
    }

    /// Execute `task(i)` for every `i in 0..n_tasks`, blocking until all
    /// complete.  Task `i` runs on slot `i % threads()`; the calling
    /// thread executes slot 0's share, so a 1-slot pool is a plain serial
    /// loop with zero dispatch overhead.  Tasks must not call back into
    /// the same pool (they would deadlock behind the dispatch lock).
    pub fn run(&self, n_tasks: usize, task: &(dyn Fn(usize) + Sync)) {
        if n_tasks == 0 {
            return;
        }
        if self.handles.is_empty() || n_tasks == 1 {
            for i in 0..n_tasks {
                task(i);
            }
            return;
        }
        let _dispatch = lock_ignore_poison(&self.run_lock);
        // SAFETY: the erased reference is published to the workers and
        // cleared again below, strictly before `run` returns; the wait on
        // `active == 0` guarantees no worker still holds it (even when the
        // caller's own share panics — see the catch_unwind below).
        let erased: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(task) };
        // Worker w owns task indices {w, w + slots, …}, so only workers
        // with w < n_tasks have any work; the rest skip the generation
        // without joining the completion count, keeping the caller's wait
        // proportional to the tasks dispatched, not the pool size.
        let participants = n_tasks.min(self.slots) - 1;
        {
            let mut st = lock_ignore_poison(&self.shared.state);
            st.generation = st.generation.wrapping_add(1);
            st.job = Some(Job { f: erased, n_tasks, slots: self.slots });
            st.n_tasks = n_tasks;
            st.active = participants;
            self.shared.work_cv.notify_all();
        }
        // The caller participates as slot 0.
        let caller = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut i = 0;
            while i < n_tasks {
                task(i);
                i += self.slots;
            }
        }));
        let worker_panicked = {
            let mut st = lock_ignore_poison(&self.shared.state);
            while st.active != 0 {
                st = self
                    .shared
                    .done_cv
                    .wait(st)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
            }
            st.job = None;
            std::mem::replace(&mut st.panicked, false)
        };
        if let Err(payload) = caller {
            std::panic::resume_unwind(payload);
        }
        if worker_panicked {
            panic!("worker pool task panicked");
        }
    }

    /// Split `data` into ceil-div chunks of `chunk_len` and run
    /// `f(chunk_index, chunk)` for each on the pool.  Chunk `i` covers
    /// `data[i*chunk_len .. min((i+1)*chunk_len, len)]` — the same
    /// boundaries as `slice::chunks_mut`, so callers keep the exact chunk
    /// math of the old scoped-thread paths.  Boundaries come from the
    /// cached [`ChunkPlan`], and the static `i % slots` assignment gives
    /// every chunk a stable slot across dispatches with the same plan
    /// (shard→slot affinity: a shard's pages are always touched by the
    /// same thread, which keeps them node-local under first-touch NUMA
    /// placement).
    pub fn run_chunks_mut<T, F>(&self, data: &mut [T], chunk_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(chunk_len > 0, "chunk_len must be positive");
        let len = data.len();
        if len == 0 {
            return;
        }
        let plan = self.chunk_plan(len, chunk_len);
        let base = data.as_mut_ptr() as usize;
        self.run(plan.ranges.len(), &|i| {
            let (start, end) = plan.ranges[i];
            // SAFETY: chunks are pairwise disjoint across task indices and
            // `run` does not return until every task has finished, so the
            // caller's exclusive borrow of `data` outlives all of them.
            let chunk = unsafe {
                std::slice::from_raw_parts_mut((base as *mut T).add(start), end - start)
            };
            f(i, chunk);
        });
    }

    /// First-touch page initialization: fault in each chunk's pages from
    /// the slot that will own that chunk in later `run_chunks_mut`
    /// dispatches with the same `(len, chunk_len)` plan.  On NUMA hosts
    /// with the default first-touch policy this places every shard's
    /// pages on the socket of the worker that will keep reducing it.
    /// Value-preserving (each probed element is written back to itself
    /// volatilely, so fresh `calloc` zero pages become resident without
    /// disturbing already-initialized buffers); one store per 4 KiB page
    /// suffices to fault it in.
    pub fn first_touch(&self, data: &mut [f32], chunk_len: usize) {
        const PAGE_F32: usize = 4096 / std::mem::size_of::<f32>();
        if data.is_empty() {
            return;
        }
        self.run_chunks_mut(data, chunk_len, |_, chunk| {
            let mut i = 0;
            while i < chunk.len() {
                // SAFETY: in-bounds element of this task's exclusive chunk;
                // volatile so the self-store is not elided.
                unsafe {
                    let p = chunk.as_mut_ptr().add(i);
                    std::ptr::write_volatile(p, std::ptr::read(p));
                }
                i += PAGE_F32;
            }
        });
    }

    /// Pin each pool slot to CPU `slot % host_cpus` (opt-in via
    /// `--pool-pin`): slot 0 is the calling thread, slots 1.. the pool
    /// workers.  Combined with shard→slot affinity and first-touch this
    /// keeps a shard's pages, its worker, and its CPU on one NUMA node.
    /// Best-effort — returns how many slots were actually pinned (0 on
    /// non-Linux targets or when the syscall is denied).
    pub fn pin_threads(&self) -> usize {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let pinned = AtomicUsize::new(0);
        self.run(self.slots, &|i| {
            if pin_current_thread(i) {
                pinned.fetch_add(1, Ordering::Relaxed);
            }
        });
        pinned.load(Ordering::Relaxed)
    }
}

/// Whether thread pinning can do anything on this target (`--pool-pin`
/// logs a no-op notice when it cannot).
pub fn pin_supported() -> bool {
    cfg!(all(target_os = "linux", target_arch = "x86_64"))
}

/// Best-effort pin of the calling thread to `cpu` (mod the host's CPU
/// count).  Implemented as a raw `sched_setaffinity` syscall — the crate
/// deliberately has no libc dependency — on Linux x86_64; a `false`
/// no-op elsewhere.  Failure is benign (the scheduler keeps balancing).
pub fn pin_current_thread(cpu: usize) -> bool {
    pin_impl(cpu)
}

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
fn pin_impl(cpu: usize) -> bool {
    let ncpu = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let cpu = cpu % ncpu;
    let mut mask = [0u64; 16]; // 1024 CPUs is plenty for one host
    mask[(cpu / 64) % 16] = 1u64 << (cpu % 64);
    let ret: i64;
    // SAFETY: sched_setaffinity(pid = 0 → calling thread, size, *mask)
    // only reads `mask`; the syscall ABI clobbers rcx/r11.
    unsafe {
        std::arch::asm!(
            "syscall",
            inlateout("rax") 203i64 => ret, // __NR_sched_setaffinity
            in("rdi") 0usize,
            in("rsi") std::mem::size_of_val(&mask),
            in("rdx") mask.as_ptr(),
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack)
        );
    }
    ret == 0
}

#[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
fn pin_impl(_cpu: usize) -> bool {
    false
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = lock_ignore_poison(&self.shared.state);
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared, worker: usize) {
    let mut last_gen = 0u64;
    loop {
        let job = {
            let mut st = lock_ignore_poison(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.generation != last_gen {
                    last_gen = st.generation;
                    if worker >= st.n_tasks {
                        // No indices assigned to this slot: skip the
                        // generation without joining the completion count
                        // (the dispatcher never counted this worker in
                        // `active`).  Decided from `st.n_tasks`, never
                        // from `st.job` — the job may already be cleared
                        // if this worker woke after the dispatch ended.
                        break None;
                    }
                    break Some(st.job.expect("job published with the generation bump"));
                }
                st = shared.work_cv.wait(st).unwrap_or_else(std::sync::PoisonError::into_inner);
            }
        };
        let Some(job) = job else {
            continue;
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut i = worker;
            while i < job.n_tasks {
                (job.f)(i);
                i += job.slots;
            }
        }));
        let mut st = lock_ignore_poison(&shared.state);
        if result.is_err() {
            st.panicked = true;
        }
        st.active -= 1;
        if st.active == 0 {
            shared.done_cv.notify_all();
        }
    }
}

/// `threads == 0` resolves to the host's available parallelism.
pub fn resolve_threads(threads: usize) -> usize {
    let t = if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    };
    t.max(1)
}

static POOLS: OnceLock<Mutex<Vec<(usize, Arc<WorkerPool>)>>> = OnceLock::new();

/// The process-wide pool registry: one pool per resolved thread count,
/// created on first request and kept for the process lifetime (parked
/// threads cost only a stack each).  Every subsystem sized to the same
/// `--pool-threads` therefore dispatches onto the same threads.
pub fn shared_pool(threads: usize) -> Arc<WorkerPool> {
    let resolved = resolve_threads(threads);
    let registry = POOLS.get_or_init(|| Mutex::new(Vec::new()));
    let mut pools = lock_ignore_poison(registry);
    if let Some((_, p)) = pools.iter().find(|(t, _)| *t == resolved) {
        return Arc::clone(p);
    }
    let pool = Arc::new(WorkerPool::new(resolved));
    pools.push((resolved, Arc::clone(&pool)));
    pool
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = WorkerPool::new(4);
        for n in [0usize, 1, 3, 4, 7, 64, 257] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run(n, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "task {i} of {n}");
            }
        }
    }

    #[test]
    fn chunks_cover_disjointly() {
        let pool = WorkerPool::new(3);
        for len in [1usize, 2, 5, 16, 33, 100] {
            for chunk in [1usize, 3, 7, 200] {
                let mut data = vec![0u32; len];
                pool.run_chunks_mut(&mut data, chunk, |_, c| {
                    for v in c.iter_mut() {
                        *v += 1; // every element touched exactly once
                    }
                });
                assert!(data.iter().all(|&v| v == 1), "len={len} chunk={chunk}");
            }
        }
    }

    #[test]
    fn chunk_boundaries_match_chunks_mut() {
        let pool = WorkerPool::new(4);
        let mut data: Vec<usize> = vec![0; 23];
        pool.run_chunks_mut(&mut data, 5, |i, c| {
            let l = c.len();
            for v in c.iter_mut() {
                *v = i + 100 * l;
            }
        });
        let mut expect = vec![0usize; 23];
        for (i, c) in expect.chunks_mut(5).enumerate() {
            let l = c.len();
            for v in c.iter_mut() {
                *v = i + 100 * l;
            }
        }
        assert_eq!(data, expect);
    }

    #[test]
    fn oversubscribed_pool_is_deterministic() {
        // Far more slots than hardware threads: scheduling changes, results
        // must not.
        let pool = WorkerPool::new(32);
        let run_once = || {
            let mut out = vec![0f32; 1000];
            pool.run_chunks_mut(&mut out, 13, |i, c| {
                for (k, v) in c.iter_mut().enumerate() {
                    *v = (i * 31 + k) as f32 * 0.5;
                }
            });
            out
        };
        assert_eq!(run_once(), run_once());
    }

    #[test]
    fn shared_pool_is_shared_per_size() {
        let a = shared_pool(2);
        let b = shared_pool(2);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(a.threads(), 2);
        let c = shared_pool(3);
        assert!(!Arc::ptr_eq(&a, &c));
    }

    #[test]
    fn concurrent_dispatches_serialize() {
        let pool = shared_pool(4);
        let total = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let pool = Arc::clone(&pool);
                let total = &total;
                s.spawn(move || {
                    for _ in 0..50 {
                        pool.run(8, &|_| {
                            total.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 4 * 50 * 8);
    }

    #[test]
    fn chunk_plan_is_cached_and_matches_chunks_mut() {
        let pool = WorkerPool::new(3);
        let p1 = pool.chunk_plan(23, 5);
        let p2 = pool.chunk_plan(23, 5);
        assert!(Arc::ptr_eq(&p1, &p2), "same key reuses the cached plan");
        let expect: Vec<(usize, usize)> =
            vec![(0, 5), (5, 10), (10, 15), (15, 20), (20, 23)];
        assert_eq!(p1.ranges, expect);
        assert_eq!(p1.slot_of(0), 0);
        assert_eq!(p1.slot_of(4), 1);
        // A different key rebuilds; re-asking for the first key rebuilds
        // again (single-entry cache) but with identical boundaries.
        let p3 = pool.chunk_plan(24, 5);
        assert_eq!(p3.ranges.len(), 5);
        assert_eq!(p3.ranges[4], (20, 24));
        let p4 = pool.chunk_plan(23, 5);
        assert_eq!(p4.ranges, expect);
    }

    #[test]
    fn chunk_slot_affinity_is_stable_across_dispatches() {
        let pool = WorkerPool::new(4);
        let run_once = || {
            let ids: Vec<Mutex<Option<std::thread::ThreadId>>> =
                (0..10).map(|_| Mutex::new(None)).collect();
            let mut data = vec![0u8; 10];
            pool.run_chunks_mut(&mut data, 1, |i, _| {
                *ids[i].lock().unwrap() = Some(std::thread::current().id());
            });
            ids.into_iter().map(|m| m.into_inner().unwrap().unwrap()).collect::<Vec<_>>()
        };
        let a = run_once();
        let b = run_once();
        assert_eq!(a, b, "each chunk must run on the same thread every dispatch");
        // And the assignment follows the plan's slot_of: chunks i and
        // i + slots share a thread.
        assert_eq!(a[0], a[4]);
        assert_eq!(a[1], a[5]);
    }

    #[test]
    fn first_touch_preserves_values() {
        let pool = WorkerPool::new(3);
        // Fresh zeroed buffer stays zeroed…
        let mut fresh = vec![0.0f32; 10_000];
        pool.first_touch(&mut fresh, 2048);
        assert!(fresh.iter().all(|&v| v == 0.0));
        // …and an initialized buffer is untouched bit-for-bit.
        let mut init: Vec<f32> = (0..10_000).map(|i| (i as f32).sin()).collect();
        let before = init.clone();
        pool.first_touch(&mut init, 2048);
        assert_eq!(init, before);
    }

    #[test]
    fn pinning_is_best_effort_and_harmless() {
        // On Linux x86_64 pinning the current thread to CPU 0 must
        // succeed; elsewhere it must report a clean no-op.
        if pin_supported() {
            assert!(pin_current_thread(0));
            // Out-of-range CPUs wrap onto the host range instead of
            // failing with EINVAL.
            assert!(pin_current_thread(100_000));
        } else {
            assert!(!pin_current_thread(0));
        }
        let pool = WorkerPool::new(2);
        let pinned = pool.pin_threads();
        if pin_supported() {
            assert_eq!(pinned, 2);
        } else {
            assert_eq!(pinned, 0);
        }
        // The pool still dispatches normally afterwards.
        let n = AtomicUsize::new(0);
        pool.run(8, &|_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(8, &|i| {
                if i == 5 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err());
        // The pool still works after a task panic.
        let n = AtomicUsize::new(0);
        pool.run(8, &|_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 8);
    }
}
