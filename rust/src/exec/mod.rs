//! The executor layer: a persistent, work-stealing-free worker pool for
//! the two wall-clock hot paths — group reductions (`comm::collective::
//! PooledCollective`) and the native backend's per-step lane fan-out
//! (`native::ParallelNativeMlp`).
//!
//! Before this layer existed both paths paid a full `std::thread::scope`
//! spawn + join per call (one per reduction, one per training step).  A
//! [`WorkerPool`] instead parks long-lived threads on a condvar and wakes
//! them per dispatch, which replaces thread creation (~tens of µs each)
//! with a notify/wait round-trip (~single-digit µs total).
//!
//! ## Determinism contract
//!
//! The pool never splits, reorders, or steals work: the caller defines an
//! indexed task list and every task index is executed exactly once, with a
//! *static* index→thread assignment (`index % slots`).  Because each
//! task's output depends only on its own index (callers hand tasks
//! disjoint output chunks computed from `(len, slots)` with the same
//! ceil-div math the old scoped-thread paths used), results are
//! bit-identical across runs, thread counts, and oversubscription — the
//! same contract `ShardedCollective` established, now without per-call
//! spawns.  See DESIGN.md §"The executor layer".
//!
//! ## Ownership
//!
//! Pools are process-wide and come from [`shared_pool`]: one pool per
//! resolved thread count, shared by every subsystem that asks for that
//! size (so the collective and the native backend of one run dispatch
//! onto the *same* threads instead of oversubscribing the host twice).
//! Concurrent `run` calls on one pool are serialized internally, so
//! sharing is safe from any thread.

use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// A dispatched batch of indexed tasks, lifetime-erased for the worker
/// threads.  `run` blocks until every worker has finished its share, so
/// the erased borrow can never outlive the data it points into.
#[derive(Clone, Copy)]
struct Job {
    f: &'static (dyn Fn(usize) + Sync),
    n_tasks: usize,
    /// Total execution slots (worker threads + the calling thread).
    slots: usize,
}

struct State {
    /// Bumped once per dispatch; workers run each generation exactly once.
    generation: u64,
    job: Option<Job>,
    /// Task count of the current generation, kept OUTSIDE `job` so a
    /// non-participating worker that wakes late — after `run` has already
    /// returned and cleared `job` — can still decide "no indices for my
    /// slot" without touching the cleared job.  (Participants can never be
    /// late: `run` blocks until every one of them has finished.)
    n_tasks: usize,
    /// Participating workers still executing the current generation.
    active: usize,
    /// Set when a worker-side task panicked (re-raised by the caller).
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between dispatches.
    work_cv: Condvar,
    /// The dispatching thread waits here for `active == 0`.
    done_cv: Condvar,
}


/// Locks ignoring poisoning: every panic in pool code is confined to the
/// catch_unwind blocks around task execution, so state behind these locks
/// is always consistent; a poisoned flag would only turn one reported
/// panic into a cascade.
fn lock_ignore_poison<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// A fixed-size pool of parked OS threads executing indexed task batches.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Serializes whole dispatches so a pool can be shared across callers.
    run_lock: Mutex<()>,
    slots: usize,
}

impl WorkerPool {
    /// A pool with `threads` total execution slots (the calling thread
    /// counts as slot 0, so `threads - 1` OS threads are spawned).
    /// `threads == 0` resolves to the host's available parallelism.
    /// Counts above the hardware parallelism are allowed (oversubscription
    /// changes scheduling, never results).
    pub fn new(threads: usize) -> WorkerPool {
        let slots = resolve_threads(threads);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                generation: 0,
                job: None,
                n_tasks: 0,
                active: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (1..slots)
            .map(|w| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("hier-avg-pool-{w}"))
                    .spawn(move || worker_loop(&shared, w))
                    .expect("spawning pool worker")
            })
            .collect();
        WorkerPool { shared, handles, run_lock: Mutex::new(()), slots }
    }

    /// Total execution slots (worker threads + the caller).
    pub fn threads(&self) -> usize {
        self.slots
    }

    /// Execute `task(i)` for every `i in 0..n_tasks`, blocking until all
    /// complete.  Task `i` runs on slot `i % threads()`; the calling
    /// thread executes slot 0's share, so a 1-slot pool is a plain serial
    /// loop with zero dispatch overhead.  Tasks must not call back into
    /// the same pool (they would deadlock behind the dispatch lock).
    pub fn run(&self, n_tasks: usize, task: &(dyn Fn(usize) + Sync)) {
        if n_tasks == 0 {
            return;
        }
        if self.handles.is_empty() || n_tasks == 1 {
            for i in 0..n_tasks {
                task(i);
            }
            return;
        }
        let _dispatch = lock_ignore_poison(&self.run_lock);
        // SAFETY: the erased reference is published to the workers and
        // cleared again below, strictly before `run` returns; the wait on
        // `active == 0` guarantees no worker still holds it (even when the
        // caller's own share panics — see the catch_unwind below).
        let erased: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(task) };
        // Worker w owns task indices {w, w + slots, …}, so only workers
        // with w < n_tasks have any work; the rest skip the generation
        // without joining the completion count, keeping the caller's wait
        // proportional to the tasks dispatched, not the pool size.
        let participants = n_tasks.min(self.slots) - 1;
        {
            let mut st = lock_ignore_poison(&self.shared.state);
            st.generation = st.generation.wrapping_add(1);
            st.job = Some(Job { f: erased, n_tasks, slots: self.slots });
            st.n_tasks = n_tasks;
            st.active = participants;
            self.shared.work_cv.notify_all();
        }
        // The caller participates as slot 0.
        let caller = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut i = 0;
            while i < n_tasks {
                task(i);
                i += self.slots;
            }
        }));
        let worker_panicked = {
            let mut st = lock_ignore_poison(&self.shared.state);
            while st.active != 0 {
                st = self
                    .shared
                    .done_cv
                    .wait(st)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
            }
            st.job = None;
            std::mem::replace(&mut st.panicked, false)
        };
        if let Err(payload) = caller {
            std::panic::resume_unwind(payload);
        }
        if worker_panicked {
            panic!("worker pool task panicked");
        }
    }

    /// Split `data` into ceil-div chunks of `chunk_len` and run
    /// `f(chunk_index, chunk)` for each on the pool.  Chunk `i` covers
    /// `data[i*chunk_len .. min((i+1)*chunk_len, len)]` — the same
    /// boundaries as `slice::chunks_mut`, so callers keep the exact chunk
    /// math of the old scoped-thread paths.
    pub fn run_chunks_mut<T, F>(&self, data: &mut [T], chunk_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(chunk_len > 0, "chunk_len must be positive");
        let len = data.len();
        if len == 0 {
            return;
        }
        let n_chunks = len.div_ceil(chunk_len);
        let base = data.as_mut_ptr() as usize;
        self.run(n_chunks, &|i| {
            let start = i * chunk_len;
            let end = (start + chunk_len).min(len);
            // SAFETY: chunks are pairwise disjoint across task indices and
            // `run` does not return until every task has finished, so the
            // caller's exclusive borrow of `data` outlives all of them.
            let chunk = unsafe {
                std::slice::from_raw_parts_mut((base as *mut T).add(start), end - start)
            };
            f(i, chunk);
        });
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = lock_ignore_poison(&self.shared.state);
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared, worker: usize) {
    let mut last_gen = 0u64;
    loop {
        let job = {
            let mut st = lock_ignore_poison(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.generation != last_gen {
                    last_gen = st.generation;
                    if worker >= st.n_tasks {
                        // No indices assigned to this slot: skip the
                        // generation without joining the completion count
                        // (the dispatcher never counted this worker in
                        // `active`).  Decided from `st.n_tasks`, never
                        // from `st.job` — the job may already be cleared
                        // if this worker woke after the dispatch ended.
                        break None;
                    }
                    break Some(st.job.expect("job published with the generation bump"));
                }
                st = shared.work_cv.wait(st).unwrap_or_else(std::sync::PoisonError::into_inner);
            }
        };
        let Some(job) = job else {
            continue;
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut i = worker;
            while i < job.n_tasks {
                (job.f)(i);
                i += job.slots;
            }
        }));
        let mut st = lock_ignore_poison(&shared.state);
        if result.is_err() {
            st.panicked = true;
        }
        st.active -= 1;
        if st.active == 0 {
            shared.done_cv.notify_all();
        }
    }
}

/// `threads == 0` resolves to the host's available parallelism.
pub fn resolve_threads(threads: usize) -> usize {
    let t = if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    };
    t.max(1)
}

static POOLS: OnceLock<Mutex<Vec<(usize, Arc<WorkerPool>)>>> = OnceLock::new();

/// The process-wide pool registry: one pool per resolved thread count,
/// created on first request and kept for the process lifetime (parked
/// threads cost only a stack each).  Every subsystem sized to the same
/// `--pool-threads` therefore dispatches onto the same threads.
pub fn shared_pool(threads: usize) -> Arc<WorkerPool> {
    let resolved = resolve_threads(threads);
    let registry = POOLS.get_or_init(|| Mutex::new(Vec::new()));
    let mut pools = lock_ignore_poison(registry);
    if let Some((_, p)) = pools.iter().find(|(t, _)| *t == resolved) {
        return Arc::clone(p);
    }
    let pool = Arc::new(WorkerPool::new(resolved));
    pools.push((resolved, Arc::clone(&pool)));
    pool
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = WorkerPool::new(4);
        for n in [0usize, 1, 3, 4, 7, 64, 257] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run(n, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "task {i} of {n}");
            }
        }
    }

    #[test]
    fn chunks_cover_disjointly() {
        let pool = WorkerPool::new(3);
        for len in [1usize, 2, 5, 16, 33, 100] {
            for chunk in [1usize, 3, 7, 200] {
                let mut data = vec![0u32; len];
                pool.run_chunks_mut(&mut data, chunk, |_, c| {
                    for v in c.iter_mut() {
                        *v += 1; // every element touched exactly once
                    }
                });
                assert!(data.iter().all(|&v| v == 1), "len={len} chunk={chunk}");
            }
        }
    }

    #[test]
    fn chunk_boundaries_match_chunks_mut() {
        let pool = WorkerPool::new(4);
        let mut data: Vec<usize> = vec![0; 23];
        pool.run_chunks_mut(&mut data, 5, |i, c| {
            let l = c.len();
            for v in c.iter_mut() {
                *v = i + 100 * l;
            }
        });
        let mut expect = vec![0usize; 23];
        for (i, c) in expect.chunks_mut(5).enumerate() {
            let l = c.len();
            for v in c.iter_mut() {
                *v = i + 100 * l;
            }
        }
        assert_eq!(data, expect);
    }

    #[test]
    fn oversubscribed_pool_is_deterministic() {
        // Far more slots than hardware threads: scheduling changes, results
        // must not.
        let pool = WorkerPool::new(32);
        let run_once = || {
            let mut out = vec![0f32; 1000];
            pool.run_chunks_mut(&mut out, 13, |i, c| {
                for (k, v) in c.iter_mut().enumerate() {
                    *v = (i * 31 + k) as f32 * 0.5;
                }
            });
            out
        };
        assert_eq!(run_once(), run_once());
    }

    #[test]
    fn shared_pool_is_shared_per_size() {
        let a = shared_pool(2);
        let b = shared_pool(2);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(a.threads(), 2);
        let c = shared_pool(3);
        assert!(!Arc::ptr_eq(&a, &c));
    }

    #[test]
    fn concurrent_dispatches_serialize() {
        let pool = shared_pool(4);
        let total = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let pool = Arc::clone(&pool);
                let total = &total;
                s.spawn(move || {
                    for _ in 0..50 {
                        pool.run(8, &|_| {
                            total.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 4 * 50 * 8);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(8, &|i| {
                if i == 5 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err());
        // The pool still works after a task panic.
        let n = AtomicUsize::new(0);
        pool.run(8, &|_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 8);
    }
}
