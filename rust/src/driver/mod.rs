//! Assembles a `Trainer` from a `RunConfig`: picks the backend (XLA
//! artifacts or native), builds the matching synthetic dataset, and loads
//! the synchronized initial parameters.

use anyhow::{bail, Result};

use crate::backend::StepBackend;
use crate::config::{BackendKind, RunConfig};
use crate::coordinator::Trainer;
use crate::data::{ClassifyData, DataSource, MixtureSpec, TokenData, TokenSpec};
use crate::metrics::RunRecord;
use crate::native::NativeMlp;
use crate::params::{FlatParams, ParamLayout};
use crate::runtime::{Manifest, ModelKind, XlaBackend};
use crate::util::rng::Pcg32;

/// Model registry mirror (python/compile/model.py MODELS) so the native
/// backend can run without artifacts: name -> (dims, batch, eval_batch).
pub const MODEL_DIMS: &[(&str, &[usize], usize, usize)] = &[
    ("quickstart", &[32, 64, 10], 16, 64),
    ("resnet18_sim", &[128, 256, 256, 10], 16, 128),
    ("googlenet_sim", &[128, 192, 192, 192, 10], 16, 128),
    ("mobilenet_sim", &[128, 96, 96, 10], 16, 128),
    ("vgg19_sim", &[128, 512, 10], 16, 128),
    ("imagenet_sim", &[256, 384, 100], 16, 256),
];

pub fn model_dims(name: &str) -> Option<(&'static [usize], usize, usize)> {
    MODEL_DIMS.iter().find(|(n, ..)| *n == name).map(|&(_, d, b, eb)| (d, b, eb))
}

/// Copy parameters between two layouts matching tensors by name (the JAX
/// manifest flattens dicts in sorted-key order — b before w — while the
/// native layout is w, b; names like "0/w" agree across both).
pub fn remap_by_name(
    src_layout: &ParamLayout,
    src: &[f32],
    dst_layout: &ParamLayout,
) -> Result<FlatParams> {
    let mut out = vec![0.0f32; dst_layout.total];
    for (i, d) in dst_layout.entries.iter().enumerate() {
        let Some((j, s)) =
            src_layout.entries.iter().enumerate().find(|(_, s)| s.name == d.name)
        else {
            bail!("tensor {:?} missing from source layout", d.name);
        };
        if s.size != d.size {
            bail!("tensor {:?} size mismatch: {} vs {}", d.name, s.size, d.size);
        }
        out[d.offset..d.offset + d.size].copy_from_slice(src_layout.slice(j, src));
        let _ = i;
    }
    Ok(out)
}

/// The classification-mixture spec a config's MLP data source is built
/// from.  Shared with the planner's manifest-independent validation runs
/// (`planner::validation_record`) so they train on byte-identical data to
/// a `driver::run` of the same config.
pub(crate) fn mixture_spec(cfg: &RunConfig, dims: &[usize]) -> MixtureSpec {
    MixtureSpec {
        dim: dims[0],
        classes: *dims.last().unwrap(),
        train_n: cfg.train_n,
        test_n: cfg.test_n,
        radius: cfg.radius,
        noise: cfg.noise,
        subclusters: cfg.subclusters,
        label_noise: cfg.label_noise,
        seed: cfg.seed ^ 0x5eed,
    }
}

fn build_data(cfg: &RunConfig, kind: &ModelKind) -> Box<dyn DataSource> {
    match kind {
        ModelKind::Mlp { dims, .. } => {
            Box::new(ClassifyData::generate(mixture_spec(cfg, dims)))
        }
        ModelKind::Lm { vocab, seq_len, .. } => {
            let mut spec = TokenSpec::tiny_corpus(*vocab, *seq_len);
            spec.train_n = cfg.train_n;
            spec.test_n = cfg.test_n;
            spec.seed = cfg.seed ^ 0x70c3;
            Box::new(TokenData::generate(spec))
        }
    }
}

/// Build backend + data + init for a config (the pieces of a `Trainer`).
pub fn build(cfg: &RunConfig) -> Result<(Box<dyn StepBackend>, Box<dyn DataSource>, FlatParams)> {
    match cfg.backend {
        BackendKind::Xla => {
            let manifest = Manifest::load_default()?;
            let entry = manifest.model(&cfg.model)?;
            let data = build_data(cfg, &entry.kind);
            let init = manifest.load_init(entry)?;
            let backend = XlaBackend::load(&manifest, &cfg.model, cfg.p)?;
            Ok((Box::new(backend), data, init))
        }
        BackendKind::Native => {
            let Some((dims, batch, eval_batch)) = model_dims(&cfg.model) else {
                bail!(
                    "model {:?} is not a native MLP (native supports: {:?})",
                    cfg.model,
                    MODEL_DIMS.iter().map(|m| m.0).collect::<Vec<_>>()
                );
            };
            // Parallel lanes pay off once several learners step per
            // dispatch; below that even the pool's (cheap) dispatch
            // overhead dominates.  The lane fan-out runs on the same
            // process-wide pool a pooled collective sized by
            // `--pool-threads` resolves to (exec::shared_pool).
            let backend: Box<dyn StepBackend> = if cfg.p >= 8 {
                Box::new(crate::native::ParallelNativeMlp::with_pool(
                    dims,
                    batch,
                    eval_batch,
                    cfg.p.min(8),
                    crate::exec::shared_pool(cfg.pool_threads),
                )?)
            } else {
                Box::new(NativeMlp::new(dims, batch, eval_batch)?)
            };
            let kind = ModelKind::Mlp { dims: dims.to_vec(), activation: "relu".into() };
            let data = build_data(cfg, &kind);
            // Prefer the artifact's init blob (exact parity with the XLA
            // path); fall back to a seeded he-init when artifacts are
            // absent.  A throwaway serial instance provides layout/init.
            let proto = NativeMlp::new(dims, batch, eval_batch)?;
            let init = match Manifest::load_default() {
                Ok(m) => match m.model(&cfg.model) {
                    Ok(entry) => {
                        let blob = m.load_init(entry)?;
                        remap_by_name(&entry.layout, &blob, proto.layout())?
                    }
                    Err(_) => proto.init(&mut Pcg32::seeded(cfg.seed)),
                },
                Err(_) => proto.init(&mut Pcg32::seeded(cfg.seed)),
            };
            Ok((backend, data, init))
        }
    }
}

/// The parameter layout a config's backend uses (manifest layout for XLA,
/// the native w/b-per-layer layout otherwise).
pub fn layout_for(cfg: &RunConfig) -> Result<ParamLayout> {
    match cfg.backend {
        BackendKind::Xla => Ok(Manifest::load_default()?.model(&cfg.model)?.layout.clone()),
        BackendKind::Native => {
            let Some((dims, batch, eval_batch)) = model_dims(&cfg.model) else {
                bail!("unknown native model {:?}", cfg.model);
            };
            Ok(NativeMlp::new(dims, batch, eval_batch)?.layout().clone())
        }
    }
}

/// Resume guards for the topology / elastic-membership sidecar fields
/// (beside the `--schedule` guard in [`run`]): a checkpoint that
/// recorded its hierarchy only resumes onto the same chain, and one
/// whose saving run saw membership events (preemptions, re-entries,
/// migrations) only resumes with a `--faults` layer armed — its
/// parameters embed survivor-weighted averages that a fault-free run
/// would silently misread as a clean history.  Legacy sidecars record
/// neither field: no constraint.
pub(crate) fn check_resume_meta(
    path: &str,
    snap_levels: Option<&[usize]>,
    snap_membership_epoch: Option<u64>,
    cfg: &RunConfig,
) -> Result<()> {
    if let Some(levels) = snap_levels {
        let want = cfg.hierarchy()?.sizes().to_vec();
        if levels != want.as_slice() {
            bail!(
                "checkpoint {path} was saved by a run reducing over hierarchy {levels:?} \
                 but this run reduces over {want:?}; rerun with --levels {} (or retrain \
                 from scratch) — group membership does not transfer across topologies",
                levels.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(",")
            );
        }
    }
    if let Some(epoch) = snap_membership_epoch {
        if epoch > 0 && cfg.faults.is_none() {
            bail!(
                "checkpoint {path} was saved by an elastic run that saw {epoch} membership \
                 event(s) (--faults), but this run has no fault layer; add --faults (e.g. \
                 --faults 0 to arm the layer without new outages) so the resumed run's \
                 records stay attributable, or retrain from scratch"
            );
        }
    }
    Ok(())
}

/// Run one training job end to end.
pub fn run(cfg: &RunConfig) -> Result<RunRecord> {
    let (backend, data, mut init) = build(cfg)?;
    let mut policy_state = None;
    if let Some(path) = &cfg.init_params {
        // Warm start: remap the snapshot into this backend's layout.
        let snap = crate::checkpoint::load(std::path::Path::new(path))?;
        init = remap_by_name(&snap.layout, &snap.params, &layout_for(cfg)?)?;
        // A checkpoint that recorded its schedule policy only resumes
        // under the same policy: controller state cannot transfer across
        // policies, and silently restarting an adaptive controller cold
        // would diverge from the run it claims to continue.
        if let Some((spec, state)) = &snap.schedule_policy {
            let want = cfg.schedule_policy.spec();
            if *spec != want {
                anyhow::bail!(
                    "checkpoint {path} was saved by a --schedule {spec} run but this run \
                     uses --schedule {want}; rerun with --schedule {spec} (or retrain from \
                     scratch) — controller state does not transfer across policies"
                );
            }
            policy_state = Some(state.clone());
        }
        check_resume_meta(path, snap.levels.as_deref(), snap.membership_epoch, cfg)?;
    }
    let mut trainer = Trainer::new(cfg, backend, data, init)?;
    trainer.restore_policy_state = policy_state;
    trainer.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::ParamEntry;

    #[test]
    fn remap_swaps_order() {
        let src = ParamLayout::from_entries(vec![
            ParamEntry { name: "0/b".into(), shape: vec![2], offset: 0, size: 2 },
            ParamEntry { name: "0/w".into(), shape: vec![3], offset: 2, size: 3 },
        ])
        .unwrap();
        let dst = ParamLayout::from_entries(vec![
            ParamEntry { name: "0/w".into(), shape: vec![3], offset: 0, size: 3 },
            ParamEntry { name: "0/b".into(), shape: vec![2], offset: 3, size: 2 },
        ])
        .unwrap();
        let flat = vec![1.0, 2.0, 10.0, 11.0, 12.0];
        let out = remap_by_name(&src, &flat, &dst).unwrap();
        assert_eq!(out, vec![10.0, 11.0, 12.0, 1.0, 2.0]);
    }

    #[test]
    fn remap_rejects_missing() {
        let src = ParamLayout::from_entries(vec![ParamEntry {
            name: "a".into(),
            shape: vec![1],
            offset: 0,
            size: 1,
        }])
        .unwrap();
        let dst = ParamLayout::from_entries(vec![ParamEntry {
            name: "b".into(),
            shape: vec![1],
            offset: 0,
            size: 1,
        }])
        .unwrap();
        assert!(remap_by_name(&src, &[0.0], &dst).is_err());
    }

    #[test]
    fn resume_meta_guards_topology_and_membership() {
        let cfg = RunConfig::defaults("m"); // hierarchy [4, 16]
        // Legacy sidecar: no constraint.
        check_resume_meta("ck", None, None, &cfg).unwrap();
        // Matching topology, quiet membership: fine.
        check_resume_meta("ck", Some(&[4, 16]), Some(0), &cfg).unwrap();
        // Topology mismatch fails loudly and names both chains.
        let err =
            check_resume_meta("ck", Some(&[2, 8, 32]), None, &cfg).unwrap_err().to_string();
        assert!(err.contains("[2, 8, 32]") && err.contains("[4, 16]"), "unhelpful: {err}");
        assert!(err.contains("--levels 2,8,32"), "no fix suggested: {err}");
        // An elastic checkpoint refuses a fault-free resume...
        let err = check_resume_meta("ck", None, Some(3), &cfg).unwrap_err().to_string();
        assert!(err.contains("--faults"), "no fix suggested: {err}");
        // ... and resumes once a fault layer is armed.
        let mut elastic = RunConfig::defaults("m");
        elastic.exec = crate::sim::ExecKind::Event;
        elastic.faults = Some(crate::sim::parse_faults("0").unwrap());
        check_resume_meta("ck", Some(&[4, 16]), Some(3), &elastic).unwrap();
    }

    #[test]
    fn registry_mirrors_python() {
        let (dims, b, eb) = model_dims("resnet18_sim").unwrap();
        assert_eq!(dims, &[128, 256, 256, 10]);
        assert_eq!((b, eb), (16, 128));
        assert!(model_dims("nope").is_none());
    }
}
