//! The learner engine: owns the replicated learner state and drives one
//! synchronous step at a time through the three pluggable layers —
//! topology ([`HierTopology`]: who reduces with whom), schedule policy
//! ([`SchedulePolicy`]: *decides* when each tier reduces, consulting the
//! epoch's base [`HierSchedule`] and, for the adaptive controller, the
//! timeline's stall feedback), and collective (inside the [`Reducer`]:
//! how the bytes move).
//!
//! The engine is deliberately backend- and epoch-agnostic: `Trainer`
//! (coordinator/mod.rs) keeps the epoch loop, evaluation, and record
//! assembly, and calls [`Engine::step`] once per synchronous step.  The
//! split is what lets N-level hierarchies, adaptive schedules, and
//! alternative collectives compose without touching the training loop.

use anyhow::Result;

use crate::algorithms::{HierSchedule, SchedulePolicy};
use crate::backend::{StepBackend, StepOut};
use crate::comm::Reducer;
use crate::config::RunConfig;
use crate::data::{BatchBuf, DataSource};
use crate::optimizer::Sgd;
use crate::params::FlatParams;
use crate::sim::ExecModel;
use crate::topology::HierTopology;
use crate::util::rng::Pcg32;

/// Replicated per-learner training state (parameters, gradients, optimizer
/// state, PRNG streams) plus the shared step-output scratch.
pub struct LearnerSet {
    pub replicas: Vec<FlatParams>,
    pub grads: Vec<FlatParams>,
    pub outs: Vec<StepOut>,
    pub opts: Vec<Sgd>,
    pub rngs: Vec<Pcg32>,
}

impl LearnerSet {
    pub fn new(cfg: &RunConfig, n_params: usize, init: &FlatParams) -> LearnerSet {
        let p = cfg.p;
        let mut root = Pcg32::new(cfg.seed, 0x48494552); // "HIER"
        LearnerSet {
            replicas: vec![init.clone(); p],
            grads: vec![vec![0.0; n_params]; p],
            outs: vec![StepOut::default(); p],
            opts: (0..p).map(|_| Sgd::new(cfg.momentum, cfg.weight_decay, n_params)).collect(),
            rngs: (0..p).map(|j| root.fork(j as u64)).collect(),
        }
    }

    pub fn p(&self) -> usize {
        self.replicas.len()
    }
}

/// A reduction that fired after a step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReduceOutcome {
    /// Hierarchy level that reduced (0 = innermost).
    pub level: usize,
    /// Modelled seconds the reduction cost.
    pub seconds: f64,
    /// Trace tag ('L' innermost, 'G' outermost, digits between).
    pub kind: char,
}

/// What one synchronous step produced.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepOutcome {
    /// Mean training loss across learners.
    pub mean_loss: f64,
    /// Total correct predictions across learners.
    pub ncorrect: f64,
    /// The reduction event, if the schedule fired one.
    pub reduce: Option<ReduceOutcome>,
}

/// Drives the P learners: batch sampling, the stacked backend dispatch,
/// local SGD updates, and scheduled hierarchical reductions.  The
/// `timeline` (selected by `--exec`) accounts virtual time for every step
/// and reduction the engine executes; it never influences the parameter
/// math, so execution models are interchangeable without perturbing
/// training numerics.
pub struct Engine<'a> {
    pub cfg: &'a RunConfig,
    pub topo: HierTopology,
    pub reducer: Reducer,
    pub learners: LearnerSet,
    pub timeline: Box<dyn ExecModel>,
    /// The schedule-policy layer: decides, per step and per level,
    /// whether to reduce, and receives the timeline's stall attribution
    /// after every fired reduction (`--schedule`; built by the trainer
    /// via `PolicyKind::build` so the condition-(3.5) clamp matches the
    /// planner's).
    pub policy: Box<dyn SchedulePolicy>,
    /// Per-level realized reduction events (decisions the policy fired),
    /// reported in the run record's `schedule` block.
    pub realized: Vec<u64>,
    batch: BatchBuf,
    t: u64,
}

impl<'a> Engine<'a> {
    /// `step_seconds` is the modelled base-rate compute time of one
    /// synchronous step ([`crate::coordinator::sim_step_seconds`]); the
    /// timeline charges it (scaled per learner in event mode) on every
    /// step.  `policy` is the schedule-policy layer the engine consults
    /// instead of reading the interval table directly.
    pub fn new(
        cfg: &'a RunConfig,
        n_params: usize,
        init: &FlatParams,
        step_seconds: f64,
        policy: Box<dyn SchedulePolicy>,
    ) -> Result<Engine<'a>> {
        let topo = cfg.hierarchy()?;
        // A pooled collective resolves against the run's `--pool-threads`,
        // landing on the same process-wide pool the native backend's lane
        // fan-out uses (exec::shared_pool), so one run never oversubscribes
        // the host with two thread sets.
        let collective = cfg.collective.build_for(cfg.pool_threads);
        let mut reducer = Reducer::with_collective(cfg.cost, cfg.strategy, n_params, collective);
        reducer.reserve_levels(topo.n_levels());
        let timeline = cfg.exec.build(cfg.p, topo.n_levels(), step_seconds, &cfg.het_spec());
        let realized = vec![0u64; topo.n_levels()];
        Ok(Engine {
            cfg,
            topo,
            reducer,
            learners: LearnerSet::new(cfg, n_params, init),
            timeline,
            policy,
            realized,
            batch: BatchBuf::default(),
            t: 0,
        })
    }

    /// Completed step count (1-based after the first step).
    pub fn t(&self) -> u64 {
        self.t
    }

    /// One synchronous step: every learner draws a mini-batch and takes one
    /// local SGD step (a single stacked backend dispatch), then the
    /// schedule policy decides which hierarchy tier (if any) averages —
    /// `sched` is the epoch's base schedule the policy consults (and, for
    /// `StaticPolicy`, follows verbatim).
    pub fn step(
        &mut self,
        backend: &mut dyn StepBackend,
        data: &dyn DataSource,
        lr: f32,
        sched: &HierSchedule,
    ) -> Result<StepOutcome> {
        let p = self.learners.p();
        let b = backend.train_batch();
        self.batch.clear();
        for rng in self.learners.rngs.iter_mut() {
            data.fill_train(rng, b, &mut self.batch);
        }
        backend.grads(
            &self.learners.replicas,
            &self.batch,
            &mut self.learners.grads,
            &mut self.learners.outs,
        )?;
        for j in 0..p {
            self.learners.opts[j].apply(&mut self.learners.replicas[j], &self.learners.grads[j], lr);
        }
        self.t += 1;
        self.timeline.on_step();
        let reduce = match self.policy.decide(self.t, sched) {
            Some(level) => {
                self.realized[level] += 1;
                let seconds =
                    self.reducer.reduce_level(&mut self.learners.replicas, &self.topo, level);
                // Symmetric groups at one level cost the same, so the
                // reducer's max-over-groups is also each group's barrier
                // cost on the timeline.  The stall the barrier charged is
                // the policy's feedback signal — a pure function of the
                // seeded timeline, so replays reproduce every adaptation.
                let stall = self.timeline.on_reduction(&self.topo, level, seconds);
                self.policy.observe(self.t, level, stall, seconds);
                Some(ReduceOutcome { level, seconds, kind: self.topo.trace_kind(level) })
            }
            None => None,
        };
        let mean_loss =
            self.learners.outs.iter().map(|o| o.loss as f64).sum::<f64>() / p as f64;
        let ncorrect = self.learners.outs.iter().map(|o| o.ncorrect as f64).sum::<f64>();
        Ok(StepOutcome { mean_loss, ncorrect, reduce })
    }

    /// The paper's w̃: the mean of all replicas, without perturbing them.
    pub fn mean_params(&self, out: &mut FlatParams) {
        self.reducer.mean_of(&self.learners.replicas, out);
    }
}
