//! The learner engine: owns the replicated learner state and drives one
//! synchronous step at a time through the three pluggable layers —
//! topology ([`HierTopology`]: who reduces with whom), schedule policy
//! ([`SchedulePolicy`]: *decides* when each tier reduces, consulting the
//! epoch's base [`HierSchedule`] and, for the adaptive controller, the
//! timeline's stall feedback), and collective (inside the [`Reducer`]:
//! how the bytes move).
//!
//! The engine is deliberately backend- and epoch-agnostic: `Trainer`
//! (coordinator/mod.rs) keeps the epoch loop, evaluation, and record
//! assembly, and calls [`Engine::step`] once per synchronous step.  The
//! split is what lets N-level hierarchies, adaptive schedules, and
//! alternative collectives compose without touching the training loop.

use anyhow::Result;

use std::sync::{Arc, Mutex};

use crate::algorithms::{HierSchedule, SchedulePolicy};
use crate::backend::{StepBackend, StepOut};
use crate::comm::{CompressedCollective, EfState, Reducer};
use crate::config::RunConfig;
use crate::data::{BatchBuf, DataSource};
use crate::exec::WorkerPool;
use crate::optimizer::SgdPool;
use crate::params::{FlatParams, ParamArena};
use crate::sim::{ExecModel, MembershipModel};
use crate::topology::HierTopology;
use crate::util::rng::Pcg32;
use crate::util::simd;

/// Minimum fleet size for the pool-parallel step pipeline.  Below it the
/// per-learner loops cost less than the pool dispatch, so the engine runs
/// the literal serial reference loops; the same serial loops also run when
/// `--pool-threads` is unset/0/1, which is what keeps every golden (all
/// recorded at the default) on the executable reference path.
pub const POOL_STEP_MIN_P: usize = 4;

/// Fixed block width of the loss/ncorrect tree reduction.  Both the serial
/// and the pooled step paths sum through [`tree_sum`] with this shape, so
/// the result is a pure function of the values — independent of thread
/// count and identical between the two pipelines.  For P ≤ LOSS_BLOCK the
/// tree degenerates to the single ascending left fold the pre-arena engine
/// used, which is what keeps existing goldens (P ≤ 256) byte-stable.
const LOSS_BLOCK: usize = 256;

/// Fixed-shape blocked sum: ascending left fold within each LOSS_BLOCK
/// block, then an ascending left fold over the block partials.  With a
/// pool the block partials are computed concurrently (each partial is the
/// same serial fold either way), so pooled and serial calls agree bitwise.
pub fn tree_sum(vals: &[f64], pool: Option<&WorkerPool>) -> f64 {
    if vals.len() <= LOSS_BLOCK {
        return vals.iter().sum();
    }
    let n_blocks = vals.len().div_ceil(LOSS_BLOCK);
    let mut partials = vec![0.0f64; n_blocks];
    let fill = |i: usize, out: &mut [f64]| {
        let s = i * LOSS_BLOCK;
        let e = (s + LOSS_BLOCK).min(vals.len());
        out[0] = vals[s..e].iter().sum();
    };
    match pool {
        Some(pool) => pool.run_chunks_mut(&mut partials, 1, fill),
        None => {
            for i in 0..n_blocks {
                fill(i, &mut partials[i..i + 1]);
            }
        }
    }
    partials.iter().sum()
}

/// Raw base pointer that may cross into pool workers.  Each worker derives
/// a slice over a *disjoint* region from it (disjointness is the caller's
/// SAFETY obligation at each use site).
struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}

/// Replicated per-learner training state — parameters, gradients, and
/// optimizer state each live in one contiguous [`ParamArena`] (rows =
/// learners, stride = n_params) — plus the per-learner PRNG streams and
/// the shared step-output scratch.  One allocation per field means the
/// pool's row→slot affinity (and `--pool-pin`) can be made physical via
/// first-touch for *all* learner state, not just replicas.
pub struct LearnerSet {
    pub replicas: ParamArena,
    pub grads: ParamArena,
    pub outs: Vec<StepOut>,
    pub opt: SgdPool,
    pub rngs: Vec<Pcg32>,
}

impl LearnerSet {
    pub fn new(cfg: &RunConfig, n_params: usize, init: &FlatParams) -> LearnerSet {
        let p = cfg.p;
        let mut root = Pcg32::new(cfg.seed, 0x48494552); // "HIER"
        LearnerSet {
            replicas: ParamArena::replicated(init, p),
            grads: ParamArena::zeroed(p, n_params),
            outs: vec![StepOut::default(); p],
            opt: SgdPool::new(cfg.momentum, cfg.weight_decay, p, n_params),
            rngs: (0..p).map(|j| root.fork(j as u64)).collect(),
        }
    }

    pub fn p(&self) -> usize {
        self.replicas.rows()
    }
}

/// Membership-event counters the engine accumulates when the elastic
/// fault layer (`--faults`) is active, reported in the run record's
/// `faults` block.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounts {
    /// Up→down edges: a learner was preempted mid-run.
    pub preemptions: u64,
    /// Down→up edges: a repaired learner rejoined the fleet.
    pub reentries: u64,
    /// Parameter restores from the in-memory checkpoint cache (one per
    /// re-entry: the learner reloads the last global average before
    /// warm-syncing to its group).
    pub checkpoint_restores: u64,
    /// Learners the schedule policy migrated out of their sub-top groups
    /// after a persistent stall streak.
    pub migrations: u64,
    /// Groups that ran a degraded survivor-only barrier (reweighted
    /// averaging over the live members) instead of the full collective.
    pub survivor_reductions: u64,
    /// Monotone membership version: bumped on every preemption, re-entry,
    /// and migration.  Persisted in checkpoint sidecars so a resume can
    /// refuse to silently replay an elastic run without its fault layer.
    pub membership_epoch: u64,
}

/// Parameter-side elastic-membership state (`--faults`): the engine's
/// mirror of the timeline's [`MembershipModel`], driven from the *same*
/// seed and plan so both sides agree step by step on who is up.  The
/// timeline prices outages; this struct owns the deterministic parameter
/// consequences — frozen replicas while down, checkpoint restore +
/// group warm-sync on re-entry, survivor-only reductions.
struct FaultRuntime {
    membership: MembershipModel,
    /// Was learner j down during the previous step? (edge detection)
    down_prev: Vec<bool>,
    /// Is learner j up for the step being executed?
    alive: Vec<bool>,
    /// Learners migrated out of their sub-top groups by the policy; they
    /// participate only in outermost reductions.
    detached: Vec<bool>,
    /// In-memory checkpoint: the last globally averaged parameter vector,
    /// refreshed after every outermost reduction (all participants hold
    /// the identical average then, so one copy suffices).  Seeded with
    /// the initial parameters — the "epoch 0" checkpoint.
    cache: FlatParams,
    counts: FaultCounts,
}

/// A reduction that fired after a step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReduceOutcome {
    /// Hierarchy level that reduced (0 = innermost).
    pub level: usize,
    /// Modelled seconds the reduction cost.
    pub seconds: f64,
    /// Trace tag ('L' innermost, 'G' outermost, digits between).
    pub kind: char,
}

/// What one synchronous step produced.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepOutcome {
    /// Mean training loss across learners.
    pub mean_loss: f64,
    /// Total correct predictions across learners.
    pub ncorrect: f64,
    /// The reduction event, if the schedule fired one.
    pub reduce: Option<ReduceOutcome>,
}

/// Drives the P learners: batch sampling, the stacked backend dispatch,
/// local SGD updates, and scheduled hierarchical reductions.  The
/// `timeline` (selected by `--exec`) accounts virtual time for every step
/// and reduction the engine executes; it never influences the parameter
/// math, so execution models are interchangeable without perturbing
/// training numerics.
pub struct Engine<'a> {
    pub cfg: &'a RunConfig,
    pub topo: HierTopology,
    pub reducer: Reducer,
    pub learners: LearnerSet,
    pub timeline: Box<dyn ExecModel>,
    /// The schedule-policy layer: decides, per step and per level,
    /// whether to reduce, and receives the timeline's stall attribution
    /// after every fired reduction (`--schedule`; built by the trainer
    /// via `PolicyKind::build` so the condition-(3.5) clamp matches the
    /// planner's).
    pub policy: Box<dyn SchedulePolicy>,
    /// Per-level realized reduction events (decisions the policy fired),
    /// reported in the run record's `schedule` block.
    pub realized: Vec<u64>,
    /// Elastic-membership runtime, Some only when `cfg.faults` is set.
    /// With it None the step path is exactly the legacy code, so
    /// fault-free runs stay bit-identical to pre-fault builds.
    faults: Option<FaultRuntime>,
    /// Error-feedback residual state, Some only when `cfg.compress` is
    /// set (shared with the `CompressedCollective` inside the reducer;
    /// read at end of run for the record's `compression` block).
    ef_state: Option<Arc<Mutex<EfState>>>,
    /// The shared worker pool (same registry entry the pooled collective
    /// and the native backend's lane fan-out resolve to, so one run never
    /// oversubscribes the host with two thread sets).
    pool: Arc<WorkerPool>,
    /// Run the pool-parallel step pipeline (batch fill, SGD apply, loss
    /// tree-sum)?  False ⇒ the literal serial reference loops.
    pooled_step: bool,
    batch: BatchBuf,
    t: u64,
}

impl<'a> Engine<'a> {
    /// `step_seconds` is the modelled base-rate compute time of one
    /// synchronous step ([`crate::coordinator::sim_step_seconds`]); the
    /// timeline charges it (scaled per learner in event mode) on every
    /// step.  `policy` is the schedule-policy layer the engine consults
    /// instead of reading the interval table directly.
    pub fn new(
        cfg: &'a RunConfig,
        n_params: usize,
        init: &FlatParams,
        step_seconds: f64,
        policy: Box<dyn SchedulePolicy>,
    ) -> Result<Engine<'a>> {
        let topo = cfg.hierarchy()?;
        // A pooled collective resolves against the run's `--pool-threads`,
        // landing on the same process-wide pool the native backend's lane
        // fan-out uses (exec::shared_pool), so one run never oversubscribes
        // the host with two thread sets.
        let mut collective = cfg.collective.build_for(cfg.pool_threads);
        // `--compress` wraps the chosen engine with the payload transform
        // (top-k / rand-k / quantization + error feedback); with `none` no
        // wrapper exists and the path is byte-for-byte the legacy one.
        let ef_state = if cfg.compress.is_none() {
            None
        } else {
            let (wrapped, state) = CompressedCollective::new(collective, cfg.compress, cfg.seed);
            collective = Box::new(wrapped);
            Some(state)
        };
        let mut reducer = Reducer::with_collective(cfg.cost, cfg.strategy, n_params, collective);
        reducer.compression = cfg.compress;
        reducer.reserve_levels(topo.n_levels());
        let mut timeline = cfg.exec.build(cfg.p, topo.n_levels(), step_seconds, &cfg.het_spec());
        let faults = cfg.faults.as_ref().map(|plan| {
            // Timeline and engine each build a MembershipModel from the
            // same (p, seed, plan): membership is a pure function of
            // those, so the two stay in lockstep without any channel
            // between them.
            timeline.install_faults(cfg.seed, plan);
            // A policy restored from a checkpoint may carry migration
            // decisions from the saved run: re-apply the detachments so a
            // warm restart keeps its degraded membership instead of
            // silently re-attaching stalled learners.  Counters are NOT
            // re-bumped — the counts block reports this run's events.
            let mut detached = vec![false; cfg.p];
            for l in policy.migrated_learners() {
                if l < cfg.p {
                    detached[l] = true;
                    timeline.set_detached(l);
                }
            }
            FaultRuntime {
                membership: MembershipModel::new(cfg.p, cfg.seed, plan),
                down_prev: vec![false; cfg.p],
                alive: vec![true; cfg.p],
                detached,
                cache: init.clone(),
                counts: FaultCounts::default(),
            }
        });
        let realized = vec![0u64; topo.n_levels()];
        let mut learners = LearnerSet::new(cfg, n_params, init);
        // NUMA locality (pure placement — never changes parameter values):
        // `--pool-pin` pins each pool slot to a CPU so the pool's stable
        // shard→slot affinity becomes physical; with the pooled collective
        // we additionally fault each replica's pages in from the slot that
        // will keep reducing that shard (first-touch page placement), using
        // the same ceil-div shard math as `PooledCollective::mean_of`.
        let pool = match cfg.collective {
            crate::comm::CollectiveKind::Pooled { threads } if threads > 0 => {
                crate::exec::shared_pool(threads)
            }
            _ => crate::exec::shared_pool(cfg.pool_threads),
        };
        if cfg.pool_pin {
            // Status goes to stderr and only when not --quiet, so JSON
            // consumers and log-grepping smokes see clean streams.
            if crate::exec::pin_supported() {
                let pinned = pool.pin_threads();
                if !cfg.quiet {
                    eprintln!(
                        "[engine] --pool-pin: pinned {pinned}/{} pool slots",
                        pool.threads()
                    );
                }
            } else if !cfg.quiet {
                eprintln!(
                    "[engine] --pool-pin: sched_setaffinity unavailable on this target (no-op)"
                );
            }
        }
        // The pooled step pipeline needs an explicit worker budget (≥ 2)
        // and enough learners to amortize the dispatch; otherwise every
        // per-learner loop below stays on the serial reference path.
        let pooled_step = cfg.pool_threads >= 2 && cfg.p >= POOL_STEP_MIN_P;
        if pooled_step {
            // First-touch every learner-state arena row-granular from the
            // pool slot that will own that row in `run_chunks_mut`, making
            // the pool's stable row→slot affinity (and `--pool-pin`)
            // physical page placement for replicas, grads, and velocity.
            let stride = learners.replicas.stride().max(1);
            pool.first_touch(learners.replicas.as_mut_slice(), stride);
            pool.first_touch(learners.grads.as_mut_slice(), stride);
            if let Some(vel) = learners.opt.velocity_mut() {
                pool.first_touch(vel.as_mut_slice(), stride);
            }
        } else if matches!(cfg.collective, crate::comm::CollectiveKind::Pooled { .. }) {
            // Serial step path with a pooled collective: fault each
            // replica row's pages in shard-granular from the slot that
            // keeps reducing that shard (same ceil-div shard math as
            // `PooledCollective::mean_of`).
            let t = pool.threads().clamp(1, n_params.max(1));
            let shard = n_params.div_ceil(t);
            for j in 0..learners.replicas.rows() {
                pool.first_touch(learners.replicas.row_mut(j), shard);
            }
        }
        Ok(Engine {
            cfg,
            topo,
            reducer,
            learners,
            timeline,
            policy,
            realized,
            faults,
            ef_state,
            pool,
            pooled_step,
            batch: BatchBuf::default(),
            t: 0,
        })
    }

    /// L2 norm of the un-transmitted error-feedback mass across all
    /// learners, Some only when `--compress` is active.
    pub fn residual_l2(&self) -> Option<f64> {
        self.ef_state.as_ref().map(|s| s.lock().expect("compression state poisoned").residual_l2())
    }

    /// Completed step count (1-based after the first step).
    pub fn t(&self) -> u64 {
        self.t
    }

    /// One synchronous step: every learner draws a mini-batch and takes one
    /// local SGD step (a single stacked backend dispatch), then the
    /// schedule policy decides which hierarchy tier (if any) averages —
    /// `sched` is the epoch's base schedule the policy consults (and, for
    /// `StaticPolicy`, follows verbatim).
    pub fn step(
        &mut self,
        backend: &mut dyn StepBackend,
        data: &dyn DataSource,
        lr: f32,
        sched: &HierSchedule,
    ) -> Result<StepOutcome> {
        let p = self.learners.p();
        if self.faults.is_some() {
            self.resolve_membership();
        }
        let b = backend.train_batch();
        let n = self.learners.replicas.stride();
        self.batch.clear();
        // Every learner draws its batch even while down: the per-learner
        // data streams must stay aligned with the fault-free run so that
        // `--faults 0` (and any two runs differing only in outages) see
        // identical sample sequences.
        if self.pooled_step {
            // Pool-parallel fill: the stacked batch is carved into
            // disjoint per-learner regions (the exact element counts one
            // `fill_train` call appends) and each pool slot fills its rows
            // with that learner's own RNG fork — byte-identical to the
            // serial append loop, including RNG consumption.
            let (nf, ni, ny) = data.train_region(b);
            self.batch.xf.resize(p * nf, 0.0);
            self.batch.xi.resize(p * ni, 0);
            self.batch.y.resize(p * ny, 0);
            self.batch.rows = p * b;
            let xf = SendPtr(self.batch.xf.as_mut_ptr());
            let xi = SendPtr(self.batch.xi.as_mut_ptr());
            let y = SendPtr(self.batch.y.as_mut_ptr());
            self.pool.run_chunks_mut(&mut self.learners.rngs, 1, |j, rng| {
                // SAFETY: chunk j owns exactly rng j, and the three region
                // slices [j·len, (j+1)·len) are disjoint across chunks and
                // in-bounds of the vectors resized to p·len above.
                let (xf, xi, y) = unsafe {
                    (
                        std::slice::from_raw_parts_mut(xf.0.add(j * nf), nf),
                        std::slice::from_raw_parts_mut(xi.0.add(j * ni), ni),
                        std::slice::from_raw_parts_mut(y.0.add(j * ny), ny),
                    )
                };
                data.fill_train_region(&mut rng[0], b, xf, xi, y);
            });
        } else {
            for rng in self.learners.rngs.iter_mut() {
                data.fill_train(rng, b, &mut self.batch);
            }
        }
        backend.grads(
            self.learners.replicas.view(),
            &self.batch,
            self.learners.grads.view_mut(),
            &mut self.learners.outs,
        )?;
        // Local SGD apply: one fused momentum+weight-decay pass per arena
        // row.  Rows are independent, and the pooled path runs the same
        // `util::simd` kernels per row as `SgdPool::apply_row`, so the
        // result is bit-identical to the serial reference at any thread
        // count.
        if self.pooled_step {
            let mu = self.learners.opt.momentum;
            let wd = self.learners.opt.weight_decay;
            let grads = self.learners.grads.view();
            let alive = self.faults.as_ref().map(|fs| fs.alive.as_slice());
            match self.learners.opt.velocity_mut() {
                Some(vel) => {
                    let vbase = SendPtr(vel.as_mut_slice().as_mut_ptr());
                    self.pool.run_chunks_mut(self.learners.replicas.as_mut_slice(), n, |j, w| {
                        if alive.is_some_and(|a| !a[j]) {
                            return; // down: parameters freeze until re-entry
                        }
                        // SAFETY: chunk j is replica row j, so velocity row
                        // j ([j·n, (j+1)·n) of an arena with the same
                        // geometry) is touched by exactly one worker.
                        let v = unsafe {
                            std::slice::from_raw_parts_mut(vbase.0.add(j * n), n)
                        };
                        simd::sgd_step_momentum(w, grads.row(j), v, lr, mu, wd);
                    });
                }
                None => {
                    self.pool.run_chunks_mut(self.learners.replicas.as_mut_slice(), n, |j, w| {
                        if alive.is_some_and(|a| !a[j]) {
                            return; // down: parameters freeze until re-entry
                        }
                        if wd == 0.0 {
                            simd::sgd_step_plain(w, grads.row(j), lr);
                        } else {
                            simd::sgd_step_wd(w, grads.row(j), lr, wd);
                        }
                    });
                }
            }
        } else {
            for j in 0..p {
                if let Some(fs) = &self.faults {
                    if !fs.alive[j] {
                        continue; // down: parameters freeze until re-entry
                    }
                }
                self.learners.opt.apply_row(
                    j,
                    self.learners.replicas.row_mut(j),
                    self.learners.grads.row(j),
                    lr,
                );
            }
        }
        self.t += 1;
        self.timeline.on_step();
        let reduce = match self.policy.decide(self.t, sched) {
            Some(level) => {
                self.realized[level] += 1;
                let top = level + 1 == self.topo.n_levels();
                let seconds = match self.faults.as_mut() {
                    Some(fs) => {
                        // Survivor barrier: down learners — and, below
                        // the top, migrated learners — are excluded.
                        // Full groups take the exact legacy collective
                        // path inside the reducer, so fault-free groups
                        // stay bit-identical.
                        let part: Vec<bool> = (0..p)
                            .map(|j| fs.alive[j] && (top || !fs.detached[j]))
                            .collect();
                        let (secs, degraded) = self.reducer.reduce_level_survivors(
                            self.learners.replicas.view_mut(),
                            &self.topo,
                            level,
                            &part,
                        );
                        fs.counts.survivor_reductions += degraded;
                        secs
                    }
                    None => self.reducer.reduce_level(
                        self.learners.replicas.view_mut(),
                        &self.topo,
                        level,
                    ),
                };
                // Symmetric groups at one level cost the same, so the
                // reducer's max-over-groups is also each group's barrier
                // cost on the timeline.  The stall the barrier charged is
                // the policy's feedback signal — a pure function of the
                // seeded timeline, so replays reproduce every adaptation.
                let stall = self.timeline.on_reduction(&self.topo, level, seconds);
                self.policy.observe(self.t, level, stall, seconds);
                if self.faults.is_some() {
                    // The timeline knows which participant the whole
                    // barrier waited for; the policy turns a persistent
                    // culprit into a migration instead of widening
                    // everyone's interval.
                    if let Some(culprit) = self.timeline.last_culprit() {
                        self.policy.observe_culprit(self.t, level, culprit, stall, seconds);
                    }
                    if let Some(moved) = self.policy.take_migration() {
                        let fs = self.faults.as_mut().expect("fault runtime present");
                        if moved < p && !fs.detached[moved] {
                            fs.detached[moved] = true;
                            fs.counts.migrations += 1;
                            fs.counts.membership_epoch += 1;
                            self.timeline.set_detached(moved);
                        }
                    }
                    if top {
                        // All participants of an outermost reduction now
                        // hold the identical global average: refresh the
                        // in-memory checkpoint from the first one.
                        let fs = self.faults.as_mut().expect("fault runtime present");
                        if let Some(src) = (0..p).find(|&j| fs.alive[j]) {
                            fs.cache.copy_from_slice(self.learners.replicas.row(src));
                        }
                    }
                }
                Some(ReduceOutcome { level, seconds, kind: self.topo.trace_kind(level) })
            }
            None => None,
        };
        // Mean loss averages the *live* fleet (a preempted machine reports
        // nothing); `ncorrect` keeps the full-fleet sum because the
        // trainer's accuracy denominator is the fixed `p·b` per step.
        // Both accumulate through the fixed-shape `tree_sum`, which the
        // pooled path parallelizes over blocks — for P ≤ LOSS_BLOCK that
        // is exactly the legacy ascending left fold on either path.
        let sum_pool = if self.pooled_step { Some(&*self.pool) } else { None };
        let mean_loss = match &self.faults {
            Some(fs) if fs.alive.iter().any(|&a| a) => {
                let vals: Vec<f64> = (0..p)
                    .filter(|&j| fs.alive[j])
                    .map(|j| self.learners.outs[j].loss as f64)
                    .collect();
                tree_sum(&vals, sum_pool) / vals.len() as f64
            }
            _ => {
                let vals: Vec<f64> =
                    self.learners.outs.iter().map(|o| o.loss as f64).collect();
                tree_sum(&vals, sum_pool) / p as f64
            }
        };
        let corr: Vec<f64> =
            self.learners.outs.iter().map(|o| o.ncorrect as f64).collect();
        let ncorrect = tree_sum(&corr, sum_pool);
        Ok(StepOutcome { mean_loss, ncorrect, reduce })
    }

    /// Membership pass for the step about to execute (`self.t + 1`,
    /// matching the timeline's 1-based step ordinals): resolve who is up,
    /// count preemption edges, and run re-entry recovery for learners
    /// whose repair completed — restore the last checkpointed global
    /// average, then warm-sync to the current mean of the live
    /// innermost-group peers so the returnee rejoins near its group's
    /// state rather than a stale snapshot.  Both restores are plain
    /// deterministic parameter math: serial, ascending-index, reciprocal
    /// multiply — independent of the collective backend.
    fn resolve_membership(&mut self) {
        let p = self.learners.p();
        let t = self.t + 1;
        let fs = self.faults.as_mut().expect("resolve_membership requires faults");
        for j in 0..p {
            let down = fs.membership.is_down(j, t);
            fs.alive[j] = !down;
            if down && !fs.down_prev[j] {
                fs.down_prev[j] = true;
                fs.counts.preemptions += 1;
                fs.counts.membership_epoch += 1;
            }
        }
        for j in 0..p {
            if !(fs.alive[j] && fs.down_prev[j]) {
                continue;
            }
            // Down→up edge: re-entry.
            fs.down_prev[j] = false;
            fs.counts.reentries += 1;
            fs.counts.checkpoint_restores += 1;
            fs.counts.membership_epoch += 1;
            self.learners.replicas.row_mut(j).copy_from_slice(&fs.cache);
            let g = self.topo.group_of(0, j);
            let peers: Vec<usize> = self
                .topo
                .group_members(0, g)
                .filter(|&i| i != j && fs.alive[i])
                .collect();
            if peers.is_empty() {
                continue; // no live peer: the checkpoint is the best state
            }
            // Same op order as the pre-arena code: zeroed accumulator,
            // ascending live peers, reciprocal multiply, write-back.
            let mut acc = vec![0.0f32; self.learners.replicas.stride()];
            for &i in &peers {
                for (a, &v) in acc.iter_mut().zip(self.learners.replicas.row(i).iter()) {
                    *a += v;
                }
            }
            let inv = 1.0 / peers.len() as f32;
            acc.iter_mut().for_each(|x| *x *= inv);
            self.learners.replicas.row_mut(j).copy_from_slice(&acc);
        }
    }

    /// Fault counters so far, Some only when the elastic layer is active.
    pub fn fault_counts(&self) -> Option<FaultCounts> {
        self.faults.as_ref().map(|fs| fs.counts)
    }

    /// The paper's w̃: the mean of all replicas, without perturbing them.
    pub fn mean_params(&self, out: &mut FlatParams) {
        self.reducer.mean_of(self.learners.replicas.view(), out);
    }
}
