//! The trainer: Algorithm 1 of the paper (generalized to N levels),
//! orchestrated at L3.
//!
//! The training core is decomposed into three pluggable layers, each owned
//! by [`engine::Engine`]:
//!
//! - **topology** (`HierTopology`) — who reduces with whom: an N-level
//!   hierarchy of nested groups, each on a link class of the cost model;
//! - **schedule policy** (`algorithms::SchedulePolicy`, `--schedule`) —
//!   when each tier reduces: the static per-level interval table
//!   `K1 ≤ K2 ≤ …` verbatim, an online straggler-aware controller that
//!   widens/narrows intervals from the timeline's stall attribution
//!   (clamped by condition (3.5)), or a dense-to-sparse warmup; the
//!   outermost boundary always subsumes inner ones;
//! - **collective** (`comm::Collective`) — how the bytes move: simulated
//!   single-thread, spawn-per-call sharded, or persistent-pool pooled —
//!   bit-identical numerics across all three.
//!
//! A fourth pluggable layer sits beside them: the **execution model**
//! (`sim::ExecModel`, `--exec lockstep|event`) — *when* modelled work
//! happens per learner.  It only accounts virtual time (per-learner
//! clocks, group-local barriers, stall attribution); the parameter math
//! never consults it, so homogeneous event runs are bit-identical to
//! lockstep (DESIGN.md §Execution models).
//!
//! `Trainer` keeps what is not per-step: the epoch loop, evaluation of the
//! paper's w̃, and `RunRecord` assembly.  One engine step = every learner
//! takes one local SGD step (one stacked backend dispatch), then the
//! schedule decides which tier (if any) averages.

pub mod engine;

use std::time::Instant;

use anyhow::{bail, Result};

use crate::backend::StepBackend;
use crate::config::RunConfig;
use crate::data::{BatchBuf, DataSource};
use crate::metrics::{EpochStats, RunRecord};
use crate::params::FlatParams;
// Trait must be in scope to call `now()`/`breakdown()` on the engine's
// boxed timeline.
use crate::sim::ExecModel as _;

pub use engine::{Engine, FaultCounts, LearnerSet, ReduceOutcome, StepOutcome};

/// Per-step modelled compute seconds on the simulated cluster: all P
/// learners step concurrently; fwd+bwd ≈ 6·B·n_params flops on a
/// P100-class device (DESIGN.md §1: modelled, not measured).  Shared by
/// the trainer's epoch clock and the sweep planner's time-to-target
/// scoring so both tick against the same device model.
///
/// Provenance: `DEVICE_FLOPS` is the paper platform's datasheet number
/// (Tesla P100 fp32 peak, Zhou & Cong 2019 §4), not a measurement of
/// this host.  `scripts/calibrate_cost_model.py` derives the equivalent
/// constant from this machine's measured step throughput
/// (BENCH_step.json, written by `scripts/bless_bench.sh`) if you want
/// the simulated clock to track local hardware instead.
pub fn sim_step_seconds(batch: usize, n_params: usize) -> f64 {
    const DEVICE_FLOPS: f64 = 10.6e12; // P100 fp32 peak
    6.0 * batch as f64 * n_params as f64 / DEVICE_FLOPS
}

pub struct Trainer<'a> {
    pub cfg: &'a RunConfig,
    pub backend: Box<dyn StepBackend>,
    pub data: Box<dyn DataSource>,
    pub init: FlatParams,
    /// Controller state from a checkpoint sidecar (`driver::run` sets it
    /// when warm-starting): restored into the schedule policy before the
    /// first step so a resumed adaptive run continues its controller
    /// exactly where the saved run left it.
    pub restore_policy_state: Option<crate::util::json::Json>,
}

impl<'a> Trainer<'a> {
    pub fn new(
        cfg: &'a RunConfig,
        backend: Box<dyn StepBackend>,
        data: Box<dyn DataSource>,
        init: FlatParams,
    ) -> Result<Trainer<'a>> {
        cfg.validate()?;
        if init.len() != backend.n_params() {
            bail!("init has {} params, backend expects {}", init.len(), backend.n_params());
        }
        Ok(Trainer { cfg, backend, data, init, restore_policy_state: None })
    }

    /// Steps per epoch: one epoch processes `train_n` samples across all
    /// P·B per-step samples (matching the paper's fixed-data budget).
    pub fn steps_per_epoch(&self) -> usize {
        (self.data.train_n() / (self.cfg.p * self.backend.train_batch())).max(1)
    }

    /// This trainer's per-step modelled compute seconds (see
    /// [`sim_step_seconds`]).
    fn sim_step_seconds(&self) -> f64 {
        sim_step_seconds(self.backend.train_batch(), self.backend.n_params())
    }

    pub fn run(&mut self) -> Result<RunRecord> {
        let cfg = self.cfg;
        let p = cfg.p;
        let b = self.backend.train_batch();
        let n_params = self.backend.n_params();
        let step_secs = self.sim_step_seconds();
        // The schedule-policy layer: the adaptive controller's interval
        // ceiling comes from condition (3.5) in this run's (P, B) regime
        // — the same clamp the planner scores with.
        let k2_clamp = cfg.k2_clamp(b);
        let mut policy = cfg.schedule_policy.build(k2_clamp, step_secs, p);
        if let Some(state) = &self.restore_policy_state {
            policy.restore(state)?;
        }
        let mut engine = Engine::new(cfg, n_params, &self.init, step_secs, policy)?;

        let mut record = RunRecord { label: cfg.label(), ..Default::default() };
        let spe = self.steps_per_epoch();
        let units = self.backend.units_per_row() as f64;
        let started = Instant::now();
        let mut wbar: FlatParams = Vec::new();

        for epoch in 0..cfg.epochs {
            let lr = cfg.lr.lr_at(epoch);
            // Adaptive K2 (paper §3.3): the schedule may change per epoch.
            let sched = cfg.hier_schedule_at(epoch)?;
            let mut ep_loss = 0.0f64;
            let mut ep_correct = 0.0f64;
            for _ in 0..spe {
                let out = engine.step(self.backend.as_mut(), self.data.as_ref(), lr, &sched)?;
                if let Some(r) = out.reduce {
                    if cfg.record_trace {
                        record.trace.push(crate::metrics::TraceEvent {
                            step: engine.t(),
                            kind: r.kind,
                            seconds: r.seconds,
                        });
                    }
                }
                ep_loss += out.mean_loss;
                ep_correct += out.ncorrect;
                if cfg.record_steps {
                    record.step_loss.push(out.mean_loss as f32);
                }
            }
            record.sim_compute_seconds += spe as f64 * step_secs;

            let do_eval = epoch % cfg.eval_every.max(1) == 0 || epoch + 1 == cfg.epochs;
            let (test_loss, test_acc) = if do_eval {
                // Evaluate the paper's w̃: the global mean of all replicas
                // (without perturbing them if t is mid-interval).
                engine.mean_params(&mut wbar);
                self.evaluate(&wbar)?
            } else {
                (f64::NAN, f64::NAN)
            };

            record.epochs.push(EpochStats {
                epoch,
                train_loss: ep_loss / spe as f64,
                train_acc: ep_correct / (spe * p * b) as f64 / units,
                test_loss,
                test_acc,
                // The execution model's clock: under lockstep this equals
                // the legacy compute + comm sum mathematically (low-order
                // bits may differ from pre-event-engine releases — the
                // clock now accumulates step by step, which is what makes
                // homogeneous event runs bit-identical; re-bless goldens
                // once when upgrading); under the event model it is the
                // makespan of the per-learner timeline.
                sim_seconds: engine.timeline.now(),
                wall_seconds: started.elapsed().as_secs_f64(),
            });
        }

        let breakdown = engine.timeline.breakdown();
        record.exec_model = breakdown.model.to_string();
        record.makespan_seconds = breakdown.makespan_seconds;
        record.busy_seconds = breakdown.busy_seconds;
        record.blocked_seconds = breakdown.blocked_seconds;
        record.idle_seconds = breakdown.idle_seconds;
        record.level_stall_seconds = breakdown.level_stall_seconds;
        record.straggler_events = breakdown.straggler_events;
        record.comm = engine.reducer.stats;
        record.comm_levels = engine.reducer.level_stats().to_vec();
        record.level_links = (0..engine.topo.n_levels())
            .map(|l| engine.topo.link(l).name().to_string())
            .collect();
        record.total_steps = engine.t();
        // The schedule block: what the policy actually decided (realized
        // per-level events, interval trajectory) plus its serializable
        // controller state for the checkpoint sidecar.
        let final_base = cfg.hier_schedule_at(cfg.epochs.saturating_sub(1))?;
        record.schedule = Some(crate::algorithms::ScheduleSummary {
            policy: cfg.schedule_policy.spec(),
            realized: engine.realized.clone(),
            final_intervals: engine.policy.intervals(&final_base),
            k2_clamp,
            changes: engine.policy.changes().to_vec(),
            state: engine.policy.state(),
        });
        // The faults block: what the elastic-membership layer did.  Lost
        // time comes from the timeline's breakdown (down steps + restore
        // surcharges); the event counters come from the engine, which owns
        // the parameter-side consequences.
        if let Some(counts) = engine.fault_counts() {
            record.faults = Some(crate::metrics::FaultSummary {
                spec: cfg.faults.as_ref().map(|f| f.spec()).unwrap_or_default(),
                preemptions: counts.preemptions,
                reentries: counts.reentries,
                checkpoint_restores: counts.checkpoint_restores,
                migrations: counts.migrations,
                survivor_reductions: counts.survivor_reductions,
                lost_seconds: breakdown.lost_seconds.iter().sum(),
                membership_epoch: counts.membership_epoch,
            });
        }
        // The compression block: the wire format's byte accounting (the
        // `comm` totals already reflect compressed pricing; the dense
        // shadow alongside is the savings denominator) plus the
        // error-feedback mass still held locally at end of run.
        if !cfg.compress.is_none() {
            let total_bytes =
                engine.reducer.stats.local_bytes
                    + engine.reducer.stats.global_bytes
                    + engine.reducer.stats.rack_bytes;
            record.compression = Some(crate::metrics::CompressionSummary {
                spec: cfg.compress.spec(),
                payload_bytes: cfg.compress.payload_bytes(n_params) as u64,
                dense_payload_bytes: (n_params * 4) as u64,
                compressed_bytes: total_bytes,
                dense_bytes: engine.reducer.dense_bytes,
                residual_l2: engine.residual_l2().unwrap_or(0.0),
            });
        }
        if cfg.keep_final_params {
            let mut final_params = Vec::new();
            engine.mean_params(&mut final_params);
            record.final_params = Some(final_params);
        }
        Ok(record)
    }

    /// Mean loss + accuracy of one parameter vector over the full eval set
    /// (full batches only — the XLA eval artifact has a fixed batch shape).
    pub fn evaluate(&mut self, params: &FlatParams) -> Result<(f64, f64)> {
        let eb = self.backend.eval_batch();
        let units = self.backend.units_per_row() as f64;
        let n_total = self.data.eval_n();
        let n_batches = n_total / eb;
        if n_batches == 0 {
            bail!("eval set ({n_total}) smaller than eval batch ({eb})");
        }
        let mut buf = BatchBuf::default();
        let mut sum_loss = 0.0f64;
        let mut ncorrect = 0.0f64;
        for i in 0..n_batches {
            buf.clear();
            let filled = self.data.fill_eval(i * eb, eb, &mut buf);
            debug_assert_eq!(filled, eb);
            let (l, c) = self.backend.eval_batch_stats(params, &buf, eb)?;
            sum_loss += l as f64;
            ncorrect += c as f64;
        }
        let rows = (n_batches * eb) as f64;
        Ok((sum_loss / (rows * units), ncorrect / (rows * units)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BackendKind;
    use crate::data::{ClassifyData, MixtureSpec};
    use crate::native::NativeMlp;
    use crate::util::rng::Pcg32;

    fn quick_cfg() -> RunConfig {
        let mut cfg = RunConfig::defaults("native-test");
        cfg.p = 4;
        cfg.s = 2;
        cfg.k1 = 2;
        cfg.k2 = 4;
        cfg.epochs = 4;
        cfg.train_n = 512;
        cfg.test_n = 128;
        cfg.backend = BackendKind::Native;
        cfg.lr = crate::optimizer::LrSchedule::Constant(0.1);
        cfg.noise = 0.6;
        cfg
    }

    fn make_trainer(cfg: &RunConfig) -> Trainer<'_> {
        let dims = [16usize, 32, 4];
        let backend = NativeMlp::new(&dims, 8, 32).unwrap();
        let data = ClassifyData::generate(MixtureSpec {
            dim: 16,
            classes: 4,
            train_n: cfg.train_n,
            test_n: cfg.test_n,
            radius: cfg.radius,
            noise: cfg.noise,
            subclusters: 1,
            label_noise: 0.0,
            seed: cfg.seed,
        });
        let mut rng = Pcg32::seeded(cfg.seed);
        let init = backend.init(&mut rng);
        Trainer::new(cfg, Box::new(backend), Box::new(data), init).unwrap()
    }

    #[test]
    fn training_learns() {
        let cfg = quick_cfg();
        let mut tr = make_trainer(&cfg);
        let rec = tr.run().unwrap();
        assert_eq!(rec.epochs.len(), 4);
        let first = rec.epochs.first().unwrap();
        let last = rec.epochs.last().unwrap();
        assert!(last.train_loss < first.train_loss);
        assert!(last.test_acc > 0.5, "test_acc={}", last.test_acc);
    }

    #[test]
    fn deterministic_runs() {
        let cfg = quick_cfg();
        let a = make_trainer(&cfg).run().unwrap();
        let b = make_trainer(&cfg).run().unwrap();
        assert_eq!(a.epochs.len(), b.epochs.len());
        for (x, y) in a.epochs.iter().zip(&b.epochs) {
            assert_eq!(x.train_loss, y.train_loss);
            assert_eq!(x.test_acc, y.test_acc);
        }
    }

    #[test]
    fn comm_counts_match_schedule() {
        let cfg = quick_cfg();
        let mut tr = make_trainer(&cfg);
        let rec = tr.run().unwrap();
        let sched = cfg.schedule().unwrap();
        let (g, l) = sched.reduction_counts(rec.total_steps);
        assert_eq!(rec.comm.global_reductions, g);
        // Each Local event fires one reduction per cluster.
        let clusters = (cfg.p / cfg.s) as u64;
        assert_eq!(rec.comm.local_reductions, l * clusters);
    }

    #[test]
    fn sync_sgd_keeps_replicas_identical() {
        let mut cfg = quick_cfg();
        cfg.k1 = 1;
        cfg.k2 = 1;
        cfg.s = 1;
        let mut tr = make_trainer(&cfg);
        let rec = tr.run().unwrap();
        // After every step a global average runs: loss should decrease as a
        // large-batch SGD.
        assert!(rec.epochs.last().unwrap().train_loss < rec.epochs[0].train_loss);
        assert_eq!(rec.comm.global_reductions, rec.total_steps);
    }

    #[test]
    fn homogeneous_event_mode_matches_lockstep_training() {
        let lockstep = quick_cfg();
        let mut event = quick_cfg();
        event.exec = crate::sim::ExecKind::Event;
        let ra = make_trainer(&lockstep).run().unwrap();
        let rb = make_trainer(&event).run().unwrap();
        assert_eq!(ra.exec_model, "lockstep");
        assert_eq!(rb.exec_model, "event");
        for (x, y) in ra.epochs.iter().zip(&rb.epochs) {
            assert_eq!(x.train_loss, y.train_loss);
            assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits());
            // homogeneous timelines coincide to the bit
            assert_eq!(x.sim_seconds.to_bits(), y.sim_seconds.to_bits());
        }
        assert_eq!(ra.comm, rb.comm);
        assert_eq!(ra.makespan_seconds.to_bits(), rb.makespan_seconds.to_bits());
        assert_eq!(ra.busy_seconds, rb.busy_seconds);
        assert!(rb.blocked_seconds.iter().all(|&x| x == 0.0));
        assert_eq!(ra.level_stall_seconds, rb.level_stall_seconds);
    }

    #[test]
    fn straggler_run_keeps_parameters_and_stretches_the_clock() {
        let lockstep = quick_cfg();
        let mut strag = quick_cfg();
        strag.exec = crate::sim::ExecKind::Event;
        strag.het = 0.2;
        strag.straggler_prob = 0.1;
        strag.straggler_mult = 4.0;
        let ra = make_trainer(&lockstep).run().unwrap();
        let rb = make_trainer(&strag).run().unwrap();
        // Heterogeneity is a time model only: training numerics and the
        // communication account are untouched.
        for (x, y) in ra.epochs.iter().zip(&rb.epochs) {
            assert_eq!(x.train_loss, y.train_loss);
            assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits());
        }
        assert_eq!(ra.comm, rb.comm);
        // ... while the modelled wall clock stretches past the lockstep sum
        assert!(rb.makespan_seconds > ra.makespan_seconds);
        assert!(rb.straggler_events > 0);
        assert!(rb.blocked_seconds.iter().sum::<f64>() > 0.0);
    }

    #[test]
    fn zero_fault_run_matches_plain_event_training() {
        // Arming the fault layer with prob 0 must not perturb one bit:
        // the layer forces the event core onto its per-learner pool, and
        // this pin is what guarantees that switch is invisible.
        let mut plain = quick_cfg();
        plain.exec = crate::sim::ExecKind::Event;
        let mut armed = plain.clone();
        armed.faults = Some(crate::sim::parse_faults("0").unwrap());
        let ra = make_trainer(&plain).run().unwrap();
        let rb = make_trainer(&armed).run().unwrap();
        for (x, y) in ra.epochs.iter().zip(&rb.epochs) {
            assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits());
            assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits());
            assert_eq!(x.sim_seconds.to_bits(), y.sim_seconds.to_bits());
        }
        assert_eq!(ra.comm, rb.comm);
        assert_eq!(ra.makespan_seconds.to_bits(), rb.makespan_seconds.to_bits());
        let f = rb.faults.expect("armed run reports a faults block");
        assert_eq!(f.preemptions, 0);
        assert_eq!(f.reentries, 0);
        assert_eq!(f.migrations, 0);
        assert_eq!(f.survivor_reductions, 0);
        assert_eq!(f.lost_seconds, 0.0);
        assert!(ra.faults.is_none());
    }

    #[test]
    fn scripted_outage_degrades_and_recovers() {
        // Learner 1 of the first innermost group goes down for steps 3-6
        // and re-enters at step 7.  The run must complete with finite
        // losses, count exactly one preemption/re-entry/restore, run the
        // intervening innermost reductions degraded, and lose time on the
        // modelled clock.
        let mut cfg = quick_cfg();
        cfg.exec = crate::sim::ExecKind::Event;
        cfg.faults = Some(crate::sim::parse_faults("trace:3@1x4").unwrap());
        let rec = make_trainer(&cfg).run().unwrap();
        for e in &rec.epochs {
            assert!(e.train_loss.is_finite());
        }
        assert!(rec.epochs.last().unwrap().train_loss < rec.epochs[0].train_loss);
        let f = rec.faults.expect("faults block present");
        assert_eq!(f.preemptions, 1);
        assert_eq!(f.reentries, 1);
        assert_eq!(f.checkpoint_restores, 1);
        // K1 = 2: the innermost reductions at steps 4 and 6 ran without
        // learner 1.
        assert_eq!(f.survivor_reductions, 2);
        assert!(f.lost_seconds > 0.0, "lost_seconds={}", f.lost_seconds);
        // One preemption + one re-entry bump the membership epoch twice.
        assert_eq!(f.membership_epoch, 2);
    }

    #[test]
    fn compressed_training_learns_and_accounts_bytes() {
        // A sparse-global run must still train (error feedback carries the
        // untransmitted mass), and the comm account must shrink relative
        // to the dense shadow recorded next to it.
        let mut cfg = quick_cfg();
        cfg.compress = crate::comm::Compression::parse("topk:0.1").unwrap();
        let rec = make_trainer(&cfg).run().unwrap();
        for e in &rec.epochs {
            assert!(e.train_loss.is_finite());
        }
        assert!(rec.epochs.last().unwrap().train_loss < rec.epochs[0].train_loss);
        let c = rec.compression.expect("compression block present");
        assert_eq!(c.spec, "topk:0.1");
        assert!(c.payload_bytes < c.dense_payload_bytes);
        assert!(c.compressed_bytes < c.dense_bytes, "{} vs {}", c.compressed_bytes, c.dense_bytes);
        assert!(c.residual_l2 > 0.0, "top-k leaves untransmitted mass in the residuals");
        // the comm account is the compressed one
        let total = rec.comm.local_bytes + rec.comm.global_bytes + rec.comm.rack_bytes;
        assert_eq!(total, c.compressed_bytes);
        // ... and a dense run's record carries no block at all
        let dense = make_trainer(&quick_cfg()).run().unwrap();
        assert!(dense.compression.is_none());
    }

    #[test]
    fn quantized_training_matches_dense_closely() {
        // q8 is near-lossless: the training curve should track the dense
        // run tightly while the byte account shrinks ~4x.
        let dense = make_trainer(&quick_cfg()).run().unwrap();
        let mut cfg = quick_cfg();
        cfg.compress = crate::comm::Compression::parse("q8").unwrap();
        let q = make_trainer(&cfg).run().unwrap();
        let (a, b) = (dense.epochs.last().unwrap(), q.epochs.last().unwrap());
        assert!((a.train_loss - b.train_loss).abs() < 0.05, "{} vs {}", a.train_loss, b.train_loss);
        let c = q.compression.unwrap();
        assert!(c.compressed_bytes * 3 < c.dense_bytes, "q8 moves ~1/4 the bytes");
    }

    #[test]
    fn record_steps_collects_curve() {
        let mut cfg = quick_cfg();
        cfg.record_steps = true;
        let mut tr = make_trainer(&cfg);
        let spe = tr.steps_per_epoch();
        let rec = tr.run().unwrap();
        assert_eq!(rec.step_loss.len(), spe * cfg.epochs);
    }
}
