//! Synthetic datasets + sharded sampling.
//!
//! Substitutes for the paper's data (DESIGN.md §1):
//! - `ClassifyData` — anisotropic Gaussian-mixture classification
//!   ("cifar-sim" / "imagenet-sim").  Learnable but non-trivial for an MLP;
//!   gradient variance (the paper's M) is controlled by `noise`.
//! - `TokenData` — a noisy-deterministic Markov token stream for the
//!   transformer LM end-to-end driver.
//!
//! Sampling follows the paper's model: each learner draws i.i.d. mini-
//! batches (with replacement) from the training distribution using its own
//! PRNG stream; an "epoch" is the step count at which P·B·steps equals one
//! pass over the training set.

use crate::util::rng::Pcg32;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataKind {
    Classify { dim: usize, classes: usize },
    Tokens { vocab: usize, seq_len: usize },
}

/// A (possibly stacked) batch: MLP models use `xf`, LM models use `xi`.
#[derive(Debug, Clone, Default)]
pub struct BatchBuf {
    pub xf: Vec<f32>,
    pub xi: Vec<i32>,
    pub y: Vec<i32>,
    /// Rows currently held (across all learners for stacked batches).
    pub rows: usize,
}

impl BatchBuf {
    pub fn clear(&mut self) {
        self.xf.clear();
        self.xi.clear();
        self.y.clear();
        self.rows = 0;
    }
}

pub trait DataSource: Send + Sync {
    fn kind(&self) -> DataKind;
    /// Append `b` i.i.d. training samples drawn with `rng`.
    fn fill_train(&self, rng: &mut Pcg32, b: usize, out: &mut BatchBuf);
    /// Element counts `(xf, xi, y)` that one `fill_train(rng, b, _)` call
    /// appends — the per-learner region layout of a stacked batch.
    fn train_region(&self, b: usize) -> (usize, usize, usize);
    /// Write exactly the samples `fill_train(rng, b, _)` would append into
    /// pre-sized regions (same RNG consumption, same values, same order) —
    /// the engine's pool-parallel batch fill carves a stacked `BatchBuf`
    /// into disjoint per-learner regions and fills them concurrently,
    /// byte-identical to the serial append loop.
    fn fill_train_region(
        &self,
        rng: &mut Pcg32,
        b: usize,
        xf: &mut [f32],
        xi: &mut [i32],
        y: &mut [i32],
    );
    /// Size of the held-out evaluation set.
    fn eval_n(&self) -> usize;
    /// Append evaluation samples `[start, start+b)` (clamped); returns the
    /// number appended.
    fn fill_eval(&self, start: usize, b: usize, out: &mut BatchBuf) -> usize;
    /// Nominal training-set size (defines the epoch length).
    fn train_n(&self) -> usize;
}

// ---------------------------------------------------------------------------
// Gaussian-mixture classification
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MixtureSpec {
    pub dim: usize,
    pub classes: usize,
    pub train_n: usize,
    pub test_n: usize,
    /// Class-center radius (signal).
    pub radius: f32,
    /// Within-class noise std per coordinate.
    pub noise: f32,
    /// Sub-clusters per class (> 1 makes the decision boundary non-convex,
    /// so the MLP's hidden layer is actually needed and training takes
    /// many epochs — mirroring CIFAR-style difficulty).
    pub subclusters: usize,
    /// Probability a training/test label is resampled uniformly: caps the
    /// reachable accuracy at (1−p) + p/C and keeps gradient variance (the
    /// paper's M) bounded away from zero through the whole run.
    pub label_noise: f32,
    pub seed: u64,
}

impl MixtureSpec {
    /// Default "cifar-sim" difficulty for a given model input/classes.
    pub fn cifar_sim(dim: usize, classes: usize, train_n: usize, test_n: usize) -> MixtureSpec {
        MixtureSpec {
            dim,
            classes,
            train_n,
            test_n,
            radius: 1.0,
            noise: 1.4,
            subclusters: 8,
            label_noise: 0.05,
            seed: 1234,
        }
    }
}

pub struct ClassifyData {
    pub spec: MixtureSpec,
    centers: Vec<f32>, // classes * dim
    train_x: Vec<f32>, // train_n * dim
    train_y: Vec<i32>,
    test_x: Vec<f32>,
    test_y: Vec<i32>,
}

impl ClassifyData {
    pub fn generate(spec: MixtureSpec) -> ClassifyData {
        assert!(spec.subclusters >= 1, "subclusters must be >= 1");
        let mut rng = Pcg32::new(spec.seed, 77);
        let d = spec.dim;
        let m = spec.subclusters;
        // Sub-cluster centers: random Gaussian directions scaled so
        // ||center|| = radius·sqrt(d) (per-coordinate scale `radius`,
        // comparable to the per-coordinate noise).
        let mut centers = vec![0.0f32; spec.classes * m * d];
        for c in 0..spec.classes * m {
            let row = &mut centers[c * d..(c + 1) * d];
            let mut norm = 0.0f32;
            for v in row.iter_mut() {
                *v = rng.next_normal();
                norm += *v * *v;
            }
            let scale = spec.radius * (d as f32).sqrt() / norm.sqrt().max(1e-12);
            for v in row.iter_mut() {
                *v *= scale;
            }
        }
        let gen_split = |n: usize, rng: &mut Pcg32| {
            let mut xs = vec![0.0f32; n * d];
            let mut ys = vec![0i32; n];
            for i in 0..n {
                let c = rng.next_below(spec.classes as u32) as usize;
                let sub = rng.next_below(m as u32) as usize;
                // Label noise: resample the label uniformly with prob p.
                ys[i] = if spec.label_noise > 0.0 && rng.next_f32() < spec.label_noise {
                    rng.next_below(spec.classes as u32) as i32
                } else {
                    c as i32
                };
                let center = &centers[(c * m + sub) * d..(c * m + sub + 1) * d];
                let row = &mut xs[i * d..(i + 1) * d];
                for (x, mu) in row.iter_mut().zip(center) {
                    *x = mu + spec.noise * rng.next_normal();
                }
            }
            (xs, ys)
        };
        let mut train_rng = rng.fork(1);
        let mut test_rng = rng.fork(2);
        let (train_x, train_y) = gen_split(spec.train_n, &mut train_rng);
        let (test_x, test_y) = gen_split(spec.test_n, &mut test_rng);
        ClassifyData { spec, centers, train_x, train_y, test_x, test_y }
    }

    pub fn center(&self, c: usize) -> &[f32] {
        &self.centers[c * self.spec.dim..(c + 1) * self.spec.dim]
    }
}

impl DataSource for ClassifyData {
    fn kind(&self) -> DataKind {
        DataKind::Classify { dim: self.spec.dim, classes: self.spec.classes }
    }

    fn fill_train(&self, rng: &mut Pcg32, b: usize, out: &mut BatchBuf) {
        let d = self.spec.dim;
        for _ in 0..b {
            let i = rng.next_below(self.spec.train_n as u32) as usize;
            out.xf.extend_from_slice(&self.train_x[i * d..(i + 1) * d]);
            out.y.push(self.train_y[i]);
        }
        out.rows += b;
    }

    fn train_region(&self, b: usize) -> (usize, usize, usize) {
        (b * self.spec.dim, 0, b)
    }

    fn fill_train_region(
        &self,
        rng: &mut Pcg32,
        b: usize,
        xf: &mut [f32],
        _xi: &mut [i32],
        y: &mut [i32],
    ) {
        let d = self.spec.dim;
        debug_assert_eq!(xf.len(), b * d);
        debug_assert_eq!(y.len(), b);
        for k in 0..b {
            let i = rng.next_below(self.spec.train_n as u32) as usize;
            xf[k * d..(k + 1) * d].copy_from_slice(&self.train_x[i * d..(i + 1) * d]);
            y[k] = self.train_y[i];
        }
    }

    fn eval_n(&self) -> usize {
        self.spec.test_n
    }

    fn fill_eval(&self, start: usize, b: usize, out: &mut BatchBuf) -> usize {
        let d = self.spec.dim;
        let end = (start + b).min(self.spec.test_n);
        for i in start..end {
            out.xf.extend_from_slice(&self.test_x[i * d..(i + 1) * d]);
            out.y.push(self.test_y[i]);
        }
        let n = end.saturating_sub(start);
        out.rows += n;
        n
    }

    fn train_n(&self) -> usize {
        self.spec.train_n
    }
}

// ---------------------------------------------------------------------------
// Markov token stream (LM)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TokenSpec {
    pub vocab: usize,
    pub seq_len: usize,
    /// Probability the deterministic successor rule fires (vs uniform
    /// noise).  The LM's achievable loss is the entropy of this channel.
    pub determinism: f32,
    /// Nominal corpus size in sequences (epoch bookkeeping).
    pub train_n: usize,
    pub test_n: usize,
    pub seed: u64,
}

impl TokenSpec {
    pub fn tiny_corpus(vocab: usize, seq_len: usize) -> TokenSpec {
        TokenSpec { vocab, seq_len, determinism: 0.85, train_n: 4096, test_n: 256, seed: 99 }
    }
}

pub struct TokenData {
    pub spec: TokenSpec,
    test_x: Vec<i32>, // test_n * seq_len
    test_y: Vec<i32>,
}

impl TokenData {
    pub fn generate(spec: TokenSpec) -> TokenData {
        let mut rng = Pcg32::new(spec.seed, 13);
        let n = spec.test_n;
        let t = spec.seq_len;
        let mut test_x = vec![0i32; n * t];
        let mut test_y = vec![0i32; n * t];
        for i in 0..n {
            Self::fill_seq(&spec, &mut rng, &mut test_x[i * t..(i + 1) * t], &mut test_y[i * t..(i + 1) * t]);
        }
        TokenData { spec, test_x, test_y }
    }

    /// Markov rule: successor(v) = (31·v + 7) mod V with prob `determinism`,
    /// else uniform.  An LM that learns the rule reaches
    /// H = −p·log p − (1−p)·log((1−p)/V) nats.
    fn fill_seq(spec: &TokenSpec, rng: &mut Pcg32, x: &mut [i32], y: &mut [i32]) {
        let v = spec.vocab as u32;
        let mut tok = rng.next_below(v);
        for i in 0..x.len() {
            x[i] = tok as i32;
            let next = if rng.next_f32() < spec.determinism {
                (tok.wrapping_mul(31).wrapping_add(7)) % v
            } else {
                rng.next_below(v)
            };
            y[i] = next as i32;
            tok = next;
        }
    }

    /// The per-token cross entropy (nats) of the generating channel — the
    /// LM's information-theoretic floor.
    pub fn entropy_floor(&self) -> f64 {
        let p = self.spec.determinism as f64;
        let v = self.spec.vocab as f64;
        // With prob (1-p) the next token is uniform over V (which includes
        // the deterministic successor with prob 1/V).
        let p_succ = p + (1.0 - p) / v;
        let p_other = (1.0 - p) / v;
        -(p_succ * p_succ.ln() + (v - 1.0) * p_other * p_other.ln())
    }
}

impl DataSource for TokenData {
    fn kind(&self) -> DataKind {
        DataKind::Tokens { vocab: self.spec.vocab, seq_len: self.spec.seq_len }
    }

    fn fill_train(&self, rng: &mut Pcg32, b: usize, out: &mut BatchBuf) {
        let t = self.spec.seq_len;
        let base_x = out.xi.len();
        let base_y = out.y.len();
        out.xi.resize(base_x + b * t, 0);
        out.y.resize(base_y + b * t, 0);
        for i in 0..b {
            Self::fill_seq(
                &self.spec,
                rng,
                &mut out.xi[base_x + i * t..base_x + (i + 1) * t],
                &mut out.y[base_y + i * t..base_y + (i + 1) * t],
            );
        }
        out.rows += b;
    }

    fn train_region(&self, b: usize) -> (usize, usize, usize) {
        (0, b * self.spec.seq_len, b * self.spec.seq_len)
    }

    fn fill_train_region(
        &self,
        rng: &mut Pcg32,
        b: usize,
        _xf: &mut [f32],
        xi: &mut [i32],
        y: &mut [i32],
    ) {
        let t = self.spec.seq_len;
        debug_assert_eq!(xi.len(), b * t);
        debug_assert_eq!(y.len(), b * t);
        for i in 0..b {
            Self::fill_seq(&self.spec, rng, &mut xi[i * t..(i + 1) * t], &mut y[i * t..(i + 1) * t]);
        }
    }

    fn eval_n(&self) -> usize {
        self.spec.test_n
    }

    fn fill_eval(&self, start: usize, b: usize, out: &mut BatchBuf) -> usize {
        let t = self.spec.seq_len;
        let end = (start + b).min(self.spec.test_n);
        for i in start..end {
            out.xi.extend_from_slice(&self.test_x[i * t..(i + 1) * t]);
            out.y.extend_from_slice(&self.test_y[i * t..(i + 1) * t]);
        }
        let n = end.saturating_sub(start);
        out.rows += n;
        n
    }

    fn train_n(&self) -> usize {
        self.spec.train_n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_mixture() -> ClassifyData {
        ClassifyData::generate(MixtureSpec {
            dim: 8,
            classes: 3,
            train_n: 100,
            test_n: 40,
            radius: 1.0,
            noise: 0.5,
            subclusters: 1,
            label_noise: 0.0,
            seed: 1,
        })
    }

    #[test]
    fn mixture_shapes() {
        let d = small_mixture();
        assert_eq!(d.train_x.len(), 800);
        assert_eq!(d.test_y.len(), 40);
        assert!(d.train_y.iter().all(|&y| (0..3).contains(&y)));
    }

    #[test]
    fn mixture_deterministic() {
        let a = small_mixture();
        let b = small_mixture();
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.test_y, b.test_y);
    }

    #[test]
    fn mixture_classes_are_separated() {
        // Samples must be closer (on average) to their own center.
        let d = small_mixture();
        let dim = d.spec.dim;
        let mut own = 0.0f64;
        let mut other = 0.0f64;
        let mut n_other = 0.0f64;
        for i in 0..d.spec.train_n {
            let x = &d.train_x[i * dim..(i + 1) * dim];
            for c in 0..3 {
                let mu = d.center(c);
                let dist: f32 = x.iter().zip(mu).map(|(a, b)| (a - b) * (a - b)).sum();
                if c as i32 == d.train_y[i] {
                    own += dist as f64;
                } else {
                    other += dist as f64;
                    n_other += 1.0;
                }
            }
        }
        assert!(own / (d.spec.train_n as f64) < other / n_other);
    }

    #[test]
    fn batch_fill_appends() {
        let d = small_mixture();
        let mut rng = Pcg32::seeded(5);
        let mut buf = BatchBuf::default();
        d.fill_train(&mut rng, 4, &mut buf);
        d.fill_train(&mut rng, 4, &mut buf);
        assert_eq!(buf.rows, 8);
        assert_eq!(buf.xf.len(), 8 * 8);
        assert_eq!(buf.y.len(), 8);
    }

    #[test]
    fn region_fill_matches_append_fill() {
        // The pool-parallel batch fill depends on region fills being
        // byte-identical (values AND RNG consumption) to the append path.
        let sources: [&dyn DataSource; 2] = [
            &small_mixture(),
            &TokenData::generate(TokenSpec::tiny_corpus(64, 16)),
        ];
        for d in sources {
            let b = 6;
            let mut appended = BatchBuf::default();
            let mut rng_a = Pcg32::seeded(41);
            d.fill_train(&mut rng_a, b, &mut appended);
            d.fill_train(&mut rng_a, b, &mut appended);

            let (nxf, nxi, ny) = d.train_region(b);
            let mut xf = vec![0.0f32; 2 * nxf];
            let mut xi = vec![0i32; 2 * nxi];
            let mut y = vec![0i32; 2 * ny];
            let mut rng_b = Pcg32::seeded(41);
            for k in 0..2 {
                d.fill_train_region(
                    &mut rng_b,
                    b,
                    &mut xf[k * nxf..(k + 1) * nxf],
                    &mut xi[k * nxi..(k + 1) * nxi],
                    &mut y[k * ny..(k + 1) * ny],
                );
            }
            assert_eq!(appended.xf, xf);
            assert_eq!(appended.xi, xi);
            assert_eq!(appended.y, y);
            // Streams stay aligned: both paths consumed the same draws.
            assert_eq!(rng_a.next_f32().to_bits(), rng_b.next_f32().to_bits());
        }
    }

    #[test]
    fn eval_fill_clamps() {
        let d = small_mixture();
        let mut buf = BatchBuf::default();
        assert_eq!(d.fill_eval(36, 16, &mut buf), 4);
        assert_eq!(buf.rows, 4);
        assert_eq!(d.fill_eval(40, 16, &mut buf), 0);
    }

    #[test]
    fn token_rule_mostly_holds() {
        let td = TokenData::generate(TokenSpec::tiny_corpus(64, 32));
        let mut rng = Pcg32::seeded(3);
        let mut buf = BatchBuf::default();
        td.fill_train(&mut rng, 64, &mut buf);
        let t = 32;
        let mut hits = 0;
        let mut total = 0;
        for i in 0..64 {
            for j in 0..t {
                let x = buf.xi[i * t + j] as u32;
                let y = buf.y[i * t + j] as u32;
                if (x.wrapping_mul(31).wrapping_add(7)) % 64 == y {
                    hits += 1;
                }
                total += 1;
            }
        }
        let rate = hits as f64 / total as f64;
        assert!(rate > 0.8 && rate < 0.95, "rate={rate}");
    }

    #[test]
    fn token_targets_shift_by_one() {
        // y[i] must equal x[i+1] within a sequence.
        let td = TokenData::generate(TokenSpec::tiny_corpus(32, 16));
        let mut buf = BatchBuf::default();
        td.fill_eval(0, 4, &mut buf);
        for s in 0..4 {
            for i in 0..15 {
                assert_eq!(buf.y[s * 16 + i], buf.xi[s * 16 + i + 1]);
            }
        }
    }

    #[test]
    fn entropy_floor_sane() {
        let td = TokenData::generate(TokenSpec::tiny_corpus(256, 32));
        let h = td.entropy_floor();
        // Between 0 (deterministic) and ln(256) (uniform).
        assert!(h > 0.3 && h < (256f64).ln(), "h={h}");
    }
}
