//! The execution-model layer: *when* does modelled work happen, per
//! learner, on the simulated cluster.
//!
//! The paper's central trade — local reductions are cheap because they
//! synchronize only a subgroup, global reductions are expensive because
//! they stall all P learners — only becomes visible when learners own
//! their clocks.  This module decouples the *time model* from the
//! *parameter math*: the engine keeps computing parameters in the same
//! deterministic step order under every model (so numerics are identical
//! by construction), while the selected [`ExecModel`] accounts for how
//! those steps and reductions land on a virtual timeline.
//!
//! Module layout (the heap/calendar core of the event engine):
//!
//! - [`mod@event`] — [`EventModel`], the production virtual-time core.
//!   Learner state lives in flat memory-pooled arrays that are
//!   materialized lazily (a homogeneous run never allocates an O(P)
//!   vector: one shared step node stands for all P learners), steps are
//!   announced as shared next-event nodes (`on_steps` is O(1), not an
//!   O(P) clock scan), and level-ℓ reductions fire as group-local
//!   barrier nodes at max arrival.
//! - [`mod@scan`] — [`ScanEventModel`], the legacy O(P)-per-step scan
//!   implementation, kept verbatim as the executable reference the
//!   property tests compare the heap core against bit for bit
//!   (rust/tests/event_heap.rs).
//! - [`mod@replay`] — the timeline-only replay mode: an
//!   [`EventCalendar`] (binary min-heap merging the per-level event
//!   streams of a static schedule) drives a model from barrier node to
//!   barrier node without any parameter math, which is how the planner
//!   prices straggler-aware makespans at P up to 1,000,000
//!   (`sweep --timeline-only`).
//! - [`mod@faults`] — seeded membership traces for the elastic-fleet
//!   layer (`--faults`): per-learner preempt/repair intervals drawn from
//!   a dedicated Pcg32 stream ("FAUL"), consulted by the event models via
//!   [`ExecModel::install_faults`].  A down learner's steps are charged
//!   to `lost_seconds` instead of `busy_seconds`, its group's barriers
//!   fire over the survivors only, and its first up step pays a
//!   deterministic restore surcharge.  Faults are seeded-timeline data
//!   only: the parameter path holds its own identical `MembershipModel`,
//!   and zero-fault traces leave both paths bit-identical to plain event
//!   mode.
//!
//! Two models (`--exec lockstep|event`):
//!
//! - [`LockstepModel`] — the legacy semantics: one shared clock, every
//!   step charges every learner the same compute time, every reduction
//!   serializes against the shared clock (concurrent symmetric groups are
//!   charged once, the max — same convention as `Reducer::reduce_level`).
//! - [`EventModel`] — the virtual-time event engine: each learner has its
//!   own clock driven by a deterministic per-learner rate ramp (`--het`)
//!   plus seeded straggler spikes (`--straggler`, an independent `Pcg32`
//!   stream per learner that never touches the training streams).  A
//!   level-ℓ reduction is a **group-local barrier**: it blocks only that
//!   group's members at their max arrival time plus the modelled
//!   collective cost, while every other group keeps stepping.  Modelled
//!   wall clock is the makespan of the timeline (max over learner
//!   clocks).
//!
//! Determinism contract (enforced by rust/tests/golden_trace.rs,
//! rust/tests/event_heap.rs, and the property tests in
//! rust/tests/hierarchy.rs): with homogeneous compute times (`het = 0`,
//! `straggler_prob = 0`) the event model reproduces lockstep **bit for
//! bit** — same parameters, same reduction trace, same comm bytes, and
//! the identical timeline breakdown — because every arithmetic operation
//! the two models perform is then the same IEEE operation in the same
//! order.  Heterogeneity changes *time only*: the parameter path never
//! consults the timeline.  The heap core additionally reproduces the
//! legacy scan timeline exactly under *every* heterogeneity spec, because
//! lazy advancement replays each learner's per-step accumulation in the
//! learner's own step order (cross-learner values never mix into any
//! single f64 accumulation except the stall tallies, which keep the
//! legacy group-then-member order).

use anyhow::{anyhow, bail, Result};

use crate::topology::HierTopology;

pub mod event;
pub mod faults;
pub mod replay;
pub mod scan;

pub use event::EventModel;
pub use faults::{
    parse_faults, FaultEvent, FaultPlan, FaultSpec, MembershipModel, DEFAULT_MTTR,
    FAULT_STREAM, REENTRY_RESTORE_STEPS,
};
pub use replay::{
    drive_timeline, drive_timeline_policy, replay_timeline, replay_timeline_stats,
    replay_timeline_stats_faults, EventCalendar, TimelineStats,
};
pub use scan::ScanEventModel;

/// Which execution model accounts the run's virtual time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecKind {
    /// One shared clock; reductions serialize against it (legacy).
    Lockstep,
    /// Per-learner clocks with group-local barriers (virtual-time events).
    Event,
}

impl ExecKind {
    /// Parse the config/CLI spelling (`lockstep`, `event`).
    pub fn parse(s: &str) -> Result<ExecKind> {
        match s {
            "lockstep" => Ok(ExecKind::Lockstep),
            "event" => Ok(ExecKind::Event),
            _ => bail!("unknown execution model {s:?} (lockstep|event)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ExecKind::Lockstep => "lockstep",
            ExecKind::Event => "event",
        }
    }

    /// Build the model for a run of `p` learners over an `n_levels`-deep
    /// hierarchy whose synchronous step costs `step_seconds` at base rate.
    pub fn build(
        &self,
        p: usize,
        n_levels: usize,
        step_seconds: f64,
        spec: &HetSpec,
    ) -> Box<dyn ExecModel> {
        match self {
            ExecKind::Lockstep => Box::new(LockstepModel::new(p, n_levels, step_seconds)),
            ExecKind::Event => Box::new(EventModel::new(p, n_levels, step_seconds, spec)),
        }
    }
}

/// Heterogeneity knobs for the event model.
///
/// - `het` — deterministic per-learner rate spread: learner `j`'s step
///   time is scaled by `1 + het · j/(P−1)` (learner 0 runs at base rate,
///   learner P−1 is the slowest).  `0` = homogeneous.
/// - `straggler_prob` / `straggler_mult` — seeded straggler spikes: each
///   (learner, step) independently takes `straggler_mult ×` as long with
///   probability `straggler_prob`.  Spikes draw from per-learner `Pcg32`
///   streams forked from `seed` on a stream id distinct from every
///   training stream, so enabling them never perturbs the parameter math.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HetSpec {
    pub het: f64,
    pub straggler_prob: f64,
    pub straggler_mult: f64,
    pub seed: u64,
}

impl Default for HetSpec {
    fn default() -> HetSpec {
        HetSpec { het: 0.0, straggler_prob: 0.0, straggler_mult: 4.0, seed: 42 }
    }
}

impl HetSpec {
    /// Reject out-of-range knobs with actionable errors (negative or
    /// non-finite rates, probabilities outside [0, 1], speed-up
    /// "stragglers").
    pub fn validate(&self) -> Result<()> {
        if !self.het.is_finite() || self.het < 0.0 {
            bail!(
                "--het must be a finite rate spread >= 0 (got {}): learner j's step time \
                 scales by 1 + het*j/(P-1), so a negative spread would model \
                 faster-than-hardware learners",
                self.het
            );
        }
        if !self.straggler_prob.is_finite() || !(0.0..=1.0).contains(&self.straggler_prob) {
            bail!(
                "--straggler probability must lie in [0, 1] (got {}): it is the chance any \
                 one learner-step spikes",
                self.straggler_prob
            );
        }
        if !self.straggler_mult.is_finite() || self.straggler_mult < 1.0 {
            bail!(
                "--straggler multiplier must be >= 1 (got {}): a spike makes a step slower, \
                 never faster",
                self.straggler_mult
            );
        }
        Ok(())
    }

    /// Whether this spec leaves every learner at base rate — the regime
    /// where event mode must reproduce lockstep bit for bit (and where
    /// the heap core collapses all P learners onto one shared step node).
    pub fn is_homogeneous(&self) -> bool {
        self.het == 0.0 && self.straggler_prob == 0.0
    }

    /// Apply the shared `--het F` / `--straggler PROB[:MULT]` CLI grammar
    /// on top of this spec — the one place the flag spelling and the
    /// default-multiplier fall-through live, shared by `train`, `sweep`,
    /// and the examples (range checks stay in [`HetSpec::validate`]).
    pub fn apply_args(&mut self, args: &crate::util::cli::Args) -> Result<()> {
        self.het = args.parse_or("het", self.het)?;
        if let Some(s) = args.get("straggler") {
            let (prob, mult) = parse_straggler(s, self.straggler_mult)?;
            self.straggler_prob = prob;
            self.straggler_mult = mult;
        }
        Ok(())
    }
}

/// Parse a `--straggler PROB[:MULT]` flag value (e.g. `0.05` or `0.05:4`).
/// `default_mult` fills in when `:MULT` is omitted.  Range checks live in
/// [`HetSpec::validate`].
pub fn parse_straggler(s: &str, default_mult: f64) -> Result<(f64, f64)> {
    let (p, m) = match s.split_once(':') {
        Some((p, m)) => (p, Some(m)),
        None => (s, None),
    };
    let prob: f64 = p
        .trim()
        .parse()
        .map_err(|e| anyhow!("invalid --straggler probability {p:?}: {e} (expected PROB[:MULT], e.g. 0.05:4)"))?;
    let mult: f64 = match m {
        Some(m) => m
            .trim()
            .parse()
            .map_err(|e| anyhow!("invalid --straggler multiplier {m:?}: {e} (expected PROB[:MULT], e.g. 0.05:4)"))?,
        None => default_mult,
    };
    Ok((prob, mult))
}

/// Stream id of the straggler PRNGs ("SIMT"): distinct from the training
/// streams ("HIER" in `LearnerSet::new`, the data/init streams), so the
/// time model owns its own randomness.  Shared by the heap core and the
/// scan reference so their per-learner spike streams are the same
/// streams.
pub(crate) const STRAGGLER_STREAM: u64 = 0x53494D54;

/// Final timeline accounting, per learner and per hierarchy level.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecBreakdown {
    /// `ExecKind::name()` of the model that produced this breakdown.
    pub model: &'static str,
    /// Modelled wall clock of the whole run: max over learner clocks.
    pub makespan_seconds: f64,
    /// Per-learner compute time (rate ramp and spikes included).
    pub busy_seconds: Vec<f64>,
    /// Per-learner time spent waiting at barriers for slower peers.
    pub blocked_seconds: Vec<f64>,
    /// Per-learner `makespan − own clock`: time the run keeps running
    /// after this learner's last event (zero under homogeneity).
    pub idle_seconds: Vec<f64>,
    /// Barrier wait time attributed to each hierarchy level (sum over the
    /// waits its barriers caused, across all learners and events).
    pub level_stall_seconds: Vec<f64>,
    /// Per-learner time lost to preemption: down steps plus the re-entry
    /// restore surcharge.  All zeros unless a fault layer is installed.
    pub lost_seconds: Vec<f64>,
    /// Straggler spikes that fired over the run.
    pub straggler_events: u64,
}

/// A virtual-time execution model the engine drives step by step.
///
/// The engine calls [`ExecModel::on_step`] once per synchronous step
/// (after the parameter update) and [`ExecModel::on_reduction`] for every
/// fired reduction, in the same order the `Reducer` applies them.  Models
/// account time only — they never influence what the engine computes.
///
/// `now` and `breakdown` take `&mut self` because the heap core advances
/// learner clocks lazily: a query must first flush every learner to the
/// current step node (consuming straggler RNG state in the process).
pub trait ExecModel {
    fn name(&self) -> &'static str;

    /// Charge one local SGD step to every learner's clock.
    fn on_step(&mut self);

    /// Charge `n` consecutive steps — the calendar fast path used by the
    /// timeline-only replay driver between barrier nodes.  The default
    /// body repeats [`ExecModel::on_step`] (statically dispatched within
    /// the impl, so scan-style models pay no per-step vtable cost); the
    /// heap core overrides it with an O(1) shared step-node bump.
    fn on_steps(&mut self, n: u64) {
        for _ in 0..n {
            self.on_step();
        }
    }

    /// Charge a level-`level` reduction: every group at that level
    /// barriers its members and pays `seconds` (one symmetric group's
    /// modelled collective cost — groups at one level are identical in
    /// size, link, and payload).  Size-1 levels below the top are no-ops,
    /// mirroring `Reducer::reduce_level`.  Returns the barrier stall this
    /// event charged (the sum of member waits across the level's groups;
    /// always 0 under lockstep) — the feedback signal the engine hands to
    /// an adaptive `SchedulePolicy`.
    fn on_reduction(&mut self, topo: &HierTopology, level: usize, seconds: f64) -> f64;

    /// Modelled wall clock so far (max over learner clocks).
    fn now(&mut self) -> f64;

    /// Snapshot the per-learner / per-level accounting.
    fn breakdown(&mut self) -> ExecBreakdown;

    /// Arm the elastic-membership layer: the model realizes its own
    /// [`MembershipModel`] from `(p, seed, plan)` and thereafter charges
    /// down steps to `lost_seconds`, fires barriers over survivors only,
    /// and adds the re-entry restore surcharge.  Default: unsupported
    /// no-op — only the event models implement it, and config validation
    /// rejects `--faults` under lockstep before any model is built.
    fn install_faults(&mut self, _seed: u64, _plan: &FaultPlan) {}

    /// The learner whose late arrival set the barrier height at the most
    /// recent [`ExecModel::on_reduction`] (first index on ties), if the
    /// fault layer is installed and any learner participated.  The engine
    /// feeds this to `SchedulePolicy::observe_culprit` so a persistent
    /// straggler can be migrated instead of widening everyone's K2.
    fn last_culprit(&self) -> Option<usize> {
        None
    }

    /// Detach `learner` from its sub-top reduction groups (group
    /// migration): from now on it barriers only at the outermost level.
    /// Default no-op for models without a fault layer.
    fn set_detached(&mut self, _learner: usize) {}
}

/// The legacy shared-clock model: every learner is charged the same step
/// time, every reduction stalls everyone.  Kept deliberately scalar (O(1)
/// per step) — it is the baseline the event loop's dispatch overhead is
/// benchmarked against (rust/benches/event_loop.rs).
#[derive(Debug, Clone)]
pub struct LockstepModel {
    base: f64,
    p: usize,
    n_levels: usize,
    clock: f64,
    busy: f64,
}

impl LockstepModel {
    pub fn new(p: usize, n_levels: usize, step_seconds: f64) -> LockstepModel {
        LockstepModel { base: step_seconds, p, n_levels, clock: 0.0, busy: 0.0 }
    }
}

impl ExecModel for LockstepModel {
    fn name(&self) -> &'static str {
        ExecKind::Lockstep.name()
    }

    fn on_step(&mut self) {
        self.busy += self.base;
        self.clock += self.base;
    }

    fn on_reduction(&mut self, topo: &HierTopology, level: usize, seconds: f64) -> f64 {
        if topo.size(level) <= 1 && level + 1 < topo.n_levels() {
            return 0.0; // the reducer's no-op convention
        }
        self.clock += seconds;
        0.0 // one shared clock: nobody ever waits
    }

    fn now(&mut self) -> f64 {
        self.clock
    }

    fn breakdown(&mut self) -> ExecBreakdown {
        ExecBreakdown {
            model: self.name(),
            makespan_seconds: self.clock,
            busy_seconds: vec![self.busy; self.p],
            blocked_seconds: vec![0.0; self.p],
            idle_seconds: vec![0.0; self.p],
            level_stall_seconds: vec![0.0; self.n_levels],
            lost_seconds: vec![0.0; self.p],
            straggler_events: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{HierSchedule, StaticPolicy};

    fn topo_2x8() -> HierTopology {
        HierTopology::new(vec![2, 8]).unwrap()
    }

    #[test]
    fn exec_kind_parse_and_name() {
        for k in [ExecKind::Lockstep, ExecKind::Event] {
            assert_eq!(ExecKind::parse(k.name()).unwrap(), k);
        }
        assert!(ExecKind::parse("async").is_err());
    }

    #[test]
    fn het_spec_validation() {
        HetSpec::default().validate().unwrap();
        assert!(HetSpec { het: -0.1, ..Default::default() }.validate().is_err());
        assert!(HetSpec { het: f64::NAN, ..Default::default() }.validate().is_err());
        assert!(HetSpec { straggler_prob: 1.5, ..Default::default() }.validate().is_err());
        assert!(HetSpec { straggler_prob: -0.1, ..Default::default() }.validate().is_err());
        assert!(HetSpec { straggler_mult: 0.5, ..Default::default() }.validate().is_err());
        assert!(HetSpec { straggler_mult: f64::INFINITY, ..Default::default() }
            .validate()
            .is_err());
    }

    #[test]
    fn straggler_flag_parses() {
        assert_eq!(parse_straggler("0.05", 4.0).unwrap(), (0.05, 4.0));
        assert_eq!(parse_straggler("0.1:8", 4.0).unwrap(), (0.1, 8.0));
        assert!(parse_straggler("lots", 4.0).is_err());
        assert!(parse_straggler("0.1:fast", 4.0).is_err());
    }

    #[test]
    fn homogeneous_event_matches_lockstep_bitwise() {
        let topo = topo_2x8();
        let sched = HierSchedule::new(vec![2, 8]).unwrap();
        let secs = [1e-4, 1e-3];
        let mut lock = LockstepModel::new(8, 2, 1e-3);
        let mut event = EventModel::new(8, 2, 1e-3, &HetSpec::default());
        drive_timeline(&mut lock, &topo, &sched, 100, &secs);
        drive_timeline(&mut event, &topo, &sched, 100, &secs);
        assert_eq!(lock.now().to_bits(), event.now().to_bits());
        let (bl, be) = (lock.breakdown(), event.breakdown());
        assert_eq!(bl.makespan_seconds.to_bits(), be.makespan_seconds.to_bits());
        for j in 0..8 {
            assert_eq!(bl.busy_seconds[j].to_bits(), be.busy_seconds[j].to_bits());
            assert_eq!(be.blocked_seconds[j], 0.0);
            assert_eq!(be.idle_seconds[j], 0.0);
        }
        assert_eq!(be.level_stall_seconds, vec![0.0, 0.0]);
        assert_eq!(be.straggler_events, 0);
    }

    #[test]
    fn rate_ramp_slows_the_last_learner() {
        let topo = topo_2x8();
        let sched = HierSchedule::new(vec![4, 16]).unwrap();
        let spec = HetSpec { het: 0.5, ..Default::default() };
        let mut m = EventModel::new(8, 2, 1e-3, &spec);
        drive_timeline(&mut m, &topo, &sched, 64, &[1e-4, 1e-3]);
        let b = m.breakdown();
        assert!(b.busy_seconds[7] > b.busy_seconds[0]);
        // learner 7 is always last to arrive: it never waits, everyone
        // else does.
        assert_eq!(b.blocked_seconds[7], 0.0);
        assert!(b.blocked_seconds[0] > 0.0);
        // and the ramp stretches the makespan past the homogeneous sum
        let hom = 64.0 * 1e-3 + 12.0 * 1e-4 + 4.0 * 1e-3; // 16 events: 12 local + 4 global
        assert!(b.makespan_seconds > hom);
    }

    #[test]
    fn group_local_barrier_does_not_stall_other_groups() {
        // Level-0 barriers only sync within each group of 2: learner 0/1
        // meet, learner 6/7 meet, but group {0,1} never waits for {6,7}.
        let topo = topo_2x8();
        let spec = HetSpec { het: 1.0, ..Default::default() };
        let mut m = EventModel::new(8, 2, 1.0, &spec);
        m.on_step();
        m.on_reduction(&topo, 0, 0.0);
        // after the local barrier, clocks agree within groups only
        assert_eq!(m.clock_of(0), m.clock_of(1));
        assert_eq!(m.clock_of(6), m.clock_of(7));
        assert!(m.clock_of(1) < m.clock_of(6));
        // a global barrier then aligns everyone
        m.on_reduction(&topo, 1, 0.0);
        for j in 1..8 {
            assert_eq!(m.clock_of(0), m.clock_of(j));
        }
    }

    #[test]
    fn stall_attribution_sums_to_blocked_time() {
        let topo = HierTopology::new(vec![2, 4, 8]).unwrap();
        let sched = HierSchedule::new(vec![2, 4, 8]).unwrap();
        let spec =
            HetSpec { het: 0.3, straggler_prob: 0.2, straggler_mult: 3.0, seed: 9 };
        let mut m = EventModel::new(8, 3, 1e-3, &spec);
        drive_timeline(&mut m, &topo, &sched, 200, &[1e-4, 5e-4, 1e-3]);
        let b = m.breakdown();
        let stalls: f64 = b.level_stall_seconds.iter().sum();
        let blocked: f64 = b.blocked_seconds.iter().sum();
        assert!((stalls - blocked).abs() < 1e-9 * blocked.max(1.0));
        assert!(b.straggler_events > 0);
        assert!(b.idle_seconds.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn straggler_spikes_are_seed_deterministic() {
        let topo = topo_2x8();
        let sched = HierSchedule::new(vec![2, 8]).unwrap();
        let spec =
            HetSpec { het: 0.0, straggler_prob: 0.1, straggler_mult: 4.0, seed: 7 };
        let run = |spec: &HetSpec| {
            let mut m = EventModel::new(8, 2, 1e-3, spec);
            drive_timeline(&mut m, &topo, &sched, 300, &[1e-4, 1e-3]);
            m.breakdown()
        };
        let a = run(&spec);
        let b = run(&spec);
        assert_eq!(a.makespan_seconds.to_bits(), b.makespan_seconds.to_bits());
        assert_eq!(a.straggler_events, b.straggler_events);
        let c = run(&HetSpec { seed: 8, ..spec });
        assert_ne!(a.makespan_seconds.to_bits(), c.makespan_seconds.to_bits());
    }

    #[test]
    fn size_one_inner_level_is_a_noop() {
        let topo = HierTopology::new(vec![1, 8]).unwrap();
        let mut m = EventModel::new(8, 2, 1.0, &HetSpec { het: 0.5, ..Default::default() });
        m.on_step();
        let before: Vec<u64> = (0..8).map(|j| m.clock_of(j).to_bits()).collect();
        m.on_reduction(&topo, 0, 123.0);
        let after: Vec<u64> = (0..8).map(|j| m.clock_of(j).to_bits()).collect();
        assert_eq!(before, after);
        assert_eq!(m.breakdown().level_stall_seconds[0], 0.0);
        let mut l = LockstepModel::new(8, 2, 1.0);
        l.on_step();
        l.on_reduction(&topo, 0, 123.0);
        assert_eq!(l.now(), 1.0);
    }

    #[test]
    fn policy_driven_loop_with_static_policy_matches_fixed_schedule() {
        let topo = topo_2x8();
        let sched = HierSchedule::new(vec![2, 8]).unwrap();
        let spec =
            HetSpec { het: 0.3, straggler_prob: 0.1, straggler_mult: 4.0, seed: 5 };
        let secs = [1e-4, 1e-3];
        let mut a = EventModel::new(8, 2, 1e-3, &spec);
        drive_timeline(&mut a, &topo, &sched, 256, &secs);
        let mut b = EventModel::new(8, 2, 1e-3, &spec);
        let mut policy = StaticPolicy::new();
        let realized =
            drive_timeline_policy(&mut b, &topo, &mut policy, &sched, 256, &secs);
        assert_eq!(a.breakdown(), b.breakdown());
        // The realized counts are exactly the schedule's closed-form
        // event counts.
        assert_eq!(realized, sched.reduction_counts(256));
    }

    #[test]
    fn on_reduction_returns_the_stall_it_charges() {
        let topo = topo_2x8();
        let spec = HetSpec { het: 1.0, ..Default::default() };
        let mut m = EventModel::new(8, 2, 1.0, &spec);
        m.on_step();
        let before: f64 = m.breakdown().blocked_seconds.iter().sum();
        let stall = m.on_reduction(&topo, 1, 0.0);
        let after: f64 = m.breakdown().blocked_seconds.iter().sum();
        assert!(stall > 0.0);
        assert!((stall - (after - before)).abs() < 1e-12 * stall);
        // Lockstep never reports a wait.
        let mut l = LockstepModel::new(8, 2, 1.0);
        l.on_step();
        assert_eq!(l.on_reduction(&topo, 1, 0.5), 0.0);
    }

    #[test]
    fn replay_timeline_homogeneous_matches_closed_form() {
        let topo = topo_2x8();
        let sched = HierSchedule::new(vec![2, 8]).unwrap();
        let b = replay_timeline(&topo, &sched, 64, 1e-3, &[1e-4, 1e-3], &HetSpec::default());
        // 64 steps, 24 local events, 8 global events
        let expect = 64.0 * 1e-3 + 24.0 * 1e-4 + 8.0 * 1e-3;
        assert!((b.makespan_seconds - expect).abs() < 1e-12, "{}", b.makespan_seconds);
        assert_eq!(b.level_stall_seconds, vec![0.0, 0.0]);
    }

    #[test]
    fn frequent_global_barriers_amplify_straggler_cost() {
        // Under random spikes, a barrier every step pays max-over-P spikes
        // every step; sparse barriers let spikes average out within the
        // interval first.  Relative makespan inflation must reflect that.
        let topo = HierTopology::new(vec![1, 16]).unwrap();
        let spec =
            HetSpec { het: 0.0, straggler_prob: 0.2, straggler_mult: 3.0, seed: 11 };
        let run = |k: u64| {
            let sched = HierSchedule::new(vec![k, k]).unwrap();
            let events = 512 / k;
            let b = replay_timeline(&topo, &sched, 512, 1e-3, &[0.0, 1e-3], &spec);
            b.makespan_seconds / (512.0 * 1e-3 + events as f64 * 1e-3)
        };
        assert!(run(1) > run(32), "sync {} vs sparse {}", run(1), run(32));
    }

    #[test]
    fn homogeneous_core_allocates_no_per_learner_state() {
        // The shared step node stands for all P learners: a homogeneous
        // million-learner model is O(1) to build and drive, and only the
        // final breakdown materializes O(P) vectors.
        let p = 1 << 20;
        let topo = HierTopology::new(vec![1 << 10, p]).unwrap();
        let sched = HierSchedule::new(vec![4, 32]).unwrap();
        let mut m = EventModel::new(p, 2, 1e-3, &HetSpec::default());
        drive_timeline(&mut m, &topo, &sched, 512, &[1e-4, 1e-3]);
        let expect = 512.0 * 1e-3 + 112.0 * 1e-4 + 16.0 * 1e-3;
        assert!((m.now() - expect).abs() < 1e-9, "{}", m.now());
        let s = replay_timeline_stats(&topo, &sched, 512, 1e-3, &[1e-4, 1e-3], &HetSpec::default());
        assert_eq!(s.makespan_seconds.to_bits(), m.now().to_bits());
        assert_eq!(s.straggler_events, 0);
        assert_eq!(s.blocked_seconds_total, 0.0);
    }
}
