//! The heap/calendar event core: lazily-advanced, memory-pooled learner
//! timelines.
//!
//! The reference model ([`super::ScanEventModel`]) walks every learner
//! clock on every step and materializes five O(P) vectors before the
//! first event — fine at P = 64, infeasible at P = 1,000,000.  This core
//! restructures the same semantics around next-event nodes:
//!
//! - **Shared step node** — `on_step`/`on_steps` only bump a pending-step
//!   counter (O(1)); learner clocks are advanced lazily when a barrier
//!   node, `now()`, or `breakdown()` actually needs them.  The pending
//!   counter is the degenerate calendar entry every learner's next event
//!   points at (all learners step in lockstep between barriers, so one
//!   node stands for all P).
//! - **Group-local barrier nodes** — `on_reduction` advances only the
//!   fired level's members to the current step node and fires each
//!   group's barrier at max arrival.  Stall tallies keep the reference's
//!   group-then-member accumulation order, so every f64 is bit-identical.
//! - **Pooled, lazily-materialized state** — under a homogeneous
//!   [`HetSpec`] all P learners share one op sequence, so the pool is two
//!   scalars: building and driving a million-learner homogeneous model
//!   allocates no O(P) vector at all (the planner's timeline-only sweep
//!   rides this path).  A heterogeneous spec materializes flat clock /
//!   busy / blocked / synced arrays on first touch, and straggler `Pcg32`
//!   streams are forked from the root strictly in learner order but only
//!   up to the highest learner actually advanced — the same streams the
//!   reference forks up front.
//!
//! Determinism: per-learner clock and busy accumulations replay the
//! reference's per-step additions in the learner's own step order, and
//! group arrival maxima are order-free, so the heap core reproduces the
//! scan timeline bit for bit under every heterogeneity spec
//! (rust/tests/event_heap.rs drives both across random topologies).

use crate::topology::HierTopology;
use crate::util::rng::Pcg32;

use super::{
    ExecBreakdown, ExecKind, ExecModel, FaultPlan, HetSpec, MembershipModel,
    REENTRY_RESTORE_STEPS, STRAGGLER_STREAM,
};

/// The production virtual-time event engine: per-learner clocks,
/// group-local barriers, straggler spikes — advanced lazily from a shared
/// step node instead of eager O(P) scans.
///
/// Bit-for-bit note: under a homogeneous [`HetSpec`] every operation the
/// shared pool performs is the exact IEEE operation `LockstepModel`
/// performs in the same order (`rate = 1.0` multiplications are exact,
/// equal-clock maxima return the shared value, `x − x = +0.0` waits), so
/// the homogeneous-equivalence golden tests stay byte-stable.
#[derive(Debug, Clone)]
pub struct EventModel {
    base: f64,
    p: usize,
    n_levels: usize,
    spec: HetSpec,
    /// Steps announced so far — the shared step node every learner's
    /// next-event pointer refers to.
    step: u64,
    pool: Pool,
    level_stalls: Vec<f64>,
    straggler_events: u64,
    /// Elastic-membership layer (`--faults`), None when not installed.
    /// Installing it forces the pooled per-learner arrays — the shared
    /// fast path cannot represent per-learner downtime.
    faults: Option<FaultState>,
    last_culprit: Option<usize>,
}

/// The heap core's fault-layer state: its own [`MembershipModel`]
/// realization plus the per-learner edge detectors and counters.
#[derive(Debug, Clone)]
struct FaultState {
    membership: MembershipModel,
    /// Was learner j down during its previously flushed step?
    down_prev: Vec<bool>,
    /// Learners migrated out of their sub-top reduction groups.
    detached: Vec<bool>,
    preemptions: u64,
    reentries: u64,
}

impl FaultState {
    fn new(p: usize, seed: u64, plan: &FaultPlan) -> FaultState {
        FaultState {
            membership: MembershipModel::new(p, seed, plan),
            down_prev: vec![false; p],
            detached: vec![false; p],
            preemptions: 0,
            reentries: 0,
        }
    }
}

#[derive(Debug, Clone)]
enum Pool {
    /// Homogeneous learners: one representative op sequence stands for
    /// all P.  `synced` is the step the scalars are advanced to.
    Shared { clock: f64, busy: f64, synced: u64 },
    /// Heterogeneous spec, but no learner touched yet: the O(P) arrays
    /// are not materialized until a barrier or query needs them.
    Lazy,
    /// Heterogeneous learners in flat pooled arrays.
    Learners(LearnerPool),
}

#[derive(Debug, Clone)]
struct LearnerPool {
    clocks: Vec<f64>,
    busy: Vec<f64>,
    blocked: Vec<f64>,
    /// Time lost to preemption (down steps + restore surcharge); stays
    /// all-zero unless a fault layer is installed.
    lost: Vec<f64>,
    /// Step each learner's clock is advanced to (lags `EventModel::step`
    /// between barriers).
    synced: Vec<u64>,
    /// Root of the straggler streams; children fork lazily in learner
    /// order (each fork advances this state exactly as the reference's
    /// up-front fork loop does).
    root: Pcg32,
    /// Forked spike streams for learners `0..rngs.len()`; empty while
    /// `straggler_prob == 0` (the reference never draws from them then,
    /// so their state is unobservable).
    rngs: Vec<Pcg32>,
}

impl LearnerPool {
    fn new(p: usize, seed: u64) -> LearnerPool {
        LearnerPool {
            clocks: vec![0.0; p],
            busy: vec![0.0; p],
            blocked: vec![0.0; p],
            lost: vec![0.0; p],
            synced: vec![0; p],
            root: Pcg32::new(seed, STRAGGLER_STREAM),
            rngs: Vec::new(),
        }
    }
}

/// Replay learner `j`'s pending steps: the reference's per-step additions
/// in the learner's own step order (hoisting `base × rate` is exact —
/// the product is the same f64 every step).  With a fault layer, down
/// steps charge `lost` instead of `busy` and draw no spike (the spike
/// stream only advances while up), and the first up step after an outage
/// pays the restore surcharge — the same per-step branch order as the
/// scan reference, so the timelines stay bit-identical.
fn flush_learner(
    pool: &mut LearnerPool,
    base: f64,
    spec: &HetSpec,
    p: usize,
    j: usize,
    to: u64,
    spikes: &mut u64,
    faults: Option<&mut FaultState>,
) {
    let from = pool.synced[j];
    if from >= to {
        return;
    }
    pool.synced[j] = to;
    let rate = if p > 1 { 1.0 + spec.het * j as f64 / (p - 1) as f64 } else { 1.0 };
    let dt_base = base * rate;
    let mut clock = pool.clocks[j];
    let mut busy = pool.busy[j];
    if spec.straggler_prob > 0.0 {
        // Fork spike streams lazily but strictly in learner order, so
        // stream j is the identical stream the reference forked.
        while pool.rngs.len() <= j {
            let tag = pool.rngs.len() as u64;
            let child = pool.root.fork(tag);
            pool.rngs.push(child);
        }
    }
    match faults {
        None => {
            if spec.straggler_prob > 0.0 {
                let rng = &mut pool.rngs[j];
                for _ in from..to {
                    let mut dt = dt_base;
                    if rng.next_f64() < spec.straggler_prob {
                        dt *= spec.straggler_mult;
                        *spikes += 1;
                    }
                    busy += dt;
                    clock += dt;
                }
            } else {
                for _ in from..to {
                    busy += dt_base;
                    clock += dt_base;
                }
            }
        }
        Some(fs) => {
            let mut lost = pool.lost[j];
            for s in from..to {
                let t = s + 1; // 1-based step ordinal, as the scan counts
                if fs.membership.is_down(j, t) {
                    if !fs.down_prev[j] {
                        fs.preemptions += 1;
                        fs.down_prev[j] = true;
                    }
                    lost += dt_base;
                    clock += dt_base;
                    continue;
                }
                if fs.down_prev[j] {
                    fs.down_prev[j] = false;
                    fs.reentries += 1;
                    let restore = REENTRY_RESTORE_STEPS * dt_base;
                    lost += restore;
                    clock += restore;
                }
                let mut dt = dt_base;
                if spec.straggler_prob > 0.0 && pool.rngs[j].next_f64() < spec.straggler_prob
                {
                    dt *= spec.straggler_mult;
                    *spikes += 1;
                }
                busy += dt;
                clock += dt;
            }
            pool.lost[j] = lost;
        }
    }
    pool.clocks[j] = clock;
    pool.busy[j] = busy;
}

impl EventModel {
    pub fn new(p: usize, n_levels: usize, step_seconds: f64, spec: &HetSpec) -> EventModel {
        let pool = if spec.is_homogeneous() {
            Pool::Shared { clock: 0.0, busy: 0.0, synced: 0 }
        } else {
            Pool::Lazy
        };
        EventModel {
            base: step_seconds,
            p,
            n_levels,
            spec: *spec,
            step: 0,
            pool,
            level_stalls: vec![0.0; n_levels],
            straggler_events: 0,
            faults: None,
            last_culprit: None,
        }
    }

    fn ensure_learners(&mut self) {
        if matches!(self.pool, Pool::Lazy) {
            self.pool = Pool::Learners(LearnerPool::new(self.p, self.spec.seed));
        }
    }

    /// Advance every learner to the current step node.
    fn flush(&mut self) {
        let step = self.step;
        if !matches!(self.pool, Pool::Shared { .. }) {
            self.ensure_learners();
        }
        match &mut self.pool {
            Pool::Shared { clock, busy, synced } => {
                for _ in *synced..step {
                    *busy += self.base;
                    *clock += self.base;
                }
                *synced = step;
            }
            Pool::Learners(pool) => {
                for j in 0..self.p {
                    flush_learner(
                        pool,
                        self.base,
                        &self.spec,
                        self.p,
                        j,
                        step,
                        &mut self.straggler_events,
                        self.faults.as_mut(),
                    );
                }
            }
            Pool::Lazy => unreachable!("materialized above"),
        }
    }

    /// Learner `j`'s clock, flushed to the current step node (test and
    /// diagnostic accessor).
    pub fn clock_of(&mut self, j: usize) -> f64 {
        assert!(j < self.p, "learner {j} out of range (p = {})", self.p);
        self.flush();
        match &self.pool {
            Pool::Shared { clock, .. } => *clock,
            Pool::Learners(pool) => pool.clocks[j],
            Pool::Lazy => unreachable!("flush materializes"),
        }
    }

    /// Sum of per-learner compute time (no O(P) vector materialized on
    /// the shared path — this is a stats view, not the bit-pinned
    /// breakdown).
    pub fn busy_seconds_total(&mut self) -> f64 {
        self.flush();
        match &self.pool {
            Pool::Shared { busy, .. } => *busy * self.p as f64,
            Pool::Learners(pool) => pool.busy.iter().sum(),
            Pool::Lazy => unreachable!("flush materializes"),
        }
    }

    /// Sum of per-learner barrier waits.
    pub fn blocked_seconds_total(&mut self) -> f64 {
        self.flush();
        match &self.pool {
            Pool::Shared { .. } => 0.0,
            Pool::Learners(pool) => pool.blocked.iter().sum(),
            Pool::Lazy => unreachable!("flush materializes"),
        }
    }

    /// Barrier wait time attributed to each level so far.
    pub fn level_stall_seconds(&self) -> &[f64] {
        &self.level_stalls
    }

    /// Straggler spikes fired so far (flushed learners only — call after
    /// a flush-inducing query for the run total).
    pub fn straggler_events(&self) -> u64 {
        self.straggler_events
    }

    /// Sum of per-learner time lost to preemption (down steps + restore
    /// surcharges); 0 unless a fault layer is installed.
    pub fn lost_seconds_total(&mut self) -> f64 {
        if self.faults.is_none() {
            return 0.0;
        }
        self.flush();
        match &self.pool {
            Pool::Learners(pool) => pool.lost.iter().sum(),
            _ => 0.0,
        }
    }

    /// Timeline-side fault counters: `(preemptions, reentries)` observed
    /// by flushed learners so far — call after a flush-inducing query
    /// (`now`/`breakdown`) for the run total.
    pub fn fault_counts(&self) -> (u64, u64) {
        match &self.faults {
            Some(fs) => (fs.preemptions, fs.reentries),
            None => (0, 0),
        }
    }
}

impl ExecModel for EventModel {
    fn name(&self) -> &'static str {
        ExecKind::Event.name()
    }

    fn on_step(&mut self) {
        // O(1): learners advance lazily when the next barrier node or
        // query needs their clocks.
        self.step += 1;
    }

    fn on_steps(&mut self, n: u64) {
        self.step = self.step.saturating_add(n);
    }

    fn on_reduction(&mut self, topo: &HierTopology, level: usize, seconds: f64) -> f64 {
        debug_assert_eq!(topo.n_levels(), self.n_levels);
        debug_assert_eq!(topo.p(), self.p);
        if topo.size(level) <= 1 && level + 1 < topo.n_levels() {
            return 0.0; // the reducer's no-op convention
        }
        let step = self.step;
        if !matches!(self.pool, Pool::Shared { .. }) {
            self.ensure_learners();
        }
        match &mut self.pool {
            Pool::Shared { clock, busy, synced } => {
                for _ in *synced..step {
                    *busy += self.base;
                    *clock += self.base;
                }
                *synced = step;
                // Every learner arrives at the shared clock: arrival is
                // the clock itself, waits are x − x = +0.0, and the
                // reference's per-member `+= 0.0` tallies leave blocked
                // and level stalls untouched — so one shared barrier node
                // replaces the whole O(P) member walk, bit for bit.
                *clock += seconds;
                0.0
            }
            Pool::Learners(pool) => {
                let top = level + 1 == topo.n_levels();
                self.last_culprit = None;
                let mut best_clock = f64::NEG_INFINITY;
                let mut event_stall = 0.0;
                for g in 0..topo.n_groups(level) {
                    let members = topo.group_members(level, g);
                    // Advance the group's members to the current step
                    // node, then fire the barrier at max arrival.  The
                    // max is order-free; the stall tallies below keep the
                    // reference's group-then-member order.
                    for j in members.clone() {
                        flush_learner(
                            pool,
                            self.base,
                            &self.spec,
                            self.p,
                            j,
                            step,
                            &mut self.straggler_events,
                            self.faults.as_mut(),
                        );
                    }
                    match self.faults.as_mut() {
                        None => {
                            let arrival = members
                                .clone()
                                .map(|j| pool.clocks[j])
                                .fold(f64::NEG_INFINITY, f64::max);
                            for j in members {
                                let wait = arrival - pool.clocks[j];
                                pool.blocked[j] += wait;
                                self.level_stalls[level] += wait;
                                event_stall += wait;
                                pool.clocks[j] = arrival + seconds;
                            }
                        }
                        Some(fs) => {
                            // Barrier over the group's participants only:
                            // down learners — and, below the top,
                            // detached learners — neither wait nor are
                            // waited for.  Same rule, same order, as the
                            // scan reference.
                            let mut arrival = f64::NEG_INFINITY;
                            let mut any = false;
                            for j in members.clone() {
                                let part = !fs.membership.is_down(j, step)
                                    && (top || !fs.detached[j]);
                                if part {
                                    any = true;
                                    if pool.clocks[j] > arrival {
                                        arrival = pool.clocks[j];
                                    }
                                    if pool.clocks[j] > best_clock {
                                        best_clock = pool.clocks[j];
                                        self.last_culprit = Some(j);
                                    }
                                }
                            }
                            if !any {
                                continue; // whole group down: no barrier
                            }
                            for j in members {
                                if fs.membership.is_down(j, step)
                                    || (!top && fs.detached[j])
                                {
                                    continue;
                                }
                                let wait = arrival - pool.clocks[j];
                                pool.blocked[j] += wait;
                                self.level_stalls[level] += wait;
                                event_stall += wait;
                                pool.clocks[j] = arrival + seconds;
                            }
                        }
                    }
                }
                event_stall
            }
            Pool::Lazy => unreachable!("materialized above"),
        }
    }

    fn now(&mut self) -> f64 {
        if self.p == 0 {
            return 0.0;
        }
        self.flush();
        match &self.pool {
            Pool::Shared { clock, .. } => f64::max(0.0, *clock),
            Pool::Learners(pool) => pool.clocks.iter().cloned().fold(0.0, f64::max),
            Pool::Lazy => unreachable!("flush materializes"),
        }
    }

    fn breakdown(&mut self) -> ExecBreakdown {
        self.flush();
        match &self.pool {
            Pool::Shared { clock, busy, .. } => {
                let makespan = if self.p == 0 { 0.0 } else { f64::max(0.0, *clock) };
                ExecBreakdown {
                    model: ExecKind::Event.name(),
                    makespan_seconds: makespan,
                    busy_seconds: vec![*busy; self.p],
                    blocked_seconds: vec![0.0; self.p],
                    // the reference's makespan − clock is c − c = +0.0
                    idle_seconds: vec![0.0; self.p],
                    level_stall_seconds: self.level_stalls.clone(),
                    lost_seconds: vec![0.0; self.p],
                    straggler_events: self.straggler_events,
                }
            }
            Pool::Learners(pool) => {
                let makespan = pool.clocks.iter().cloned().fold(0.0, f64::max);
                ExecBreakdown {
                    model: ExecKind::Event.name(),
                    makespan_seconds: makespan,
                    busy_seconds: pool.busy.clone(),
                    blocked_seconds: pool.blocked.clone(),
                    idle_seconds: pool.clocks.iter().map(|&c| makespan - c).collect(),
                    level_stall_seconds: self.level_stalls.clone(),
                    lost_seconds: pool.lost.clone(),
                    straggler_events: self.straggler_events,
                }
            }
            Pool::Lazy => unreachable!("flush materializes"),
        }
    }

    fn install_faults(&mut self, seed: u64, plan: &FaultPlan) {
        debug_assert_eq!(self.step, 0, "install the fault layer before driving the model");
        // The shared fast path cannot represent per-learner downtime:
        // force the pooled per-learner arrays.  A homogeneous pooled walk
        // performs the identical IEEE additions the shared scalars
        // perform (pinned by the heap ≡ scan property tests), so arming
        // an *empty* fault layer stays bit-identical to the un-armed run.
        self.pool = Pool::Learners(LearnerPool::new(self.p, self.spec.seed));
        self.faults = Some(FaultState::new(self.p, seed, plan));
    }

    fn last_culprit(&self) -> Option<usize> {
        self.last_culprit
    }

    fn set_detached(&mut self, learner: usize) {
        if let Some(fs) = self.faults.as_mut() {
            if learner < fs.detached.len() {
                fs.detached[learner] = true;
            }
        }
    }
}
