//! Timeline-only replay: drive an execution model from barrier node to
//! barrier node, with no parameter math in between.
//!
//! A static [`HierSchedule`] fires level ℓ at every multiple of its
//! interval `k_ℓ`, outermost level winning shared boundaries.  The
//! [`EventCalendar`] merges those L periodic event streams in a binary
//! min-heap: `next()` pops the earliest pending boundary in O(log L),
//! fires the outermost level that shares it, and re-arms each popped
//! level at its next multiple.  Between consecutive barrier nodes the
//! driver announces the whole step gap with one [`ExecModel::on_steps`]
//! call, which the heap core absorbs in O(1) — so replaying a
//! 1,000,000-learner homogeneous timeline costs O(events · log L), not
//! O(horizon · P).
//!
//! [`replay_timeline_stats`] is the planner-facing entry point: it prices
//! a candidate (topology, schedule) pair into a [`TimelineStats`] summary
//! without materializing any O(P) vector, which is what makes
//! `sweep --timeline-only` feasible at P up to 1,000,000.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::algorithms::{HierSchedule, SchedulePolicy, StaticPolicy};
use crate::topology::HierTopology;

use super::{EventModel, ExecBreakdown, ExecModel, FaultPlan, HetSpec, MembershipModel};

/// Merged per-level event calendar of a static schedule: a min-heap of
/// `(step, level)` nodes, one live node per level, each re-armed at its
/// next interval multiple after it pops.
#[derive(Debug, Clone)]
pub struct EventCalendar {
    heap: BinaryHeap<Reverse<(u64, usize)>>,
    intervals: Vec<u64>,
    horizon: u64,
}

impl EventCalendar {
    pub fn new(sched: &HierSchedule, horizon: u64) -> EventCalendar {
        let intervals = sched.intervals().to_vec();
        let mut heap = BinaryHeap::with_capacity(intervals.len());
        for (l, &k) in intervals.iter().enumerate() {
            if k <= horizon {
                heap.push(Reverse((k, l)));
            }
        }
        EventCalendar { heap, intervals, horizon }
    }

    fn rearm(&mut self, t: u64, level: usize) {
        let next = t.saturating_add(self.intervals[level]);
        if next <= self.horizon {
            self.heap.push(Reverse((next, level)));
        }
    }

    /// The next barrier node: `(step, level)` where `level` is the
    /// outermost level whose interval divides `step` — exactly
    /// [`HierSchedule::event_after`], because the heap holds level ℓ's
    /// node precisely at ℓ's multiples and every node sharing the popped
    /// step is consumed here (inner boundaries are subsumed, then
    /// re-armed at their next multiple).  O(log L) per event.
    pub fn next(&mut self) -> Option<(u64, usize)> {
        let Reverse((t, first)) = self.heap.pop()?;
        debug_assert!(t <= self.horizon);
        let mut fired = first;
        self.rearm(t, first);
        while let Some(&Reverse((t2, level))) = self.heap.peek() {
            if t2 != t {
                break;
            }
            self.heap.pop();
            if level > fired {
                fired = level;
            }
            self.rearm(t, level);
        }
        Some((t, fired))
    }
}

/// Drive `model` through `horizon` steps under `policy` (consulting
/// `sched` as the base schedule), charging `level_seconds[l]` per
/// level-`l` event — the one canonical loop mirroring `Engine::step`'s
/// decide → on_step → on_reduction → observe call order (the planner's
/// adaptive replay, the property tests, and the benches all reuse it, so
/// they cannot drift from each other or from the engine).  The stall each
/// barrier charges is fed straight back to the policy, so adaptive
/// decisions and the virtual clock co-evolve exactly as they do in a
/// live engine run; replay stays deterministic because that feedback is
/// a pure function of the seeded timeline.  Returns the per-level
/// realized event counts.
///
/// This loop is necessarily per-step — a policy may fire at any `t` — so
/// it cannot ride the calendar fast path.  Static schedules should go
/// through [`drive_timeline`] instead.
pub fn drive_timeline_policy(
    model: &mut dyn ExecModel,
    topo: &HierTopology,
    policy: &mut dyn SchedulePolicy,
    sched: &HierSchedule,
    horizon: u64,
    level_seconds: &[f64],
) -> Vec<u64> {
    debug_assert_eq!(level_seconds.len(), topo.n_levels());
    let mut realized = vec![0u64; topo.n_levels()];
    for t in 1..=horizon {
        model.on_step();
        if let Some(level) = policy.decide(t, sched) {
            realized[level] += 1;
            let stall = model.on_reduction(topo, level, level_seconds[level]);
            policy.observe(t, level, stall, level_seconds[level]);
        }
    }
    realized
}

/// The fixed-schedule driver, calendar-driven: walk [`EventCalendar`]
/// nodes and announce each inter-barrier step gap with one
/// [`ExecModel::on_steps`] call.  Produces the identical op sequence the
/// per-step [`drive_timeline_policy`] + [`StaticPolicy`] loop produces
/// (the calendar fires exactly `event_after`'s events; `on_steps`
/// defaults to repeated `on_step`), which the sim tests pin — but lets
/// the heap core skip per-step dispatch entirely.
pub fn drive_timeline(
    model: &mut dyn ExecModel,
    topo: &HierTopology,
    sched: &HierSchedule,
    horizon: u64,
    level_seconds: &[f64],
) {
    debug_assert_eq!(level_seconds.len(), topo.n_levels());
    let mut cal = EventCalendar::new(sched, horizon);
    let mut done = 0u64;
    while let Some((t, level)) = cal.next() {
        model.on_steps(t - done);
        done = t;
        model.on_reduction(topo, level, level_seconds[level]);
    }
    model.on_steps(horizon - done);
}

/// Drive a bare event timeline (no training): `horizon` steps under
/// `sched`, charging `level_seconds[l]` per level-`l` group event.  This
/// is the planner's straggler-aware makespan estimator — it prices a
/// candidate schedule against heterogeneous learners without running the
/// engine.
pub fn replay_timeline(
    topo: &HierTopology,
    sched: &HierSchedule,
    horizon: u64,
    step_seconds: f64,
    level_seconds: &[f64],
    spec: &HetSpec,
) -> ExecBreakdown {
    let mut model = EventModel::new(topo.p(), topo.n_levels(), step_seconds, spec);
    drive_timeline(&mut model, topo, sched, horizon, level_seconds);
    model.breakdown()
}

/// Aggregate accounting of a timeline-only replay: everything the
/// planner needs to rank a candidate, nothing per-learner.
#[derive(Debug, Clone, PartialEq)]
pub struct TimelineStats {
    /// Modelled wall clock: max over learner clocks.
    pub makespan_seconds: f64,
    /// Total compute time summed over learners.
    pub busy_seconds_total: f64,
    /// Total barrier wait summed over learners.
    pub blocked_seconds_total: f64,
    /// Barrier wait attributed to each hierarchy level.
    pub level_stall_seconds: Vec<f64>,
    /// Straggler spikes that fired over the run.
    pub straggler_events: u64,
    /// Steps driven (the horizon).
    pub steps: u64,
    /// Barrier nodes fired (reduction events, all levels).
    pub reduction_events: u64,
    /// Total time lost to preemption (down steps + restore surcharges);
    /// 0 when no fault layer is installed.
    pub lost_seconds_total: f64,
    /// Preemptions observed on the timeline (0 without a fault layer).
    pub preemptions: u64,
    /// Checkpoint re-entries observed on the timeline (0 without a fault
    /// layer).
    pub reentries: u64,
    /// Barrier groups priced at a survivor subset because one or more
    /// members were down when the barrier fired (0 without a fault
    /// layer).  Mirrors the engine's `survivor_reductions` counter.
    pub degraded_group_barriers: u64,
}

impl TimelineStats {
    /// Timeline nodes processed: step announcements + barrier firings
    /// (the unit the events/sec bench curve counts).
    pub fn timeline_events(&self) -> u64 {
        self.steps + self.reduction_events
    }
}

/// [`replay_timeline`] without the O(P) breakdown vectors: the
/// timeline-only pricing path (`sweep --timeline-only`).  A homogeneous
/// spec never allocates per-learner state at all, so P = 1,000,000
/// candidates price in microseconds; heterogeneous specs pay the flat
/// pooled arrays but skip the four breakdown clones.
pub fn replay_timeline_stats(
    topo: &HierTopology,
    sched: &HierSchedule,
    horizon: u64,
    step_seconds: f64,
    level_seconds: &[f64],
    spec: &HetSpec,
) -> TimelineStats {
    replay_stats_inner(topo, sched, horizon, step_seconds, level_seconds, spec, None)
}

/// [`replay_timeline_stats`] with an armed fault layer: the planner's
/// fault-aware makespan estimator.  The membership trace forks from
/// `spec.seed` on the dedicated fault stream, so the same `--seed` that
/// fixes the straggler spikes fixes the outages — a candidate's price is
/// a pure function of `(topology, schedule, spec, plan)`.  Note an armed
/// fault layer forces per-learner state, so this path is O(horizon · P)
/// like any heterogeneous replay — `sweep --faults` keeps its existing P
/// bounds rather than riding the O(1) homogeneous fast path.
///
/// Barriers are priced the way the engine prices them: a group with
/// every member up charges exactly `level_seconds[level]`, a group
/// shrunk to a survivor subset charges `survivor_seconds(level,
/// n_part)` — the caller's hook into the cost model, mirroring
/// `Reducer::reduce_level_survivors` (which reprices degraded groups at
/// the survivor participant count over the *dense* payload; degraded
/// barriers never compress).  An all-down group charges nothing, and the
/// step's barrier charge is the max over its non-empty groups, exactly
/// the engine's serialized-group convention.  The survivor trace comes
/// from an independent [`MembershipModel`] forked from the same
/// `spec.seed` the timeline's fault layer uses, so the pricing and the
/// clock charging see the identical outage schedule.
pub fn replay_timeline_stats_faults(
    topo: &HierTopology,
    sched: &HierSchedule,
    horizon: u64,
    step_seconds: f64,
    level_seconds: &[f64],
    spec: &HetSpec,
    plan: &FaultPlan,
    survivor_seconds: &dyn Fn(usize, usize) -> f64,
) -> TimelineStats {
    replay_stats_inner(
        topo,
        sched,
        horizon,
        step_seconds,
        level_seconds,
        spec,
        Some((plan, survivor_seconds)),
    )
}

fn replay_stats_inner(
    topo: &HierTopology,
    sched: &HierSchedule,
    horizon: u64,
    step_seconds: f64,
    level_seconds: &[f64],
    spec: &HetSpec,
    faults: Option<(&FaultPlan, &dyn Fn(usize, usize) -> f64)>,
) -> TimelineStats {
    debug_assert_eq!(level_seconds.len(), topo.n_levels());
    let mut model = EventModel::new(topo.p(), topo.n_levels(), step_seconds, spec);
    // Independent survivor trace for barrier *pricing*; the timeline's own
    // fault layer (same seed, same stream) does the clock charging.  Kept
    // None when the trace can't fire so the no-fault walk below stays
    // structurally identical to the fault-free path — bit-identical
    // makespans for `prob: 0` plans.
    let mut pricing = None;
    if let Some((plan, pricer)) = faults {
        model.install_faults(spec.seed, plan);
        let membership = MembershipModel::new(topo.p(), spec.seed, plan);
        if !membership.is_empty() {
            pricing = Some((membership, pricer));
        }
    }
    let mut cal = EventCalendar::new(sched, horizon);
    let mut done = 0u64;
    let mut reduction_events = 0u64;
    let mut degraded_group_barriers = 0u64;
    while let Some((t, level)) = cal.next() {
        model.on_steps(t - done);
        done = t;
        let secs = match &mut pricing {
            None => level_seconds[level],
            Some((membership, pricer)) => {
                // Survivor-aware pricing, mirroring reduce_level_survivors:
                // max over non-empty groups; full groups keep the exact
                // closed-form charge.  Size-1 groups below the top are
                // no-op barriers (the model ignores them too).
                let mut max_secs = 0.0f64;
                if topo.size(level) > 1 || level + 1 == topo.n_levels() {
                    for g in 0..topo.n_groups(level) {
                        let members = topo.group_members(level, g);
                        let total = members.len();
                        let n_part = members.filter(|&j| !membership.is_down(j, t)).count();
                        let secs = if n_part == total {
                            level_seconds[level]
                        } else if n_part == 0 {
                            continue;
                        } else {
                            degraded_group_barriers += 1;
                            pricer(level, n_part)
                        };
                        if secs > max_secs {
                            max_secs = secs;
                        }
                    }
                }
                max_secs
            }
        };
        model.on_reduction(topo, level, secs);
        reduction_events += 1;
    }
    model.on_steps(horizon - done);
    let makespan_seconds = model.now(); // flushes every learner first
    let (preemptions, reentries) = model.fault_counts();
    TimelineStats {
        makespan_seconds,
        busy_seconds_total: model.busy_seconds_total(),
        blocked_seconds_total: model.blocked_seconds_total(),
        level_stall_seconds: model.level_stall_seconds().to_vec(),
        straggler_events: model.straggler_events(),
        steps: horizon,
        reduction_events,
        lost_seconds_total: model.lost_seconds_total(),
        preemptions,
        reentries,
        degraded_group_barriers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calendar_matches_event_after_exactly() {
        for intervals in [vec![2u64, 8], vec![1, 4, 8], vec![3, 3, 9], vec![5, 20, 20, 40]] {
            let sched = HierSchedule::new(intervals).unwrap();
            let horizon = 97;
            let mut cal = EventCalendar::new(&sched, horizon);
            for t in 1..=horizon {
                let expect = sched.event_after(t);
                if let Some(level) = expect {
                    assert_eq!(cal.next(), Some((t, level)), "t={t}");
                }
            }
            assert_eq!(cal.next(), None);
        }
    }

    #[test]
    fn calendar_driver_matches_per_step_driver() {
        let topo = HierTopology::new(vec![2, 4, 16]).unwrap();
        let sched = HierSchedule::new(vec![2, 6, 24]).unwrap();
        let spec = HetSpec { het: 0.4, straggler_prob: 0.15, straggler_mult: 3.0, seed: 13 };
        let secs = [1e-4, 5e-4, 2e-3];
        let mut a = EventModel::new(16, 3, 1e-3, &spec);
        drive_timeline(&mut a, &topo, &sched, 240, &secs);
        let mut b = EventModel::new(16, 3, 1e-3, &spec);
        let mut policy = StaticPolicy::new();
        drive_timeline_policy(&mut b, &topo, &mut policy, &sched, 240, &secs);
        assert_eq!(a.breakdown(), b.breakdown());
    }

    #[test]
    fn stats_agree_with_breakdown() {
        let topo = HierTopology::new(vec![4, 16]).unwrap();
        let sched = HierSchedule::new(vec![4, 16]).unwrap();
        let spec = HetSpec { het: 0.7, straggler_prob: 0.1, straggler_mult: 4.0, seed: 3 };
        let b = replay_timeline(&topo, &sched, 128, 1e-3, &[1e-4, 1e-3], &spec);
        let s = replay_timeline_stats(&topo, &sched, 128, 1e-3, &[1e-4, 1e-3], &spec);
        assert_eq!(s.makespan_seconds.to_bits(), b.makespan_seconds.to_bits());
        assert_eq!(s.straggler_events, b.straggler_events);
        assert_eq!(s.level_stall_seconds, b.level_stall_seconds);
        let blocked: f64 = b.blocked_seconds.iter().sum();
        assert!((s.blocked_seconds_total - blocked).abs() <= 1e-12 * blocked.max(1.0));
        let busy: f64 = b.busy_seconds.iter().sum();
        assert!((s.busy_seconds_total - busy).abs() <= 1e-9 * busy.max(1.0));
        // 128 steps, 24 local + 8 global barrier nodes
        assert_eq!(s.steps, 128);
        assert_eq!(s.reduction_events, 32);
        assert_eq!(s.timeline_events(), 160);
    }

    #[test]
    fn fault_replay_loses_time_and_stays_deterministic() {
        use super::super::{FaultPlan, FaultSpec};
        let topo = HierTopology::new(vec![4, 16]).unwrap();
        let sched = HierSchedule::new(vec![4, 16]).unwrap();
        let spec = HetSpec { het: 0.3, straggler_prob: 0.05, straggler_mult: 4.0, seed: 17 };
        let secs = [1e-4, 1e-3];
        // proportional survivor pricing: a degraded group is cheaper
        let pricer =
            |level: usize, n_part: usize| secs[level] * n_part as f64 / topo.size(level) as f64;
        let plan = FaultPlan::Sampled(FaultSpec { prob: 0.01, mttr: 10 });
        let a = replay_timeline_stats_faults(&topo, &sched, 256, 1e-3, &secs, &spec, &plan, &pricer);
        let b = replay_timeline_stats_faults(&topo, &sched, 256, 1e-3, &secs, &spec, &plan, &pricer);
        assert_eq!(a.makespan_seconds.to_bits(), b.makespan_seconds.to_bits());
        assert_eq!((a.preemptions, a.reentries), (b.preemptions, b.reentries));
        assert!(a.preemptions > 0, "hazard 0.01 over 16×256 learner-steps fired nothing");
        assert!(a.reentries > 0);
        assert!(a.lost_seconds_total > 0.0);
        // a down interval always straddles a barrier here (mttr 10 > k1 4,
        // and the horizon itself is a global boundary), so some group was
        // priced at its survivor count
        assert!(a.degraded_group_barriers > 0);
        // survivor pricing never charges *more* than the old full-group rule
        let full = |level: usize, _n_part: usize| secs[level];
        let pessimistic =
            replay_timeline_stats_faults(&topo, &sched, 256, 1e-3, &secs, &spec, &plan, &full);
        assert!(a.makespan_seconds <= pessimistic.makespan_seconds);
        assert_eq!(a.degraded_group_barriers, pessimistic.degraded_group_barriers);
        assert_eq!(a.lost_seconds_total.to_bits(), pessimistic.lost_seconds_total.to_bits());
        // an armed-but-empty fault layer prices identically to no layer
        let empty = FaultPlan::Sampled(FaultSpec { prob: 0.0, mttr: 10 });
        let z =
            replay_timeline_stats_faults(&topo, &sched, 256, 1e-3, &secs, &spec, &empty, &pricer);
        let plain = replay_timeline_stats(&topo, &sched, 256, 1e-3, &secs, &spec);
        assert_eq!(z.makespan_seconds.to_bits(), plain.makespan_seconds.to_bits());
        assert_eq!(z.blocked_seconds_total.to_bits(), plain.blocked_seconds_total.to_bits());
        assert_eq!(z.lost_seconds_total, 0.0);
        assert_eq!((z.preemptions, z.reentries), (0, 0));
        assert_eq!(z.degraded_group_barriers, 0);
    }

    #[test]
    fn scripted_outage_degrades_exactly_the_barriers_it_straddles() {
        use super::super::{FaultEvent, FaultPlan};
        let topo = HierTopology::new(vec![4, 16]).unwrap();
        let sched = HierSchedule::new(vec![4, 16]).unwrap();
        let spec = HetSpec { het: 0.0, straggler_prob: 0.0, straggler_mult: 1.0, seed: 7 };
        let secs = [1e-4, 1e-3];
        // learner 0 down for steps 14..18: among the barrier nodes
        // {4, 8, 12, 16, 20, ...} the interval straddles only the global
        // barrier at t = 16, so exactly one group is survivor-priced.
        let plan = FaultPlan::Scripted(vec![FaultEvent { step: 14, learner: 0, down_steps: 4 }]);
        let pricer =
            |level: usize, n_part: usize| secs[level] * n_part as f64 / topo.size(level) as f64;
        let s = replay_timeline_stats_faults(&topo, &sched, 32, 1e-3, &secs, &spec, &plan, &pricer);
        assert_eq!((s.preemptions, s.reentries), (1, 1));
        assert_eq!(s.degraded_group_barriers, 1);
        // the survivor charge for 15/16 participants is what the barrier
        // must have cost: repricing it at the full-group rate can only
        // raise the makespan
        let full = |level: usize, _n_part: usize| secs[level];
        let f = replay_timeline_stats_faults(&topo, &sched, 32, 1e-3, &secs, &spec, &plan, &full);
        assert_eq!(f.degraded_group_barriers, 1);
        assert!(s.makespan_seconds <= f.makespan_seconds);
    }
}
