//! Seeded fault injection: membership traces for the elastic-fleet layer.
//!
//! A membership trace says, for every learner and every step, whether the
//! learner is up.  Traces come in two forms: a sampled spot-preemption
//! model (`--faults PROB[:mttr]` — each up learner is preempted with
//! probability `PROB` per step and repairs after `mttr` steps) and an
//! explicit scripted form (`--faults trace:STEP@LEARNERxDOWN,...`).  Both
//! are pure functions of `(seed, plan, p)`: the sampled form draws from
//! per-learner Pcg32 streams forked, in learner order, from a root on the
//! dedicated fault stream [`FAULT_STREAM`] — disjoint from the training
//! ("HIER") and straggler ("SIMT") streams, so arming the fault layer
//! perturbs neither batch draws nor straggler spikes.
//!
//! The engine (parameter path), the heap event model (time path), and the
//! scan reference each hold their *own* [`MembershipModel`] instance;
//! because a trace is a pure function of its inputs, the three instances
//! agree step for step, and faults stay seeded-timeline data only — no
//! cross-layer mutable channel exists for them to disagree through.
//!
//! Step ordinals are 1-based, matching the driver loop (`t in
//! 1..=horizon`) and the engine's post-increment step counter.  A down
//! interval `[start, end)` means the learner is down during steps
//! `start..end` and re-enters (pays its restore, rejoins barriers) at
//! step `end`.  Sampled gaps are geometric with per-step hazard `prob`,
//! so the sampled form is distributionally identical to flipping a
//! per-step Bernoulli coin while up — but closed-form, so advancing a
//! learner's trace to step `t` costs O(intervals), not O(t).

use anyhow::{bail, Context, Result};

use crate::util::rng::Pcg32;

/// Pcg32 stream id for fault traces ("FAUL"), disjoint from the training
/// ("HIER") and straggler ("SIMT") streams.
pub const FAULT_STREAM: u64 = 0x4641_554C;

/// Default repair time (steps) when `--faults PROB` omits `:mttr`.
pub const DEFAULT_MTTR: u64 = 25;

/// Warm-restart surcharge a re-entering learner pays at its first up
/// step, in units of its own base step time: checkpoint read + parameter
/// install + rejoin handshake, modelled as two lost steps.
pub const REENTRY_RESTORE_STEPS: f64 = 2.0;

/// The sampled spot-preemption model: per-step preemption hazard plus a
/// fixed repair time.  `Copy` so the planner's `ScoreCtx` stays `Copy`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Per-step, per-learner preemption probability while up.
    pub prob: f64,
    /// Repair time in steps (mean time to repair; fixed, not sampled).
    pub mttr: u64,
}

impl Default for FaultSpec {
    fn default() -> FaultSpec {
        FaultSpec { prob: 0.0, mttr: DEFAULT_MTTR }
    }
}

/// One scripted outage: learner `learner` is down for `down_steps` steps
/// starting at step `step` (1-based), re-entering at `step + down_steps`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub step: u64,
    pub learner: usize,
    pub down_steps: u64,
}

/// A parsed `--faults` argument: sampled spot-preemption or an explicit
/// scripted trace.  `--faults 0` is `Sampled { prob: 0.0, .. }` — the
/// elastic layer installs (forced per-learner pool, membership queries,
/// survivor-aware reduction path) but the trace is empty, which is what
/// the zero-fault bit-identity tests pin against plain event mode.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultPlan {
    Sampled(FaultSpec),
    Scripted(Vec<FaultEvent>),
}

impl FaultPlan {
    /// Canonical spec string: parses back to an equal plan.
    pub fn spec(&self) -> String {
        match self {
            FaultPlan::Sampled(s) => format!("{}:{}", s.prob, s.mttr),
            FaultPlan::Scripted(events) => {
                let parts: Vec<String> = events
                    .iter()
                    .map(|e| format!("{}@{}x{}", e.step, e.learner, e.down_steps))
                    .collect();
                format!("trace:{}", parts.join(","))
            }
        }
    }

    /// The sampled spec, if this is the sampled form (the only form the
    /// sweep accepts: a scripted trace names specific learners, which
    /// cannot transfer across candidate topologies of varying P).
    pub fn sampled(&self) -> Option<FaultSpec> {
        match self {
            FaultPlan::Sampled(s) => Some(*s),
            FaultPlan::Scripted(_) => None,
        }
    }

    /// Validate against a fleet of `p` learners, with actionable errors.
    pub fn validate(&self, p: usize) -> Result<()> {
        match self {
            FaultPlan::Sampled(s) => {
                if !s.prob.is_finite() || !(0.0..=1.0).contains(&s.prob) {
                    bail!(
                        "--faults probability {} is outside [0, 1]: it is a per-step, \
                         per-learner preemption hazard (0.003 preempts each learner about \
                         once every 333 steps)",
                        s.prob
                    );
                }
                if s.mttr == 0 {
                    bail!(
                        "--faults mttr must be at least 1 step: a repair time of 0 means \
                         the learner never actually leaves, so no trace exists for it"
                    );
                }
            }
            FaultPlan::Scripted(events) => {
                if events.is_empty() {
                    bail!("--faults trace: lists no outages; use --faults 0 for an armed-but-empty fault layer");
                }
                let mut per: Vec<Vec<(u64, u64)>> = vec![Vec::new(); p];
                for e in events {
                    if e.step == 0 {
                        bail!("--faults trace step 0 is invalid: steps are 1-based (the first trainable step is 1)");
                    }
                    if e.down_steps == 0 {
                        bail!("--faults trace outage {}@{}x0 lasts zero steps: down_steps must be at least 1", e.step, e.learner);
                    }
                    if e.learner >= p {
                        bail!(
                            "--faults trace names learner {} but this run has only {} learners (0..={}): \
                             fix the trace or raise --p",
                            e.learner,
                            p,
                            p.saturating_sub(1)
                        );
                    }
                    per[e.learner].push((e.step, e.step.saturating_add(e.down_steps)));
                }
                for (j, list) in per.iter_mut().enumerate() {
                    list.sort_unstable();
                    for w in list.windows(2) {
                        if w[1].0 <= w[0].1 {
                            bail!(
                                "--faults trace outages for learner {j} overlap or touch \
                                 (steps {}..{} then {}..{}): a learner must be up for at \
                                 least one step between outages so its re-entry is well defined",
                                w[0].0, w[0].1, w[1].0, w[1].1
                            );
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

/// Parse a `--faults` argument: `PROB[:mttr]` (e.g. `0.003:20`) or
/// `trace:STEP@LEARNERxDOWN[,...]` (e.g. `trace:10@3x20,50@7x30`).
/// Range validation happens in [`FaultPlan::validate`], which knows `p`.
pub fn parse_faults(s: &str) -> Result<FaultPlan> {
    if let Some(rest) = s.strip_prefix("trace:") {
        let mut events = Vec::new();
        for part in rest.split(',').filter(|p| !p.is_empty()) {
            let (step_s, rest2) = part.split_once('@').with_context(|| {
                format!("--faults trace entry {part:?} is not STEP@LEARNERxDOWN (e.g. 10@3x20)")
            })?;
            let (learner_s, down_s) = rest2.split_once('x').with_context(|| {
                format!("--faults trace entry {part:?} is not STEP@LEARNERxDOWN (e.g. 10@3x20)")
            })?;
            let step: u64 = step_s
                .parse()
                .with_context(|| format!("--faults trace entry {part:?}: bad step {step_s:?}"))?;
            let learner: usize = learner_s
                .parse()
                .with_context(|| format!("--faults trace entry {part:?}: bad learner {learner_s:?}"))?;
            let down_steps: u64 = down_s
                .parse()
                .with_context(|| format!("--faults trace entry {part:?}: bad down-step count {down_s:?}"))?;
            events.push(FaultEvent { step, learner, down_steps });
        }
        if events.is_empty() {
            bail!("--faults trace: lists no outages; use --faults 0 for an armed-but-empty fault layer");
        }
        return Ok(FaultPlan::Scripted(events));
    }
    let (prob_s, mttr_s) = match s.split_once(':') {
        Some((a, b)) => (a, Some(b)),
        None => (s, None),
    };
    let prob: f64 = prob_s.parse().with_context(|| {
        format!("--faults {s:?} is neither PROB[:mttr] (e.g. 0.003:20) nor trace:STEP@LEARNERxDOWN,...")
    })?;
    let mttr: u64 = match mttr_s {
        Some(m) => m
            .parse()
            .with_context(|| format!("--faults {s:?}: bad mttr {m:?} (steps, e.g. 0.003:20)"))?,
        None => DEFAULT_MTTR,
    };
    Ok(FaultPlan::Sampled(FaultSpec { prob, mttr }))
}

/// The queryable membership trace: per-learner down intervals, realized
/// lazily.  Queries must be monotone non-decreasing in `t` per learner
/// (every consumer walks the timeline forward); learners may be touched
/// in any order — sampled streams fork in strictly ascending learner
/// order regardless, so lazy realization equals eager realization.
#[derive(Debug, Clone)]
pub struct MembershipModel {
    prob: f64,
    mttr: u64,
    root: Pcg32,
    rngs: Vec<Pcg32>,
    /// Scripted form: per-learner sorted `[start, end)` outage lists.
    script: Option<Vec<Vec<(u64, u64)>>>,
    /// Scripted form: per-learner index of the next unconsumed outage.
    cursor: Vec<usize>,
    /// Current-or-next interval per learner (the first with `end > t`).
    cur: Vec<Option<(u64, u64)>>,
    /// End of the most recently passed interval (0 if none): `last_end[j]
    /// == t` exactly at learner `j`'s re-entry step.
    last_end: Vec<u64>,
    ready: Vec<bool>,
}

impl MembershipModel {
    pub fn new(p: usize, seed: u64, plan: &FaultPlan) -> MembershipModel {
        let (prob, mttr, script) = match plan {
            FaultPlan::Sampled(s) => (s.prob, s.mttr, None),
            FaultPlan::Scripted(events) => {
                let mut per: Vec<Vec<(u64, u64)>> = vec![Vec::new(); p];
                for e in events {
                    if e.learner < p {
                        per[e.learner].push((e.step, e.step.saturating_add(e.down_steps)));
                    }
                }
                for list in &mut per {
                    list.sort_unstable();
                }
                (0.0, 0, Some(per))
            }
        };
        MembershipModel {
            prob,
            mttr,
            root: Pcg32::new(seed, FAULT_STREAM),
            rngs: Vec::new(),
            script,
            cursor: vec![0; p],
            cur: vec![None; p],
            last_end: vec![0; p],
            ready: vec![false; p],
        }
    }

    pub fn p(&self) -> usize {
        self.cur.len()
    }

    /// True iff the trace can never mark anyone down (the `--faults 0`
    /// armed-but-empty case, or a scripted plan with no entries).
    pub fn is_empty(&self) -> bool {
        match &self.script {
            Some(per) => per.iter().all(|list| list.is_empty()),
            None => self.prob <= 0.0,
        }
    }

    fn next_interval(&mut self, j: usize, from: u64) -> Option<(u64, u64)> {
        if let Some(script) = &self.script {
            let i = self.cursor[j];
            self.cursor[j] += 1;
            return script[j].get(i).copied();
        }
        if self.prob <= 0.0 {
            return None;
        }
        // Fork per-learner streams in ascending learner order, exactly
        // once each, no matter which learner is queried first — so lazy
        // realization is bit-identical to eager realization.
        while self.rngs.len() <= j {
            let tag = self.rngs.len() as u64;
            let fork = self.root.fork(tag);
            self.rngs.push(fork);
        }
        let u = self.rngs[j].next_f64();
        // Geometric gap with per-step hazard `prob`: support {1, 2, ...},
        // P(gap = 1) = prob — distributionally a per-step Bernoulli coin.
        // u in [0, 1) keeps the numerator finite; prob == 1 sends the
        // denominator to -inf and the ratio to -0.0, i.e. gap 1 always.
        let denom = (1.0 - self.prob).ln();
        let mut gap = ((1.0 - u).ln() / denom).floor() + 1.0;
        if !gap.is_finite() || gap < 1.0 {
            gap = 1.0;
        }
        let start = from.saturating_add(gap as u64);
        Some((start, start.saturating_add(self.mttr)))
    }

    fn ensure(&mut self, j: usize) {
        if !self.ready[j] {
            self.ready[j] = true;
            self.cur[j] = self.next_interval(j, 0);
        }
    }

    fn advance(&mut self, j: usize, t: u64) {
        self.ensure(j);
        while let Some((_, end)) = self.cur[j] {
            if end > t {
                break;
            }
            self.last_end[j] = end;
            self.cur[j] = self.next_interval(j, end);
        }
    }

    /// Is learner `j` down during step `t`?  (1-based step ordinals.)
    pub fn is_down(&mut self, j: usize, t: u64) -> bool {
        self.advance(j, t);
        matches!(self.cur[j], Some((start, _)) if start <= t)
    }

    /// Does learner `j` re-enter exactly at step `t` (first up step after
    /// an outage)?  Requires the same monotone query discipline as
    /// [`Self::is_down`].
    pub fn reentered_at(&mut self, j: usize, t: u64) -> bool {
        self.advance(j, t);
        self.last_end[j] == t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sampled_forms() {
        assert_eq!(
            parse_faults("0.01").unwrap(),
            FaultPlan::Sampled(FaultSpec { prob: 0.01, mttr: DEFAULT_MTTR })
        );
        assert_eq!(
            parse_faults("0.25:40").unwrap(),
            FaultPlan::Sampled(FaultSpec { prob: 0.25, mttr: 40 })
        );
        assert_eq!(
            parse_faults("0").unwrap(),
            FaultPlan::Sampled(FaultSpec { prob: 0.0, mttr: DEFAULT_MTTR })
        );
    }

    #[test]
    fn parse_scripted_form() {
        let plan = parse_faults("trace:10@3x20,50@7x30").unwrap();
        assert_eq!(
            plan,
            FaultPlan::Scripted(vec![
                FaultEvent { step: 10, learner: 3, down_steps: 20 },
                FaultEvent { step: 50, learner: 7, down_steps: 30 },
            ])
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in ["bogus", "0.1:x", "trace:", "trace:10@3", "trace:10x3@20", "trace:a@b*c"] {
            assert!(parse_faults(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn spec_round_trips() {
        for s in ["0.003:20", "0:25", "trace:10@3x20,50@7x30"] {
            let plan = parse_faults(s).unwrap();
            assert_eq!(parse_faults(&plan.spec()).unwrap(), plan, "spec {s:?}");
        }
    }

    #[test]
    fn validate_catches_bad_specs() {
        assert!(FaultPlan::Sampled(FaultSpec { prob: 1.5, mttr: 10 }).validate(4).is_err());
        assert!(FaultPlan::Sampled(FaultSpec { prob: -0.1, mttr: 10 }).validate(4).is_err());
        assert!(FaultPlan::Sampled(FaultSpec { prob: f64::NAN, mttr: 10 }).validate(4).is_err());
        assert!(FaultPlan::Sampled(FaultSpec { prob: 0.1, mttr: 0 }).validate(4).is_err());
        assert!(FaultPlan::Sampled(FaultSpec { prob: 0.1, mttr: 1 }).validate(4).is_ok());
        // learner out of range
        let plan = FaultPlan::Scripted(vec![FaultEvent { step: 5, learner: 4, down_steps: 2 }]);
        assert!(plan.validate(4).is_err());
        assert!(plan.validate(5).is_ok());
        // zero-length outage, step 0
        assert!(FaultPlan::Scripted(vec![FaultEvent { step: 5, learner: 0, down_steps: 0 }])
            .validate(4)
            .is_err());
        assert!(FaultPlan::Scripted(vec![FaultEvent { step: 0, learner: 0, down_steps: 2 }])
            .validate(4)
            .is_err());
        // touching outages: down 5..8, then down again at 8 — re-entry undefined
        let touching = FaultPlan::Scripted(vec![
            FaultEvent { step: 5, learner: 1, down_steps: 3 },
            FaultEvent { step: 8, learner: 1, down_steps: 2 },
        ]);
        assert!(touching.validate(4).is_err());
        let gapped = FaultPlan::Scripted(vec![
            FaultEvent { step: 5, learner: 1, down_steps: 3 },
            FaultEvent { step: 9, learner: 1, down_steps: 2 },
        ]);
        assert!(gapped.validate(4).is_ok());
    }

    #[test]
    fn scripted_trace_is_exact() {
        let plan = FaultPlan::Scripted(vec![FaultEvent { step: 4, learner: 1, down_steps: 3 }]);
        let mut m = MembershipModel::new(3, 42, &plan);
        for t in 1..=12 {
            for j in 0..3 {
                let expect = j == 1 && (4..7).contains(&t);
                assert_eq!(m.is_down(j, t), expect, "j={j} t={t}");
                assert_eq!(m.reentered_at(j, t), j == 1 && t == 7, "reenter j={j} t={t}");
            }
        }
    }

    #[test]
    fn zero_prob_never_goes_down() {
        let plan = FaultPlan::Sampled(FaultSpec { prob: 0.0, mttr: 10 });
        let mut m = MembershipModel::new(8, 7, &plan);
        assert!(m.is_empty());
        for t in 1..=200 {
            for j in 0..8 {
                assert!(!m.is_down(j, t));
                assert!(!m.reentered_at(j, t));
            }
        }
    }

    #[test]
    fn sampled_trace_is_deterministic_and_lazy_order_invariant() {
        let plan = FaultPlan::Sampled(FaultSpec { prob: 0.2, mttr: 3 });
        // a queries learners in ascending order, c in descending order:
        // the realized grids must agree because stream forking is
        // order-invariant (streams fork 0..=j ascending on first touch).
        let mut a = MembershipModel::new(6, 99, &plan);
        let mut c = MembershipModel::new(6, 99, &plan);
        let mut grid_a = Vec::new();
        let mut grid_c = Vec::new();
        let mut downs = 0usize;
        for t in 1..=400u64 {
            for j in 0..6 {
                let d = a.is_down(j, t);
                downs += d as usize;
                grid_a.push(d);
            }
            let mut row = vec![false; 6];
            for j in (0..6).rev() {
                row[j] = c.is_down(j, t);
            }
            grid_c.extend(row);
        }
        assert_eq!(grid_a, grid_c);
        // hazard 0.2 over 6×400 learner-steps: outages are plentiful
        assert!(downs > 100, "expected a busy trace, got {downs} down learner-steps");
    }

    #[test]
    fn sampled_learners_draw_disjoint_streams() {
        let plan = FaultPlan::Sampled(FaultSpec { prob: 0.3, mttr: 2 });
        let mut m = MembershipModel::new(2, 11, &plan);
        let mut traces: Vec<Vec<bool>> = vec![Vec::new(); 2];
        for t in 1..=300 {
            for j in 0..2 {
                traces[j].push(m.is_down(j, t));
            }
        }
        assert_ne!(traces[0], traces[1], "two learners realized identical 300-step traces");
    }

    #[test]
    fn down_intervals_respect_mttr() {
        let plan = FaultPlan::Sampled(FaultSpec { prob: 0.05, mttr: 4 });
        let mut m = MembershipModel::new(1, 5, &plan);
        let mut run = 0u64;
        let mut saw_outage = false;
        for t in 1..=2000 {
            if m.is_down(0, t) {
                run += 1;
            } else {
                if run > 0 {
                    saw_outage = true;
                    assert_eq!(run, 4, "every outage lasts exactly mttr steps");
                    assert!(m.reentered_at(0, t), "first up step is the re-entry step");
                }
                run = 0;
            }
        }
        assert!(saw_outage, "hazard 0.05 over 2000 steps produced no outage");
    }
}
