//! The legacy scan-based event model, kept as the executable reference
//! specification for the heap/calendar core.
//!
//! [`ScanEventModel`] is the original `EventModel` implementation: every
//! `on_step` walks all P learner clocks, every reduction iterates each
//! group's members.  It is O(P) per step and materializes every
//! per-learner vector up front — exactly the costs the heap core
//! ([`super::EventModel`]) removes — but its semantics are the contract:
//! the property tests in rust/tests/event_heap.rs drive both models over
//! random topologies × heterogeneity specs and require bit-identical
//! timelines.  Any behavioural change to the event engine must land here
//! first, as a deliberate edit to the reference, never as a silent
//! divergence of the fast path.

use crate::topology::HierTopology;
use crate::util::rng::Pcg32;

use super::{ExecBreakdown, ExecKind, ExecModel, HetSpec, STRAGGLER_STREAM};

/// The reference virtual-time event engine: per-learner clocks, group-local
/// barriers, straggler spikes, all advanced by eager O(P) scans.
///
/// Bit-for-bit note: under a homogeneous [`HetSpec`] every operation here
/// degenerates to the exact IEEE operation `LockstepModel` performs in
/// the same order (`rate = 1.0` multiplications are exact, equal-clock
/// maxima return the shared value, `x − x = +0.0` waits), which is what
/// makes the homogeneous-equivalence golden tests byte-stable.
#[derive(Debug, Clone)]
pub struct ScanEventModel {
    base: f64,
    n_levels: usize,
    rates: Vec<f64>,
    spike_prob: f64,
    spike_mult: f64,
    rngs: Vec<Pcg32>,
    clocks: Vec<f64>,
    busy: Vec<f64>,
    blocked: Vec<f64>,
    level_stalls: Vec<f64>,
    straggler_events: u64,
}

impl ScanEventModel {
    pub fn new(p: usize, n_levels: usize, step_seconds: f64, spec: &HetSpec) -> ScanEventModel {
        let rates = (0..p)
            .map(|j| {
                if p > 1 {
                    1.0 + spec.het * j as f64 / (p - 1) as f64
                } else {
                    1.0
                }
            })
            .collect();
        let mut root = Pcg32::new(spec.seed, STRAGGLER_STREAM);
        ScanEventModel {
            base: step_seconds,
            n_levels,
            rates,
            spike_prob: spec.straggler_prob,
            spike_mult: spec.straggler_mult,
            rngs: (0..p).map(|j| root.fork(j as u64)).collect(),
            clocks: vec![0.0; p],
            busy: vec![0.0; p],
            blocked: vec![0.0; p],
            level_stalls: vec![0.0; n_levels],
            straggler_events: 0,
        }
    }
}

impl ExecModel for ScanEventModel {
    fn name(&self) -> &'static str {
        // The reference reports the same model name: it is the same
        // semantics, and breakdown comparisons must not differ on a label.
        ExecKind::Event.name()
    }

    fn on_step(&mut self) {
        for j in 0..self.clocks.len() {
            let mut dt = self.base * self.rates[j];
            // prob = 0 draws nothing, keeping the homogeneous path free of
            // RNG state (and bit-identical to lockstep).
            if self.spike_prob > 0.0 && self.rngs[j].next_f64() < self.spike_prob {
                dt *= self.spike_mult;
                self.straggler_events += 1;
            }
            self.busy[j] += dt;
            self.clocks[j] += dt;
        }
    }

    fn on_reduction(&mut self, topo: &HierTopology, level: usize, seconds: f64) -> f64 {
        debug_assert_eq!(topo.n_levels(), self.n_levels);
        debug_assert_eq!(topo.p(), self.clocks.len());
        if topo.size(level) <= 1 && level + 1 < topo.n_levels() {
            return 0.0; // the reducer's no-op convention
        }
        let mut event_stall = 0.0;
        for g in 0..topo.n_groups(level) {
            let members = topo.group_members(level, g);
            // Group-local barrier: members meet at the slowest arrival,
            // then pay the collective together.  Other groups' clocks are
            // untouched — they keep stepping.
            let arrival = members
                .clone()
                .map(|j| self.clocks[j])
                .fold(f64::NEG_INFINITY, f64::max);
            for j in members {
                let wait = arrival - self.clocks[j];
                self.blocked[j] += wait;
                self.level_stalls[level] += wait;
                event_stall += wait;
                self.clocks[j] = arrival + seconds;
            }
        }
        event_stall
    }

    fn now(&mut self) -> f64 {
        self.clocks.iter().cloned().fold(0.0, f64::max)
    }

    fn breakdown(&mut self) -> ExecBreakdown {
        let makespan = self.clocks.iter().cloned().fold(0.0, f64::max);
        ExecBreakdown {
            model: self.name(),
            makespan_seconds: makespan,
            busy_seconds: self.busy.clone(),
            blocked_seconds: self.blocked.clone(),
            idle_seconds: self.clocks.iter().map(|&c| makespan - c).collect(),
            level_stall_seconds: self.level_stalls.clone(),
            straggler_events: self.straggler_events,
        }
    }
}
