//! The legacy scan-based event model, kept as the executable reference
//! specification for the heap/calendar core.
//!
//! [`ScanEventModel`] is the original `EventModel` implementation: every
//! `on_step` walks all P learner clocks, every reduction iterates each
//! group's members.  It is O(P) per step and materializes every
//! per-learner vector up front — exactly the costs the heap core
//! ([`super::EventModel`]) removes — but its semantics are the contract:
//! the property tests in rust/tests/event_heap.rs drive both models over
//! random topologies × heterogeneity specs and require bit-identical
//! timelines.  Any behavioural change to the event engine must land here
//! first, as a deliberate edit to the reference, never as a silent
//! divergence of the fast path.

use crate::topology::HierTopology;
use crate::util::rng::Pcg32;

use super::{
    ExecBreakdown, ExecKind, ExecModel, FaultPlan, HetSpec, MembershipModel,
    REENTRY_RESTORE_STEPS, STRAGGLER_STREAM,
};

/// The reference virtual-time event engine: per-learner clocks, group-local
/// barriers, straggler spikes, all advanced by eager O(P) scans.
///
/// Bit-for-bit note: under a homogeneous [`HetSpec`] every operation here
/// degenerates to the exact IEEE operation `LockstepModel` performs in
/// the same order (`rate = 1.0` multiplications are exact, equal-clock
/// maxima return the shared value, `x − x = +0.0` waits), which is what
/// makes the homogeneous-equivalence golden tests byte-stable.
#[derive(Debug, Clone)]
pub struct ScanEventModel {
    base: f64,
    n_levels: usize,
    rates: Vec<f64>,
    spike_prob: f64,
    spike_mult: f64,
    rngs: Vec<Pcg32>,
    clocks: Vec<f64>,
    busy: Vec<f64>,
    blocked: Vec<f64>,
    level_stalls: Vec<f64>,
    straggler_events: u64,
    /// Steps announced so far — the 1-based ordinal membership queries use.
    step: u64,
    /// Elastic-membership layer (`--faults`), None when not installed.
    faults: Option<MembershipModel>,
    /// Was learner j down during the previous step?  Drives the
    /// preemption/re-entry edge detection and the restore surcharge.
    down_prev: Vec<bool>,
    /// Learners migrated out of their sub-top groups: they barrier only
    /// at the outermost level.
    detached: Vec<bool>,
    /// Per-learner time lost to outages (down steps + restore surcharge).
    lost: Vec<f64>,
    preemptions: u64,
    reentries: u64,
    last_culprit: Option<usize>,
}

impl ScanEventModel {
    pub fn new(p: usize, n_levels: usize, step_seconds: f64, spec: &HetSpec) -> ScanEventModel {
        let rates = (0..p)
            .map(|j| {
                if p > 1 {
                    1.0 + spec.het * j as f64 / (p - 1) as f64
                } else {
                    1.0
                }
            })
            .collect();
        let mut root = Pcg32::new(spec.seed, STRAGGLER_STREAM);
        ScanEventModel {
            base: step_seconds,
            n_levels,
            rates,
            spike_prob: spec.straggler_prob,
            spike_mult: spec.straggler_mult,
            rngs: (0..p).map(|j| root.fork(j as u64)).collect(),
            clocks: vec![0.0; p],
            busy: vec![0.0; p],
            blocked: vec![0.0; p],
            level_stalls: vec![0.0; n_levels],
            straggler_events: 0,
            step: 0,
            faults: None,
            down_prev: vec![false; p],
            detached: vec![false; p],
            lost: vec![0.0; p],
            preemptions: 0,
            reentries: 0,
            last_culprit: None,
        }
    }

    /// Does learner `j` take part in a barrier at step `t`?  Down
    /// learners never do; detached (migrated) learners rejoin only at the
    /// outermost level.
    fn participates(&mut self, j: usize, t: u64, top: bool) -> bool {
        match self.faults.as_mut() {
            Some(m) => !m.is_down(j, t) && (top || !self.detached[j]),
            None => true,
        }
    }

    /// Timeline-side fault counters: `(preemptions, reentries)`.
    pub fn fault_counts(&self) -> (u64, u64) {
        (self.preemptions, self.reentries)
    }
}

impl ExecModel for ScanEventModel {
    fn name(&self) -> &'static str {
        // The reference reports the same model name: it is the same
        // semantics, and breakdown comparisons must not differ on a label.
        ExecKind::Event.name()
    }

    fn on_step(&mut self) {
        self.step += 1;
        let t = self.step;
        for j in 0..self.clocks.len() {
            let dt_base = self.base * self.rates[j];
            if let Some(m) = self.faults.as_mut() {
                if m.is_down(j, t) {
                    // A down step advances the learner's clock at its own
                    // base rate (wall time passes while the machine is
                    // gone) but is charged to `lost`, not `busy`, and
                    // draws no straggler spike — the spike stream only
                    // advances while the learner is up.
                    if !self.down_prev[j] {
                        self.preemptions += 1;
                        self.down_prev[j] = true;
                    }
                    self.lost[j] += dt_base;
                    self.clocks[j] += dt_base;
                    continue;
                }
                if self.down_prev[j] {
                    // First up step after an outage: pay the restore
                    // surcharge (checkpoint read + warm sync) before the
                    // step's own compute.
                    self.down_prev[j] = false;
                    self.reentries += 1;
                    let restore = REENTRY_RESTORE_STEPS * dt_base;
                    self.lost[j] += restore;
                    self.clocks[j] += restore;
                }
            }
            let mut dt = dt_base;
            // prob = 0 draws nothing, keeping the homogeneous path free of
            // RNG state (and bit-identical to lockstep).
            if self.spike_prob > 0.0 && self.rngs[j].next_f64() < self.spike_prob {
                dt *= self.spike_mult;
                self.straggler_events += 1;
            }
            self.busy[j] += dt;
            self.clocks[j] += dt;
        }
    }

    fn on_reduction(&mut self, topo: &HierTopology, level: usize, seconds: f64) -> f64 {
        debug_assert_eq!(topo.n_levels(), self.n_levels);
        debug_assert_eq!(topo.p(), self.clocks.len());
        if topo.size(level) <= 1 && level + 1 < topo.n_levels() {
            return 0.0; // the reducer's no-op convention
        }
        let t = self.step;
        let top = level + 1 == topo.n_levels();
        // Culprit tracking is a fault-layer feature: without one,
        // `last_culprit` stays None (matching the heap core).
        let track_culprit = self.faults.is_some();
        self.last_culprit = None;
        let mut best_clock = f64::NEG_INFINITY;
        let mut event_stall = 0.0;
        for g in 0..topo.n_groups(level) {
            let members = topo.group_members(level, g);
            // Group-local barrier over the group's *participants*: down
            // learners — and, below the top, detached learners — neither
            // wait nor are waited for, so the barrier degrades gracefully
            // to the survivors.  Other groups' clocks are untouched —
            // they keep stepping.  Without a fault layer everyone
            // participates and this is the legacy max-arrival barrier,
            // operation for operation.
            let mut arrival = f64::NEG_INFINITY;
            let mut any = false;
            for j in members.clone() {
                if self.participates(j, t, top) {
                    any = true;
                    if self.clocks[j] > arrival {
                        arrival = self.clocks[j];
                    }
                    if track_culprit && self.clocks[j] > best_clock {
                        best_clock = self.clocks[j];
                        self.last_culprit = Some(j);
                    }
                }
            }
            if !any {
                continue; // whole group down: the barrier never fires
            }
            for j in members {
                if !self.participates(j, t, top) {
                    continue;
                }
                let wait = arrival - self.clocks[j];
                self.blocked[j] += wait;
                self.level_stalls[level] += wait;
                event_stall += wait;
                self.clocks[j] = arrival + seconds;
            }
        }
        event_stall
    }

    fn now(&mut self) -> f64 {
        self.clocks.iter().cloned().fold(0.0, f64::max)
    }

    fn breakdown(&mut self) -> ExecBreakdown {
        let makespan = self.clocks.iter().cloned().fold(0.0, f64::max);
        ExecBreakdown {
            model: self.name(),
            makespan_seconds: makespan,
            busy_seconds: self.busy.clone(),
            blocked_seconds: self.blocked.clone(),
            idle_seconds: self.clocks.iter().map(|&c| makespan - c).collect(),
            level_stall_seconds: self.level_stalls.clone(),
            lost_seconds: self.lost.clone(),
            straggler_events: self.straggler_events,
        }
    }

    fn install_faults(&mut self, seed: u64, plan: &FaultPlan) {
        self.faults = Some(MembershipModel::new(self.clocks.len(), seed, plan));
    }

    fn last_culprit(&self) -> Option<usize> {
        self.last_culprit
    }

    fn set_detached(&mut self, learner: usize) {
        if learner < self.detached.len() {
            self.detached[learner] = true;
        }
    }
}
