//! Experiment scale: `full` uses the paper's parameters (P, epochs, data
//! volume); `small` shrinks epochs / dataset so the whole suite runs on a
//! laptop-class CPU in tens of minutes while preserving every *relative*
//! comparison (same P, S, K1, K2 grids); results/<exp>/ output directories
//! record which scale produced each table.

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Small,
    Full,
}

impl Scale {
    pub fn parse(s: &str) -> Result<Scale> {
        match s {
            "small" => Ok(Scale::Small),
            "full" => Ok(Scale::Full),
            _ => bail!("unknown scale {s:?} (small|full)"),
        }
    }

    /// Training epochs (paper: 200 on CIFAR-10, 90 on ImageNet).
    pub fn epochs(&self, paper: usize) -> usize {
        match self {
            Scale::Full => paper,
            // ~10x shorter, LR milestones rescaled by the caller.
            Scale::Small => (paper / 10).max(8),
        }
    }

    /// Steps per epoch (paper CIFAR: 50k/(P·64); we hold this at a level
    /// where K2 ≤ 32 fires several times per epoch).
    pub fn steps_per_epoch(&self, paper: usize) -> usize {
        match self {
            Scale::Full => paper,
            Scale::Small => 64,
        }
    }

    pub fn test_n(&self, paper: usize) -> usize {
        match self {
            Scale::Full => paper,
            Scale::Small => (paper / 8).clamp(512, 2048),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses() {
        assert_eq!(Scale::parse("small").unwrap(), Scale::Small);
        assert_eq!(Scale::parse("full").unwrap(), Scale::Full);
        assert!(Scale::parse("huge").is_err());
    }

    #[test]
    fn full_is_identity() {
        assert_eq!(Scale::Full.epochs(200), 200);
        assert_eq!(Scale::Full.steps_per_epoch(780), 780);
    }

    #[test]
    fn small_shrinks() {
        assert!(Scale::Small.epochs(200) < 40);
        assert!(Scale::Small.steps_per_epoch(780) <= 128);
        assert!(Scale::Small.test_n(10_000) <= 2048);
    }
}
