//! The reproduction harness: one entry per paper table/figure
//! (DESIGN.md §5).  Each experiment builds its `RunConfig` grid, runs the
//! trainer, prints the same rows/series the paper reports, and writes
//! results/<exp>/*.csv + .json.

pub mod experiments;
pub mod scale;

use anyhow::{bail, Result};

use crate::util::cli::Args;

pub use scale::Scale;

pub fn cmd_repro(args: &Args) -> Result<()> {
    // A misspelled flag (e.g. `--from-swep`) would otherwise be silently
    // ignored and the harness would run a different experiment
    // configuration than asked.
    args.check_known(&["scale", "backend", "out", "from-sweep", "schedule", "faults", "help"])?;
    let Some(exp) = args.positional.get(1) else {
        bail!("repro needs an experiment id (fig1..fig5, table1, thm34..thm36, comm, asgd, adaptive, deep, all)");
    };
    if args.get("from-sweep").is_some() && exp != "deep" {
        bail!("--from-sweep only applies to the deep experiment (got {exp:?})");
    }
    // Known (so a typo'd value still gets a targeted message) but always
    // rejected: the repro harness pins the paper's fault-free
    // configurations, and injecting outages would silently change every
    // figure it regenerates.
    if args.get("faults").is_some() {
        bail!(
            "repro experiments reproduce the paper's fault-free runs and do not take \
             --faults; use `train --faults` for elastic runs or `sweep --faults` for \
             fault-aware shape pricing"
        );
    }
    // Parse eagerly so a bad policy spec fails before any runs start, and
    // reject it outside `deep` rather than silently running static.
    let schedule = match args.get("schedule") {
        Some(s) => {
            if exp != "deep" {
                bail!("--schedule only applies to the deep experiment (got {exp:?})");
            }
            Some(crate::algorithms::PolicyKind::parse(s)?)
        }
        None => None,
    };
    let scale = Scale::parse(args.get_or("scale", "small"))?;
    let backend = match args.get("backend") {
        Some(b) => crate::config::BackendKind::parse(b)?,
        None => crate::config::BackendKind::Xla,
    };
    let out = std::path::PathBuf::from(args.get_or("out", "results"));
    let ctx = experiments::ReproCtx { scale, backend, out };
    match exp.as_str() {
        "fig1" => experiments::fig1_fig2(&ctx),
        "fig2" => experiments::fig1_fig2(&ctx),
        "fig3" => experiments::fig3(&ctx),
        "fig4" => experiments::fig4(&ctx),
        "fig5" => experiments::fig5(&ctx),
        "table1" => experiments::table1(&ctx),
        "thm34" => experiments::thm34(&ctx),
        "thm35" => experiments::thm35(&ctx),
        "thm36" => experiments::thm36(&ctx),
        "comm" => experiments::comm(&ctx),
        "asgd" => experiments::asgd(&ctx),
        "adaptive" => experiments::adaptive(&ctx),
        "deep" => experiments::deep(&ctx, args.get("from-sweep"), schedule),
        "all" => {
            experiments::thm34(&ctx)?;
            experiments::thm35(&ctx)?;
            experiments::thm36(&ctx)?;
            experiments::comm(&ctx)?;
            experiments::fig1_fig2(&ctx)?;
            experiments::fig3(&ctx)?;
            experiments::fig4(&ctx)?;
            experiments::table1(&ctx)?;
            experiments::fig5(&ctx)?;
            experiments::asgd(&ctx)?;
            experiments::adaptive(&ctx)?;
            experiments::deep(&ctx, None, None)
        }
        other => bail!("unknown experiment {other:?}"),
    }
}
