//! One function per paper table/figure.  Every experiment prints the rows
//! the paper reports and writes CSVs under `results/<exp>/`.
//!
//! Fidelity expectations (DESIGN.md §6): orderings / monotonicity /
//! crossovers should match the paper; absolute numbers differ (synthetic
//! data + MLP stand-ins + modelled time).

use std::path::PathBuf;

use anyhow::Result;

use crate::comm::CostModel;
use crate::config::{BackendKind, RunConfig};
use crate::driver;
use crate::metrics::{write_series_csv, RunRecord};
use crate::optimizer::LrSchedule;
use crate::repro::Scale;
use crate::theory::{self, BoundParams};
use crate::util::json::Json;

pub struct ReproCtx {
    pub scale: Scale,
    pub backend: BackendKind,
    pub out: PathBuf,
}

/// The four CNN stand-ins (DESIGN.md §1).
const CNN_SIMS: [&str; 4] = ["resnet18_sim", "googlenet_sim", "mobilenet_sim", "vgg19_sim"];
const PAPER_CIFAR_EPOCHS: usize = 200;
const PAPER_CIFAR_SPE: usize = 780; // 50k samples / 64 batch
const PAPER_IMAGENET_EPOCHS: usize = 90;

impl ReproCtx {
    /// Build the common config for a CIFAR-sim run.
    pub fn cifar_cfg(&self, model: &str, p: usize, s: usize, k1: u64, k2: u64) -> RunConfig {
        let mut cfg = RunConfig::defaults(model);
        cfg.backend = self.backend;
        cfg.p = p;
        cfg.s = s;
        cfg.k1 = k1;
        cfg.k2 = k2;
        cfg.epochs = self.scale.epochs(PAPER_CIFAR_EPOCHS);
        let b = driver::model_dims(model).map(|(_, b, _)| b).unwrap_or(16);
        cfg.train_n = self.scale.steps_per_epoch(PAPER_CIFAR_SPE) * p * b;
        cfg.test_n = self.scale.test_n(10_000);
        // Paper: 0.1 dropped to 0.01 at 3/4 of training.
        cfg.lr = LrSchedule::StepDecay {
            initial: 0.1,
            milestones: vec![(cfg.epochs * 3 / 4, 0.01)],
        };
        cfg
    }

    fn save_records(&self, exp: &str, records: &[RunRecord]) -> Result<()> {
        let dir = self.out.join(exp);
        std::fs::create_dir_all(&dir)?;
        for r in records {
            r.write_json(&dir.join(format!("{}.json", r.label)))?;
            r.write_csv(&dir.join(format!("{}.csv", r.label)))?;
        }
        Ok(())
    }
}

fn run_labeled(cfg: &RunConfig, label: &str) -> Result<RunRecord> {
    eprintln!("[repro] running {label} ({})", cfg.label());
    let mut rec = driver::run(cfg)?;
    rec.label = label.to_string();
    Ok(rec)
}

/// Mean train accuracy over the last quarter of training — the paper's
/// figs 1/3/4 show the epoch-170..200 window.
fn tail_mean(rec: &RunRecord, field: fn(&crate::metrics::EpochStats) -> f64) -> f64 {
    let n = rec.epochs.len();
    let start = n - (n / 4).max(1);
    let vals: Vec<f64> =
        rec.epochs[start..].iter().map(field).filter(|v| v.is_finite()).collect();
    vals.iter().sum::<f64>() / vals.len().max(1) as f64
}

// ---------------------------------------------------------------------------
// Figures 1 & 2: impact of K2 (training / test accuracy), P=32, K1=4, S=4.
// ---------------------------------------------------------------------------

pub fn fig1_fig2(ctx: &ReproCtx) -> Result<()> {
    println!("\n=== Fig 1 & 2: impact of K2 (P=32, K1=4, S=4, K2 in {{8,16,32}}) ===");
    let mut all = Vec::new();
    for model in CNN_SIMS {
        let mut runs = Vec::new();
        for k2 in [8u64, 16, 32] {
            let cfg = ctx.cifar_cfg(model, 32, 4, 4, k2);
            runs.push(run_labeled(&cfg, &format!("{model}-k2_{k2}"))?);
        }
        println!("\n{model}:");
        println!("  {:<8} {:>14} {:>14} {:>14} {:>10}", "K2", "train_acc(tail)", "test_acc(final)", "test_acc(best)", "glob_reds");
        for (r, k2) in runs.iter().zip([8u64, 16, 32]) {
            println!(
                "  {:<8} {:>14.4} {:>14.4} {:>14.4} {:>10}",
                k2,
                tail_mean(r, |e| e.train_acc),
                r.final_test_acc(),
                r.best_test_acc(),
                r.comm.global_reductions
            );
        }
        let refs: Vec<&RunRecord> = runs.iter().collect();
        write_series_csv(&ctx.out.join("fig1").join(format!("{model}.csv")), &refs, "train_acc")?;
        write_series_csv(&ctx.out.join("fig2").join(format!("{model}.csv")), &refs, "test_acc")?;
        all.extend(runs);
    }
    ctx.save_records("fig1_fig2_runs", &all)?;
    println!("\npaper's claim: no clue that smaller K2 converges faster; larger K2 often best on test.");
    Ok(())
}

// ---------------------------------------------------------------------------
// Figure 3: impact of K1 (training loss), K1 in {4,8}, K2=32, S=4, P=16.
// ---------------------------------------------------------------------------

pub fn fig3(ctx: &ReproCtx) -> Result<()> {
    println!("\n=== Fig 3: impact of K1 (P=16, K2=32, S=4, K1 in {{4,8}}) ===");
    let mut all = Vec::new();
    for model in CNN_SIMS {
        let mut runs = Vec::new();
        for k1 in [4u64, 8] {
            let cfg = ctx.cifar_cfg(model, 16, 4, k1, 32);
            runs.push(run_labeled(&cfg, &format!("{model}-k1_{k1}"))?);
        }
        let l4 = tail_mean(&runs[0], |e| e.train_loss);
        let l8 = tail_mean(&runs[1], |e| e.train_loss);
        println!(
            "{model}: tail train_loss K1=4: {l4:.4}  K1=8: {l8:.4}  -> {} (paper: K1=4 lower)",
            if l4 < l8 { "K1=4 lower ✓" } else { "K1=8 lower ✗" }
        );
        let refs: Vec<&RunRecord> = runs.iter().collect();
        write_series_csv(&ctx.out.join("fig3").join(format!("{model}.csv")), &refs, "train_loss")?;
        all.extend(runs);
    }
    ctx.save_records("fig3_runs", &all)
}

// ---------------------------------------------------------------------------
// Figure 4: impact of S (training loss), S in {2,4}, K2=32, K1=4, P=16.
// ---------------------------------------------------------------------------

pub fn fig4(ctx: &ReproCtx) -> Result<()> {
    println!("\n=== Fig 4: impact of S (P=16, K2=32, K1=4, S in {{2,4}}) ===");
    let mut all = Vec::new();
    for model in CNN_SIMS {
        let mut runs = Vec::new();
        for s in [2usize, 4] {
            let cfg = ctx.cifar_cfg(model, 16, s, 4, 32);
            runs.push(run_labeled(&cfg, &format!("{model}-s_{s}"))?);
        }
        let l2 = tail_mean(&runs[0], |e| e.train_loss);
        let l4 = tail_mean(&runs[1], |e| e.train_loss);
        println!(
            "{model}: tail train_loss S=2: {l2:.4}  S=4: {l4:.4}  -> {} (paper: S=4 lower)",
            if l4 < l2 { "S=4 lower ✓" } else { "S=2 lower ✗" }
        );
        let refs: Vec<&RunRecord> = runs.iter().collect();
        write_series_csv(&ctx.out.join("fig4").join(format!("{model}.csv")), &refs, "train_loss")?;
        all.extend(runs);
    }
    ctx.save_records("fig4_runs", &all)
}

// ---------------------------------------------------------------------------
// Table 1: Hier-AVG vs K-AVG (test accuracy) on resnet18-sim.
// ---------------------------------------------------------------------------

pub fn table1(ctx: &ReproCtx) -> Result<()> {
    println!("\n=== Table 1: Hier-AVG vs K-AVG (resnet18-sim) ===");
    // (algo, K_opt/K2, K1, S, P) rows exactly as the paper's table.
    struct Row {
        algo: &'static str,
        k2: u64,
        k1: u64,
        s: usize,
        p: usize,
    }
    let rows = [
        Row { algo: "K-AVG", k2: 32, k1: 32, s: 1, p: 16 },
        Row { algo: "Hier-AVG", k2: 64, k1: 2, s: 4, p: 16 },
        Row { algo: "Hier-AVG", k2: 64, k1: 4, s: 4, p: 16 },
        Row { algo: "Hier-AVG", k2: 64, k1: 16, s: 4, p: 16 },
        Row { algo: "K-AVG", k2: 4, k1: 4, s: 1, p: 32 },
        Row { algo: "Hier-AVG", k2: 8, k1: 4, s: 8, p: 32 },
        Row { algo: "K-AVG", k2: 4, k1: 4, s: 1, p: 64 },
        Row { algo: "Hier-AVG", k2: 8, k1: 1, s: 4, p: 64 },
    ];
    println!(
        "{:<10} {:>4} {:>4} {:>3} {:>4} {:>12} {:>12} {:>11} {:>13}",
        "Alg.", "K2", "K1", "S", "P", "test_acc", "best_acc", "glob_reds", "comm_model_s"
    );
    let mut records = Vec::new();
    for row in &rows {
        let cfg = ctx.cifar_cfg("resnet18_sim", row.p, row.s, row.k1, row.k2);
        let rec = run_labeled(
            &cfg,
            &format!("{}-p{}-k2_{}-k1_{}-s{}", row.algo, row.p, row.k2, row.k1, row.s),
        )?;
        println!(
            "{:<10} {:>4} {:>4} {:>3} {:>4} {:>12.4} {:>12.4} {:>11} {:>13.4}",
            row.algo,
            row.k2,
            row.k1,
            row.s,
            row.p,
            rec.final_test_acc(),
            rec.best_test_acc(),
            rec.comm.global_reductions,
            rec.comm.total_seconds()
        );
        records.push(rec);
    }
    ctx.save_records("table1", &records)?;
    println!("\npaper's claim: Hier-AVG with K2 = 2·K_opt and S=4 matches/beats K-AVG accuracy\nwith half the global reductions.");
    Ok(())
}

// ---------------------------------------------------------------------------
// Figure 5: ImageNet-sim, K-AVG (K=43) vs Hier-AVG (K2=43, K1=20, S=4), P=16.
// ---------------------------------------------------------------------------

pub fn fig5(ctx: &ReproCtx) -> Result<()> {
    println!("\n=== Fig 5: imagenet-sim, K-AVG(K=43) vs Hier-AVG(K2=43,K1=20,S=4), P=16 ===");
    let mk = |k1: u64, s: usize| -> RunConfig {
        let mut cfg = ctx.cifar_cfg("imagenet_sim", 16, s, k1, 43);
        cfg.epochs = ctx.scale.epochs(PAPER_IMAGENET_EPOCHS);
        cfg.lr = LrSchedule::StepDecay {
            initial: 0.1,
            milestones: vec![(cfg.epochs * 2 / 3, 0.01)],
        };
        // imagenet-sim is harder: 100 classes.
        cfg.noise = 1.0;
        cfg
    };
    let kavg = run_labeled(&mk(43, 1), "kavg-k43")?;
    let hier = run_labeled(&mk(20, 4), "hier-k2_43-k1_20-s4")?;
    println!("\n{:<8} {:>12} {:>12} {:>12} {:>12}", "epoch", "kavg_train", "hier_train", "kavg_test", "hier_test");
    for (a, b) in kavg.epochs.iter().zip(&hier.epochs) {
        println!(
            "{:<8} {:>12.4} {:>12.4} {:>12.4} {:>12.4}",
            a.epoch, a.train_acc, b.train_acc, a.test_acc, b.test_acc
        );
    }
    println!(
        "\nfinal: hier train {:.4} vs kavg {:.4} (paper: hier higher); hier test {:.4} vs kavg {:.4} (paper: hier +0.51%)",
        hier.epochs.last().unwrap().train_acc,
        kavg.epochs.last().unwrap().train_acc,
        hier.final_test_acc(),
        kavg.final_test_acc()
    );
    let refs = [&kavg, &hier];
    write_series_csv(&ctx.out.join("fig5").join("train_acc.csv"), &refs, "train_acc")?;
    write_series_csv(&ctx.out.join("fig5").join("test_acc.csv"), &refs, "test_acc")?;
    ctx.save_records("fig5", &[kavg, hier])
}

// ---------------------------------------------------------------------------
// §3.3 / Theorem 3.4: bound B(K2) over K2; optimal K2 > 1 when (3.11) holds.
// ---------------------------------------------------------------------------

pub fn thm34(ctx: &ReproCtx) -> Result<()> {
    println!("\n=== Thm 3.4: bound B(K2), fixed data budget (K1=4, S=4) ===");
    let t = 20_000u64;
    let mut far = BoundParams::default();
    far.f_gap = 100.0; // far-from-optimum regime: condition (3.11) holds
    let mut near = BoundParams::default();
    near.f_gap = 1e-3; // near-optimum regime: condition fails
    let mut rows = Vec::new();
    println!("{:>4} {:>16} {:>16}", "K2", "B(K2) far-init", "B(K2) near-init");
    for k2 in [1u64, 2, 4, 8, 16, 32, 64] {
        let k1 = 4u64.min(k2);
        let bf = theory::thm34_budget_bound(&far, t, k1, k2, 4);
        let bn = theory::thm34_budget_bound(&near, t, k1, k2, 4);
        println!("{k2:>4} {bf:>16.6} {bn:>16.6}");
        let mut o = Json::obj();
        o.set("k2", Json::from(k2 as usize))
            .set("far", Json::from(bf))
            .set("near", Json::from(bn));
        rows.push(o);
    }
    let k2_far = theory::optimal_k2(&far, t, 1, 4, 128);
    let k2_near = theory::optimal_k2(&near, t, 1, 4, 128);
    println!(
        "condition (3.11) far-init: {} -> K2* = {k2_far} (paper: K2* > 1)",
        theory::thm34_condition(&far, t, 4)
    );
    println!(
        "condition (3.11) near-init: {} -> K2* = {k2_near} (paper: K2* = 1)",
        theory::thm34_condition(&near, t, 4)
    );
    let dir = ctx.out.join("thm34");
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join("bounds.json"), Json::Arr(rows).pretty())?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Theorem 3.5: bound monotone increasing in K1, decreasing in S.
// ---------------------------------------------------------------------------

pub fn thm35(ctx: &ReproCtx) -> Result<()> {
    println!("\n=== Thm 3.5: bound (3.6) vs K1 (rows) and S (cols), K2=32, N=100 ===");
    let p = BoundParams::default();
    let ks = [1u64, 2, 4, 8, 16, 32];
    let ss = [1u64, 2, 4, 8];
    print!("{:>6}", "K1\\S");
    for s in ss {
        print!("{s:>14}");
    }
    println!();
    let mut rows = Vec::new();
    for k1 in ks {
        print!("{k1:>6}");
        for s in ss {
            let b = theory::thm32_bound(&p, 100, k1, 32, s);
            print!("{b:>14.6}");
            let mut o = Json::obj();
            o.set("k1", Json::from(k1 as usize))
                .set("s", Json::from(s as usize))
                .set("bound", Json::from(b));
            rows.push(o);
        }
        println!();
    }
    println!("check: rows increase downward (K1 ↑ worse), columns decrease rightward (S ↑ better).");
    let dir = ctx.out.join("thm35");
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join("grid.json"), Json::Arr(rows).pretty())?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Theorem 3.6: Hier-AVG (K2=(1+a)K, K1=1, S=4) bound vs K-AVG(K).
// ---------------------------------------------------------------------------

pub fn thm36(ctx: &ReproCtx) -> Result<()> {
    println!("\n=== Thm 3.6: H(K) / χ(K) (<1 means Hier-AVG tighter), T=10k ===");
    let p = BoundParams::default();
    let avals = [0.0, 0.2, 0.4, 0.6];
    print!("{:>6}", "K\\a");
    for a in avals {
        print!("{a:>10.1}");
    }
    println!();
    let mut rows = Vec::new();
    for k in [2u64, 4, 8, 16, 32, 64] {
        print!("{k:>6}");
        for a in avals {
            let (h, x) = theory::thm36_pair(&p, 10_000, k, a);
            print!("{:>10.4}", h / x);
            let mut o = Json::obj();
            o.set("k", Json::from(k as usize))
                .set("a", Json::from(a))
                .set("ratio", Json::from(h / x));
            rows.push(o);
        }
        println!();
    }
    println!("paper: ratio < 1 for all K >= 2, a in [0, 0.6] — Hier-AVG converges faster\nwhile using 1/(1+a) as many global reductions.");
    let dir = ctx.out.join("thm36");
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join("ratios.json"), Json::Arr(rows).pretty())?;
    Ok(())
}

// ---------------------------------------------------------------------------
// ASGD baseline (§1 motivation): parameter-server async SGD vs Hier-AVG at
// equal sample budgets — accuracy AND modelled time.
// ---------------------------------------------------------------------------

pub fn asgd(ctx: &ReproCtx) -> Result<()> {
    println!("\n=== ASGD (param server) vs Hier-AVG — the paper's §1 motivation ===");
    use crate::algorithms::asgd::AsgdTrainer;
    let mut records = Vec::new();
    println!(
        "{:<28} {:>4} {:>10} {:>10} {:>12} {:>14}",
        "run", "P", "test_acc", "best_acc", "server_msgs", "sim_total_s"
    );
    for p in [16usize, 32] {
        // Same model / data / sample budget for both.
        let hier_cfg = ctx.cifar_cfg("resnet18_sim", p, 4, 4, 8);
        let hier = run_labeled(&hier_cfg, &format!("hier-p{p}"))?;

        let mut asgd_cfg = hier_cfg.clone();
        asgd_cfg.s = 1;
        asgd_cfg.k1 = 1;
        asgd_cfg.k2 = 1; // unused by the ASGD runner
        // The server applies one worker's gradient at a time: build the
        // backend for single-learner dispatch.
        let mut build_cfg = asgd_cfg.clone();
        build_cfg.p = 1;
        build_cfg.s = 1;
        let (backend, data, init) = crate::driver::build(&build_cfg)?;
        let mut runner = AsgdTrainer::new(&asgd_cfg, backend, data, init, 1)?;
        let mut arec = runner.run()?;
        arec.label = format!("asgd-p{p}");

        for r in [&hier, &arec] {
            println!(
                "{:<28} {:>4} {:>10.4} {:>10.4} {:>12} {:>14.4}",
                r.label,
                p,
                r.final_test_acc(),
                r.best_test_acc(),
                r.comm.global_reductions,
                r.sim_total_seconds()
            );
        }
        println!(
            "  -> modelled speedup of Hier-AVG over ASGD at P={p}: {:.2}x (server serialization)",
            arec.sim_total_seconds() / hier.sim_total_seconds()
        );
        records.push(hier);
        records.push(arec);
    }
    println!("\npaper §1: a single parameter server cannot serve aggregation requests fast\nenough at scale; bulk-synchronous Hier-AVG avoids both the bottleneck and\nunbounded staleness.");
    ctx.save_records("asgd", &records)
}

// ---------------------------------------------------------------------------
// Adaptive K2 (§3.3: "adaptive choice of K2 may be better"): anneal K2
// downward as F(w̃) − F* shrinks (condition (3.11) weakens near optimum).
// ---------------------------------------------------------------------------

pub fn adaptive(ctx: &ReproCtx) -> Result<()> {
    println!("\n=== Adaptive K2 (paper §3.3 extension): fixed 32 vs fixed 8 vs 32→16→8 ===");
    let epochs = ctx.scale.epochs(PAPER_CIFAR_EPOCHS);
    let mk = |k2: u64, sched: Vec<(usize, u64)>| {
        let mut cfg = ctx.cifar_cfg("resnet18_sim", 16, 4, 4, k2);
        cfg.k2_schedule = sched;
        cfg
    };
    let runs = [
        ("fixed-k2_32", mk(32, vec![])),
        ("fixed-k2_8", mk(8, vec![])),
        (
            "adaptive-32-16-8",
            mk(32, vec![(epochs / 3, 16), (2 * epochs / 3, 8)]),
        ),
    ];
    let mut records = Vec::new();
    println!(
        "{:<20} {:>12} {:>10} {:>10} {:>12}",
        "run", "tail_loss", "test_acc", "best_acc", "glob_reds"
    );
    for (label, cfg) in runs {
        let rec = run_labeled(&cfg, label)?;
        println!(
            "{:<20} {:>12.4} {:>10.4} {:>10.4} {:>12}",
            label,
            tail_mean(&rec, |e| e.train_loss),
            rec.final_test_acc(),
            rec.best_test_acc(),
            rec.comm.global_reductions
        );
        records.push(rec);
    }
    println!("\nexpectation: the anneal matches fixed-K2=8's late-phase convergence while\nspending global reductions at an intermediate rate (K2* shrinks as the\ninitial-gap term in (3.11) decays).");
    ctx.save_records("adaptive", &records)
}

// ---------------------------------------------------------------------------
// Deep hierarchies (beyond the paper): GPU -> node -> rack.  The engine's
// N-level generalization lets the paper's trade (global reductions for
// cheap local ones) recurse: the rack tier absorbs most of what the
// 2-level shape still paid on the global fabric.
// ---------------------------------------------------------------------------

pub fn deep(
    ctx: &ReproCtx,
    from_sweep: Option<&str>,
    schedule: Option<crate::algorithms::PolicyKind>,
) -> Result<()> {
    let mut runs = match from_sweep {
        // Planner follow-through: train the sweep's winner instead of the
        // hand-picked pair, against the best 2-level entry of the same
        // report as the paper-shaped reference.
        Some(path) => sweep_deep_runs(ctx, std::path::Path::new(path))?,
        None => {
            println!("\n=== Deep hierarchy: 2-level vs 3-level at P=32, equal data budget ===");
            let p = 32usize;
            // 2-level: the paper's shape, S=4, K=[4,16].
            let two = ctx.cifar_cfg("resnet18_sim", p, 4, 4, 16);
            // 3-level: GPU quads -> nodes of 16 -> the 32-learner rack,
            // reducing each tier 4x less often than the one below.
            let mut three = ctx.cifar_cfg("resnet18_sim", p, 4, 4, 16);
            three.set_levels(vec![4, 16, 32]);
            three.set_ks(vec![4, 16, 64]);
            vec![("two-level-s4".to_string(), two), ("three-level-4x16x32".to_string(), three)]
        }
    };
    // `repro deep --schedule`: run every shape under the requested policy
    // (overriding whatever the sweep report recorded), so the 2-level
    // baseline and the deep winner are compared like for like.
    if let Some(policy) = schedule {
        println!("(schedule policy override: {})", policy.spec());
        for (_, cfg) in runs.iter_mut() {
            cfg.schedule_policy = policy;
            cfg.validate()?;
        }
    }
    let mut records = Vec::new();
    println!(
        "{:<24} {:>12} {:>10} {:>12} {:>12} {:>14}",
        "run", "tail_loss", "test_acc", "glob_reds", "loc_reds", "comm_model_s"
    );
    for (label, cfg) in runs {
        let rec = run_labeled(&cfg, &label)?;
        println!(
            "{:<24} {:>12.4} {:>10.4} {:>12} {:>12} {:>14.4}",
            label,
            tail_mean(&rec, |e| e.train_loss),
            rec.final_test_acc(),
            rec.comm.global_reductions,
            rec.comm.local_reductions,
            rec.comm.total_seconds()
        );
        let topo = cfg.hierarchy()?;
        for (lev, ls) in rec.comm_levels.iter().enumerate() {
            println!(
                "    level {lev} (groups of {:>3}): {:>8} reductions  {:.4}s  stall {:.4}s",
                topo.size(lev),
                ls.reductions,
                ls.seconds,
                rec.level_stall_seconds.get(lev).copied().unwrap_or(0.0)
            );
        }
        records.push(rec);
    }
    println!("\nexpectation: the 3-level run fires ~4x fewer rack-wide reductions while the\nnode tier keeps learners synchronized, so modelled comm time drops without\ngiving up the convergence the 2-level shape achieves.");
    ctx.save_records("deep", &records)
}

/// Build the `repro deep` run list from a `SWEEP_<p>.json` report: the
/// top-ranked candidate, plus the report's best 2-level candidate as the
/// paper-shaped reference (skipped when the winner already is 2-level).
fn sweep_deep_runs(
    ctx: &ReproCtx,
    path: &std::path::Path,
) -> Result<Vec<(String, RunConfig)>> {
    use anyhow::{anyhow, Context};

    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading sweep report {}", path.display()))?;
    let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
    let model = j.req("model")?.as_str()?.to_string();
    let p = j.req("p")?.as_usize()?;
    let cands = j.req("candidates")?.as_arr()?;
    if cands.is_empty() {
        anyhow::bail!("sweep report {} ranks no candidates", path.display());
    }
    // A heterogeneity-ranked winner was selected for its straggler-aware
    // makespan — replaying it under homogeneous lockstep would hide the
    // very property it won on, so the runs inherit the report's het
    // regime (reports from homogeneous sweeps stay lockstep).
    let het = match j.get("het") {
        Some(h) => crate::sim::HetSpec {
            het: h.req("het")?.as_f64()?,
            straggler_prob: h.req("straggler_prob")?.as_f64()?,
            straggler_mult: h.req("straggler_mult")?.as_f64()?,
            seed: h.req("seed")?.as_usize()? as u64,
        },
        None => crate::sim::HetSpec::default(),
    };

    let to_cfg = |cand: &Json| -> Result<(String, RunConfig)> {
        let label = cand.req("label")?.as_str()?.to_string();
        let levels = cand.req("levels")?.usize_arr()?;
        let ks: Vec<u64> =
            cand.req("ks")?.usize_arr()?.into_iter().map(|k| k as u64).collect();
        let links = cand
            .req("links")?
            .as_arr()?
            .iter()
            .map(|l| {
                let s = l.as_str()?;
                crate::topology::LinkClass::parse(s)
                    .ok_or_else(|| anyhow!("unknown link class {s:?} in sweep report"))
            })
            .collect::<Result<Vec<_>>>()?;
        let (s, k1, k2) = (
            *levels.first().ok_or_else(|| anyhow!("candidate {label} has no levels"))?,
            *ks.first().ok_or_else(|| anyhow!("candidate {label} has no intervals"))?,
            *ks.last().unwrap(),
        );
        let mut cfg = ctx.cifar_cfg(&model, p, s, k1, k2);
        cfg.set_levels(levels);
        cfg.set_ks(ks);
        cfg.links = links;
        // Candidates ranked under a schedule policy train under it too
        // (reports from before the policy field stay static).
        if let Some(policy) = cand.get("policy") {
            cfg.schedule_policy = crate::algorithms::PolicyKind::parse(policy.as_str()?)?;
        }
        if !het.is_homogeneous() {
            cfg.exec = crate::sim::ExecKind::Event;
            cfg.set_het_spec(&het);
        }
        cfg.validate()
            .with_context(|| format!("sweep candidate {label} is not a valid run config"))?;
        Ok((label, cfg))
    };

    let top = to_cfg(&cands[0])?;
    println!(
        "\n=== Deep hierarchy from sweep {}: top-ranked {} (model {model}, P={p}) ===",
        path.display(),
        top.0
    );
    if !het.is_homogeneous() {
        println!(
            "(event execution, inherited from the report: het={} straggler={}:{} seed={})",
            het.het, het.straggler_prob, het.straggler_mult, het.seed
        );
    }
    let mut runs = Vec::new();
    // The reference goes first so the comparison reads baseline -> winner.
    if top.1.hierarchy()?.n_levels() > 2 {
        if let Some(two) = cands.iter().skip(1).find(|c| {
            c.req("levels").and_then(|l| l.usize_arr()).map(|l| l.len() == 2).unwrap_or(false)
        }) {
            runs.push(to_cfg(two)?);
        }
    }
    runs.push(top);
    Ok(runs)
}

// ---------------------------------------------------------------------------
// Communication model: the claim the paper could not measure (§4.3).
// ---------------------------------------------------------------------------

pub fn comm(ctx: &ReproCtx) -> Result<()> {
    println!("\n=== Comm model: modelled reduction time per epoch, K-AVG vs Hier-AVG ===");
    use crate::algorithms::HierAvgSchedule;
    use crate::topology::LinkClass;
    let cm = CostModel::default();
    let n_params = 101_386usize; // resnet18-sim
    let bytes = n_params * 4;
    let spe = 780u64; // paper CIFAR steps/epoch
    println!(
        "{:>4} {:>22} {:>22} {:>10}",
        "P", "K-AVG(K=4) s/epoch", "Hier(8,4,S=4) s/epoch", "speedup"
    );
    let mut rows = Vec::new();
    for p in [16usize, 32, 64, 128, 256] {
        let kavg = HierAvgSchedule::k_avg(4).unwrap();
        let hier = HierAvgSchedule::new(4, 8).unwrap();
        let strategy = crate::comm::ReduceStrategy::Ring;
        let (g1, _) = kavg.reduction_counts(spe);
        let (g2, l2) = hier.reduction_counts(spe);
        let t_kavg = g1 as f64 * cm.allreduce_seconds(p, bytes, LinkClass::InterNode, strategy);
        let t_hier = g2 as f64 * cm.allreduce_seconds(p, bytes, LinkClass::InterNode, strategy)
            + l2 as f64 * cm.allreduce_seconds(4, bytes, LinkClass::IntraNode, strategy);
        println!("{p:>4} {t_kavg:>22.4} {t_hier:>22.4} {:>10.2}x", t_kavg / t_hier);
        let mut o = Json::obj();
        o.set("p", Json::from(p))
            .set("kavg_s", Json::from(t_kavg))
            .set("hier_s", Json::from(t_hier));
        rows.push(o);
    }
    println!("\npaper §3.5: trading global for (cheap) local reductions wins once P is large;\nthe speedup here is the modelled realization of that claim.");
    let dir = ctx.out.join("comm");
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join("model.json"), Json::Arr(rows).pretty())?;
    Ok(())
}
