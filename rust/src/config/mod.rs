//! Typed run configuration: the single description of a training run the
//! CLI, examples, repro harness and tests all share.  Loadable from a JSON
//! config file (configs/*.json) with CLI overrides.

use anyhow::{anyhow, bail, Context, Result};

use crate::algorithms::{policy, HierAvgSchedule, HierSchedule, PolicyKind};
use crate::comm::{CollectiveKind, Compression, CostModel, ReduceStrategy};
use crate::optimizer::LrSchedule;
use crate::sim::{parse_faults, ExecKind, FaultPlan, HetSpec};
use crate::topology::{HierTopology, LinkClass, Topology};
use crate::util::cli::Args;
use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT HLO artifacts through PJRT (the production path).
    Xla,
    /// Pure-Rust MLP (tests / fast sweeps).
    Native,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "xla" => Ok(BackendKind::Xla),
            "native" => Ok(BackendKind::Native),
            _ => bail!("unknown backend {s:?} (xla|native)"),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    /// Model name from artifacts/manifest.json (or native dims for the
    /// native backend).
    pub model: String,
    pub p: usize,
    pub s: usize,
    pub k1: u64,
    pub k2: u64,
    /// N-level hierarchy: group sizes per level (innermost first, last
    /// entry = P).  Empty = the paper's two-level `[s, p]` shape.
    pub levels: Vec<usize>,
    /// Per-level averaging intervals matching `levels` (non-decreasing
    /// outward).  Empty = the two-level `[k1, k2]`.
    pub ks: Vec<u64>,
    /// Which schedule policy decides, per step and per level, whether to
    /// reduce (`--schedule static|adaptive[:target]|warmup[:k]`): the
    /// base intervals verbatim, the online straggler-aware controller,
    /// or the dense-to-sparse warmup (`algorithms::policy`).
    pub schedule_policy: PolicyKind,
    /// Which collective engine executes reductions.
    pub collective: CollectiveKind,
    /// Payload compression applied at full-group barriers
    /// (`--compress none|topk:RATIO|randk:RATIO|q8|q4[:ef|:noef]`):
    /// top-k / random-k sparsification or 8/4-bit linear quantization with
    /// per-learner error-feedback residuals (`comm::compress`).  `None`
    /// builds no wrapper and is bit-identical to pre-compression builds.
    pub compress: Compression,
    /// Execution slots of the persistent worker pool the pooled collective
    /// and the native backend's lane fan-out dispatch onto (0 = available
    /// parallelism).  Oversubscription is allowed and never changes
    /// results (the pool's static assignment is deterministic).
    pub pool_threads: usize,
    /// Pin each pool slot to CPU `slot % host_cpus` (`--pool-pin`).
    /// Combined with the pool's stable shard→slot affinity and first-touch
    /// page placement this keeps every shard's pages, worker, and CPU on
    /// one NUMA node.  Best-effort: a no-op (with a notice) on targets
    /// without `sched_setaffinity`.  Never changes results — only where
    /// the deterministic work runs.
    pub pool_pin: bool,
    /// Suppress engine status notices on stderr (`--quiet`), e.g. the
    /// `--pool-pin` pin report, so JSON consumers and log-grepping CI
    /// smokes see clean streams.  Never changes results.
    pub quiet: bool,
    /// Per-level link-class overrides matching `levels` (innermost first):
    /// `intra` / `inter` / `rack`.  Empty = the default assignment
    /// (innermost intra-node, every outer level inter-node).
    pub links: Vec<LinkClass>,
    /// Which execution model accounts the run's virtual time: the legacy
    /// shared-clock `lockstep`, or the per-learner-clock `event` engine
    /// with group-local barriers (`sim::ExecModel`).
    pub exec: ExecKind,
    /// Deterministic per-learner compute-rate spread (event mode only):
    /// learner j's step time scales by `1 + het * j/(P-1)`.
    pub het: f64,
    /// Per-(learner, step) straggler-spike probability (event mode only).
    pub straggler_prob: f64,
    /// Spike slowdown factor (a spiked step takes `straggler_mult ×` the
    /// learner's nominal step time).
    pub straggler_mult: f64,
    /// Elastic-membership fault plan (`--faults PROB[:MTTR]` or
    /// `--faults trace:STEP@LEARNERxDOWN,...`, event mode only): seeded
    /// preemption/repair traces the timeline prices and the engine's
    /// parameter math degrades around (`sim::faults`).  None = the fault
    /// layer is absent and runs are bit-identical to pre-fault builds.
    pub faults: Option<FaultPlan>,
    pub epochs: usize,
    /// Nominal training-set size; steps/epoch = train_n / (P·B).
    pub train_n: usize,
    pub test_n: usize,
    pub lr: LrSchedule,
    pub momentum: f32,
    pub weight_decay: f32,
    pub backend: BackendKind,
    pub strategy: ReduceStrategy,
    pub seed: u64,
    /// Dataset difficulty (classification).
    pub noise: f32,
    pub radius: f32,
    /// Sub-clusters per class (non-convex structure; see data::MixtureSpec).
    pub subclusters: usize,
    /// Label-noise probability (keeps gradient variance M > 0).
    pub label_noise: f32,
    /// Adaptive-K2 milestones (paper §3.3: "adaptive choice of K2 may be
    /// better"): at each (epoch, k2) the global interval switches to k2.
    pub k2_schedule: Vec<(usize, u64)>,
    /// Evaluate every `eval_every` epochs (always at the last).
    pub eval_every: usize,
    /// Record the per-step loss curve.
    pub record_steps: bool,
    /// Record every reduction event (step, kind, modelled seconds).
    pub record_trace: bool,
    /// Keep the final averaged parameters in the RunRecord (for
    /// checkpointing / warm starts).
    pub keep_final_params: bool,
    /// Warm-start from a checkpoint saved with `checkpoint::save`.
    pub init_params: Option<String>,
    pub cost: CostModel,
}

impl RunConfig {
    pub fn defaults(model: &str) -> RunConfig {
        RunConfig {
            model: model.to_string(),
            p: 16,
            s: 4,
            k1: 4,
            k2: 32,
            levels: Vec::new(),
            ks: Vec::new(),
            schedule_policy: PolicyKind::Static,
            collective: CollectiveKind::Simulated,
            compress: Compression::None,
            pool_threads: 0,
            pool_pin: false,
            quiet: false,
            links: Vec::new(),
            exec: ExecKind::Lockstep,
            het: 0.0,
            straggler_prob: 0.0,
            straggler_mult: 4.0,
            faults: None,
            epochs: 20,
            train_n: 4096,
            test_n: 1024,
            lr: LrSchedule::StepDecay { initial: 0.1, milestones: vec![(15, 0.01)] },
            momentum: 0.0,
            weight_decay: 0.0,
            backend: BackendKind::Xla,
            strategy: ReduceStrategy::Ring,
            seed: 42,
            noise: 1.4,
            radius: 1.0,
            subclusters: 8,
            label_noise: 0.05,
            k2_schedule: Vec::new(),
            eval_every: 1,
            record_steps: false,
            record_trace: false,
            keep_final_params: false,
            init_params: None,
            cost: CostModel::default(),
        }
    }

    /// The paper's two-level view (valid for any config; N-level runs keep
    /// `s = levels[0]` and `p = levels.last()` in sync).
    pub fn topology(&self) -> Result<Topology> {
        Topology::new(self.p, self.s)
    }

    /// The run's reduction hierarchy: `levels` when set, else the
    /// two-level `[s, p]`; per-level `links` overrides applied when given.
    pub fn hierarchy(&self) -> Result<HierTopology> {
        let topo = if self.levels.is_empty() {
            self.topology()?.to_hier()
        } else {
            let topo = HierTopology::new(self.levels.clone())?;
            if topo.p() != self.p {
                bail!(
                    "hierarchy {:?} ends at {} learners but p = {}",
                    self.levels,
                    topo.p(),
                    self.p
                );
            }
            topo
        };
        if self.links.is_empty() {
            Ok(topo)
        } else {
            HierTopology::with_links(topo.sizes().to_vec(), self.links.clone())
        }
    }

    pub fn schedule(&self) -> Result<HierAvgSchedule> {
        HierAvgSchedule::new(self.k1, self.k2)
    }

    /// The run's base per-level intervals: `ks` when set, else `[k1, k2]`.
    pub fn base_intervals(&self) -> Vec<u64> {
        if self.ks.is_empty() { vec![self.k1, self.k2] } else { self.ks.clone() }
    }

    pub fn hier_schedule(&self) -> Result<HierSchedule> {
        HierSchedule::new(self.base_intervals())
    }

    /// Effective K2 (the outermost interval) at an epoch under the
    /// adaptive schedule.
    pub fn k2_at(&self, epoch: usize) -> u64 {
        let mut k2 = *self.base_intervals().last().unwrap();
        for &(e, v) in &self.k2_schedule {
            if epoch >= e {
                k2 = v;
            }
        }
        k2
    }

    /// Effective averaging schedule at an epoch (K1 clamps to K2).
    pub fn schedule_at(&self, epoch: usize) -> Result<HierAvgSchedule> {
        let k2 = self.k2_at(epoch);
        HierAvgSchedule::new(self.k1.min(k2), k2)
    }

    /// Effective N-level schedule at an epoch: the adaptive K2 replaces the
    /// outermost interval and clamps every inner interval down to it (the
    /// N-level generalization of `schedule_at`'s `K1.min(K2)`).
    pub fn hier_schedule_at(&self, epoch: usize) -> Result<HierSchedule> {
        let k2 = self.k2_at(epoch);
        let mut ks = self.base_intervals();
        let last = ks.len() - 1;
        ks[last] = k2;
        for k in ks[..last].iter_mut() {
            *k = (*k).min(k2);
        }
        HierSchedule::new(ks)
    }

    /// The event model's heterogeneity knobs as one spec (straggler
    /// streams are forked from the run seed on their own stream id, so
    /// they never perturb the training streams).
    pub fn het_spec(&self) -> HetSpec {
        HetSpec {
            het: self.het,
            straggler_prob: self.straggler_prob,
            straggler_mult: self.straggler_mult,
            seed: self.seed,
        }
    }

    /// The condition-(3.5) ceiling on the adaptive schedule controller's
    /// widening: the largest K2 for which Theorem 3.4's bound is still a
    /// convergence guarantee.  Built from the same `BoundParams`
    /// construction as the planner's [`crate::planner::ScoreCtx`] (the
    /// default regime with this run's P and B installed), so a replayed
    /// candidate and a live engine run share one clamp by construction —
    /// note condition (3.5) itself currently depends only on `L`, `γ`,
    /// and `δ_grad`, so with the default regime the clamp is the same
    /// number for every platform; `batch` matters only if the bound
    /// regime ever becomes (P, B)-sensitive.
    pub fn k2_clamp(&self, batch: usize) -> u64 {
        let mut bp = crate::theory::BoundParams::default();
        bp.p = self.p as f64;
        bp.b = batch.max(1) as f64;
        crate::theory::max_k2_condition_35(&bp, policy::K2_CLAMP_CAP).unwrap_or(1)
    }

    /// Install a het spec (the inverse of [`RunConfig::het_spec`]): every
    /// knob including the seed, so the run's straggler streams match a
    /// replay built from the same spec.  Does not switch `exec` — callers
    /// decide whether a heterogeneous spec implies event mode.
    pub fn set_het_spec(&mut self, spec: &HetSpec) {
        self.het = spec.het;
        self.straggler_prob = spec.straggler_prob;
        self.straggler_mult = spec.straggler_mult;
        self.seed = spec.seed;
    }

    pub fn validate(&self) -> Result<()> {
        let topo = self.hierarchy()?;
        let sched = self.hier_schedule()?;
        if sched.n_levels() != topo.n_levels() {
            bail!(
                "{} averaging intervals for a {}-level hierarchy",
                sched.n_levels(),
                topo.n_levels()
            );
        }
        if !self.levels.is_empty() && self.s != self.levels[0] {
            bail!(
                "s = {} out of sync with the hierarchy's innermost level {:?} (set levels via \
                 set_levels/CLI/JSON so the two-level mirrors stay aligned)",
                self.s,
                self.levels
            );
        }
        if !self.ks.is_empty() && (self.k1 != self.ks[0] || self.k2 != *self.ks.last().unwrap()) {
            bail!(
                "k1/k2 ({}, {}) out of sync with ks {:?} (set ks via the CLI/JSON so they stay aligned)",
                self.k1,
                self.k2,
                self.ks
            );
        }
        for &(e, _) in &self.k2_schedule {
            self.hier_schedule_at(e)?;
        }
        self.schedule_policy.validate()?;
        if self.epochs == 0 || self.train_n == 0 {
            bail!("epochs and train_n must be positive");
        }
        self.het_spec().validate()?;
        if self.exec == ExecKind::Lockstep && (self.het > 0.0 || self.straggler_prob > 0.0) {
            bail!(
                "--het/--straggler model heterogeneous compute, which the lockstep \
                 execution model cannot represent: add --exec event (lockstep charges \
                 every learner the same step time against one shared clock)"
            );
        }
        if let Some(plan) = &self.faults {
            plan.validate(self.p)?;
            if self.exec == ExecKind::Lockstep {
                bail!(
                    "--faults models preempted learners and survivor-only barriers, \
                     which the lockstep execution model cannot represent: add --exec \
                     event (lockstep advances one shared clock for the whole fleet)"
                );
            }
        }
        Ok(())
    }

    /// A short identifier for logs and CSV columns (the two-level form is
    /// kept stable for existing results directories).
    pub fn label(&self) -> String {
        if self.levels.len() > 2 {
            let sizes: Vec<String> = self.levels.iter().map(|s| s.to_string()).collect();
            let ks: Vec<String> = self.base_intervals().iter().map(|k| k.to_string()).collect();
            format!("{}-h{}-k{}", self.model, sizes.join("x"), ks.join("_"))
        } else {
            format!(
                "{}-p{}-s{}-k1_{}-k2_{}",
                self.model, self.p, self.s, self.k1, self.k2
            )
        }
    }

    /// Set an N-level hierarchy, keeping the two-level mirrors (`p`, `s`)
    /// in sync.
    pub fn set_levels(&mut self, levels: Vec<usize>) {
        if let (Some(&first), Some(&last)) = (levels.first(), levels.last()) {
            self.s = first;
            self.p = last;
        }
        self.levels = levels;
    }

    /// Set per-level intervals, keeping the two-level mirrors (`k1`, `k2`)
    /// in sync.
    pub fn set_ks(&mut self, ks: Vec<u64>) {
        if let (Some(&first), Some(&last)) = (ks.first(), ks.last()) {
            self.k1 = first;
            self.k2 = last;
        }
        self.ks = ks;
    }

    /// Load from a JSON file then apply `apply_json` overrides.
    pub fn from_json_file(path: &std::path::Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let j = Json::parse(&text)?;
        let model = j.req("model")?.as_str()?.to_string();
        let mut cfg = RunConfig::defaults(&model);
        cfg.apply_json(&j)?;
        Ok(cfg)
    }

    pub fn apply_json(&mut self, j: &Json) -> Result<()> {
        let obj = j.as_obj()?;
        for (k, v) in obj {
            match k.as_str() {
                "model" => self.model = v.as_str()?.to_string(),
                "p" => self.p = v.as_usize()?,
                "s" => self.s = v.as_usize()?,
                "k1" => self.k1 = v.as_usize()? as u64,
                "k2" => self.k2 = v.as_usize()? as u64,
                "levels" => self.set_levels(v.usize_arr()?),
                "ks" => {
                    let ks = v
                        .as_arr()?
                        .iter()
                        .map(|k| Ok(k.as_usize()? as u64))
                        .collect::<Result<Vec<_>>>()?;
                    self.set_ks(ks);
                }
                "collective" => self.collective = CollectiveKind::parse(v.as_str()?)?,
                "compress" => self.compress = Compression::parse(v.as_str()?)?,
                "pool_threads" => self.pool_threads = v.as_usize()?,
                "pool_pin" => self.pool_pin = v.as_bool()?,
                "quiet" => self.quiet = v.as_bool()?,
                "links" => {
                    self.links = v
                        .as_arr()?
                        .iter()
                        .map(|l| {
                            let s = l.as_str()?;
                            LinkClass::parse(s).ok_or_else(|| {
                                anyhow!("unknown link class {s:?} (intra|inter|rack)")
                            })
                        })
                        .collect::<Result<Vec<_>>>()?
                }
                "schedule" => self.schedule_policy = PolicyKind::parse(v.as_str()?)?,
                "exec" => self.exec = ExecKind::parse(v.as_str()?)?,
                "het" => self.het = v.as_f64()?,
                "straggler_prob" => self.straggler_prob = v.as_f64()?,
                "straggler_mult" => self.straggler_mult = v.as_f64()?,
                "faults" => self.faults = Some(parse_faults(v.as_str()?)?),
                "epochs" => self.epochs = v.as_usize()?,
                "train_n" => self.train_n = v.as_usize()?,
                "test_n" => self.test_n = v.as_usize()?,
                "lr" => self.lr = LrSchedule::parse(v.as_str()?)?,
                "momentum" => self.momentum = v.as_f64()? as f32,
                "weight_decay" => self.weight_decay = v.as_f64()? as f32,
                "backend" => self.backend = BackendKind::parse(v.as_str()?)?,
                "strategy" => {
                    self.strategy = ReduceStrategy::parse(v.as_str()?)
                        .ok_or_else(|| anyhow::anyhow!("bad strategy"))?
                }
                "seed" => self.seed = v.as_usize()? as u64,
                "noise" => self.noise = v.as_f64()? as f32,
                "radius" => self.radius = v.as_f64()? as f32,
                "subclusters" => self.subclusters = v.as_usize()?,
                "label_noise" => self.label_noise = v.as_f64()? as f32,
                "k2_schedule" => {
                    self.k2_schedule = v
                        .as_arr()?
                        .iter()
                        .map(|m| {
                            let pair = m.as_arr()?;
                            anyhow::ensure!(pair.len() == 2, "k2_schedule entries are [epoch, k2]");
                            Ok((pair[0].as_usize()?, pair[1].as_usize()? as u64))
                        })
                        .collect::<Result<Vec<_>>>()?
                }
                "eval_every" => self.eval_every = v.as_usize()?,
                "record_steps" => self.record_steps = v.as_bool()?,
                "record_trace" => self.record_trace = v.as_bool()?,
                "init_params" => self.init_params = Some(v.as_str()?.to_string()),
                "alpha_intra" => self.cost.alpha_intra = v.as_f64()?,
                "beta_intra" => self.cost.beta_intra = v.as_f64()?,
                "alpha_inter" => self.cost.alpha_inter = v.as_f64()?,
                "beta_inter" => self.cost.beta_inter = v.as_f64()?,
                "alpha_rack" => self.cost.alpha_rack = v.as_f64()?,
                "beta_rack" => self.cost.beta_rack = v.as_f64()?,
                other => bail!("unknown config key {other:?}"),
            }
        }
        Ok(())
    }

    /// Build a run config from CLI flags (the `train` subcommand's
    /// grammar; see the usage text in main.rs).  A `--config` file is
    /// loaded first, then individual flags override it.
    pub fn from_args(args: &Args) -> Result<RunConfig> {
        let mut cfg = if let Some(path) = args.get("config") {
            RunConfig::from_json_file(std::path::Path::new(path))?
        } else {
            RunConfig::defaults(args.get_or("model", "resnet18_sim"))
        };
        if let Some(m) = args.get("model") {
            cfg.model = m.to_string();
        }
        if let Some(b) = args.get("backend") {
            cfg.backend = BackendKind::parse(b)?;
        }
        // N-level flags come first so --p / --s / --k1 / --k2 can still
        // override (validate() catches inconsistent combinations).
        if let Some(ls) = args.get("levels") {
            cfg.set_levels(parse_list::<usize>(ls, "levels")?);
        }
        if let Some(ks) = args.get("ks") {
            cfg.set_ks(parse_list::<u64>(ks, "ks")?);
        }
        if let Some(c) = args.get("collective") {
            cfg.collective = CollectiveKind::parse(c)?;
        }
        if let Some(c) = args.get("compress") {
            cfg.compress = Compression::parse(c)?;
        }
        cfg.pool_threads = args.parse_or("pool-threads", cfg.pool_threads)?;
        if args.has("pool-pin") {
            cfg.pool_pin = true;
        }
        if args.has("quiet") {
            cfg.quiet = true;
        }
        if let Some(ls) = args.get("links") {
            cfg.links = ls
                .split(',')
                .map(|x| {
                    let x = x.trim();
                    LinkClass::parse(x)
                        .ok_or_else(|| anyhow!("invalid --links entry {x:?} (intra|inter|rack)"))
                })
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(s) = args.get("schedule") {
            cfg.schedule_policy = PolicyKind::parse(s)?;
        }
        if let Some(e) = args.get("exec") {
            cfg.exec = ExecKind::parse(e)?;
        }
        // Shared `--het` / `--straggler` grammar (one definition for
        // train, sweep, and the examples).
        let mut het = cfg.het_spec();
        het.apply_args(args)?;
        cfg.set_het_spec(&het);
        if let Some(f) = args.get("faults") {
            cfg.faults = Some(parse_faults(f)?);
        }
        cfg.p = args.parse_or("p", cfg.p)?;
        cfg.s = args.parse_or("s", cfg.s)?;
        cfg.k1 = args.parse_or("k1", cfg.k1)?;
        cfg.k2 = args.parse_or("k2", cfg.k2)?;
        cfg.epochs = args.parse_or("epochs", cfg.epochs)?;
        cfg.train_n = args.parse_or("train-n", cfg.train_n)?;
        cfg.test_n = args.parse_or("test-n", cfg.test_n)?;
        cfg.seed = args.parse_or("seed", cfg.seed)?;
        cfg.noise = args.parse_or("noise", cfg.noise)?;
        cfg.radius = args.parse_or("radius", cfg.radius)?;
        cfg.momentum = args.parse_or("momentum", cfg.momentum)?;
        if let Some(lr) = args.get("lr") {
            cfg.lr = LrSchedule::parse(lr)?;
        }
        if let Some(s) = args.get("strategy") {
            cfg.strategy =
                ReduceStrategy::parse(s).ok_or_else(|| anyhow!("unknown strategy {s:?}"))?;
        }
        if args.has("record-steps") {
            cfg.record_steps = true;
        }
        if let Some(p) = args.get("init-params") {
            cfg.init_params = Some(p.to_string());
        }
        if args.get("save-params").is_some() {
            cfg.keep_final_params = true;
        }
        if args.get("trace").is_some() {
            cfg.record_trace = true;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Parse a comma-separated list flag value (e.g. `--levels 2,8,32`).
fn parse_list<T: std::str::FromStr>(s: &str, flag: &str) -> Result<Vec<T>>
where
    T::Err: std::fmt::Display,
{
    s.split(',')
        .map(|x| {
            x.trim()
                .parse::<T>()
                .map_err(|e| anyhow!("invalid --{flag} entry {x:?}: {e}"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        RunConfig::defaults("resnet18_sim").validate().unwrap();
    }

    #[test]
    fn invalid_combinations_rejected() {
        let mut c = RunConfig::defaults("m");
        c.p = 10;
        c.s = 4;
        assert!(c.validate().is_err());
        let mut c = RunConfig::defaults("m");
        c.k1 = 9;
        c.k2 = 8;
        assert!(c.validate().is_err());
    }

    #[test]
    fn json_override() {
        let mut c = RunConfig::defaults("m");
        let j = Json::parse(
            r#"{"p": 32, "k1": 2, "k2": 8, "lr": "const:0.05", "backend": "native",
                "strategy": "tree", "record_steps": true}"#,
        )
        .unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.p, 32);
        assert_eq!(c.k2, 8);
        assert_eq!(c.lr, LrSchedule::Constant(0.05));
        assert_eq!(c.backend, BackendKind::Native);
        assert_eq!(c.strategy, ReduceStrategy::Tree);
        assert!(c.record_steps);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = RunConfig::defaults("m");
        let j = Json::parse(r#"{"bogus": 1}"#).unwrap();
        assert!(c.apply_json(&j).is_err());
    }

    #[test]
    fn label_is_stable() {
        let c = RunConfig::defaults("resnet18_sim");
        assert_eq!(c.label(), "resnet18_sim-p16-s4-k1_4-k2_32");
    }

    #[test]
    fn two_level_hierarchy_defaults() {
        let c = RunConfig::defaults("m");
        let h = c.hierarchy().unwrap();
        assert_eq!(h.sizes(), &[4, 16]);
        let s = c.hier_schedule().unwrap();
        assert_eq!(s.intervals(), &[4, 32]);
    }

    #[test]
    fn n_level_config_via_json() {
        let mut c = RunConfig::defaults("m");
        let j = Json::parse(
            r#"{"levels": [2, 8, 32], "ks": [2, 8, 32], "collective": "sharded:4",
                "backend": "native"}"#,
        )
        .unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.p, 32);
        assert_eq!(c.s, 2);
        assert_eq!(c.k1, 2);
        assert_eq!(c.k2, 32);
        assert_eq!(c.collective, CollectiveKind::Sharded { threads: 4 });
        c.validate().unwrap();
        assert_eq!(c.hierarchy().unwrap().n_levels(), 3);
        assert_eq!(c.label(), "m-h2x8x32-k2_8_32");
    }

    #[test]
    fn n_level_mismatch_rejected() {
        let mut c = RunConfig::defaults("m");
        c.set_levels(vec![2, 8, 32]);
        // 2 intervals for 3 levels
        assert!(c.validate().is_err());
        c.set_ks(vec![2, 8, 32]);
        c.validate().unwrap();
        // later --p override that contradicts the chain
        c.p = 64;
        assert!(c.validate().is_err());
        // later --s override that contradicts the innermost level
        let mut c = RunConfig::defaults("m");
        c.set_levels(vec![2, 8, 32]);
        c.set_ks(vec![2, 8, 32]);
        c.s = 8;
        assert!(c.validate().is_err());
    }

    #[test]
    fn adaptive_k2_clamps_all_levels() {
        let mut c = RunConfig::defaults("m");
        c.set_levels(vec![2, 8, 32]);
        c.set_ks(vec![4, 8, 32]);
        c.k2_schedule = vec![(5, 2)];
        c.validate().unwrap();
        assert_eq!(c.hier_schedule_at(0).unwrap().intervals(), &[4, 8, 32]);
        assert_eq!(c.hier_schedule_at(5).unwrap().intervals(), &[2, 2, 2]);
    }

    #[test]
    fn pool_threads_and_links_via_json() {
        let mut c = RunConfig::defaults("m");
        let j = Json::parse(
            r#"{"levels": [2, 8, 32], "ks": [2, 8, 32], "collective": "pooled:4",
                "pool_threads": 3, "pool_pin": true, "quiet": true,
                "links": ["intra", "inter", "rack"],
                "alpha_rack": 1e-4, "beta_rack": 1e-9, "backend": "native"}"#,
        )
        .unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.collective, CollectiveKind::Pooled { threads: 4 });
        assert_eq!(c.pool_threads, 3);
        assert!(c.pool_pin);
        assert!(c.quiet);
        assert_eq!(c.cost.alpha_rack, 1e-4);
        c.validate().unwrap();
        let h = c.hierarchy().unwrap();
        assert_eq!(h.link(0), crate::topology::LinkClass::IntraNode);
        assert_eq!(h.link(2), crate::topology::LinkClass::RackFabric);
    }

    #[test]
    fn links_length_mismatch_rejected() {
        let mut c = RunConfig::defaults("m");
        c.set_levels(vec![2, 8, 32]);
        c.set_ks(vec![2, 8, 32]);
        c.links = vec![LinkClass::IntraNode, LinkClass::RackFabric];
        assert!(c.validate().is_err());
        let j = Json::parse(r#"{"links": ["nvlink"]}"#).unwrap();
        assert!(RunConfig::defaults("m").apply_json(&j).is_err());
    }

    #[test]
    fn links_apply_to_two_level_default_shape() {
        let mut c = RunConfig::defaults("m");
        c.links = vec![LinkClass::IntraNode, LinkClass::RackFabric];
        c.validate().unwrap();
        let h = c.hierarchy().unwrap();
        assert_eq!(h.sizes(), &[4, 16]);
        assert_eq!(h.link(1), LinkClass::RackFabric);
    }

    #[test]
    fn from_args_parses_pool_and_link_flags() {
        use crate::util::cli::Args;
        let argv: Vec<String> = [
            "train", "--model", "quickstart", "--backend", "native", "--levels", "2,4,8",
            "--ks", "2,4,8", "--collective", "pooled", "--pool-threads", "5",
            "--pool-pin", "--quiet", "--links", "intra,inter,rack", "--epochs", "2",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(argv, &["record-steps", "pool-pin", "quiet", "help"]).unwrap();
        let cfg = RunConfig::from_args(&args).unwrap();
        assert_eq!(cfg.collective, CollectiveKind::Pooled { threads: 0 });
        assert_eq!(cfg.pool_threads, 5);
        assert!(cfg.pool_pin);
        assert!(cfg.quiet);
        assert_eq!(
            cfg.links,
            vec![LinkClass::IntraNode, LinkClass::InterNode, LinkClass::RackFabric]
        );
        assert_eq!(cfg.hierarchy().unwrap().link(2), LinkClass::RackFabric);
    }

    #[test]
    fn exec_and_het_knobs_via_json_and_args() {
        let mut c = RunConfig::defaults("m");
        let j = Json::parse(
            r#"{"exec": "event", "het": 0.25, "straggler_prob": 0.05,
                "straggler_mult": 6.0, "backend": "native"}"#,
        )
        .unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.exec, ExecKind::Event);
        assert_eq!(c.het, 0.25);
        assert_eq!(c.straggler_prob, 0.05);
        assert_eq!(c.straggler_mult, 6.0);
        c.validate().unwrap();
        let spec = c.het_spec();
        assert!(!spec.is_homogeneous());
        assert_eq!(spec.seed, c.seed);

        use crate::util::cli::Args;
        let argv: Vec<String> = [
            "train", "--model", "quickstart", "--backend", "native", "--exec", "event",
            "--het", "0.1", "--straggler", "0.02:5",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(argv, &["record-steps", "help"]).unwrap();
        let cfg = RunConfig::from_args(&args).unwrap();
        assert_eq!(cfg.exec, ExecKind::Event);
        assert_eq!(cfg.het, 0.1);
        assert_eq!((cfg.straggler_prob, cfg.straggler_mult), (0.02, 5.0));
    }

    #[test]
    fn out_of_range_het_knobs_rejected() {
        let mut c = RunConfig::defaults("m");
        c.exec = ExecKind::Event;
        c.het = -0.5;
        assert!(c.validate().unwrap_err().to_string().contains("--het"));
        let mut c = RunConfig::defaults("m");
        c.exec = ExecKind::Event;
        c.straggler_prob = 1.5;
        assert!(c.validate().unwrap_err().to_string().contains("[0, 1]"));
        let mut c = RunConfig::defaults("m");
        c.exec = ExecKind::Event;
        c.straggler_prob = 0.1;
        c.straggler_mult = 0.25;
        assert!(c.validate().unwrap_err().to_string().contains("multiplier"));
        // heterogeneity without the event model is a contradiction, not a
        // silent no-op
        let mut c = RunConfig::defaults("m");
        c.het = 0.2;
        assert!(c.validate().unwrap_err().to_string().contains("--exec event"));
        // ... and the CLI straggler grammar rejects garbage with context
        use crate::util::cli::Args;
        let argv: Vec<String> =
            ["train", "--straggler", "often"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(argv, &["record-steps", "help"]).unwrap();
        assert!(RunConfig::from_args(&args).is_err());
    }

    #[test]
    fn schedule_policy_via_json_and_args() {
        let mut c = RunConfig::defaults("m");
        let j = Json::parse(r#"{"schedule": "adaptive:0.5", "backend": "native"}"#).unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.schedule_policy, PolicyKind::Adaptive { target: 0.5, gain: 1.0 });
        c.validate().unwrap();

        use crate::util::cli::Args;
        let argv: Vec<String> = [
            "train", "--model", "quickstart", "--backend", "native", "--schedule",
            "warmup:32",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(argv, &["record-steps", "help"]).unwrap();
        let cfg = RunConfig::from_args(&args).unwrap();
        assert_eq!(cfg.schedule_policy, PolicyKind::Warmup { stage_steps: 32 });

        // Unknown policies and out-of-range parameters are rejected with
        // actionable errors, through both entry points.
        let bad = Json::parse(r#"{"schedule": "sometimes"}"#).unwrap();
        assert!(RunConfig::defaults("m").apply_json(&bad).is_err());
        let argv: Vec<String> =
            ["train", "--schedule", "adaptive:-1"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(argv, &["record-steps", "help"]).unwrap();
        let err = RunConfig::from_args(&args).unwrap_err().to_string();
        assert!(err.contains("target"), "unhelpful error: {err}");
        // ... and validate() re-checks programmatically-built configs.
        let mut c = RunConfig::defaults("m");
        c.schedule_policy = PolicyKind::Adaptive { target: f64::NAN, gain: 1.0 };
        assert!(c.validate().is_err());
    }

    #[test]
    fn faults_via_json_and_args() {
        let mut c = RunConfig::defaults("m");
        let j = Json::parse(
            r#"{"exec": "event", "faults": "0.01:30", "backend": "native"}"#,
        )
        .unwrap();
        c.apply_json(&j).unwrap();
        c.validate().unwrap();
        let spec = c.faults.as_ref().unwrap().sampled().unwrap();
        assert_eq!((spec.prob, spec.mttr), (0.01, 30));

        use crate::util::cli::Args;
        let argv: Vec<String> = [
            "train", "--model", "quickstart", "--backend", "native", "--exec", "event",
            "--faults", "trace:5@0x10",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(argv, &["record-steps", "help"]).unwrap();
        let cfg = RunConfig::from_args(&args).unwrap();
        match cfg.faults.as_ref().unwrap() {
            FaultPlan::Scripted(events) => {
                assert_eq!(events.len(), 1);
                assert_eq!((events[0].step, events[0].learner, events[0].down_steps), (5, 0, 10));
            }
            other => panic!("expected a scripted trace, got {other:?}"),
        }
    }

    #[test]
    fn fault_knobs_rejected_with_actionable_errors() {
        // faults without the event model is a contradiction, not a no-op
        let mut c = RunConfig::defaults("m");
        c.faults = Some(parse_faults("0.1").unwrap());
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("--exec event"), "unhelpful error: {err}");
        // out-of-range hazard probability
        let mut c = RunConfig::defaults("m");
        c.exec = ExecKind::Event;
        c.faults = Some(parse_faults("1.5:10").unwrap());
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("[0, 1]"), "unhelpful error: {err}");
        // zero repair time
        let mut c = RunConfig::defaults("m");
        c.exec = ExecKind::Event;
        c.faults = Some(parse_faults("0.1:0").unwrap());
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("mttr"), "unhelpful error: {err}");
        // a trace naming a learner the fleet doesn't have
        let mut c = RunConfig::defaults("m");
        c.exec = ExecKind::Event;
        c.faults = Some(parse_faults("trace:5@99x10").unwrap());
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("99") && err.contains("--p"), "unhelpful error: {err}");
        // ... and the CLI grammar rejects garbage with context
        use crate::util::cli::Args;
        let argv: Vec<String> =
            ["train", "--faults", "often"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(argv, &["record-steps", "help"]).unwrap();
        let err = RunConfig::from_args(&args).unwrap_err().to_string();
        assert!(err.contains("PROB"), "unhelpful error: {err}");
    }

    #[test]
    fn compress_via_json_and_args() {
        let mut c = RunConfig::defaults("m");
        let j = Json::parse(r#"{"compress": "topk:0.05", "backend": "native"}"#).unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.compress, Compression::TopK { ratio: 0.05, ef: true });
        c.validate().unwrap();

        use crate::util::cli::Args;
        let argv: Vec<String> = [
            "train", "--model", "quickstart", "--backend", "native", "--compress", "q4:noef",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(argv, &["record-steps", "help"]).unwrap();
        let cfg = RunConfig::from_args(&args).unwrap();
        assert_eq!(cfg.compress, Compression::Q4 { ef: false });

        // bad specs are rejected with context through both entry points
        let bad = Json::parse(r#"{"compress": "topk:2"}"#).unwrap();
        assert!(RunConfig::defaults("m").apply_json(&bad).is_err());
        let argv: Vec<String> =
            ["train", "--compress", "zip"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(argv, &["record-steps", "help"]).unwrap();
        let err = RunConfig::from_args(&args).unwrap_err().to_string();
        assert!(err.contains("compression"), "unhelpful error: {err}");
    }

    #[test]
    fn k2_clamp_matches_theory_threshold() {
        let c = RunConfig::defaults("m");
        let clamp = c.k2_clamp(16);
        let mut bp = crate::theory::BoundParams::default();
        bp.p = c.p as f64;
        bp.b = 16.0;
        assert!(bp.condition_35(clamp));
        assert!(!bp.condition_35(clamp + 1));
    }

    #[test]
    fn from_args_parses_n_level_flags() {
        use crate::util::cli::Args;
        let argv: Vec<String> = [
            "train", "--model", "quickstart", "--backend", "native", "--levels", "2,4,8",
            "--ks", "2,4,8", "--collective", "sharded", "--epochs", "2",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(argv, &["record-steps", "help"]).unwrap();
        let cfg = RunConfig::from_args(&args).unwrap();
        assert_eq!(cfg.p, 8);
        assert_eq!(cfg.hierarchy().unwrap().sizes(), &[2, 4, 8]);
        assert_eq!(cfg.hier_schedule().unwrap().intervals(), &[2, 4, 8]);
        assert_eq!(cfg.collective, CollectiveKind::Sharded { threads: 0 });
    }
}
