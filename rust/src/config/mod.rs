//! Typed run configuration: the single description of a training run the
//! CLI, examples, repro harness and tests all share.  Loadable from a JSON
//! config file (configs/*.json) with CLI overrides.

use anyhow::{bail, Context, Result};

use crate::algorithms::HierAvgSchedule;
use crate::comm::{CostModel, ReduceStrategy};
use crate::optimizer::LrSchedule;
use crate::topology::Topology;
use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT HLO artifacts through PJRT (the production path).
    Xla,
    /// Pure-Rust MLP (tests / fast sweeps).
    Native,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "xla" => Ok(BackendKind::Xla),
            "native" => Ok(BackendKind::Native),
            _ => bail!("unknown backend {s:?} (xla|native)"),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    /// Model name from artifacts/manifest.json (or native dims for the
    /// native backend).
    pub model: String,
    pub p: usize,
    pub s: usize,
    pub k1: u64,
    pub k2: u64,
    pub epochs: usize,
    /// Nominal training-set size; steps/epoch = train_n / (P·B).
    pub train_n: usize,
    pub test_n: usize,
    pub lr: LrSchedule,
    pub momentum: f32,
    pub weight_decay: f32,
    pub backend: BackendKind,
    pub strategy: ReduceStrategy,
    pub seed: u64,
    /// Dataset difficulty (classification).
    pub noise: f32,
    pub radius: f32,
    /// Sub-clusters per class (non-convex structure; see data::MixtureSpec).
    pub subclusters: usize,
    /// Label-noise probability (keeps gradient variance M > 0).
    pub label_noise: f32,
    /// Adaptive-K2 milestones (paper §3.3: "adaptive choice of K2 may be
    /// better"): at each (epoch, k2) the global interval switches to k2.
    pub k2_schedule: Vec<(usize, u64)>,
    /// Evaluate every `eval_every` epochs (always at the last).
    pub eval_every: usize,
    /// Record the per-step loss curve.
    pub record_steps: bool,
    /// Record every reduction event (step, kind, modelled seconds).
    pub record_trace: bool,
    /// Keep the final averaged parameters in the RunRecord (for
    /// checkpointing / warm starts).
    pub keep_final_params: bool,
    /// Warm-start from a checkpoint saved with `checkpoint::save`.
    pub init_params: Option<String>,
    pub cost: CostModel,
}

impl RunConfig {
    pub fn defaults(model: &str) -> RunConfig {
        RunConfig {
            model: model.to_string(),
            p: 16,
            s: 4,
            k1: 4,
            k2: 32,
            epochs: 20,
            train_n: 4096,
            test_n: 1024,
            lr: LrSchedule::StepDecay { initial: 0.1, milestones: vec![(15, 0.01)] },
            momentum: 0.0,
            weight_decay: 0.0,
            backend: BackendKind::Xla,
            strategy: ReduceStrategy::Ring,
            seed: 42,
            noise: 1.4,
            radius: 1.0,
            subclusters: 8,
            label_noise: 0.05,
            k2_schedule: Vec::new(),
            eval_every: 1,
            record_steps: false,
            record_trace: false,
            keep_final_params: false,
            init_params: None,
            cost: CostModel::default(),
        }
    }

    pub fn topology(&self) -> Result<Topology> {
        Topology::new(self.p, self.s)
    }

    pub fn schedule(&self) -> Result<HierAvgSchedule> {
        HierAvgSchedule::new(self.k1, self.k2)
    }

    /// Effective K2 at an epoch under the adaptive schedule.
    pub fn k2_at(&self, epoch: usize) -> u64 {
        let mut k2 = self.k2;
        for &(e, v) in &self.k2_schedule {
            if epoch >= e {
                k2 = v;
            }
        }
        k2
    }

    /// Effective averaging schedule at an epoch (K1 clamps to K2).
    pub fn schedule_at(&self, epoch: usize) -> Result<HierAvgSchedule> {
        let k2 = self.k2_at(epoch);
        HierAvgSchedule::new(self.k1.min(k2), k2)
    }

    pub fn validate(&self) -> Result<()> {
        self.topology()?;
        self.schedule()?;
        for &(e, _) in &self.k2_schedule {
            self.schedule_at(e)?;
        }
        if self.epochs == 0 || self.train_n == 0 {
            bail!("epochs and train_n must be positive");
        }
        Ok(())
    }

    /// A short identifier for logs and CSV columns.
    pub fn label(&self) -> String {
        format!(
            "{}-p{}-s{}-k1_{}-k2_{}",
            self.model, self.p, self.s, self.k1, self.k2
        )
    }

    /// Load from a JSON file then apply `apply_json` overrides.
    pub fn from_json_file(path: &std::path::Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let j = Json::parse(&text)?;
        let model = j.req("model")?.as_str()?.to_string();
        let mut cfg = RunConfig::defaults(&model);
        cfg.apply_json(&j)?;
        Ok(cfg)
    }

    pub fn apply_json(&mut self, j: &Json) -> Result<()> {
        let obj = j.as_obj()?;
        for (k, v) in obj {
            match k.as_str() {
                "model" => self.model = v.as_str()?.to_string(),
                "p" => self.p = v.as_usize()?,
                "s" => self.s = v.as_usize()?,
                "k1" => self.k1 = v.as_usize()? as u64,
                "k2" => self.k2 = v.as_usize()? as u64,
                "epochs" => self.epochs = v.as_usize()?,
                "train_n" => self.train_n = v.as_usize()?,
                "test_n" => self.test_n = v.as_usize()?,
                "lr" => self.lr = LrSchedule::parse(v.as_str()?)?,
                "momentum" => self.momentum = v.as_f64()? as f32,
                "weight_decay" => self.weight_decay = v.as_f64()? as f32,
                "backend" => self.backend = BackendKind::parse(v.as_str()?)?,
                "strategy" => {
                    self.strategy = ReduceStrategy::parse(v.as_str()?)
                        .ok_or_else(|| anyhow::anyhow!("bad strategy"))?
                }
                "seed" => self.seed = v.as_usize()? as u64,
                "noise" => self.noise = v.as_f64()? as f32,
                "radius" => self.radius = v.as_f64()? as f32,
                "subclusters" => self.subclusters = v.as_usize()?,
                "label_noise" => self.label_noise = v.as_f64()? as f32,
                "k2_schedule" => {
                    self.k2_schedule = v
                        .as_arr()?
                        .iter()
                        .map(|m| {
                            let pair = m.as_arr()?;
                            anyhow::ensure!(pair.len() == 2, "k2_schedule entries are [epoch, k2]");
                            Ok((pair[0].as_usize()?, pair[1].as_usize()? as u64))
                        })
                        .collect::<Result<Vec<_>>>()?
                }
                "eval_every" => self.eval_every = v.as_usize()?,
                "record_steps" => self.record_steps = v.as_bool()?,
                "record_trace" => self.record_trace = v.as_bool()?,
                "init_params" => self.init_params = Some(v.as_str()?.to_string()),
                "alpha_intra" => self.cost.alpha_intra = v.as_f64()?,
                "beta_intra" => self.cost.beta_intra = v.as_f64()?,
                "alpha_inter" => self.cost.alpha_inter = v.as_f64()?,
                "beta_inter" => self.cost.beta_inter = v.as_f64()?,
                other => bail!("unknown config key {other:?}"),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        RunConfig::defaults("resnet18_sim").validate().unwrap();
    }

    #[test]
    fn invalid_combinations_rejected() {
        let mut c = RunConfig::defaults("m");
        c.p = 10;
        c.s = 4;
        assert!(c.validate().is_err());
        let mut c = RunConfig::defaults("m");
        c.k1 = 9;
        c.k2 = 8;
        assert!(c.validate().is_err());
    }

    #[test]
    fn json_override() {
        let mut c = RunConfig::defaults("m");
        let j = Json::parse(
            r#"{"p": 32, "k1": 2, "k2": 8, "lr": "const:0.05", "backend": "native",
                "strategy": "tree", "record_steps": true}"#,
        )
        .unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.p, 32);
        assert_eq!(c.k2, 8);
        assert_eq!(c.lr, LrSchedule::Constant(0.05));
        assert_eq!(c.backend, BackendKind::Native);
        assert_eq!(c.strategy, ReduceStrategy::Tree);
        assert!(c.record_steps);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = RunConfig::defaults("m");
        let j = Json::parse(r#"{"bogus": 1}"#).unwrap();
        assert!(c.apply_json(&j).is_err());
    }

    #[test]
    fn label_is_stable() {
        let c = RunConfig::defaults("resnet18_sim");
        assert_eq!(c.label(), "resnet18_sim-p16-s4-k1_4-k2_32");
    }
}
