//! Run records: per-epoch curves, communication accounting, CSV/JSON emit.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::algorithms::ScheduleSummary;
use crate::comm::{CommStats, LevelStats};
use crate::util::json::Json;

#[derive(Debug, Clone, Copy, Default)]
pub struct EpochStats {
    pub epoch: usize,
    pub train_loss: f64,
    pub train_acc: f64,
    pub test_loss: f64,
    pub test_acc: f64,
    /// Modelled (simulated-cluster) seconds elapsed so far: the execution
    /// model's clock (lockstep: compute + comm; event: the timeline's
    /// makespan across learners).
    pub sim_seconds: f64,
    /// Real wall seconds spent so far in this process.
    pub wall_seconds: f64,
}

/// One reduction event on the modelled cluster timeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    pub step: u64,
    /// 'L' local (per-cluster), 'G' global.
    pub kind: char,
    /// Modelled seconds this event cost.
    pub seconds: f64,
}

/// What the elastic fault layer (`--faults`) did over a run: membership
/// events, their parameter-side recoveries, and the time they cost
/// (the JSON `faults` block; absent when the layer is off, which keeps
/// fault-free records byte-identical to pre-fault builds).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultSummary {
    /// Canonical `--faults` spec the run was configured with
    /// (`sim::FaultPlan::spec`).
    pub spec: String,
    /// Up→down membership edges (learners preempted).
    pub preemptions: u64,
    /// Down→up membership edges (repaired learners rejoining).
    pub reentries: u64,
    /// Parameter restores from the latest checkpoint on re-entry.
    pub checkpoint_restores: u64,
    /// Learners the schedule policy migrated to outermost-only cadence.
    pub migrations: u64,
    /// Groups that reduced degraded (survivor-only barriers).
    pub survivor_reductions: u64,
    /// Modelled seconds lost to outages: down time plus re-entry restore
    /// surcharges, summed over learners (the timeline's `lost` account).
    pub lost_seconds: f64,
    /// Final membership version (one bump per preemption / re-entry /
    /// migration; checkpoint sidecars persist it).
    pub membership_epoch: u64,
}

/// What payload compression (`--compress`) did over a run: the wire
/// format's per-message byte count, the on-wire vs dense totals, and the
/// error-feedback mass still held locally at end of run (the JSON
/// `compression` block; absent when compression is off, which keeps
/// dense records byte-identical to pre-compression builds).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CompressionSummary {
    /// Canonical `--compress` spec (`comm::Compression::spec`).
    pub spec: String,
    /// Bytes one learner's compressed message occupies on the wire
    /// (`Compression::payload_bytes`; the dense equivalent is
    /// `4 · n_params`).
    pub payload_bytes: u64,
    /// Dense per-message bytes (`4 · n_params`), the savings baseline.
    pub dense_payload_bytes: u64,
    /// Total bytes the run's reductions moved under compression (equals
    /// the `comm` block's byte totals; repeated here next to its
    /// denominator).
    pub compressed_bytes: u64,
    /// What the same reduction events would have moved densely.
    pub dense_bytes: u64,
    /// L2 norm of the error-feedback residuals across all learners at end
    /// of run: the un-transmitted mass (0 exactly when `ef` is off).
    pub residual_l2: f64,
}

#[derive(Debug, Clone, Default)]
pub struct RunRecord {
    pub label: String,
    pub epochs: Vec<EpochStats>,
    /// Optional per-step training loss (mean across learners) for
    /// fine-grained curves (the e2e example logs this).
    pub step_loss: Vec<f32>,
    pub comm: CommStats,
    /// Per-hierarchy-level reduction accounts (index = level, 0 =
    /// innermost; filled by the engine, one entry per topology level).
    pub comm_levels: Vec<LevelStats>,
    /// Link-class name (`intra` / `inter` / `rack`) per hierarchy level,
    /// parallel to `comm_levels` (filled by the trainer from the
    /// topology; surfaces `--links` overrides in the JSON output).
    pub level_links: Vec<String>,
    pub total_steps: u64,
    /// Base-rate compute seconds (steps × `sim_step_seconds`; the
    /// homogeneous-compute floor, independent of the execution model).
    pub sim_compute_seconds: f64,
    /// Reduction-event trace (populated when `record_trace` is set).
    pub trace: Vec<TraceEvent>,
    /// Final averaged parameters (populated when `keep_final_params`).
    pub final_params: Option<crate::params::FlatParams>,
    /// Execution model that accounted the run's virtual time
    /// (`lockstep` / `event`; `sim::ExecKind::name`).
    pub exec_model: String,
    /// Modelled wall clock of the run: the timeline's makespan (max over
    /// learner clocks).  Under lockstep this equals compute + comm; under
    /// the event model it reflects per-learner rates, straggler spikes,
    /// and barrier waits.
    pub makespan_seconds: f64,
    /// Per-learner compute seconds (rate ramp and spikes included).
    pub busy_seconds: Vec<f64>,
    /// Per-learner seconds spent blocked at barriers for slower peers.
    pub blocked_seconds: Vec<f64>,
    /// Per-learner `makespan − own clock` tail.
    pub idle_seconds: Vec<f64>,
    /// Barrier wait seconds attributed to each hierarchy level (parallel
    /// to `comm_levels`): where the straggler tax is actually paid.
    pub level_stall_seconds: Vec<f64>,
    /// Straggler spikes that fired over the run.
    pub straggler_events: u64,
    /// What the schedule policy decided: realized per-level reduction
    /// events, the interval trajectory, and the controller's serializable
    /// state (filled by the trainer; `None` for runners without the
    /// policy layer, e.g. ASGD).
    pub schedule: Option<ScheduleSummary>,
    /// What the elastic fault layer did (filled by the trainer; `None`
    /// when `--faults` is off, so fault-free JSON is byte-identical to
    /// pre-fault builds).
    pub faults: Option<FaultSummary>,
    /// What payload compression did (filled by the trainer; `None` when
    /// `--compress` is off, so dense JSON is byte-identical to
    /// pre-compression builds).
    pub compression: Option<CompressionSummary>,
}

/// Above this learner count, `RunRecord` JSON replaces the per-learner
/// busy/blocked/idle vectors with min/mean/max/p99 summaries — three
/// million-entry f64 arrays are not a report, they are a dump.  At or
/// below it the exact vectors are emitted, so every existing golden
/// (P ≤ 64) serializes byte-identically.
pub const EXEC_VECTOR_P_LIMIT: usize = 4096;

/// Distribution summary of one per-learner timeline vector:
/// `{min, mean, max, p99}` (p99 = nearest-rank over a total_cmp sort).
fn summary_json(xs: &[f64]) -> Json {
    let mut o = Json::obj();
    if xs.is_empty() {
        return o;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(f64::total_cmp);
    let n = sorted.len();
    let p99 = sorted[((n as f64 * 0.99).ceil() as usize).clamp(1, n) - 1];
    o.set("min", Json::from(sorted[0]))
        .set("mean", Json::from(xs.iter().sum::<f64>() / n as f64))
        .set("max", Json::from(sorted[n - 1]))
        .set("p99", Json::from(p99));
    o
}

impl RunRecord {
    pub fn last(&self) -> Option<&EpochStats> {
        self.epochs.last()
    }

    pub fn best_test_acc(&self) -> f64 {
        self.epochs.iter().map(|e| e.test_acc).fold(0.0, f64::max)
    }

    pub fn final_test_acc(&self) -> f64 {
        self.last().map(|e| e.test_acc).unwrap_or(0.0)
    }

    pub fn final_train_loss(&self) -> f64 {
        self.last().map(|e| e.train_loss).unwrap_or(f64::NAN)
    }

    /// Modelled total time = compute + communication.
    pub fn sim_total_seconds(&self) -> f64 {
        self.sim_compute_seconds + self.comm.total_seconds()
    }

    /// The single JSON builder behind [`RunRecord::to_json`] and
    /// [`RunRecord::to_golden_json`] — any new field lands in both views
    /// (object keys are BTreeMap-sorted, so conditional insertion order
    /// never changes the output).
    fn json_record(&self, include_wall: bool, include_trace: bool) -> Json {
        let mut epochs = Vec::new();
        for e in &self.epochs {
            let mut o = Json::obj();
            o.set("epoch", Json::from(e.epoch))
                .set("train_loss", Json::from(e.train_loss))
                .set("train_acc", Json::from(e.train_acc))
                .set("test_loss", Json::from(e.test_loss))
                .set("test_acc", Json::from(e.test_acc))
                .set("sim_seconds", Json::from(e.sim_seconds));
            if include_wall {
                o.set("wall_seconds", Json::from(e.wall_seconds));
            }
            epochs.push(o);
        }
        let mut comm = Json::obj();
        comm.set("local_reductions", Json::from(self.comm.local_reductions as usize))
            .set("global_reductions", Json::from(self.comm.global_reductions as usize))
            .set("rack_reductions", Json::from(self.comm.rack_reductions as usize))
            .set("local_bytes", Json::from(self.comm.local_bytes as usize))
            .set("global_bytes", Json::from(self.comm.global_bytes as usize))
            .set("rack_bytes", Json::from(self.comm.rack_bytes as usize))
            .set("local_seconds", Json::from(self.comm.local_seconds))
            .set("global_seconds", Json::from(self.comm.global_seconds))
            .set("rack_seconds", Json::from(self.comm.rack_seconds));
        let mut comm_levels = Vec::new();
        for (i, l) in self.comm_levels.iter().enumerate() {
            let mut o = Json::obj();
            o.set("level", Json::from(i))
                .set("reductions", Json::from(l.reductions as usize))
                .set("bytes", Json::from(l.bytes as usize))
                .set("seconds", Json::from(l.seconds));
            if let Some(link) = self.level_links.get(i) {
                o.set("link", Json::from(link.as_str()));
            }
            comm_levels.push(o);
        }
        let mut exec = Json::obj();
        exec.set("model", Json::from(self.exec_model.as_str()))
            .set("makespan_seconds", Json::from(self.makespan_seconds))
            .set("level_stall_seconds", Json::from_f64_slice(&self.level_stall_seconds))
            .set("straggler_events", Json::from(self.straggler_events as usize));
        if self.busy_seconds.len() > EXEC_VECTOR_P_LIMIT {
            // A million-learner record would serialize three million f64s
            // here; above the limit the per-learner vectors collapse to
            // distribution summaries.  Below it the exact vectors are kept,
            // so existing goldens (P <= 64) are untouched.
            exec.set("p", Json::from(self.busy_seconds.len()))
                .set("busy_seconds_summary", summary_json(&self.busy_seconds))
                .set("blocked_seconds_summary", summary_json(&self.blocked_seconds))
                .set("idle_seconds_summary", summary_json(&self.idle_seconds));
        } else {
            exec.set("busy_seconds", Json::from_f64_slice(&self.busy_seconds))
                .set("blocked_seconds", Json::from_f64_slice(&self.blocked_seconds))
                .set("idle_seconds", Json::from_f64_slice(&self.idle_seconds));
        }
        let mut o = Json::obj();
        o.set("label", Json::from(self.label.as_str()))
            .set("epochs", Json::Arr(epochs))
            .set("comm", comm)
            .set("comm_levels", Json::Arr(comm_levels))
            .set("exec", exec);
        if let Some(s) = &self.schedule {
            let mut changes = Vec::with_capacity(s.changes.len());
            for c in &s.changes {
                let mut e = Json::obj();
                e.set("step", Json::from(c.step as usize)).set(
                    "intervals",
                    Json::Arr(c.intervals.iter().map(|&k| Json::from(k as usize)).collect()),
                );
                changes.push(e);
            }
            let mut sch = Json::obj();
            sch.set("policy", Json::from(s.policy.as_str()))
                .set(
                    "realized",
                    Json::Arr(s.realized.iter().map(|&v| Json::from(v as usize)).collect()),
                )
                .set(
                    "final_intervals",
                    Json::Arr(
                        s.final_intervals.iter().map(|&k| Json::from(k as usize)).collect(),
                    ),
                )
                .set("k2_clamp", Json::from(s.k2_clamp as usize))
                .set("adaptations", Json::Arr(changes))
                .set("state", s.state.clone());
            o.set("schedule", sch);
        }
        if let Some(f) = &self.faults {
            let mut fb = Json::obj();
            fb.set("spec", Json::from(f.spec.as_str()))
                .set("preemptions", Json::from(f.preemptions as usize))
                .set("reentries", Json::from(f.reentries as usize))
                .set("checkpoint_restores", Json::from(f.checkpoint_restores as usize))
                .set("migrations", Json::from(f.migrations as usize))
                .set("survivor_reductions", Json::from(f.survivor_reductions as usize))
                .set("lost_seconds", Json::from(f.lost_seconds))
                .set("membership_epoch", Json::from(f.membership_epoch as usize));
            o.set("faults", fb);
        }
        if let Some(c) = &self.compression {
            let mut cb = Json::obj();
            cb.set("spec", Json::from(c.spec.as_str()))
                .set("payload_bytes", Json::from(c.payload_bytes as usize))
                .set("dense_payload_bytes", Json::from(c.dense_payload_bytes as usize))
                .set("compressed_bytes", Json::from(c.compressed_bytes as usize))
                .set("dense_bytes", Json::from(c.dense_bytes as usize))
                .set("residual_l2", Json::from(c.residual_l2));
            o.set("compression", cb);
        }
        o.set("total_steps", Json::from(self.total_steps as usize))
            .set("sim_compute_seconds", Json::from(self.sim_compute_seconds))
            .set("sim_total_seconds", Json::from(self.sim_total_seconds()))
            .set(
                "step_loss",
                Json::Arr(self.step_loss.iter().map(|&l| Json::Num(l as f64)).collect()),
            );
        if include_trace {
            let mut trace = Vec::with_capacity(self.trace.len());
            for t in &self.trace {
                let mut e = Json::obj();
                e.set("step", Json::from(t.step as usize))
                    .set("kind", Json::from(t.kind.to_string()))
                    .set("seconds", Json::from(t.seconds));
                trace.push(e);
            }
            o.set("trace", Json::Arr(trace));
        }
        o
    }

    pub fn to_json(&self) -> Json {
        self.json_record(true, false)
    }

    /// The deterministic view of [`RunRecord::to_json`] used by the
    /// golden-trace regression suite (rust/tests/golden_trace.rs): drops
    /// the wall-clock fields (the only nondeterministic ones) and appends
    /// the reduction-event trace, so two bit-identical runs serialize to
    /// byte-identical JSON on any host.  The `exec` block (timeline
    /// breakdown) is included: under homogeneous compute it is identical
    /// across `lockstep`/`event` except for the `model` name — the
    /// equivalence the golden suite pins.  Callers must ensure no epoch
    /// skipped its eval (`eval_every = 1`): NaN placeholders are not
    /// representable in JSON.
    pub fn to_golden_json(&self) -> Json {
        self.json_record(false, true)
    }

    pub fn write_json(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().pretty())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Write the reduction trace as JSON-lines (one event per line).
    pub fn write_trace_jsonl(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = String::new();
        for e in &self.trace {
            let mut o = Json::obj();
            o.set("step", Json::from(e.step as usize))
                .set("kind", Json::from(e.kind.to_string()))
                .set("seconds", Json::from(e.seconds));
            o.write_compact(&mut out);
            out.push('\n');
        }
        std::fs::write(path, out).with_context(|| format!("writing {}", path.display()))
    }

    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        writeln!(
            f,
            "epoch,train_loss,train_acc,test_loss,test_acc,sim_seconds,wall_seconds"
        )?;
        for e in &self.epochs {
            writeln!(
                f,
                "{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.3}",
                e.epoch, e.train_loss, e.train_acc, e.test_loss, e.test_acc, e.sim_seconds,
                e.wall_seconds
            )?;
        }
        Ok(())
    }
}

/// Write a set of runs as one wide CSV keyed by epoch (for figure series).
pub fn write_series_csv(path: &Path, runs: &[&RunRecord], column: &str) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    let mut header = String::from("epoch");
    for r in runs {
        header.push(',');
        header.push_str(&r.label);
    }
    writeln!(f, "{header}")?;
    let n = runs.iter().map(|r| r.epochs.len()).max().unwrap_or(0);
    for i in 0..n {
        let mut line = format!("{}", i);
        for r in runs {
            line.push(',');
            if let Some(e) = r.epochs.get(i) {
                let v = match column {
                    "train_loss" => e.train_loss,
                    "train_acc" => e.train_acc,
                    "test_loss" => e.test_loss,
                    "test_acc" => e.test_acc,
                    "sim_seconds" => e.sim_seconds,
                    _ => f64::NAN,
                };
                line.push_str(&format!("{v:.6}"));
            }
        }
        writeln!(f, "{line}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(label: &str, n: usize) -> RunRecord {
        RunRecord {
            label: label.into(),
            epochs: (0..n)
                .map(|i| EpochStats {
                    epoch: i,
                    train_loss: 1.0 / (i + 1) as f64,
                    test_acc: 0.5 + i as f64 * 0.1,
                    ..Default::default()
                })
                .collect(),
            ..Default::default()
        }
    }

    #[test]
    fn best_and_final() {
        let r = record("a", 4);
        assert!((r.best_test_acc() - 0.8).abs() < 1e-12);
        assert!((r.final_test_acc() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrips() {
        let r = record("x", 3);
        let j = r.to_json();
        let parsed = Json::parse(&j.pretty()).unwrap();
        assert_eq!(parsed.req("label").unwrap().as_str().unwrap(), "x");
        assert_eq!(parsed.req("epochs").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn golden_json_drops_wall_clock_and_keeps_trace() {
        let mut r = record("g", 2);
        r.epochs[0].wall_seconds = 123.0;
        r.trace.push(TraceEvent { step: 4, kind: 'L', seconds: 0.5 });
        r.trace.push(TraceEvent { step: 8, kind: 'G', seconds: 1.5 });
        let j = r.to_golden_json();
        let parsed = Json::parse(&j.pretty()).unwrap();
        let epochs = parsed.req("epochs").unwrap().as_arr().unwrap();
        assert!(epochs[0].get("wall_seconds").is_none());
        let trace = parsed.req("trace").unwrap().as_arr().unwrap();
        assert_eq!(trace.len(), 2);
        assert_eq!(trace[1].req("kind").unwrap().as_str().unwrap(), "G");
        assert_eq!(trace[1].req("step").unwrap().as_usize().unwrap(), 8);
        // Differing wall clocks serialize identically.
        let mut r2 = r.clone();
        r2.epochs[0].wall_seconds = 456.0;
        assert_eq!(r.to_golden_json().pretty(), r2.to_golden_json().pretty());
    }

    #[test]
    fn exec_breakdown_serializes() {
        let mut r = record("e", 1);
        r.exec_model = "event".into();
        r.makespan_seconds = 2.5;
        r.busy_seconds = vec![1.0, 1.5];
        r.blocked_seconds = vec![0.5, 0.0];
        r.idle_seconds = vec![0.0, 0.25];
        r.level_stall_seconds = vec![0.1, 0.4];
        r.straggler_events = 3;
        for j in [r.to_json(), r.to_golden_json()] {
            let parsed = Json::parse(&j.pretty()).unwrap();
            let e = parsed.req("exec").unwrap();
            assert_eq!(e.req("model").unwrap().as_str().unwrap(), "event");
            assert_eq!(e.req("makespan_seconds").unwrap().as_f64().unwrap(), 2.5);
            assert_eq!(e.req("busy_seconds").unwrap().as_arr().unwrap().len(), 2);
            assert_eq!(
                e.req("level_stall_seconds").unwrap().as_arr().unwrap()[1]
                    .as_f64()
                    .unwrap(),
                0.4
            );
            assert_eq!(e.req("straggler_events").unwrap().as_usize().unwrap(), 3);
        }
    }

    #[test]
    fn exec_breakdown_summarizes_above_p_limit() {
        let p = EXEC_VECTOR_P_LIMIT + 1;
        let mut r = record("big", 1);
        r.exec_model = "event".into();
        r.busy_seconds = (0..p).map(|j| j as f64).collect();
        r.blocked_seconds = vec![0.0; p];
        r.idle_seconds = vec![0.25; p];
        for j in [r.to_json(), r.to_golden_json()] {
            let parsed = Json::parse(&j.pretty()).unwrap();
            let e = parsed.req("exec").unwrap();
            assert!(e.get("busy_seconds").is_none());
            assert!(e.get("blocked_seconds").is_none());
            assert!(e.get("idle_seconds").is_none());
            assert_eq!(e.req("p").unwrap().as_usize().unwrap(), p);
            let busy = e.req("busy_seconds_summary").unwrap();
            assert_eq!(busy.req("min").unwrap().as_f64().unwrap(), 0.0);
            assert_eq!(busy.req("max").unwrap().as_f64().unwrap(), (p - 1) as f64);
            let mean = busy.req("mean").unwrap().as_f64().unwrap();
            assert!((mean - (p - 1) as f64 / 2.0).abs() < 1e-6, "{mean}");
            let p99 = busy.req("p99").unwrap().as_f64().unwrap();
            assert!(p99 > 0.98 * p as f64 && p99 <= (p - 1) as f64, "{p99}");
            assert_eq!(
                e.req("idle_seconds_summary").unwrap().req("p99").unwrap().as_f64().unwrap(),
                0.25
            );
        }
        // At the limit exactly, the per-learner vectors are still emitted.
        r.busy_seconds.truncate(EXEC_VECTOR_P_LIMIT);
        r.blocked_seconds.truncate(EXEC_VECTOR_P_LIMIT);
        r.idle_seconds.truncate(EXEC_VECTOR_P_LIMIT);
        let parsed = Json::parse(&r.to_json().pretty()).unwrap();
        let e = parsed.req("exec").unwrap();
        assert!(e.get("busy_seconds_summary").is_none());
        assert_eq!(
            e.req("busy_seconds").unwrap().as_arr().unwrap().len(),
            EXEC_VECTOR_P_LIMIT
        );
    }

    #[test]
    fn schedule_block_serializes() {
        use crate::algorithms::{ScheduleChange, ScheduleSummary};
        let mut r = record("s", 1);
        // No policy layer (e.g. ASGD): the block is absent.
        assert!(r.to_json().get("schedule").is_none());
        r.schedule = Some(ScheduleSummary {
            policy: "adaptive:0.25".into(),
            realized: vec![12, 3],
            final_intervals: vec![2, 16],
            k2_clamp: 64,
            changes: vec![ScheduleChange { step: 8, intervals: vec![2, 16] }],
            state: Json::parse(r#"{"offset": 40}"#).unwrap(),
        });
        for j in [r.to_json(), r.to_golden_json()] {
            let parsed = Json::parse(&j.pretty()).unwrap();
            let s = parsed.req("schedule").unwrap();
            assert_eq!(s.req("policy").unwrap().as_str().unwrap(), "adaptive:0.25");
            assert_eq!(s.req("realized").unwrap().usize_arr().unwrap(), vec![12, 3]);
            assert_eq!(s.req("final_intervals").unwrap().usize_arr().unwrap(), vec![2, 16]);
            assert_eq!(s.req("k2_clamp").unwrap().as_usize().unwrap(), 64);
            let ad = s.req("adaptations").unwrap().as_arr().unwrap();
            assert_eq!(ad[0].req("step").unwrap().as_usize().unwrap(), 8);
            assert_eq!(ad[0].req("intervals").unwrap().usize_arr().unwrap(), vec![2, 16]);
            assert_eq!(
                s.req("state").unwrap().req("offset").unwrap().as_usize().unwrap(),
                40
            );
        }
    }

    #[test]
    fn faults_block_serializes_and_absence_changes_nothing() {
        let mut r = record("f", 1);
        // No fault layer: the block is absent and the JSON is what a
        // pre-fault build emitted.
        let plain = r.to_json().pretty();
        assert!(r.to_json().get("faults").is_none());
        r.faults = Some(FaultSummary {
            spec: "0.003:20".into(),
            preemptions: 5,
            reentries: 4,
            checkpoint_restores: 4,
            migrations: 1,
            survivor_reductions: 9,
            lost_seconds: 1.25,
            membership_epoch: 10,
        });
        for j in [r.to_json(), r.to_golden_json()] {
            let parsed = Json::parse(&j.pretty()).unwrap();
            let f = parsed.req("faults").unwrap();
            assert_eq!(f.req("spec").unwrap().as_str().unwrap(), "0.003:20");
            assert_eq!(f.req("preemptions").unwrap().as_usize().unwrap(), 5);
            assert_eq!(f.req("reentries").unwrap().as_usize().unwrap(), 4);
            assert_eq!(f.req("checkpoint_restores").unwrap().as_usize().unwrap(), 4);
            assert_eq!(f.req("migrations").unwrap().as_usize().unwrap(), 1);
            assert_eq!(f.req("survivor_reductions").unwrap().as_usize().unwrap(), 9);
            assert_eq!(f.req("lost_seconds").unwrap().as_f64().unwrap(), 1.25);
            assert_eq!(f.req("membership_epoch").unwrap().as_usize().unwrap(), 10);
        }
        // Clearing the block restores the byte-identical fault-free form.
        r.faults = None;
        assert_eq!(r.to_json().pretty(), plain);
    }

    #[test]
    fn compression_block_serializes_and_absence_changes_nothing() {
        let mut r = record("c", 1);
        // No compression: the block is absent and the JSON is what a
        // pre-compression build emitted.
        let plain = r.to_json().pretty();
        assert!(r.to_json().get("compression").is_none());
        r.compression = Some(CompressionSummary {
            spec: "topk:0.05".into(),
            payload_bytes: 404,
            dense_payload_bytes: 4000,
            compressed_bytes: 80_800,
            dense_bytes: 800_000,
            residual_l2: 1.5,
        });
        for j in [r.to_json(), r.to_golden_json()] {
            let parsed = Json::parse(&j.pretty()).unwrap();
            let c = parsed.req("compression").unwrap();
            assert_eq!(c.req("spec").unwrap().as_str().unwrap(), "topk:0.05");
            assert_eq!(c.req("payload_bytes").unwrap().as_usize().unwrap(), 404);
            assert_eq!(c.req("dense_payload_bytes").unwrap().as_usize().unwrap(), 4000);
            assert_eq!(c.req("compressed_bytes").unwrap().as_usize().unwrap(), 80_800);
            assert_eq!(c.req("dense_bytes").unwrap().as_usize().unwrap(), 800_000);
            assert_eq!(c.req("residual_l2").unwrap().as_f64().unwrap(), 1.5);
        }
        // Clearing the block restores the byte-identical dense form.
        r.compression = None;
        assert_eq!(r.to_json().pretty(), plain);
    }

    #[test]
    fn csv_files() {
        let dir = std::env::temp_dir().join("hier_avg_metrics_test");
        let r = record("a", 2);
        let p = dir.join("run.csv");
        r.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.lines().count() == 3);
        let r2 = record("b", 2);
        let sp = dir.join("series.csv");
        write_series_csv(&sp, &[&r, &r2], "test_acc").unwrap();
        let s = std::fs::read_to_string(&sp).unwrap();
        assert!(s.starts_with("epoch,a,b"));
    }
}
