//! Local optimizer (applied by each learner between reductions) and
//! learning-rate schedules.
//!
//! The AOT train-step artifacts return *gradients*; the update lives here
//! at L3 so schedules / momentum / weight decay are coordinator concerns,
//! matching the paper's harness (plain SGD, step-decayed LR: 0.1 → 0.01
//! after 150 of 200 epochs, §4).

use anyhow::{bail, Result};

use crate::params::ParamArena;
use crate::util::simd;

/// Plain SGD with optional Polyak momentum and decoupled weight decay.
/// Momentum buffers are per-learner (they are NOT averaged by reductions —
/// only parameters are exchanged, as in the paper and standard local-SGD
/// implementations).
#[derive(Debug, Clone)]
pub struct Sgd {
    pub momentum: f32,
    pub weight_decay: f32,
    velocity: Option<Vec<f32>>,
}

impl Sgd {
    pub fn new(momentum: f32, weight_decay: f32, n_params: usize) -> Sgd {
        let velocity = if momentum != 0.0 { Some(vec![0.0; n_params]) } else { None };
        Sgd { momentum, weight_decay, velocity }
    }

    pub fn plain() -> Sgd {
        Sgd { momentum: 0.0, weight_decay: 0.0, velocity: None }
    }

    /// One update: `w -= lr * (g + wd*w)` (or the momentum form).
    /// Hot loop — plain slice arithmetic, auto-vectorized.
    pub fn apply(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        debug_assert_eq!(params.len(), grads.len());
        let wd = self.weight_decay;
        match &mut self.velocity {
            None => {
                if wd == 0.0 {
                    for (w, g) in params.iter_mut().zip(grads) {
                        *w -= lr * g;
                    }
                } else {
                    for (w, g) in params.iter_mut().zip(grads) {
                        *w -= lr * (g + wd * *w);
                    }
                }
            }
            Some(v) => {
                let mu = self.momentum;
                for ((w, g), vel) in params.iter_mut().zip(grads).zip(v.iter_mut()) {
                    let eff = g + wd * *w;
                    *vel = mu * *vel + eff;
                    *w -= lr * *vel;
                }
            }
        }
    }
}

/// The fleet's optimizer state as one flat arena: all learners share the
/// hyperparameters (the trainer constructs identical `Sgd`s per learner
/// anyway), and the per-learner momentum buffers live in a single
/// `ParamArena` row-aligned with the replica/grad arenas — so first-touch
/// page placement and row-granular pool chunking cover optimizer state
/// too, and the velocity allocation happens once instead of P times.
///
/// `apply_row(j, ..)` performs exactly `Sgd::apply`'s operation sequence
/// on row `j` via the `util::simd` fused kernels (bit-identical to the
/// scalar loops by the summation-order contract), so a fleet stepped
/// through `SgdPool` matches a fleet of per-learner `Sgd`s bit for bit.
#[derive(Debug, Clone)]
pub struct SgdPool {
    pub momentum: f32,
    pub weight_decay: f32,
    velocity: Option<ParamArena>,
}

impl SgdPool {
    pub fn new(momentum: f32, weight_decay: f32, rows: usize, n_params: usize) -> SgdPool {
        let velocity =
            if momentum != 0.0 { Some(ParamArena::zeroed(rows, n_params)) } else { None };
        SgdPool { momentum, weight_decay, velocity }
    }

    /// One update on learner `j`'s row, matching `Sgd::apply` bitwise.
    pub fn apply_row(&mut self, j: usize, params: &mut [f32], grads: &[f32], lr: f32) {
        let wd = self.weight_decay;
        match &mut self.velocity {
            None => {
                if wd == 0.0 {
                    simd::sgd_step_plain(params, grads, lr);
                } else {
                    simd::sgd_step_wd(params, grads, lr, wd);
                }
            }
            Some(v) => {
                simd::sgd_step_momentum(params, grads, v.row_mut(j), lr, self.momentum, wd);
            }
        }
    }

    /// The momentum arena, if this configuration carries one (engine
    /// first-touch and the pool-parallel apply path reach rows through
    /// this).
    pub fn velocity_mut(&mut self) -> Option<&mut ParamArena> {
        self.velocity.as_mut()
    }

    pub fn velocity(&self) -> Option<&ParamArena> {
        self.velocity.as_ref()
    }
}

/// Learning-rate schedules, indexed by epoch (the paper schedules per
/// epoch).
#[derive(Debug, Clone, PartialEq)]
pub enum LrSchedule {
    Constant(f32),
    /// Start at `initial`; at each `(epoch, lr)` milestone switch to `lr`.
    StepDecay { initial: f32, milestones: Vec<(usize, f32)> },
    /// Cosine from `initial` to `final_lr` over `total_epochs`.
    Cosine { initial: f32, final_lr: f32, total_epochs: usize },
    /// Linear warmup over `warmup_epochs` then cosine decay.
    WarmupCosine { peak: f32, final_lr: f32, warmup_epochs: usize, total_epochs: usize },
}

impl LrSchedule {
    /// The paper's CIFAR-10 schedule (§4): 0.1, dropped to 0.01 at epoch 150.
    pub fn paper_cifar() -> LrSchedule {
        LrSchedule::StepDecay { initial: 0.1, milestones: vec![(150, 0.01)] }
    }

    pub fn lr_at(&self, epoch: usize) -> f32 {
        match self {
            LrSchedule::Constant(lr) => *lr,
            LrSchedule::StepDecay { initial, milestones } => {
                let mut lr = *initial;
                for (e, v) in milestones {
                    if epoch >= *e {
                        lr = *v;
                    }
                }
                lr
            }
            LrSchedule::Cosine { initial, final_lr, total_epochs } => {
                let t = (epoch as f32 / (*total_epochs).max(1) as f32).min(1.0);
                final_lr + 0.5 * (initial - final_lr) * (1.0 + (std::f32::consts::PI * t).cos())
            }
            LrSchedule::WarmupCosine { peak, final_lr, warmup_epochs, total_epochs } => {
                if epoch < *warmup_epochs {
                    peak * (epoch + 1) as f32 / *warmup_epochs as f32
                } else {
                    let span = total_epochs.saturating_sub(*warmup_epochs).max(1);
                    let t = ((epoch - warmup_epochs) as f32 / span as f32).min(1.0);
                    final_lr
                        + 0.5 * (peak - final_lr) * (1.0 + (std::f32::consts::PI * t).cos())
                }
            }
        }
    }

    /// Parse "const:0.05", "step:0.1@150=0.01", "cosine:0.1->0.001@200",
    /// "warmcos:0.1->0.001@5/200".
    pub fn parse(s: &str) -> Result<LrSchedule> {
        if let Some(v) = s.strip_prefix("const:") {
            return Ok(LrSchedule::Constant(v.parse()?));
        }
        if let Some(rest) = s.strip_prefix("step:") {
            let mut parts = rest.split('@');
            let initial: f32 = parts.next().unwrap_or("").parse()?;
            let mut milestones = Vec::new();
            for m in parts {
                let (e, v) = m
                    .split_once('=')
                    .ok_or_else(|| anyhow::anyhow!("bad step milestone {m:?}"))?;
                milestones.push((e.parse()?, v.parse()?));
            }
            return Ok(LrSchedule::StepDecay { initial, milestones });
        }
        if let Some(rest) = s.strip_prefix("cosine:") {
            let (lrs, te) = rest
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("cosine needs @total_epochs"))?;
            let (a, b) =
                lrs.split_once("->").ok_or_else(|| anyhow::anyhow!("cosine needs a->b"))?;
            return Ok(LrSchedule::Cosine {
                initial: a.parse()?,
                final_lr: b.parse()?,
                total_epochs: te.parse()?,
            });
        }
        if let Some(rest) = s.strip_prefix("warmcos:") {
            let (lrs, sched) = rest
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("warmcos needs @warm/total"))?;
            let (a, b) =
                lrs.split_once("->").ok_or_else(|| anyhow::anyhow!("warmcos needs a->b"))?;
            let (w, t) = sched
                .split_once('/')
                .ok_or_else(|| anyhow::anyhow!("warmcos needs warm/total"))?;
            return Ok(LrSchedule::WarmupCosine {
                peak: a.parse()?,
                final_lr: b.parse()?,
                warmup_epochs: w.parse()?,
                total_epochs: t.parse()?,
            });
        }
        bail!("unknown LR schedule {s:?} (const:/step:/cosine:/warmcos:)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_update() {
        let mut opt = Sgd::plain();
        let mut w = vec![1.0, 2.0];
        opt.apply(&mut w, &[0.5, -1.0], 0.1);
        assert_eq!(w, vec![0.95, 2.1]);
    }

    #[test]
    fn momentum_accumulates() {
        let mut opt = Sgd::new(0.9, 0.0, 1);
        let mut w = vec![0.0];
        opt.apply(&mut w, &[1.0], 1.0); // v=1, w=-1
        opt.apply(&mut w, &[1.0], 1.0); // v=1.9, w=-2.9
        assert!((w[0] + 2.9).abs() < 1e-6);
    }

    #[test]
    fn weight_decay_shrinks() {
        let mut opt = Sgd::new(0.0, 0.1, 1);
        let mut w = vec![10.0];
        opt.apply(&mut w, &[0.0], 0.5);
        assert!((w[0] - 9.5).abs() < 1e-6);
    }

    #[test]
    fn pool_matches_per_learner_sgd_bitwise() {
        use crate::util::rng::Pcg32;
        let (rows, n) = (5usize, 37usize);
        for &(mu, wd) in &[(0.0f32, 0.0f32), (0.0, 1e-4), (0.9, 0.0), (0.9, 1e-4)] {
            let mut rng = Pcg32::seeded(7);
            let init: Vec<Vec<f32>> =
                (0..rows).map(|_| (0..n).map(|_| rng.next_normal()).collect()).collect();
            let grads: Vec<Vec<f32>> = (0..rows)
                .map(|_| (0..n).map(|_| rng.next_normal() * 0.01).collect())
                .collect();
            let mut singles: Vec<Sgd> = (0..rows).map(|_| Sgd::new(mu, wd, n)).collect();
            let mut legacy = init.clone();
            let mut arena = ParamArena::from_rows(&init);
            let mut pool = SgdPool::new(mu, wd, rows, n);
            for _ in 0..3 {
                for j in 0..rows {
                    singles[j].apply(&mut legacy[j], &grads[j], 0.05);
                    pool.apply_row(j, arena.row_mut(j), &grads[j], 0.05);
                }
            }
            assert_eq!(arena.to_vecs(), legacy, "mu={mu} wd={wd}");
            if mu != 0.0 {
                assert!(pool.velocity().is_some());
            }
        }
    }

    #[test]
    fn paper_schedule() {
        let s = LrSchedule::paper_cifar();
        assert_eq!(s.lr_at(0), 0.1);
        assert_eq!(s.lr_at(149), 0.1);
        assert_eq!(s.lr_at(150), 0.01);
        assert_eq!(s.lr_at(199), 0.01);
    }

    #[test]
    fn cosine_endpoints_and_monotone() {
        let s = LrSchedule::Cosine { initial: 0.1, final_lr: 0.001, total_epochs: 100 };
        assert!((s.lr_at(0) - 0.1).abs() < 1e-6);
        assert!((s.lr_at(100) - 0.001).abs() < 1e-6);
        for e in 0..100 {
            assert!(s.lr_at(e) >= s.lr_at(e + 1));
        }
    }

    #[test]
    fn warmup_rises_then_falls() {
        let s = LrSchedule::WarmupCosine {
            peak: 0.1,
            final_lr: 0.0,
            warmup_epochs: 5,
            total_epochs: 50,
        };
        assert!(s.lr_at(0) < s.lr_at(4));
        assert!((s.lr_at(4) - 0.1).abs() < 1e-3 || s.lr_at(5) >= s.lr_at(6));
        assert!(s.lr_at(49) < 0.01);
    }

    #[test]
    fn parses() {
        assert_eq!(LrSchedule::parse("const:0.05").unwrap(), LrSchedule::Constant(0.05));
        assert_eq!(
            LrSchedule::parse("step:0.1@150=0.01").unwrap(),
            LrSchedule::StepDecay { initial: 0.1, milestones: vec![(150, 0.01)] }
        );
        assert!(LrSchedule::parse("cosine:0.1->0.001@200").is_ok());
        assert!(LrSchedule::parse("warmcos:0.1->0.001@5/200").is_ok());
        assert!(LrSchedule::parse("bogus").is_err());
    }
}
