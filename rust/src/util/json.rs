//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json`,
//! config files, and the results emitted by the repro harness: objects,
//! arrays, strings (with escapes), numbers, booleans, null.  Not
//! performance-critical — parsed once at startup.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    pub fn usize_arr(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- builders --------------------------------------------------------

    pub fn set(&mut self, key: &str, v: Json) -> &mut Json {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), v);
        } else {
            panic!("set() on non-object");
        }
        self
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected {:?} at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?} at {}", c as char, self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', got {:?} at {}", c as char, self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            // Surrogate pairs are not needed by our files;
                            // map unpaired surrogates to the replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                }
                _ => {
                    // Re-decode multi-byte UTF-8 sequences.
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if b >= 0xf0 {
                            4
                        } else if b >= 0xe0 {
                            3
                        } else {
                            2
                        };
                        let end = start + len;
                        if end > self.bytes.len() {
                            bail!("truncated UTF-8 in string");
                        }
                        s.push_str(std::str::from_utf8(&self.bytes[start..end])?);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write_compact(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    pub fn write_compact(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => escape_into(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(k, out);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
        }
    }

    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent + 1);
        let pad0 = "  ".repeat(indent);
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&pad0);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    escape_into(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&pad0);
                out.push('}');
            }
            other => other.write_compact(out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [1.5, -2e3, true, null, "x\ny"], "c": {"d": "é"}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "hi", "a": [1,2,3]}"#).unwrap();
        assert_eq!(v.req("n").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.req("s").unwrap().as_str().unwrap(), "hi");
        assert_eq!(v.req("a").unwrap().usize_arr().unwrap(), vec![1, 2, 3]);
        assert!(v.req("missing").is_err());
        assert!(v.req("s").unwrap().as_f64().is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn parses_manifest_like() {
        let src = r#"{"models": {"m": {"params": [{"name": "0/w", "shape": [4, 3],
                      "offset": 0, "size": 12}], "train": {"1": "m.hlo"}}}}"#;
        let v = Json::parse(src).unwrap();
        let p = v.req("models").unwrap().req("m").unwrap();
        let e = &p.req("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.req("shape").unwrap().usize_arr().unwrap(), vec![4, 3]);
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::parse(r#"{"a":[1,2],"b":{"c":true}}"#).unwrap();
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    }
}
