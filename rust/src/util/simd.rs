//! Shared SIMD dispatch policy plus the per-element vector kernels used
//! by the comm hot loops (`comm::collective`'s mean kernel, `Reducer::
//! survivor_group`, `compress_split`).  The matmul microkernels in
//! `native::linalg` consult the same dispatch decision.
//!
//! ## Summation-order contract (why the vector paths are bit-exact)
//!
//! Every kernel here assigns SIMD *lanes to distinct output elements* and
//! never vectorizes across a reduction index: each element's value is
//! produced by exactly the scalar sequence of rounded operations (one f32
//! multiply rounding + one f32 add rounding per term, reduction index
//! strictly ascending).  Fused multiply-add is deliberately NOT used —
//! `vfmadd` rounds once where scalar `acc + a * b` rounds twice, which
//! would flip last-bit results and invalidate every golden.  The
//! quantization kernel emulates `f32::round`'s half-away-from-zero rule
//! exactly (truncate, then bump by ±1 on an exact fractional remainder of
//! ≥ 0.5) because `vroundps`'s nearest mode is half-to-even.  The one
//! documented deviation class: reductions over NaN inputs (`max_abs`,
//! quantized NaN coordinates) may differ between paths — parameter
//! vectors are NaN-free by construction, and training is already lost if
//! they are not.
//!
//! Consequently every golden trace, EF-conservation pin and
//! cross-collective equality pin holds bit-for-bit under both dispatch
//! paths; `rust/tests/linalg_simd.rs` and the `HIER_FORCE_SCALAR=1` CI
//! job enforce exactly that.
//!
//! ## Dispatch
//!
//! [`simd_enabled`] = AVX2 detected (cached `is_x86_feature_detected!`)
//! and the `HIER_FORCE_SCALAR` env override not set.  The override is
//! re-read on every call — cheap against the granularity of these kernels
//! (whole-vector passes, never per-element), and it lets the bench
//! harness time `simd` vs `scalar` cases inside one process.  Non-x86_64
//! targets compile the scalar path only; every `*_scalar` twin stays
//! `pub` as the portable executable reference.

/// True when the host supports the AVX2 vector path (cached after the
/// first query; `is_x86_feature_detected!` is itself cheap but this keeps
/// the dispatch branch a single load).
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        use std::sync::OnceLock;
        static AVX2: OnceLock<bool> = OnceLock::new();
        *AVX2.get_or_init(|| std::is_x86_feature_detected!("avx2"))
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Pure parse of the `HIER_FORCE_SCALAR` override value: set and not
/// `"0"`/empty forces the scalar path.  Split out so the rule is testable
/// without mutating the process environment.
pub fn scalar_forced_from(val: Option<&str>) -> bool {
    matches!(val, Some(v) if !v.is_empty() && v != "0")
}

/// `HIER_FORCE_SCALAR=1` forces the portable scalar path at every
/// dispatch point (CI's dual-dispatch equality job, bench `scalar` cases).
pub fn force_scalar() -> bool {
    scalar_forced_from(std::env::var("HIER_FORCE_SCALAR").ok().as_deref())
}

/// The single dispatch decision every vector kernel in the crate uses.
pub fn simd_enabled() -> bool {
    avx2_available() && !force_scalar()
}

// ---------------------------------------------------------------------------
// Elementwise kernels: dispatchers + scalar references
// ---------------------------------------------------------------------------

/// `dst[i] += src[i]` — the survivor-sum / reference-mean accumulation.
pub fn add_assign(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        unsafe { avx2::add_assign(dst, src) };
        return;
    }
    add_assign_scalar(dst, src);
}

pub fn add_assign_scalar(dst: &mut [f32], src: &[f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

/// `dst[i] += x[i] + y[i]` — the mean kernel's paired-source pass.
pub fn add_pair_assign(dst: &mut [f32], x: &[f32], y: &[f32]) {
    debug_assert_eq!(dst.len(), x.len());
    debug_assert_eq!(dst.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        unsafe { avx2::add_pair_assign(dst, x, y) };
        return;
    }
    add_pair_assign_scalar(dst, x, y);
}

pub fn add_pair_assign_scalar(dst: &mut [f32], x: &[f32], y: &[f32]) {
    for ((d, &a), &b) in dst.iter_mut().zip(x).zip(y) {
        *d += a + b;
    }
}

/// `dst[i] *= c` — the reciprocal-multiply averaging pass.
pub fn scale_assign(dst: &mut [f32], c: f32) {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        unsafe { avx2::scale_assign(dst, c) };
        return;
    }
    scale_assign_scalar(dst, c);
}

pub fn scale_assign_scalar(dst: &mut [f32], c: f32) {
    for d in dst.iter_mut() {
        *d *= c;
    }
}

/// `dst[i] = (x[i] - r[i]) + e[i]` — the compressed barrier's
/// delta-from-reference + residual accumulation (parenthesization is part
/// of the contract).
pub fn delta_plus_residual(dst: &mut [f32], x: &[f32], r: &[f32], e: &[f32]) {
    debug_assert_eq!(dst.len(), x.len());
    debug_assert_eq!(dst.len(), r.len());
    debug_assert_eq!(dst.len(), e.len());
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        unsafe { avx2::delta_plus_residual(dst, x, r, e) };
        return;
    }
    delta_plus_residual_scalar(dst, x, r, e);
}

pub fn delta_plus_residual_scalar(dst: &mut [f32], x: &[f32], r: &[f32], e: &[f32]) {
    for i in 0..dst.len() {
        dst[i] = (x[i] - r[i]) + e[i];
    }
}

/// `dst[i] = dst[i] * c + src[i] * c` — the compressed barrier's
/// two-stream mean combine (each stream scaled before the add, exactly as
/// the scalar formulation).
pub fn scaled_sum(dst: &mut [f32], src: &[f32], c: f32) {
    debug_assert_eq!(dst.len(), src.len());
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        unsafe { avx2::scaled_sum(dst, src, c) };
        return;
    }
    scaled_sum_scalar(dst, src, c);
}

pub fn scaled_sum_scalar(dst: &mut [f32], src: &[f32], c: f32) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = *d * c + s * c;
    }
}

/// `max_i |v[i]|` — the quantizer's magnitude scan.  Order-independent
/// (hence vectorizable across the reduction) because max over
/// non-negative reals is associative and commutative; NaN inputs are the
/// documented exception (see module docs).
pub fn max_abs(v: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        return unsafe { avx2::max_abs(v) };
    }
    max_abs_scalar(v)
}

pub fn max_abs_scalar(v: &[f32]) -> f32 {
    v.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
}

/// The q8/q4 per-coordinate split: `q = round(acc*inv).clamp(-levels,
/// levels); t = q*scale; e = acc - t`, with `f32::round`'s
/// half-away-from-zero semantics preserved exactly.
pub fn quantize_split(acc: &[f32], t: &mut [f32], e: &mut [f32], inv: f32, scale: f32, levels: f32) {
    debug_assert_eq!(acc.len(), t.len());
    debug_assert_eq!(acc.len(), e.len());
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        unsafe { avx2::quantize_split(acc, t, e, inv, scale, levels) };
        return;
    }
    quantize_split_scalar(acc, t, e, inv, scale, levels);
}

pub fn quantize_split_scalar(
    acc: &[f32],
    t: &mut [f32],
    e: &mut [f32],
    inv: f32,
    scale: f32,
    levels: f32,
) {
    for i in 0..acc.len() {
        let q = (acc[i] * inv).round().clamp(-levels, levels);
        t[i] = q * scale;
        e[i] = acc[i] - t[i];
    }
}

/// Plain SGD step: `w[i] -= lr * g[i]` — exactly `Sgd::apply`'s
/// no-momentum, no-weight-decay loop (one multiply rounding, one subtract
/// rounding per element).
pub fn sgd_step_plain(w: &mut [f32], g: &[f32], lr: f32) {
    debug_assert_eq!(w.len(), g.len());
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        unsafe { avx2::sgd_step_plain(w, g, lr) };
        return;
    }
    sgd_step_plain_scalar(w, g, lr);
}

pub fn sgd_step_plain_scalar(w: &mut [f32], g: &[f32], lr: f32) {
    for (w, &g) in w.iter_mut().zip(g) {
        *w -= lr * g;
    }
}

/// Weight-decay SGD step: `w[i] -= lr * (g[i] + wd * w[i])` — exactly
/// `Sgd::apply`'s no-momentum weight-decay loop (wd-multiply, add,
/// lr-multiply, subtract: four roundings in that order).
pub fn sgd_step_wd(w: &mut [f32], g: &[f32], lr: f32, wd: f32) {
    debug_assert_eq!(w.len(), g.len());
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        unsafe { avx2::sgd_step_wd(w, g, lr, wd) };
        return;
    }
    sgd_step_wd_scalar(w, g, lr, wd);
}

pub fn sgd_step_wd_scalar(w: &mut [f32], g: &[f32], lr: f32, wd: f32) {
    for (w, &g) in w.iter_mut().zip(g) {
        *w -= lr * (g + wd * *w);
    }
}

/// Momentum SGD step: `eff = g + wd*w; v = mu*v + eff; w -= lr*v` —
/// exactly `Sgd::apply`'s momentum loop, including the unconditional
/// `wd * w` multiply (even at wd = 0, so the rounding sequence matches the
/// scalar reference at every parameter setting).
pub fn sgd_step_momentum(w: &mut [f32], g: &[f32], v: &mut [f32], lr: f32, mu: f32, wd: f32) {
    debug_assert_eq!(w.len(), g.len());
    debug_assert_eq!(w.len(), v.len());
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        unsafe { avx2::sgd_step_momentum(w, g, v, lr, mu, wd) };
        return;
    }
    sgd_step_momentum_scalar(w, g, v, lr, mu, wd);
}

pub fn sgd_step_momentum_scalar(
    w: &mut [f32],
    g: &[f32],
    v: &mut [f32],
    lr: f32,
    mu: f32,
    wd: f32,
) {
    for ((w, &g), vel) in w.iter_mut().zip(g).zip(v.iter_mut()) {
        let eff = g + wd * *w;
        *vel = mu * *vel + eff;
        *w -= lr * *vel;
    }
}

// ---------------------------------------------------------------------------
// AVX2 implementations
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    #[target_feature(enable = "avx2")]
    pub unsafe fn add_assign(dst: &mut [f32], src: &[f32]) {
        let n = dst.len();
        let (dp, sp) = (dst.as_mut_ptr(), src.as_ptr());
        let mut i = 0;
        while i + 8 <= n {
            let d = _mm256_loadu_ps(dp.add(i));
            let s = _mm256_loadu_ps(sp.add(i));
            _mm256_storeu_ps(dp.add(i), _mm256_add_ps(d, s));
            i += 8;
        }
        while i < n {
            *dp.add(i) += *sp.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn add_pair_assign(dst: &mut [f32], x: &[f32], y: &[f32]) {
        let n = dst.len();
        let (dp, xp, yp) = (dst.as_mut_ptr(), x.as_ptr(), y.as_ptr());
        let mut i = 0;
        while i + 8 <= n {
            let d = _mm256_loadu_ps(dp.add(i));
            // (x + y) first, then the accumulate — two roundings, exactly
            // the scalar `*d += x + y`.
            let s = _mm256_add_ps(_mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)));
            _mm256_storeu_ps(dp.add(i), _mm256_add_ps(d, s));
            i += 8;
        }
        while i < n {
            *dp.add(i) += *xp.add(i) + *yp.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn scale_assign(dst: &mut [f32], c: f32) {
        let n = dst.len();
        let dp = dst.as_mut_ptr();
        let vc = _mm256_set1_ps(c);
        let mut i = 0;
        while i + 8 <= n {
            let d = _mm256_loadu_ps(dp.add(i));
            _mm256_storeu_ps(dp.add(i), _mm256_mul_ps(d, vc));
            i += 8;
        }
        while i < n {
            *dp.add(i) *= c;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn delta_plus_residual(dst: &mut [f32], x: &[f32], r: &[f32], e: &[f32]) {
        let n = dst.len();
        let (dp, xp, rp, ep) = (dst.as_mut_ptr(), x.as_ptr(), r.as_ptr(), e.as_ptr());
        let mut i = 0;
        while i + 8 <= n {
            let d = _mm256_sub_ps(_mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(rp.add(i)));
            _mm256_storeu_ps(dp.add(i), _mm256_add_ps(d, _mm256_loadu_ps(ep.add(i))));
            i += 8;
        }
        while i < n {
            *dp.add(i) = (*xp.add(i) - *rp.add(i)) + *ep.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn scaled_sum(dst: &mut [f32], src: &[f32], c: f32) {
        let n = dst.len();
        let (dp, sp) = (dst.as_mut_ptr(), src.as_ptr());
        let vc = _mm256_set1_ps(c);
        let mut i = 0;
        while i + 8 <= n {
            let d = _mm256_mul_ps(_mm256_loadu_ps(dp.add(i)), vc);
            let s = _mm256_mul_ps(_mm256_loadu_ps(sp.add(i)), vc);
            _mm256_storeu_ps(dp.add(i), _mm256_add_ps(d, s));
            i += 8;
        }
        while i < n {
            *dp.add(i) = *dp.add(i) * c + *sp.add(i) * c;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn sgd_step_plain(w: &mut [f32], g: &[f32], lr: f32) {
        let n = w.len();
        let (wp, gp) = (w.as_mut_ptr(), g.as_ptr());
        let vlr = _mm256_set1_ps(lr);
        let mut i = 0;
        while i + 8 <= n {
            let wv = _mm256_loadu_ps(wp.add(i));
            // lr*g rounds, then the subtract rounds — never vfmadd.
            let step = _mm256_mul_ps(vlr, _mm256_loadu_ps(gp.add(i)));
            _mm256_storeu_ps(wp.add(i), _mm256_sub_ps(wv, step));
            i += 8;
        }
        while i < n {
            *wp.add(i) -= lr * *gp.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn sgd_step_wd(w: &mut [f32], g: &[f32], lr: f32, wd: f32) {
        let n = w.len();
        let (wp, gp) = (w.as_mut_ptr(), g.as_ptr());
        let vlr = _mm256_set1_ps(lr);
        let vwd = _mm256_set1_ps(wd);
        let mut i = 0;
        while i + 8 <= n {
            let wv = _mm256_loadu_ps(wp.add(i));
            // g + wd*w, then lr*(..), then the subtract: four roundings in
            // scalar order, no contraction.
            let eff = _mm256_add_ps(_mm256_loadu_ps(gp.add(i)), _mm256_mul_ps(vwd, wv));
            _mm256_storeu_ps(wp.add(i), _mm256_sub_ps(wv, _mm256_mul_ps(vlr, eff)));
            i += 8;
        }
        while i < n {
            *wp.add(i) -= lr * (*gp.add(i) + wd * *wp.add(i));
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn sgd_step_momentum(
        w: &mut [f32],
        g: &[f32],
        v: &mut [f32],
        lr: f32,
        mu: f32,
        wd: f32,
    ) {
        let n = w.len();
        let (wp, gp, vp) = (w.as_mut_ptr(), g.as_ptr(), v.as_mut_ptr());
        let vlr = _mm256_set1_ps(lr);
        let vmu = _mm256_set1_ps(mu);
        let vwd = _mm256_set1_ps(wd);
        let mut i = 0;
        while i + 8 <= n {
            let wv = _mm256_loadu_ps(wp.add(i));
            let eff = _mm256_add_ps(_mm256_loadu_ps(gp.add(i)), _mm256_mul_ps(vwd, wv));
            let vel = _mm256_add_ps(_mm256_mul_ps(vmu, _mm256_loadu_ps(vp.add(i))), eff);
            _mm256_storeu_ps(vp.add(i), vel);
            _mm256_storeu_ps(wp.add(i), _mm256_sub_ps(wv, _mm256_mul_ps(vlr, vel)));
            i += 8;
        }
        while i < n {
            let eff = *gp.add(i) + wd * *wp.add(i);
            *vp.add(i) = mu * *vp.add(i) + eff;
            *wp.add(i) -= lr * *vp.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn max_abs(v: &[f32]) -> f32 {
        let n = v.len();
        let vp = v.as_ptr();
        let sign = _mm256_set1_ps(-0.0);
        let mut m = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= n {
            let x = _mm256_andnot_ps(sign, _mm256_loadu_ps(vp.add(i)));
            m = _mm256_max_ps(m, x);
            i += 8;
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), m);
        let mut out = 0.0f32;
        for &l in &lanes {
            out = out.max(l);
        }
        while i < n {
            out = out.max((*vp.add(i)).abs());
            i += 1;
        }
        out
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn quantize_split(
        acc: &[f32],
        t: &mut [f32],
        e: &mut [f32],
        inv: f32,
        scale: f32,
        levels: f32,
    ) {
        let n = acc.len();
        let (ap, tp, ep) = (acc.as_ptr(), t.as_mut_ptr(), e.as_mut_ptr());
        let vinv = _mm256_set1_ps(inv);
        let vscale = _mm256_set1_ps(scale);
        let vlev = _mm256_set1_ps(levels);
        let vneg = _mm256_set1_ps(-levels);
        let half = _mm256_set1_ps(0.5);
        let nhalf = _mm256_set1_ps(-0.5);
        let one = _mm256_set1_ps(1.0);
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(ap.add(i));
            let x = _mm256_mul_ps(v, vinv);
            // f32::round is half-away-from-zero; vroundps nearest is
            // half-to-even.  Emulate exactly: truncate, take the (exact)
            // fractional remainder, bump by ±1 when it reaches 0.5.
            let tr = _mm256_round_ps::<{ _MM_FROUND_TO_ZERO | _MM_FROUND_NO_EXC }>(x);
            let frac = _mm256_sub_ps(x, tr);
            let up = _mm256_and_ps(_mm256_cmp_ps::<_CMP_GE_OQ>(frac, half), one);
            let down = _mm256_and_ps(_mm256_cmp_ps::<_CMP_LE_OQ>(frac, nhalf), one);
            let q = _mm256_sub_ps(_mm256_add_ps(tr, up), down);
            let q = _mm256_min_ps(_mm256_max_ps(q, vneg), vlev);
            let tv = _mm256_mul_ps(q, vscale);
            _mm256_storeu_ps(tp.add(i), tv);
            _mm256_storeu_ps(ep.add(i), _mm256_sub_ps(v, tv));
            i += 8;
        }
        while i < n {
            let q = (*ap.add(i) * inv).round().clamp(-levels, levels);
            *tp.add(i) = q * scale;
            *ep.add(i) = *ap.add(i) - *tp.add(i);
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn noisy(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::seeded(seed);
        (0..n).map(|_| rng.next_normal()).collect()
    }

    /// Lengths straddling the 8-lane width and its remainders.
    const LENS: &[usize] = &[0, 1, 3, 7, 8, 9, 15, 16, 17, 31, 100, 1000];

    #[test]
    fn scalar_override_parse_rule() {
        assert!(!scalar_forced_from(None));
        assert!(!scalar_forced_from(Some("")));
        assert!(!scalar_forced_from(Some("0")));
        assert!(scalar_forced_from(Some("1")));
        assert!(scalar_forced_from(Some("true")));
    }

    #[test]
    fn dispatch_matches_scalar_bitwise() {
        // On an AVX2 host (and without HIER_FORCE_SCALAR) this pins the
        // vector path against the scalar reference bit for bit; elsewhere
        // it degenerates to scalar ≡ scalar, and the CI scalar-forced job
        // covers the other branch.
        for &n in LENS {
            let x = noisy(n, 1);
            let y = noisy(n, 2);
            let base = noisy(n, 3);

            let mut a = base.clone();
            let mut b = base.clone();
            add_assign(&mut a, &x);
            add_assign_scalar(&mut b, &x);
            assert_eq!(a, b, "add_assign n={n}");

            let mut a = base.clone();
            let mut b = base.clone();
            add_pair_assign(&mut a, &x, &y);
            add_pair_assign_scalar(&mut b, &x, &y);
            assert_eq!(a, b, "add_pair_assign n={n}");

            let mut a = base.clone();
            let mut b = base.clone();
            scale_assign(&mut a, 1.0 / 3.0);
            scale_assign_scalar(&mut b, 1.0 / 3.0);
            assert_eq!(a, b, "scale_assign n={n}");

            let mut a = base.clone();
            let mut b = base.clone();
            delta_plus_residual(&mut a, &x, &y, &base);
            delta_plus_residual_scalar(&mut b, &x, &y, &base);
            assert_eq!(a, b, "delta_plus_residual n={n}");

            let mut a = base.clone();
            let mut b = base.clone();
            scaled_sum(&mut a, &x, 0.25);
            scaled_sum_scalar(&mut b, &x, 0.25);
            assert_eq!(a, b, "scaled_sum n={n}");

            assert_eq!(max_abs(&x).to_bits(), max_abs_scalar(&x).to_bits(), "max_abs n={n}");

            let (mut t1, mut e1) = (vec![0.0f32; n], vec![0.0f32; n]);
            let (mut t2, mut e2) = (vec![0.0f32; n], vec![0.0f32; n]);
            quantize_split(&x, &mut t1, &mut e1, 31.0, 1.0 / 31.0, 7.0);
            quantize_split_scalar(&x, &mut t2, &mut e2, 31.0, 1.0 / 31.0, 7.0);
            assert_eq!(t1, t2, "quantize t n={n}");
            assert_eq!(e1, e2, "quantize e n={n}");

            let mut a = base.clone();
            let mut b = base.clone();
            sgd_step_plain(&mut a, &x, 0.1);
            sgd_step_plain_scalar(&mut b, &x, 0.1);
            assert_eq!(a, b, "sgd_step_plain n={n}");

            let mut a = base.clone();
            let mut b = base.clone();
            sgd_step_wd(&mut a, &x, 0.1, 1e-4);
            sgd_step_wd_scalar(&mut b, &x, 0.1, 1e-4);
            assert_eq!(a, b, "sgd_step_wd n={n}");

            let mut a = base.clone();
            let mut b = base.clone();
            let mut va = y.clone();
            let mut vb = y.clone();
            sgd_step_momentum(&mut a, &x, &mut va, 0.1, 0.9, 1e-4);
            sgd_step_momentum_scalar(&mut b, &x, &mut vb, 0.1, 0.9, 1e-4);
            assert_eq!(a, b, "sgd_step_momentum w n={n}");
            assert_eq!(va, vb, "sgd_step_momentum v n={n}");
        }
    }

    #[test]
    fn unaligned_offsets_match_scalar_bitwise() {
        // Sub-slicing at every lane offset exercises the unaligned loads.
        let x = noisy(64, 10);
        let base = noisy(64, 11);
        for off in 0..9 {
            let mut a = base.clone();
            let mut b = base.clone();
            add_assign(&mut a[off..], &x[off..]);
            add_assign_scalar(&mut b[off..], &x[off..]);
            assert_eq!(a, b, "offset {off}");
        }
    }

    #[test]
    fn quantize_rounds_half_away_from_zero() {
        // Exact .5 multiples are where half-to-even (vroundps nearest)
        // would diverge from f32::round; the emulation must not.
        let acc = [2.5f32, -2.5, 0.5, -0.5, 1.5, -1.5, 2.499_999_8, -2.499_999_8];
        let (mut t, mut e) = (vec![0.0f32; 8], vec![0.0f32; 8]);
        quantize_split(&acc, &mut t, &mut e, 1.0, 1.0, 127.0);
        assert_eq!(t, vec![3.0, -3.0, 1.0, -1.0, 2.0, -2.0, 2.0, -2.0]);
        for i in 0..8 {
            assert_eq!(e[i], acc[i] - t[i]);
        }
        // Clamp engages past the level count.
        let acc = [200.0f32, -200.0];
        let (mut t, mut e) = (vec![0.0f32; 2], vec![0.0f32; 2]);
        quantize_split(&acc, &mut t, &mut e, 1.0, 1.0, 127.0);
        let _ = &e;
        assert_eq!(t, vec![127.0, -127.0]);
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_path_directly_matches_scalar() {
        // Pin the vector implementations themselves (not the dispatcher),
        // so the equality holds even when HIER_FORCE_SCALAR is set for
        // the whole test process.
        if !avx2_available() {
            return;
        }
        for &n in LENS {
            let x = noisy(n, 21);
            let base = noisy(n, 22);
            let mut a = base.clone();
            let mut b = base.clone();
            unsafe { avx2::add_assign(&mut a, &x) };
            add_assign_scalar(&mut b, &x);
            assert_eq!(a, b, "avx2 add_assign n={n}");

            assert_eq!(
                unsafe { avx2::max_abs(&x) }.to_bits(),
                max_abs_scalar(&x).to_bits(),
                "avx2 max_abs n={n}"
            );

            let (mut t1, mut e1) = (vec![0.0f32; n], vec![0.0f32; n]);
            let (mut t2, mut e2) = (vec![0.0f32; n], vec![0.0f32; n]);
            unsafe { avx2::quantize_split(&x, &mut t1, &mut e1, 63.0, 1.0 / 63.0, 127.0) };
            quantize_split_scalar(&x, &mut t2, &mut e2, 63.0, 1.0 / 63.0, 127.0);
            assert_eq!(t1, t2, "avx2 quantize t n={n}");
            assert_eq!(e1, e2, "avx2 quantize e n={n}");
        }
    }
}
