//! PCG32 (O'Neill 2014, XSH-RR variant): a small, fast, statistically solid
//! PRNG.  Everything random in the framework — dataset synthesis, shard
//! shuffles, initialization fallbacks, property-test inputs — flows from a
//! seeded `Pcg32`, so runs are bit-reproducible.

/// PCG-XSH-RR 64/32.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seed with an initial state and a stream id (any values are fine).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Derive an independent child generator (e.g. one per learner or per
    /// epoch) without correlating streams.
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        let s = self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15);
        Pcg32::new(s, tag.wrapping_add(0x5851f42d4c957f2d))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire's method, debiased).
    #[inline]
    pub fn next_below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Standard normal via Box–Muller (caches the spare value).
    pub fn next_normal(&mut self) -> f32 {
        // Box–Muller without caching: two uniforms per normal.  Simple and
        // branch-free; the dataset generator is not on the training path.
        let u1 = self.next_f32().max(1e-12);
        let u2 = self.next_f32();
        let r = (-2.0 * u1.ln()).sqrt();
        r * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_mean() {
        let mut r = Pcg32::seeded(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg32::seeded(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.next_below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(11);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_decorrelates() {
        let mut root = Pcg32::seeded(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
