//! Small self-contained utilities standing in for crates that are not
//! available in this offline environment (see Cargo.toml note): a PCG32
//! RNG, a minimal JSON parser/writer, and a flag-style CLI parser.

pub mod cli;
pub mod json;
pub mod rng;
pub mod simd;

pub use cli::Args;
pub use json::Json;
pub use rng::Pcg32;
