//! Minimal CLI flag parser (clap is unavailable offline).
//!
//! Grammar: `prog <subcommand> [--flag value]... [--switch]... [positional]...`
//! Flags may be given as `--key value` or `--key=value`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    /// Every switch mentioned on the command line, including explicit-off
    /// forms (`--switch=0`), so callers can reject a switch that does not
    /// apply to them regardless of its value.
    seen_switches: Vec<String>,
    known_switches: Vec<&'static str>,
}

impl Args {
    /// Parse raw argv (excluding the program name).  `switches` lists flag
    /// names that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        switches: &[&'static str],
    ) -> Result<Args> {
        let mut out = Args { known_switches: switches.to_vec(), ..Default::default() };
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    if switches.contains(&k) {
                        // `--switch=0|1`: honor the explicit value instead of
                        // silently routing a known switch into the flag map
                        // (where `has()` would miss it).
                        out.seen_switches.push(k.to_string());
                        match v {
                            "1" | "true" => out.switches.push(k.to_string()),
                            "0" | "false" => {}
                            other => bail!(
                                "--{k} is a switch: pass --{k} or --{k}=0|1 (got {other:?})"
                            ),
                        }
                    } else {
                        out.flags.insert(k.to_string(), v.to_string());
                    }
                } else if switches.contains(&name) {
                    out.seen_switches.push(name.to_string());
                    out.switches.push(name.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("flag --{name} expects a value"))?;
                    out.flags.insert(name.to_string(), v);
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env(switches: &[&'static str]) -> Result<Args> {
        Self::parse(std::env::args().skip(1), switches)
    }

    pub fn has(&self, switch: &str) -> bool {
        debug_assert!(self.known_switches.contains(&switch) || self.flags.contains_key(switch));
        self.switches.iter().any(|s| s == switch)
    }

    /// Whether `switch` appeared on the command line at all, even in an
    /// explicit-off form (`--switch=0`) — for rejecting a switch that a
    /// subcommand does not accept, regardless of its value.
    pub fn saw_switch(&self, switch: &str) -> bool {
        self.seen_switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|e| anyhow!("invalid value for --{key}: {e}")),
        }
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing required flag --{key}"))
    }

    /// Reject unknown flags (call after reading everything you accept).
    pub fn check_known(&self, accepted: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !accepted.contains(&k.as_str()) {
                bail!("unknown flag --{k} (accepted: {accepted:?})");
            }
        }
        for s in &self.seen_switches {
            if !accepted.contains(&s.as_str()) {
                bail!("unknown switch --{s}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn basic() {
        let a = Args::parse(sv(&["train", "--p", "16", "--full", "--k2=32"]), &["full"])
            .unwrap();
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("p"), Some("16"));
        assert_eq!(a.get("k2"), Some("32"));
        assert!(a.has("full"));
        assert_eq!(a.parse_or("p", 1usize).unwrap(), 16);
        assert_eq!(a.parse_or("absent", 7usize).unwrap(), 7);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(sv(&["--p"]), &[]).is_err());
    }

    #[test]
    fn switch_equals_value_forms() {
        let a = Args::parse(sv(&["--full=1", "--quiet=0"]), &["full", "quiet"]).unwrap();
        assert!(a.has("full"));
        assert!(!a.has("quiet"));
        // ... but the explicit-off mention is still visible, so callers
        // can reject an inapplicable switch regardless of its value, and
        // check_known validates it like any other switch.
        assert!(a.saw_switch("quiet"));
        assert!(!a.saw_switch("absent"));
        assert!(a.get("full").is_none(), "switch must not leak into the flag map");
        assert!(a.check_known(&["full", "quiet"]).is_ok());
        assert!(a.check_known(&["full"]).is_err(), "off-form switch must not evade check_known");
        assert!(Args::parse(sv(&["--full=yes"]), &["full"]).is_err());
    }

    #[test]
    fn check_known_rejects() {
        let a = Args::parse(sv(&["--bogus", "1"]), &[]).unwrap();
        assert!(a.check_known(&["p", "k2"]).is_err());
        assert!(a.check_known(&["bogus"]).is_ok());
    }

    #[test]
    fn bad_parse_is_error() {
        let a = Args::parse(sv(&["--p", "xyz"]), &[]).unwrap();
        assert!(a.parse_or("p", 0usize).is_err());
    }

    #[test]
    fn schedule_flag_is_a_value_flag_and_guarded() {
        // `--schedule` is an ordinary value flag on train/sweep/repro;
        // misspellings must not slip past check_known (the policy-name
        // grammar itself is validated by `PolicyKind::parse`).
        let a = Args::parse(
            sv(&["train", "--schedule", "adaptive:0.25"]),
            &["record-steps", "help"],
        )
        .unwrap();
        assert_eq!(a.get("schedule"), Some("adaptive:0.25"));
        assert!(a.check_known(&["schedule"]).is_ok());
        let typo = Args::parse(sv(&["train", "--schedle", "adaptive"]), &[]).unwrap();
        assert!(typo.check_known(&["schedule"]).is_err());
    }

    #[test]
    fn exec_model_flags_are_value_flags_and_guarded() {
        // The execution-model knobs are ordinary value flags (never
        // switches), and misspellings must not slip past check_known.
        let a = Args::parse(
            sv(&["train", "--exec", "event", "--het", "0.2", "--straggler", "0.05:4"]),
            &["record-steps", "help"],
        )
        .unwrap();
        assert_eq!(a.get("exec"), Some("event"));
        assert_eq!(a.parse_or("het", 0.0f64).unwrap(), 0.2);
        assert_eq!(a.get("straggler"), Some("0.05:4"));
        assert!(a.check_known(&["exec", "het", "straggler"]).is_ok());
        let typo = Args::parse(sv(&["train", "--stragler", "0.05"]), &[]).unwrap();
        assert!(typo.check_known(&["exec", "het", "straggler"]).is_err());
    }

    #[test]
    fn faults_flag_is_a_value_flag_and_guarded() {
        // `--faults` is an ordinary value flag (both the PROB[:mttr] and
        // trace forms); misspellings must not slip past check_known (the
        // spec grammar itself is validated by `sim::parse_faults`).
        let a = Args::parse(
            sv(&["train", "--faults", "0.01:25", "--exec", "event"]),
            &["record-steps", "help"],
        )
        .unwrap();
        assert_eq!(a.get("faults"), Some("0.01:25"));
        assert!(a.check_known(&["faults", "exec"]).is_ok());
        let trace = Args::parse(sv(&["train", "--faults=trace:5@0x10"]), &[]).unwrap();
        assert_eq!(trace.get("faults"), Some("trace:5@0x10"));
        let typo = Args::parse(sv(&["train", "--fualts", "0.01"]), &[]).unwrap();
        assert!(typo.check_known(&["faults", "exec"]).is_err());
    }
}
