//! The paper's non-asymptotic bounds, implemented as evaluable functions.
//!
//! These power the analysis reproductions (`repro thm34|thm35|thm36`): the
//! claims of §3.3–§3.5 are statements about the bound's shape (optimum
//! K2 > 1, monotone in K1 / S, Hier-AVG < K-AVG) which we verify
//! numerically over grids, and compare qualitatively against the measured
//! training runs.
//!
//! Notation (paper §2):  L Lipschitz constant, M gradient-variance bound
//! (Asm. 4), M_G second-moment bound (Asm. 5), γ step size, B batch, P
//! learners, S cluster size, K1/K2 averaging intervals,
//! δ = L²γ²(1 + δ_{∇F,w}) ∈ (0,1).

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BoundParams {
    pub l: f64,
    pub m: f64,
    pub mg: f64,
    /// F(w̃₁) − F*  (initial suboptimality).
    pub f_gap: f64,
    pub gamma: f64,
    pub b: f64,
    pub p: f64,
    /// δ_{∇F,w} (paper's intermediate-gradient constant,
    /// 0 < δ_{∇F,w} ≤ K2(K2−1)/2 − 1).
    pub delta_grad: f64,
}

impl Default for BoundParams {
    fn default() -> Self {
        // A representative regime: strongly non-convex start (large gap),
        // moderate smoothness, small constant step.
        BoundParams {
            l: 10.0,
            m: 1.0,
            mg: 1.0,
            f_gap: 10.0,
            gamma: 5e-3,
            b: 64.0,
            p: 16.0,
            delta_grad: 1.0,
        }
    }
}

impl BoundParams {
    /// δ = L²γ²(1 + δ_{∇F,w}); the theorems need δ ∈ (0,1).
    pub fn delta(&self) -> f64 {
        self.l * self.l * self.gamma * self.gamma * (1.0 + self.delta_grad)
    }

    pub fn validate(&self) -> Result<()> {
        if !(self.delta() > 0.0 && self.delta() < 1.0) {
            bail!("δ = {} must lie in (0,1); shrink γ or δ_grad", self.delta());
        }
        if self.l <= 0.0 || self.gamma <= 0.0 || self.b <= 0.0 || self.p <= 0.0 {
            bail!("L, γ, B, P must be positive");
        }
        Ok(())
    }

    /// Condition (3.5)/(3.7): step-size constraint of Theorems 3.2/3.3.
    pub fn condition_35(&self, k2: u64) -> bool {
        let lg = self.l * self.gamma;
        let k2f = k2 as f64;
        1.0 - lg * lg * (k2f * (k2f - 1.0) / 2.0 - 1.0 - self.delta_grad) - lg * k2f >= 0.0
    }
}

/// The local-deviation polynomial Φ(K1,K2,S) from (3.6)'s third term:
/// `(K2−K1)(4K2+K1−3)/S + (K1−1)(3K2+K1−2)`.
pub fn phi(k1: u64, k2: u64, s: u64) -> f64 {
    let (k1, k2, s) = (k1 as f64, k2 as f64, s as f64);
    (k2 - k1) * (4.0 * k2 + k1 - 3.0) / s + (k1 - 1.0) * (3.0 * k2 + k1 - 2.0)
}

/// Theorem 3.1, eq. (3.2): per-step metric bound after T total steps.
pub fn thm31_bound(p: &BoundParams, t: u64, k2: u64) -> f64 {
    let t = t as f64;
    let k2 = k2 as f64;
    2.0 * p.f_gap / (p.gamma * t)
        + 4.0 * p.l * p.l * p.gamma * p.gamma * k2 * k2 * p.mg * p.mg
        + p.l * p.gamma * p.m / (p.p * p.b)
}

/// Theorem 3.1 with the prescribed scalings (3.3):
/// γ = sqrt(PB/T), K2 = T^{1/4}/(PB)^{3/4} — the standard-rate form (3.4).
pub fn thm31_scaled_bound(p: &BoundParams, t: u64) -> f64 {
    let t = t as f64;
    let pb = p.p * p.b;
    (2.0 * p.f_gap + 4.0 * p.l * p.l * p.mg * p.mg + p.l * p.m) / (pb * t).sqrt()
}

/// Theorem 3.2, eq. (3.6): per-global-update metric bound after N global
/// rounds of Hier-AVG(K1, K2, S).
pub fn thm32_bound(p: &BoundParams, n: u64, k1: u64, k2: u64, s: u64) -> f64 {
    let d = p.delta();
    let n = n as f64;
    let k2f = k2 as f64;
    let denom = k2f - d;
    2.0 * p.f_gap / (n * denom * p.gamma)
        + p.l * p.gamma * p.m * k2f * k2f / (p.p * p.b * denom)
        + p.l * p.l * p.gamma * p.gamma * p.m * k2f / (12.0 * p.b * denom) * phi(k1, k2, s)
}

/// §3.3 / Theorem 3.4 setting: total step budget T = N·K2 fixed.
/// B(K2) = f(K2)·g(K2) with
///   f = α + β·K2 + η·Φ(K1,K2,S),  g = K2/(K2−δ),
///   α = 2(F(w̃₁)−F*)/(Tγ),  β = LγM/(PB),  η = L²γ²M/(12B).
pub fn thm34_budget_bound(p: &BoundParams, t: u64, k1: u64, k2: u64, s: u64) -> f64 {
    let d = p.delta();
    let alpha = 2.0 * p.f_gap / (t as f64 * p.gamma);
    let beta = p.l * p.gamma * p.m / (p.p * p.b);
    let eta = p.l * p.l * p.gamma * p.gamma * p.m / (12.0 * p.b);
    let k2f = k2 as f64;
    let f = alpha + beta * k2f + eta * phi(k1.min(k2), k2, s);
    let g = k2f / (k2f - d);
    f * g
}

/// Condition (3.11): when it holds, some K2 > 1 beats K2 = 1 (B(2) < B(1)).
pub fn thm34_condition(p: &BoundParams, t: u64, s: u64) -> bool {
    let d = p.delta();
    let alpha = 2.0 * p.f_gap / (t as f64 * p.gamma);
    let beta = p.l * p.gamma * p.m / (p.p * p.b);
    let eta = p.l * p.l * p.gamma * p.gamma * p.m / (12.0 * p.b);
    d * alpha / (1.0 - d) > 2.0 * beta + 12.0 * eta / s as f64
}

/// The largest K2 in `1..=cap` satisfying condition (3.5), or `None` when
/// even K2 = 1 violates it.  The condition's left-hand side is strictly
/// decreasing in K2 (each increment subtracts `(Lγ)²·K2 + Lγ > 0`), so the
/// feasible set is a prefix and binary search applies.  The sweep planner
/// caps its K2 search here: theorems 3.2/3.3 — and hence
/// [`thm34_budget_bound`]'s interpretation as a convergence guarantee —
/// only hold inside this range.
pub fn max_k2_condition_35(p: &BoundParams, cap: u64) -> Option<u64> {
    if cap == 0 || !p.condition_35(1) {
        return None;
    }
    let (mut lo, mut hi) = (1u64, cap);
    while lo < hi {
        let mid = lo + (hi - lo + 1) / 2;
        if p.condition_35(mid) {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    Some(lo)
}

/// argmin over K2 ∈ {multiples of K1} ∪ {1..} of the fixed-budget bound.
pub fn optimal_k2(p: &BoundParams, t: u64, k1: u64, s: u64, k2_max: u64) -> u64 {
    let mut best = (f64::INFINITY, 1u64);
    let mut k2 = k1.max(1);
    while k2 <= k2_max {
        let v = thm34_budget_bound(p, t, k1, k2, s);
        if v < best.0 {
            best = (v, k2);
        }
        k2 += k1.max(1);
    }
    best.1
}

/// Theorem 3.6 comparison.  Hier-AVG with K2=(1+a)K, K1=1, S=4 (bound
/// H(K)) vs K-AVG with interval K (bound χ(K)), both after the same data
/// budget; the second (1/PB) term is dropped per the theorem's LγP ≫ 1
/// regime.  Returns (hier, kavg).
pub fn thm36_pair(p: &BoundParams, t: u64, k: u64, a: f64) -> (f64, f64) {
    let d = p.delta();
    let alpha = 2.0 * p.f_gap / (t as f64 * p.gamma);
    let eta = p.l * p.l * p.gamma * p.gamma * p.m / (6.0 * p.b);
    let kk = k as f64;
    let k2 = (1.0 + a) * kk;
    let f1 = alpha + eta * (k2 - 1.0) * (2.0 * k2 - 1.0) / 4.0;
    let g1 = k2 / (k2 - d);
    let f2 = alpha + eta * (kk - 1.0) * (2.0 * kk - 1.0);
    let g2 = kk / (kk - d);
    (f1 * g1, f2 * g2)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> BoundParams {
        let p = BoundParams::default();
        p.validate().unwrap();
        p
    }

    #[test]
    fn phi_special_cases() {
        // K-AVG identity (K1 = K2 = K): Φ = 2(K−1)(2K−1), independent of S.
        for k in [1u64, 2, 8, 32] {
            let kf = k as f64;
            for s in [1u64, 2, 4] {
                assert!((phi(k, k, s) - 2.0 * (kf - 1.0) * (2.0 * kf - 1.0)).abs() < 1e-9);
            }
        }
        // Sync SGD: Φ(1,1,·) = 0.
        assert_eq!(phi(1, 1, 1), 0.0);
    }

    #[test]
    fn thm31_standard_rate() {
        // The scaled bound decays like 1/sqrt(PBT): quadrupling T halves it.
        let pp = p();
        let b1 = thm31_scaled_bound(&pp, 10_000);
        let b4 = thm31_scaled_bound(&pp, 40_000);
        assert!((b1 / b4 - 2.0).abs() < 1e-9);
        // and increasing P at fixed T also tightens it
        let mut p2 = pp;
        p2.p = 64.0;
        assert!(thm31_scaled_bound(&p2, 10_000) < b1);
    }

    #[test]
    fn thm35_monotone_in_k1() {
        // Bound (3.6) monotone increasing in K1 for K1 >= 2, S > 1, fixed K2.
        let pp = p();
        for s in [2u64, 4, 8] {
            let mut prev = thm32_bound(&pp, 100, 2, 32, s);
            for k1 in [4u64, 8, 16, 32] {
                let cur = thm32_bound(&pp, 100, k1, 32, s);
                assert!(cur >= prev, "k1={k1} s={s}: {cur} < {prev}");
                prev = cur;
            }
        }
    }

    #[test]
    fn thm35_monotone_in_s() {
        let pp = p();
        let mut prev = thm32_bound(&pp, 100, 4, 32, 1);
        for s in [2u64, 4, 8, 16] {
            let cur = thm32_bound(&pp, 100, 4, 32, s);
            assert!(cur <= prev, "s={s}");
            prev = cur;
        }
    }

    #[test]
    fn thm34_condition_implies_k2_gt_1() {
        // Build a regime where (3.11) holds (huge initial gap, small T).
        let mut pp = p();
        pp.f_gap = 1000.0;
        let t = 1_000;
        assert!(thm34_condition(&pp, t, 4));
        let b1 = thm34_budget_bound(&pp, t, 1, 1, 4);
        let b2 = thm34_budget_bound(&pp, t, 1, 2, 4);
        assert!(b2 < b1, "B(2)={b2} !< B(1)={b1}");
        assert!(optimal_k2(&pp, t, 1, 4, 64) > 1);
    }

    #[test]
    fn thm34_condition_false_prefers_k2_1() {
        // Tiny gap, long horizon: frequent averaging wins.
        let mut pp = p();
        pp.f_gap = 1e-4;
        let t = 10_000_000;
        assert!(!thm34_condition(&pp, t, 4));
        assert_eq!(optimal_k2(&pp, t, 1, 4, 64), 1);
    }

    #[test]
    fn thm36_hier_beats_kavg() {
        let pp = p();
        for k in [2u64, 4, 8, 16, 32, 64] {
            for a in [0.0, 0.2, 0.4, 0.6] {
                let (h, kavg) = thm36_pair(&pp, 10_000, k, a);
                assert!(h < kavg, "k={k} a={a}: hier={h} kavg={kavg}");
            }
        }
    }

    #[test]
    fn condition_35_shrinks_with_k2() {
        let pp = p();
        assert!(pp.condition_35(2));
        // With a big enough K2 the condition must eventually fail for a
        // fixed gamma.
        assert!(!pp.condition_35(100_000));
    }

    #[test]
    fn max_k2_condition_35_is_the_threshold() {
        let pp = p();
        let cap = 1_000_000;
        let k = max_k2_condition_35(&pp, cap).unwrap();
        assert!(pp.condition_35(k));
        assert!(!pp.condition_35(k + 1), "k={k} is not the last feasible K2");
        // A cap below the threshold clamps.
        assert_eq!(max_k2_condition_35(&pp, 2), Some(2));
        assert_eq!(max_k2_condition_35(&pp, 0), None);
        // Validated params always admit K2 = 1 (δ < 1 forces Lγ < 1).
        assert!(max_k2_condition_35(&pp, 1).is_some());
    }

    #[test]
    fn validate_rejects_big_gamma() {
        let mut pp = p();
        pp.gamma = 1.0;
        assert!(pp.validate().is_err());
    }
}
