//! # Hier-AVG
//!
//! A production-style reproduction of *"A Distributed Hierarchical
//! Averaging SGD Algorithm: Trading Local Reductions for Global
//! Reductions"* (Zhou & Cong, 2019) as a three-layer Rust + JAX + Pallas
//! distributed-training framework:
//!
//! - **L3 (this crate)** — the hierarchical-averaging coordinator
//!   (Algorithm 1): P learner replicas in clusters of S, local averaging
//!   every K1 steps, global reduction every K2; plus the substrates it
//!   needs (cluster/topology model, simulated collectives with an α–β
//!   hierarchical cost model, optimizers, synthetic datasets, metrics, and
//!   the paper's bounds in `theory`).
//! - **L2 (python/compile/model.py, build-time)** — JAX model graphs
//!   (MLP classifiers + a transformer LM) AOT-lowered to HLO text.
//! - **L1 (python/compile/kernels, build-time)** — Pallas kernels (fused
//!   linear + group averaging) called by L2.
//!
//! At run time the coordinator executes the artifacts through the `xla`
//! crate's PJRT CPU client (`runtime`); Python is never on the training
//! path.  See DESIGN.md for the experiment index and EXPERIMENTS.md for
//! the measured reproductions.
//!
//! ## Quick start
//!
//! ```no_run
//! use hier_avg::config::{BackendKind, RunConfig};
//! use hier_avg::driver;
//!
//! let mut cfg = RunConfig::defaults("quickstart");
//! cfg.p = 4;
//! cfg.s = 2;
//! cfg.k1 = 2;
//! cfg.k2 = 8;
//! cfg.backend = BackendKind::Xla; // or Native
//! let record = driver::run(&cfg).unwrap();
//! println!("final test acc = {:.3}", record.final_test_acc());
//! ```

pub mod algorithms;
pub mod backend;
pub mod checkpoint;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod driver;
pub mod metrics;
pub mod native;
pub mod optimizer;
pub mod params;
pub mod runtime;
pub mod theory;
pub mod topology;
pub mod util;

pub use algorithms::{HierAvgSchedule, ReduceEvent};
pub use comm::{CommStats, CostModel, ReduceStrategy, Reducer};
pub use config::{BackendKind, RunConfig};
pub use coordinator::Trainer;
pub use metrics::{EpochStats, RunRecord};
pub use params::{FlatParams, ParamLayout};
pub use topology::Topology;
pub mod repro;
