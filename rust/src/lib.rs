//! # Hier-AVG
//!
//! A production-style reproduction of *"A Distributed Hierarchical
//! Averaging SGD Algorithm: Trading Local Reductions for Global
//! Reductions"* (Zhou & Cong, 2019) as a three-layer Rust + JAX + Pallas
//! distributed-training framework:
//!
//! - **L3 (this crate)** — the hierarchical-averaging coordinator
//!   (Algorithm 1, generalized): P learner replicas in an N-level
//!   hierarchy of nested groups (the paper's clusters-of-S is the 2-level
//!   case), per-level averaging intervals `K1 ≤ K2 ≤ …`, pluggable
//!   collectives (single-thread simulated, spawn-per-call sharded, or
//!   persistent-worker-pool pooled — bit-identical numerics), and
//!   pluggable execution models (`sim`: lockstep shared clock, or a
//!   virtual-time event engine with per-learner clocks, heterogeneous
//!   rates/stragglers, and group-local barriers — time model only, never
//!   the parameter math); plus the substrates it needs
//!   (cluster/topology model, an α–β hierarchical cost model, optimizers,
//!   synthetic datasets, metrics, and the paper's bounds in `theory`).
//!   See DESIGN.md §Engine for the three-layer decomposition.
//! - **L2 (python/compile/model.py, build-time)** — JAX model graphs
//!   (MLP classifiers + a transformer LM) AOT-lowered to HLO text.
//! - **L1 (python/compile/kernels, build-time)** — Pallas kernels (fused
//!   linear + group averaging) called by L2.
//!
//! At run time the coordinator executes the artifacts through the `xla`
//! crate's PJRT CPU client (`runtime`); Python is never on the training
//! path.  See DESIGN.md for the experiment index and its §Performance
//! section for the measured hot-path numbers (tracked per PR in the
//! committed `BENCH_*.json` files).
//!
//! ## Quick start
//!
//! ```no_run
//! use hier_avg::config::{BackendKind, RunConfig};
//! use hier_avg::driver;
//!
//! let mut cfg = RunConfig::defaults("quickstart");
//! cfg.p = 4;
//! cfg.s = 2;
//! cfg.k1 = 2;
//! cfg.k2 = 8;
//! cfg.backend = BackendKind::Xla; // or Native
//! let record = driver::run(&cfg).unwrap();
//! println!("final test acc = {:.3}", record.final_test_acc());
//! ```

pub mod algorithms;
pub mod backend;
pub mod checkpoint;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod driver;
pub mod exec;
pub mod metrics;
pub mod native;
pub mod optimizer;
pub mod params;
pub mod planner;
pub mod runtime;
pub mod sim;
pub mod theory;
pub mod topology;
pub mod util;

pub use algorithms::{
    HierAvgSchedule, HierSchedule, PolicyKind, ReduceEvent, SchedulePolicy, StaticPolicy,
};
pub use comm::{
    Collective, CollectiveKind, CommStats, CostModel, LevelStats, PooledCollective,
    ReduceStrategy, Reducer, ShardedCollective, SimulatedCollective,
};
pub use config::{BackendKind, RunConfig};
pub use coordinator::{Engine, Trainer};
pub use exec::WorkerPool;
pub use metrics::{EpochStats, RunRecord};
pub use params::{FlatParams, ParamLayout};
pub use planner::{Candidate, Ranked, ScoreCtx, SweepSpace};
pub use sim::{ExecBreakdown, ExecKind, ExecModel, HetSpec};
pub use topology::{HierTopology, Topology};
pub mod repro;
