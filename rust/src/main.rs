//! `hier-avg` CLI: train / repro / list / info.

use anyhow::{bail, Result};

use hier_avg::config::RunConfig;
use hier_avg::runtime::Manifest;
use hier_avg::util::cli::Args;
use hier_avg::{driver, repro};

const USAGE: &str = "\
hier-avg — distributed hierarchical averaging SGD (Zhou & Cong 2019)

USAGE:
  hier-avg train  [--config f.json] [--model M] [--backend xla|native]
                  [--p N] [--s N] [--k1 N] [--k2 N] [--epochs N]
                  [--levels S1,S2,..,P] [--ks K1,K2,..,KL]
                  [--links intra,inter,rack]
                  [--collective simulated|sharded[:N]|pooled[:N]]
                  [--pool-threads N] [--pool-pin] [--quiet]
                  [--schedule static|adaptive[:target[:gain]]|warmup[:k]]
                  [--exec lockstep|event] [--het F] [--straggler P[:M]]
                  [--faults PROB[:mttr] | trace:STEP@LEARNERxDOWN,..]
                  [--compress none|topk:R|randk:R|q8|q4[:ef|:noef]]
                  [--train-n N] [--test-n N] [--lr SCHED] [--seed N]
                  [--noise F] [--radius F] [--strategy ring|tree|naive]
                  [--out results/run.json] [--record-steps]
                  [--save-params ckpt.bin] [--init-params ckpt.bin]
                  [--trace results/trace.jsonl]
  hier-avg repro  <fig1|fig2|fig3|fig4|fig5|table1|thm34|thm35|thm36|comm|
                   asgd|adaptive|deep|all>
                  [--scale small|full] [--backend xla|native] [--out DIR]
                  [--from-sweep SWEEP_<p>.json]   (deep only)
                  [--schedule static|adaptive[:target[:gain]]|warmup[:k]]  (deep only)
  hier-avg sweep  --p N [--model M] [--steps T] [--levels-min N]
                  [--levels-max N] [--k1-grid 1,2,4] [--k2-max N]
                  [--strategy ring|tree|naive] [--no-rack] [--no-local]
                  [--schedule static|adaptive[:target[:gain]]|warmup[:k]]
                  [--het F] [--straggler P[:M]] [--faults PROB[:mttr]]
                  [--compress SPEC[,SPEC..]] [--seed N]
                  [--validate-top N] [--collective simulated|sharded|pooled]
                  [--timeline-only] [--top N] [--out SWEEP_<p>.json]
  hier-avg list                      # models in the artifact manifest
  hier-avg info   --model M          # manifest entry details

Hierarchy: --levels gives the N-level group-size chain (innermost first,
last = P, each dividing the next) and --ks the per-level averaging
intervals; omit both for the paper's two-level --p/--s/--k1/--k2 shape.
--links assigns each level's cost-model tier (default: innermost intra,
outer levels inter).  E.g. a GPU->node->rack run:
  --levels 4,16,64 --ks 2,8,32 --links intra,inter,rack

Schedule policy: --schedule selects who decides when each tier reduces.
static (default) follows the configured intervals verbatim; adaptive runs
the online straggler-aware controller — after every reduction it observes
the barrier stall the event timeline attributed to that tier and widens
the tier's interval when stall exceeds `target` (default 0.25) of the
tier's compute budget, narrowing back when the signal fades; widening is
capped by step-size condition (3.5) and narrowing floored at the base
schedule, so an adaptive run never fires more global reductions than the
static run of the same config (the optional gain is the controller's
EWMA weight — 0 is the neutral controller, bit-identical to static);
warmup averages densely early (interval cap doubles every k
steps, default 64) and decays to the configured schedule.  Adaptation
reads only the seeded virtual timeline, so runs stay deterministic and
replayable; saved checkpoints carry the controller state and refuse to
resume under a different --schedule.

Execution: --collective pooled reduces over the persistent worker pool
(no per-reduction thread spawn); --pool-threads sizes the pool shared by
reductions and the native backend's lane fan-out (0 = all cores).
--pool-pin pins pool slot i to CPU i (sched_setaffinity; no-op with a
notice on non-Linux hosts) — with the pool's stable shard->slot affinity
and first-touch page placement a shard's pages, worker, and CPU stay on
one NUMA node.  Pinning never changes results, only where they run.
Hot per-element loops (matmul microkernels, reductions, quantizers) use
AVX2 SIMD when the CPU has it, bit-identical to the portable scalar
path; set HIER_FORCE_SCALAR=1 to force the scalar path.
--exec selects the virtual-time model: lockstep (one shared clock,
default) or event (per-learner clocks, group-local barriers — a level
reduction blocks only its group at max arrival + collective cost).
Event mode accepts --het F (learner j's step time scales by
1 + F*j/(P-1)) and --straggler P[:M] (each learner-step spikes to M x
duration with probability P; seeded, never perturbs training numerics).
Homogeneous event runs are bit-identical to lockstep (DESIGN.md
section "Execution models").

Faults: --faults arms the elastic-membership layer (event mode only).
PROB[:mttr] preempts each live learner-step with probability PROB and
repairs the learner after mttr virtual steps (default 25);
trace:STEP@LEARNERxDOWN,.. scripts exact outages instead.  While a
learner is down its groups reduce over the survivors (reweighted
averaging over the members that arrived); on repair it restores from
the fleet's checkpointed average, warm-syncs to its innermost group,
and rejoins.  Under --schedule adaptive, a learner that persistently
stalls its group's barriers is migrated to outermost-only cadence
rather than widening everyone's interval.  Outages draw from a
dedicated seeded stream disjoint from training and straggler streams,
so fault runs replay bit-identically — and --faults 0 (armed layer,
zero events) is bit-identical to the plain event run.  sweep --faults
takes only the PROB[:mttr] form and prices every candidate against the
seeded outage regime (DESIGN.md section "Fault model").

Compression: --compress sparsifies or quantizes full-group reduction
payloads.  topk:R keeps the ceil(R*n) largest-magnitude entries of each
learner's delta-from-reference (deterministic, ties toward the lower
index); randk:R keeps a seeded random R fraction; q8/q4 transmit 8/4-bit
linear quantizations.  Error feedback is on by default (:noef disables
it): what a round leaves untransmitted is carried in a per-learner
residual and re-injected next round, so nothing is silently dropped.
Sparse payloads ride an index-exchange wire format (count + row indexes
+ values) and every compressed message is capped at its dense size.
Degraded survivor barriers under --faults always reduce densely.
--compress none builds no wrapper and is bit-identical to
pre-compression runs.  sweep --compress SPEC[,SPEC..] enumerates each
spec as a variant next to every dense candidate and ranks them jointly
(DESIGN.md section \"Compression\").

Sweep: enumerates hierarchy shapes for P learners (level counts
--levels-min..--levels-max, divisor fan-outs, optional rack-tier
outermost level), scores each with the alpha-beta cost model composed
over levels plus the Thm 3.4 convergence bound (K2 search capped by
step-size condition (3.5)), ranks by modelled time-to-target, optionally
replays the top --validate-top candidates through the engine (reporting
modelled-vs-measured comm deltas), and writes SWEEP_<p>.json.
--no-local restricts the space to the K-AVG baseline family (no local
averaging); --no-rack drops the rack-tier variants.  --schedule adds a
policy variant of every shape next to its static closed-form entry:
non-static candidates are priced by replaying their policy through the
virtual-time event engine (realized events, not the interval table), so
an adaptive schedule is ranked by what it would actually fire.
--timeline-only prices every candidate by timeline-only replay (the
event engine's O(1)-per-gap heap core, no parameter math, no validation
runs) — auto-selected at --p >= 16384, where it sweeps 2-4 level
hierarchies at up to --p 1048576 in seconds; pass --timeline-only=0 to
force closed-form pricing at large P.

LR schedules: const:0.05 | step:0.1@150=0.01 | cosine:0.1->0.001@200 |
              warmcos:0.1->0.001@5/200
";

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env(&[
        "record-steps", "help", "no-rack", "no-local", "timeline-only", "pool-pin", "quiet",
    ])?;
    if args.has("help") || args.positional.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    // Sweep-only switches are registered globally (the parser needs the
    // switch list up front); any other subcommand must reject them rather
    // than silently run a different configuration than asked.
    if args.positional[0] != "sweep" {
        for s in ["no-rack", "no-local", "timeline-only"] {
            // saw_switch also catches the explicit-off form (--no-rack=0),
            // which has() deliberately reports as false.
            if args.saw_switch(s) {
                bail!("--{s} only applies to the sweep subcommand");
            }
        }
    }
    match args.positional[0].as_str() {
        "train" => cmd_train(&args),
        "repro" => repro::cmd_repro(&args),
        "sweep" => cmd_sweep(&args),
        "list" => cmd_list(),
        "info" => cmd_info(&args),
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

fn cmd_sweep(args: &Args) -> Result<()> {
    use hier_avg::comm::{CollectiveKind, CostModel, ReduceStrategy};
    use hier_avg::planner::{self, ScoreCtx, SweepSpace};

    // A misspelled flag (or a value given to a switch, e.g. `--no-rack 0`,
    // which parses as the switch plus a stray positional) would otherwise
    // be consumed and ignored, sweeping a different space than asked with
    // no warning.
    args.check_known(&[
        "p", "model", "steps", "strategy", "levels-min", "levels-max", "k2-max", "k1-grid",
        "no-rack", "no-local", "top", "validate-top", "collective", "out", "het",
        "straggler", "faults", "seed", "schedule", "timeline-only", "compress",
    ])?;
    if args.positional.len() > 1 {
        bail!(
            "sweep takes no positional arguments (got {:?}); switches are --no-rack / --no-rack=0|1",
            &args.positional[1..]
        );
    }
    // USAGE documents --p as required: a silent default would sweep the
    // wrong population without warning.
    let p: usize = args
        .require("p")?
        .parse()
        .map_err(|e| anyhow::anyhow!("invalid --p: {e}"))?;
    let model = args.get_or("model", "quickstart");
    let steps: u64 = args.parse_or("steps", 20_000u64)?;
    let strategy = ReduceStrategy::parse(args.get_or("strategy", "ring"))
        .ok_or_else(|| anyhow::anyhow!("unknown strategy (ring|tree|naive)"))?;

    let mut space = SweepSpace::new(p)?;
    space.min_levels = args.parse_or("levels-min", space.min_levels)?;
    space.max_levels = args.parse_or("levels-max", space.max_levels)?;
    space.k2_max = args.parse_or("k2-max", space.k2_max)?;
    if let Some(grid) = args.get("k1-grid") {
        space.k1_grid = grid
            .split(',')
            .map(|x| {
                x.trim()
                    .parse::<u64>()
                    .map_err(|e| anyhow::anyhow!("invalid --k1-grid entry {x:?}: {e}"))
            })
            .collect::<Result<Vec<_>>>()?;
    }
    // `--no-rack` and `--no-rack=0|1` both resolve through Args::parse's
    // switch handling (an explicit false value stays off).
    if args.has("no-rack") {
        space.use_rack = false;
    }
    if args.has("no-local") {
        space.local_averaging = false;
    }
    if let Some(s) = args.get("schedule") {
        space.policy = hier_avg::algorithms::PolicyKind::parse(s)?;
    }
    if let Some(specs) = args.get("compress") {
        use hier_avg::comm::Compression;
        space.compress = specs
            .split(',')
            .map(|s| Compression::parse(s.trim()))
            .collect::<Result<Vec<_>>>()?;
    }

    let mut ctx = ScoreCtx::for_model(model, p, steps, strategy, CostModel::default())?;
    ctx.het.apply_args(args)?;
    ctx.het.seed = args.parse_or("seed", ctx.het.seed)?;
    ctx.het.validate()?;
    if let Some(f) = args.get("faults") {
        let plan = hier_avg::sim::parse_faults(f)?;
        plan.validate(p)?;
        ctx.faults = Some(plan.sampled().ok_or_else(|| {
            anyhow::anyhow!(
                "sweep --faults takes only the sampled PROB[:mttr] form: a scripted \
                 trace names learner indices, which do not transfer across candidate \
                 topologies (got {f:?}; replay a trace with train --faults instead)"
            )
        })?);
    }
    // Timeline-only pricing: explicit flag wins (either polarity);
    // otherwise auto-select at large P, where closed-form validation runs
    // are off the table anyway.
    ctx.timeline_only = if args.saw_switch("timeline-only") {
        args.has("timeline-only")
    } else {
        p >= planner::TIMELINE_ONLY_AUTO_P
    };
    if ctx.timeline_only && !args.saw_switch("timeline-only") {
        eprintln!(
            "[sweep] p={p} >= {}: timeline-only replay pricing auto-selected \
             (pass --timeline-only=0 to override)",
            planner::TIMELINE_ONLY_AUTO_P
        );
    }
    let ranked = planner::rank(&space, &ctx)?;
    eprintln!(
        "[sweep] p={p} model={model} horizon={steps} candidates={} k2_cap={} strategy={} \
         het={} straggler={}:{} faults={} timeline_only={}",
        ranked.len(),
        space.k2_cap(&ctx.bound),
        strategy.name(),
        ctx.het.het,
        ctx.het.straggler_prob,
        ctx.het.straggler_mult,
        ctx.faults
            .map(|f| format!("{}:{}", f.prob, f.mttr))
            .unwrap_or_else(|| "off".into()),
        ctx.timeline_only,
    );

    let top: usize = args.parse_or("top", 20usize)?;
    println!(
        "{:<4} {:<28} {:>14} {:>12} {:>12} {:>12} {:>12} {:>6}",
        "rank", "candidate", "time_to_tgt_s", "makespan_s", "comm_s", "comm_MB", "bound", "c3.5"
    );
    for (i, r) in ranked.iter().take(top).enumerate() {
        println!(
            "{:<4} {:<28} {:>14.4} {:>12.4} {:>12.4} {:>12.2} {:>12.6} {:>6}",
            i,
            r.candidate.label(),
            r.score.time_to_target,
            r.score.makespan_seconds,
            r.score.comm_seconds,
            r.score.comm_bytes as f64 / 1e6,
            r.score.bound,
            if r.score.condition_35 { "ok" } else { "viol" }
        );
    }

    let mut validate_top: usize = args.parse_or("validate-top", 3usize)?;
    if ctx.timeline_only && validate_top > 0 {
        if args.get("validate-top").is_some() {
            bail!(
                "--validate-top {validate_top} conflicts with timeline-only pricing \
                 (explicit --timeline-only, or auto-selected at --p >= {}): \
                 timeline-only sweeps never run training validation — pass \
                 --validate-top 0, or --timeline-only=0 to validate at small P",
                planner::TIMELINE_ONLY_AUTO_P
            );
        }
        eprintln!("[sweep] timeline-only: skipping validation runs (validate-top -> 0)");
        validate_top = 0;
    }
    let collective = match args.get("collective") {
        Some(c) => CollectiveKind::parse(c)?,
        None => CollectiveKind::Simulated,
    };
    let validations = planner::validate_top(&ranked, &ctx, model, validate_top, collective)?;
    for v in &validations {
        println!(
            "validated {:<28} steps={:<5} comm_s modelled={:.6} measured={:.6} delta={:+.3e} \
             makespan_s modelled={:.6} measured={:.6} delta={:+.3e} train_loss={:.4}",
            v.label,
            v.total_steps,
            v.modelled_comm_seconds,
            v.measured_comm_seconds,
            v.delta_seconds,
            v.modelled_makespan_seconds,
            v.measured_makespan_seconds,
            v.makespan_delta_seconds,
            v.final_train_loss
        );
    }

    let default_out = format!("SWEEP_{p}.json");
    let out = args.get_or("out", &default_out);
    planner::report::write_sweep(
        std::path::Path::new(out),
        &space,
        &ctx,
        model,
        &ranked,
        &validations,
    )?;
    eprintln!("wrote {out}");
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    // A misspelled flag would otherwise be silently ignored and the run
    // would train a different configuration than asked.
    args.check_known(&[
        "config", "model", "backend", "p", "s", "k1", "k2", "levels", "ks", "links",
        "collective", "pool-threads", "pool-pin", "quiet", "schedule", "exec", "het", "straggler",
        "faults", "compress", "epochs", "train-n", "test-n", "lr", "seed", "noise", "radius",
        "momentum", "strategy", "record-steps", "init-params", "save-params", "trace", "out",
        "help",
    ])?;
    let cfg = RunConfig::from_args(args)?;
    let topo = cfg.hierarchy()?;
    if !cfg.quiet {
        eprintln!(
            "[train] {} backend={:?} P={} levels={:?} K={:?} schedule={} collective={} exec={} epochs={}",
            cfg.model,
            cfg.backend,
            cfg.p,
            topo.sizes(),
            cfg.base_intervals(),
            cfg.schedule_policy.spec(),
            cfg.collective.name(),
            cfg.exec.name(),
            cfg.epochs
        );
    }
    let rec = driver::run(&cfg)?;
    for e in &rec.epochs {
        println!(
            "epoch {:>3}  train_loss {:.4}  train_acc {:.4}  test_loss {:.4}  test_acc {:.4}  sim_s {:.3}",
            e.epoch, e.train_loss, e.train_acc, e.test_loss, e.test_acc, e.sim_seconds
        );
    }
    println!(
        "done: steps={} global_reductions={} local_reductions={} comm_s={:.4} (global {:.4} / local {:.4})",
        rec.total_steps,
        rec.comm.global_reductions,
        rec.comm.local_reductions,
        rec.comm.total_seconds(),
        rec.comm.global_seconds,
        rec.comm.local_seconds,
    );
    if rec.comm.rack_reductions > 0 {
        println!(
            "rack fabric: {} reductions  {} bytes  {:.4}s",
            rec.comm.rack_reductions, rec.comm.rack_bytes, rec.comm.rack_seconds
        );
    }
    for (lev, ls) in rec.comm_levels.iter().enumerate() {
        let stall = rec.level_stall_seconds.get(lev).copied().unwrap_or(0.0);
        println!(
            "level {lev} (groups of {:>4}, {:?}): {:>8} reductions  {:>14} bytes  {:.4}s  stall {:.4}s",
            topo.size(lev),
            topo.link(lev),
            ls.reductions,
            ls.bytes,
            ls.seconds,
            stall
        );
    }
    println!(
        "exec {}: makespan {:.4}s  blocked {:.4}s  idle {:.4}s  straggler_events {}",
        rec.exec_model,
        rec.makespan_seconds,
        rec.blocked_seconds.iter().sum::<f64>(),
        rec.idle_seconds.iter().sum::<f64>(),
        rec.straggler_events
    );
    if let Some(s) = &rec.schedule {
        println!(
            "schedule {}: realized {:?}  final_intervals {:?}  adaptations {}  k2_clamp {}",
            s.policy,
            s.realized,
            s.final_intervals,
            s.changes.len(),
            s.k2_clamp
        );
    }
    if let Some(f) = &rec.faults {
        println!(
            "faults {}: preemptions {}  reentries {}  restores {}  migrations {}  \
             survivor_reductions {}  lost {:.4}s  membership_epoch {}",
            f.spec,
            f.preemptions,
            f.reentries,
            f.checkpoint_restores,
            f.migrations,
            f.survivor_reductions,
            f.lost_seconds,
            f.membership_epoch
        );
    }
    if let Some(c) = &rec.compression {
        println!(
            "compress {}: payload {} bytes (dense {})  moved {} bytes (dense {})  \
             saved {:.1}%  residual_l2 {:.3e}",
            c.spec,
            c.payload_bytes,
            c.dense_payload_bytes,
            c.compressed_bytes,
            c.dense_bytes,
            100.0 * (1.0 - c.compressed_bytes as f64 / c.dense_bytes.max(1) as f64),
            c.residual_l2
        );
    }
    if let Some(out) = args.get("out") {
        rec.write_json(std::path::Path::new(out))?;
        eprintln!("wrote {out}");
    }
    if let Some(path) = args.get("save-params") {
        let params = rec
            .final_params
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("final params were not kept"))?;
        let layout = driver::layout_for(&cfg)?;
        // The sidecar carries the policy spec + controller state so a
        // warm start resumes the controller (and refuses a different
        // --schedule), plus the run's topology chain and final membership
        // epoch so a resume under a different hierarchy — or of an
        // elastic run without its fault layer — fails loudly
        // (driver::check_resume_meta).
        let schedule = rec.schedule.as_ref().map(|s| (s.policy.as_str(), &s.state));
        hier_avg::checkpoint::save_with_meta(
            std::path::Path::new(path),
            &cfg.model,
            &layout,
            params,
            schedule,
            Some(topo.sizes()),
            rec.faults.as_ref().map(|f| f.membership_epoch).unwrap_or(0),
        )?;
        eprintln!("saved parameters to {path}");
    }
    if let Some(path) = args.get("trace") {
        rec.write_trace_jsonl(std::path::Path::new(path))?;
        eprintln!("wrote trace to {path}");
    }
    Ok(())
}

fn cmd_list() -> Result<()> {
    let m = Manifest::load_default()?;
    println!("{:<16} {:<6} {:>10} {:>7} {:>10}  train_p", "model", "kind", "params", "batch", "eval_batch");
    for (name, e) in &m.models {
        let kind = match &e.kind {
            hier_avg::runtime::ModelKind::Mlp { .. } => "mlp",
            hier_avg::runtime::ModelKind::Lm { .. } => "lm",
        };
        let ps: Vec<String> = e.train_files.keys().map(|p| p.to_string()).collect();
        println!(
            "{:<16} {:<6} {:>10} {:>7} {:>10}  [{}]",
            name,
            kind,
            e.layout.total,
            e.batch,
            e.eval_batch,
            ps.join(",")
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let m = Manifest::load_default()?;
    let e = m.model(args.require("model")?)?;
    println!("model: {}", e.name);
    println!("kind: {:?}", e.kind);
    println!("batch: {}  eval_batch: {}  n_params: {}", e.batch, e.eval_batch, e.layout.total);
    println!("train artifacts:");
    for (p, f) in &e.train_files {
        println!("  P={p}: {f}");
    }
    println!("eval: {}", e.eval_file);
    println!("init: {}", e.init_file);
    println!("tensors:");
    for t in &e.layout.entries {
        println!("  {:<24} {:?} @ {}", t.name, t.shape, t.offset);
    }
    Ok(())
}
