//! Dense f32 kernels for the native backend: row-major matmuls in the three
//! orientations backprop needs.  Each orientation has two implementations
//! sharing one contract: a register-blocked scalar microkernel (MR×NR
//! accumulator tiles + k-blocking, plain safe Rust — the portable fallback
//! and the executable reference), and an explicit AVX2 microkernel with a
//! wider tile (MR×16, two 8-lane registers per output row) selected at run
//! time.  Dispatch is `util::simd::simd_enabled()`: AVX2 detected via
//! `is_x86_feature_detected!` and `HIER_FORCE_SCALAR` not set.
//!
//! ## Bit-exactness contract (summation order)
//!
//! Every kernel — scalar or SIMD — keeps the *naive* formulation's
//! per-element summation order: each output element is a single
//! accumulator folded over the reduction index in strictly ascending
//! order.  Tiling only changes *which* elements are in flight together
//! (and round-trips accumulators through memory at k-block boundaries,
//! which is exact for f32), never the order of adds into any one element.
//! The SIMD kernels extend the same argument: lanes are *distinct output
//! elements* (consecutive output columns), so widening the tile from NR=8
//! to 16 changes scheduling, not any element's reduction order; and they
//! use separate `vmulps` + `vaddps` rather than fused multiply-add,
//! because `vfmadd` rounds once where scalar `acc + a*b` rounds twice and
//! would flip last-bit results.  The Bᵀ orientation (whose reduction index
//! is the contiguous one) packs a transposed b panel first — a pure copy,
//! no arithmetic — so its SIMD inner loop also walks the reduction index
//! in the scalar order.  Results are therefore bit-identical to the
//! straightforward triple loop under BOTH dispatch paths, and everything
//! downstream (grads, training curves, goldens, repro outputs) is
//! unchanged.  Enforced by the `*_bit_identical_to_naive` tests below and
//! by `rust/tests/linalg_simd.rs` (SIMD ≡ scalar across odd shapes and
//! unaligned sub-slices; CI repeats the suites under
//! `HIER_FORCE_SCALAR=1`).
//!
//! §Perf: the scalar MR×NR tiles amortize MR+NR loads over MR·NR FMAs
//! versus the old unblocked ikj loops; the AVX2 tiles then cut instruction
//! count ~8x on the j-contiguous orientations (measured ≥2x wall-clock on
//! the large bench shapes — see `BENCH_step.json` and DESIGN.md
//! §Performance).

use crate::util::simd;

/// Accumulator tile rows (output rows held in registers per microkernel).
const MR: usize = 4;
/// Scalar accumulator tile columns; 8 f32 = one AVX2 register per row.
const NR: usize = 8;
/// SIMD accumulator tile columns: two 8-lane registers per row (8 ymm
/// accumulators + 2 b-panel loads + 1 broadcast stays well inside 16).
const NR_S: usize = 16;
/// k-block length: a KC×NR panel of b (8 KiB) stays L1-resident while a
/// tile row of accumulators round-trips through c.
const KC: usize = 256;

/// c[n,fo] = a[n,fi] @ b[fi,fo]   (all row-major)
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], n: usize, fi: usize, fo: usize) {
    debug_assert!(a.len() >= n * fi && b.len() >= fi * fo && c.len() >= n * fo);
    #[cfg(target_arch = "x86_64")]
    if simd::simd_enabled() {
        unsafe { avx2::matmul(a, b, c, n, fi, fo) };
        return;
    }
    matmul_scalar(a, b, c, n, fi, fo);
}

/// The portable scalar microkernel (also the SIMD path's executable
/// reference; `rust/tests/linalg_simd.rs` pins the two bit-identical).
pub fn matmul_scalar(a: &[f32], b: &[f32], c: &mut [f32], n: usize, fi: usize, fo: usize) {
    debug_assert!(a.len() >= n * fi && b.len() >= fi * fo && c.len() >= n * fo);
    c[..n * fo].fill(0.0);
    let mut k0 = 0;
    while k0 < fi {
        let kend = (k0 + KC).min(fi);
        let mut i0 = 0;
        while i0 < n {
            let iend = (i0 + MR).min(n);
            let mut j0 = 0;
            while j0 < fo {
                let jend = (j0 + NR).min(fo);
                if iend - i0 == MR && jend - j0 == NR {
                    // Full MR×NR microkernel: accumulators live in
                    // registers across the k loop, loaded from / stored to
                    // c at the k-block boundary (exact round-trip).
                    let mut acc = [[0.0f32; NR]; MR];
                    for (r, row) in acc.iter_mut().enumerate() {
                        let crow = &c[(i0 + r) * fo + j0..(i0 + r) * fo + j0 + NR];
                        row.copy_from_slice(crow);
                    }
                    for k in k0..kend {
                        let brow = &b[k * fo + j0..k * fo + j0 + NR];
                        for (r, row) in acc.iter_mut().enumerate() {
                            let aik = a[(i0 + r) * fi + k];
                            for (av, &bv) in row.iter_mut().zip(brow) {
                                *av += aik * bv;
                            }
                        }
                    }
                    for (r, row) in acc.iter().enumerate() {
                        let crow = &mut c[(i0 + r) * fo + j0..(i0 + r) * fo + j0 + NR];
                        crow.copy_from_slice(row);
                    }
                } else {
                    // Remainder tile: plain ikj over the partial extent —
                    // identical per-element add order.
                    for i in i0..iend {
                        let arow = &a[i * fi..(i + 1) * fi];
                        let crow = &mut c[i * fo..(i + 1) * fo];
                        for k in k0..kend {
                            let aik = arow[k];
                            let brow = &b[k * fo..(k + 1) * fo];
                            for j in j0..jend {
                                crow[j] += aik * brow[j];
                            }
                        }
                    }
                }
                j0 = jend;
            }
            i0 = iend;
        }
        k0 = kend;
    }
}

/// c[fi,fo] = a[n,fi]^T @ b[n,fo]   (wgrad; the reduction runs over n)
pub fn matmul_at_b(a: &[f32], b: &[f32], c: &mut [f32], n: usize, fi: usize, fo: usize) {
    debug_assert!(a.len() >= n * fi && b.len() >= n * fo && c.len() >= fi * fo);
    #[cfg(target_arch = "x86_64")]
    if simd::simd_enabled() {
        unsafe { avx2::matmul_at_b(a, b, c, n, fi, fo) };
        return;
    }
    matmul_at_b_scalar(a, b, c, n, fi, fo);
}

pub fn matmul_at_b_scalar(a: &[f32], b: &[f32], c: &mut [f32], n: usize, fi: usize, fo: usize) {
    debug_assert!(a.len() >= n * fi && b.len() >= n * fo && c.len() >= fi * fo);
    c[..fi * fo].fill(0.0);
    let mut i0 = 0;
    while i0 < n {
        let iend = (i0 + KC).min(n);
        let mut k0 = 0;
        while k0 < fi {
            let kend = (k0 + MR).min(fi);
            let mut j0 = 0;
            while j0 < fo {
                let jend = (j0 + NR).min(fo);
                if kend - k0 == MR && jend - j0 == NR {
                    let mut acc = [[0.0f32; NR]; MR];
                    for (r, row) in acc.iter_mut().enumerate() {
                        let crow = &c[(k0 + r) * fo + j0..(k0 + r) * fo + j0 + NR];
                        row.copy_from_slice(crow);
                    }
                    for i in i0..iend {
                        let brow = &b[i * fo + j0..i * fo + j0 + NR];
                        for (r, row) in acc.iter_mut().enumerate() {
                            let aik = a[i * fi + k0 + r];
                            for (av, &bv) in row.iter_mut().zip(brow) {
                                *av += aik * bv;
                            }
                        }
                    }
                    for (r, row) in acc.iter().enumerate() {
                        let crow = &mut c[(k0 + r) * fo + j0..(k0 + r) * fo + j0 + NR];
                        crow.copy_from_slice(row);
                    }
                } else {
                    for i in i0..iend {
                        let arow = &a[i * fi..(i + 1) * fi];
                        let brow = &b[i * fo..(i + 1) * fo];
                        for k in k0..kend {
                            let aik = arow[k];
                            let crow = &mut c[k * fo..(k + 1) * fo];
                            for j in j0..jend {
                                crow[j] += aik * brow[j];
                            }
                        }
                    }
                }
                j0 = jend;
            }
            k0 = kend;
        }
        i0 = iend;
    }
}

/// Accumulator tile columns for the scalar Bᵀ orientation (output columns
/// index rows of b, so loads are strided; a narrower tile keeps register
/// pressure down while still amortizing the a-row loads).
const NR_T: usize = 4;

/// c[n,fi] = a[n,fo] @ b[fi,fo]^T   (dgrad; b is the row-major weight;
/// the reduction runs over fo)
pub fn matmul_a_bt(a: &[f32], b: &[f32], c: &mut [f32], n: usize, fo: usize, fi: usize) {
    debug_assert!(a.len() >= n * fo && b.len() >= fi * fo && c.len() >= n * fi);
    #[cfg(target_arch = "x86_64")]
    if simd::simd_enabled() {
        unsafe { avx2::matmul_a_bt(a, b, c, n, fo, fi) };
        return;
    }
    matmul_a_bt_scalar(a, b, c, n, fo, fi);
}

pub fn matmul_a_bt_scalar(a: &[f32], b: &[f32], c: &mut [f32], n: usize, fo: usize, fi: usize) {
    debug_assert!(a.len() >= n * fo && b.len() >= fi * fo && c.len() >= n * fi);
    c[..n * fi].fill(0.0);
    let mut j0 = 0;
    while j0 < fo {
        let jend = (j0 + KC).min(fo);
        let mut i0 = 0;
        while i0 < n {
            let iend = (i0 + MR).min(n);
            let mut k0 = 0;
            while k0 < fi {
                let kend = (k0 + NR_T).min(fi);
                if iend - i0 == MR && kend - k0 == NR_T {
                    let mut acc = [[0.0f32; NR_T]; MR];
                    for (r, row) in acc.iter_mut().enumerate() {
                        let crow = &c[(i0 + r) * fi + k0..(i0 + r) * fi + k0 + NR_T];
                        row.copy_from_slice(crow);
                    }
                    for j in j0..jend {
                        let mut bvals = [0.0f32; NR_T];
                        for (q, bv) in bvals.iter_mut().enumerate() {
                            *bv = b[(k0 + q) * fo + j];
                        }
                        for (r, row) in acc.iter_mut().enumerate() {
                            let av = a[(i0 + r) * fo + j];
                            for (cv, &bv) in row.iter_mut().zip(&bvals) {
                                *cv += av * bv;
                            }
                        }
                    }
                    for (r, row) in acc.iter().enumerate() {
                        let crow = &mut c[(i0 + r) * fi + k0..(i0 + r) * fi + k0 + NR_T];
                        crow.copy_from_slice(row);
                    }
                } else {
                    for i in i0..iend {
                        let arow = &a[i * fo..(i + 1) * fo];
                        let crow = &mut c[i * fi..(i + 1) * fi];
                        for k in k0..kend {
                            let brow = &b[k * fo..(k + 1) * fo];
                            let mut acc = crow[k];
                            for j in j0..jend {
                                acc += arow[j] * brow[j];
                            }
                            crow[k] = acc;
                        }
                    }
                }
                k0 = kend;
            }
            i0 = iend;
        }
        j0 = jend;
    }
}

/// z[n,fo] += broadcast bias[fo]
pub fn add_bias(z: &mut [f32], bias: &[f32], n: usize, fo: usize) {
    for i in 0..n {
        simd::add_assign(&mut z[i * fo..(i + 1) * fo], bias);
    }
}

// ---------------------------------------------------------------------------
// AVX2 microkernels
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{KC, MR, NR_S};
    use std::arch::x86_64::*;

    /// SIMD twin of [`super::matmul_scalar`]: same loop nest, MR×NR_S tile
    /// (two ymm accumulators per output row).  Lanes are output columns;
    /// each element still folds k ascending with separate mul + add, so
    /// per-element rounding equals the scalar kernel exactly.
    #[target_feature(enable = "avx2")]
    pub unsafe fn matmul(a: &[f32], b: &[f32], c: &mut [f32], n: usize, fi: usize, fo: usize) {
        c[..n * fo].fill(0.0);
        let (ap, bp, cp) = (a.as_ptr(), b.as_ptr(), c.as_mut_ptr());
        let mut k0 = 0;
        while k0 < fi {
            let kend = (k0 + KC).min(fi);
            let mut i0 = 0;
            while i0 < n {
                let iend = (i0 + MR).min(n);
                let mut j0 = 0;
                while j0 + NR_S <= fo {
                    if iend - i0 == MR {
                        let mut acc = [[_mm256_setzero_ps(); 2]; MR];
                        for (r, row) in acc.iter_mut().enumerate() {
                            row[0] = _mm256_loadu_ps(cp.add((i0 + r) * fo + j0));
                            row[1] = _mm256_loadu_ps(cp.add((i0 + r) * fo + j0 + 8));
                        }
                        for k in k0..kend {
                            let b0 = _mm256_loadu_ps(bp.add(k * fo + j0));
                            let b1 = _mm256_loadu_ps(bp.add(k * fo + j0 + 8));
                            for (r, row) in acc.iter_mut().enumerate() {
                                let av = _mm256_set1_ps(*ap.add((i0 + r) * fi + k));
                                // mul then add, never fmadd: two roundings,
                                // exactly the scalar `acc += aik * bv`.
                                row[0] = _mm256_add_ps(row[0], _mm256_mul_ps(av, b0));
                                row[1] = _mm256_add_ps(row[1], _mm256_mul_ps(av, b1));
                            }
                        }
                        for (r, row) in acc.iter().enumerate() {
                            _mm256_storeu_ps(cp.add((i0 + r) * fo + j0), row[0]);
                            _mm256_storeu_ps(cp.add((i0 + r) * fo + j0 + 8), row[1]);
                        }
                    } else {
                        // Short row block (< MR rows): one row at a time,
                        // same two ymm columns.
                        for i in i0..iend {
                            let mut c0 = _mm256_loadu_ps(cp.add(i * fo + j0));
                            let mut c1 = _mm256_loadu_ps(cp.add(i * fo + j0 + 8));
                            for k in k0..kend {
                                let av = _mm256_set1_ps(*ap.add(i * fi + k));
                                c0 = _mm256_add_ps(
                                    c0,
                                    _mm256_mul_ps(av, _mm256_loadu_ps(bp.add(k * fo + j0))),
                                );
                                c1 = _mm256_add_ps(
                                    c1,
                                    _mm256_mul_ps(av, _mm256_loadu_ps(bp.add(k * fo + j0 + 8))),
                                );
                            }
                            _mm256_storeu_ps(cp.add(i * fo + j0), c0);
                            _mm256_storeu_ps(cp.add(i * fo + j0 + 8), c1);
                        }
                    }
                    j0 += NR_S;
                }
                // Column remainder (< NR_S): scalar, identical k-ascending
                // per-element order.
                if j0 < fo {
                    for i in i0..iend {
                        for k in k0..kend {
                            let aik = *ap.add(i * fi + k);
                            for j in j0..fo {
                                *cp.add(i * fo + j) += aik * *bp.add(k * fo + j);
                            }
                        }
                    }
                }
                i0 = iend;
            }
            k0 = kend;
        }
    }

    /// SIMD twin of [`super::matmul_at_b_scalar`]: reduction over i, output
    /// rows indexed by k.  Same structure as `matmul` with roles swapped;
    /// the reduction index i ascends identically per element.
    #[target_feature(enable = "avx2")]
    pub unsafe fn matmul_at_b(a: &[f32], b: &[f32], c: &mut [f32], n: usize, fi: usize, fo: usize) {
        c[..fi * fo].fill(0.0);
        let (ap, bp, cp) = (a.as_ptr(), b.as_ptr(), c.as_mut_ptr());
        let mut i0 = 0;
        while i0 < n {
            let iend = (i0 + KC).min(n);
            let mut k0 = 0;
            while k0 < fi {
                let kend = (k0 + MR).min(fi);
                let mut j0 = 0;
                while j0 + NR_S <= fo {
                    if kend - k0 == MR {
                        let mut acc = [[_mm256_setzero_ps(); 2]; MR];
                        for (r, row) in acc.iter_mut().enumerate() {
                            row[0] = _mm256_loadu_ps(cp.add((k0 + r) * fo + j0));
                            row[1] = _mm256_loadu_ps(cp.add((k0 + r) * fo + j0 + 8));
                        }
                        for i in i0..iend {
                            let b0 = _mm256_loadu_ps(bp.add(i * fo + j0));
                            let b1 = _mm256_loadu_ps(bp.add(i * fo + j0 + 8));
                            for (r, row) in acc.iter_mut().enumerate() {
                                let av = _mm256_set1_ps(*ap.add(i * fi + k0 + r));
                                row[0] = _mm256_add_ps(row[0], _mm256_mul_ps(av, b0));
                                row[1] = _mm256_add_ps(row[1], _mm256_mul_ps(av, b1));
                            }
                        }
                        for (r, row) in acc.iter().enumerate() {
                            _mm256_storeu_ps(cp.add((k0 + r) * fo + j0), row[0]);
                            _mm256_storeu_ps(cp.add((k0 + r) * fo + j0 + 8), row[1]);
                        }
                    } else {
                        for r in 0..kend - k0 {
                            let mut c0 = _mm256_loadu_ps(cp.add((k0 + r) * fo + j0));
                            let mut c1 = _mm256_loadu_ps(cp.add((k0 + r) * fo + j0 + 8));
                            for i in i0..iend {
                                let av = _mm256_set1_ps(*ap.add(i * fi + k0 + r));
                                c0 = _mm256_add_ps(
                                    c0,
                                    _mm256_mul_ps(av, _mm256_loadu_ps(bp.add(i * fo + j0))),
                                );
                                c1 = _mm256_add_ps(
                                    c1,
                                    _mm256_mul_ps(av, _mm256_loadu_ps(bp.add(i * fo + j0 + 8))),
                                );
                            }
                            _mm256_storeu_ps(cp.add((k0 + r) * fo + j0), c0);
                            _mm256_storeu_ps(cp.add((k0 + r) * fo + j0 + 8), c1);
                        }
                    }
                    j0 += NR_S;
                }
                if j0 < fo {
                    for i in i0..iend {
                        for k in k0..kend {
                            let aik = *ap.add(i * fi + k);
                            for j in j0..fo {
                                *cp.add(k * fo + j) += aik * *bp.add(i * fo + j);
                            }
                        }
                    }
                }
                k0 = kend;
            }
            i0 = iend;
        }
    }

    /// SIMD twin of [`super::matmul_a_bt_scalar`]: the reduction index j
    /// is the contiguous one, so lanes = 8 output columns (rows of b) and
    /// the strided b panel is packed transposed once per (j-block,
    /// k-block) — a pure copy — making the inner loads contiguous while
    /// each element's j order stays exactly the scalar one.  Widened from
    /// the scalar NR_T=4 to 8 output columns per pass.
    #[target_feature(enable = "avx2")]
    pub unsafe fn matmul_a_bt(a: &[f32], b: &[f32], c: &mut [f32], n: usize, fo: usize, fi: usize) {
        c[..n * fi].fill(0.0);
        let (ap, bp, cp) = (a.as_ptr(), b.as_ptr(), c.as_mut_ptr());
        // KC j-values × 8 k-columns of b, transposed: 8 KiB, L1-resident.
        let mut packed = [0.0f32; KC * 8];
        let mut j0 = 0;
        while j0 < fo {
            let jend = (j0 + KC).min(fo);
            let jlen = jend - j0;
            let mut k0 = 0;
            while k0 + 8 <= fi {
                for jj in 0..jlen {
                    for q in 0..8 {
                        packed[jj * 8 + q] = *bp.add((k0 + q) * fo + j0 + jj);
                    }
                }
                let pp = packed.as_ptr();
                let mut i0 = 0;
                while i0 < n {
                    let iend = (i0 + MR).min(n);
                    if iend - i0 == MR {
                        let mut acc = [_mm256_setzero_ps(); MR];
                        for (r, av) in acc.iter_mut().enumerate() {
                            *av = _mm256_loadu_ps(cp.add((i0 + r) * fi + k0));
                        }
                        for jj in 0..jlen {
                            let bv = _mm256_loadu_ps(pp.add(jj * 8));
                            for (r, accr) in acc.iter_mut().enumerate() {
                                let av = _mm256_set1_ps(*ap.add((i0 + r) * fo + j0 + jj));
                                *accr = _mm256_add_ps(*accr, _mm256_mul_ps(av, bv));
                            }
                        }
                        for (r, av) in acc.iter().enumerate() {
                            _mm256_storeu_ps(cp.add((i0 + r) * fi + k0), *av);
                        }
                    } else {
                        for i in i0..iend {
                            let mut accv = _mm256_loadu_ps(cp.add(i * fi + k0));
                            for jj in 0..jlen {
                                let av = _mm256_set1_ps(*ap.add(i * fo + j0 + jj));
                                accv = _mm256_add_ps(
                                    accv,
                                    _mm256_mul_ps(av, _mm256_loadu_ps(pp.add(jj * 8))),
                                );
                            }
                            _mm256_storeu_ps(cp.add(i * fi + k0), accv);
                        }
                    }
                    i0 = iend;
                }
                k0 += 8;
            }
            if k0 < fi {
                // k remainder (< 8 output columns): scalar dot-products,
                // j ascending within the block exactly as the scalar
                // remainder path.
                for i in 0..n {
                    for k in k0..fi {
                        let mut acc = *cp.add(i * fi + k);
                        for j in j0..jend {
                            acc += *ap.add(i * fo + j) * *bp.add(k * fo + j);
                        }
                        *cp.add(i * fi + k) = acc;
                    }
                }
            }
            j0 = jend;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The reference formulation every kernel must match bit for bit: one
    /// accumulator per element, reduction index strictly ascending.
    fn naive(a: &[f32], b: &[f32], n: usize, fi: usize, fo: usize) -> Vec<f32> {
        let mut c = vec![0.0; n * fo];
        for i in 0..n {
            for j in 0..fo {
                for k in 0..fi {
                    c[i * fo + j] += a[i * fi + k] * b[k * fo + j];
                }
            }
        }
        c
    }

    fn naive_at_b(a: &[f32], b: &[f32], n: usize, fi: usize, fo: usize) -> Vec<f32> {
        let mut c = vec![0.0; fi * fo];
        for k in 0..fi {
            for j in 0..fo {
                for i in 0..n {
                    c[k * fo + j] += a[i * fi + k] * b[i * fo + j];
                }
            }
        }
        c
    }

    fn naive_a_bt(a: &[f32], b: &[f32], n: usize, fo: usize, fi: usize) -> Vec<f32> {
        let mut c = vec![0.0; n * fi];
        for i in 0..n {
            for k in 0..fi {
                for j in 0..fo {
                    c[i * fi + k] += a[i * fo + j] * b[k * fo + j];
                }
            }
        }
        c
    }

    fn mat(len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|i| (i as f32 * scale).sin()).collect()
    }

    /// Shapes chosen to hit every remainder path: below/at/above MR, NR,
    /// NR_S, NR_T, and straddling KC.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (2, 3, 5),
        (4, 8, 8),
        (5, 7, 3),
        (3, 9, 17),
        (16, 128, 256),
        (7, 33, 65),
        (4, 257, 12),
        (9, 300, 31),
        (300, 5, 7),
        (5, 40, 300),
        (6, 19, 16),
        (11, 16, 23),
    ];

    #[test]
    fn matmul_bit_identical_to_naive() {
        for &(n, fi, fo) in SHAPES {
            let a = mat(n * fi, 0.13);
            let b = mat(fi * fo, 0.29);
            let mut c = vec![0.0; n * fo];
            matmul(&a, &b, &mut c, n, fi, fo);
            assert_eq!(c, naive(&a, &b, n, fi, fo), "shape ({n},{fi},{fo})");
            let mut cs = vec![0.0; n * fo];
            matmul_scalar(&a, &b, &mut cs, n, fi, fo);
            assert_eq!(cs, c, "scalar twin, shape ({n},{fi},{fo})");
        }
    }

    #[test]
    fn at_b_bit_identical_to_naive() {
        for &(n, fi, fo) in SHAPES {
            let a = mat(n * fi, 0.7);
            let b = mat(n * fo, 0.3);
            let mut c = vec![0.0; fi * fo];
            matmul_at_b(&a, &b, &mut c, n, fi, fo);
            assert_eq!(c, naive_at_b(&a, &b, n, fi, fo), "shape ({n},{fi},{fo})");
            let mut cs = vec![0.0; fi * fo];
            matmul_at_b_scalar(&a, &b, &mut cs, n, fi, fo);
            assert_eq!(cs, c, "scalar twin, shape ({n},{fi},{fo})");
        }
    }

    #[test]
    fn a_bt_bit_identical_to_naive() {
        for &(n, fi, fo) in SHAPES {
            let a = mat(n * fo, 0.11);
            let b = mat(fi * fo, 0.17);
            let mut c = vec![0.0; n * fi];
            matmul_a_bt(&a, &b, &mut c, n, fo, fi);
            assert_eq!(c, naive_a_bt(&a, &b, n, fo, fi), "shape ({n},{fo},{fi})");
            let mut cs = vec![0.0; n * fi];
            matmul_a_bt_scalar(&a, &b, &mut cs, n, fo, fi);
            assert_eq!(cs, c, "scalar twin, shape ({n},{fo},{fi})");
        }
    }

    #[test]
    fn at_b_is_transpose_product() {
        let (n, fi, fo) = (6, 4, 5);
        let a = mat(n * fi, 0.7);
        let b = mat(n * fo, 0.3);
        let mut c = vec![0.0; fi * fo];
        matmul_at_b(&a, &b, &mut c, n, fi, fo);
        // reference: transpose a then multiply
        let mut at = vec![0.0; fi * n];
        for i in 0..n {
            for k in 0..fi {
                at[k * n + i] = a[i * fi + k];
            }
        }
        let expect = naive(&at, &b, fi, n, fo);
        for (x, y) in c.iter().zip(&expect) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn a_bt_is_transpose_product() {
        let (n, fo, fi) = (3, 6, 4);
        let a = mat(n * fo, 0.11);
        let b = mat(fi * fo, 0.17);
        let mut c = vec![0.0; n * fi];
        matmul_a_bt(&a, &b, &mut c, n, fo, fi);
        let mut bt = vec![0.0; fo * fi];
        for k in 0..fi {
            for j in 0..fo {
                bt[j * fi + k] = b[k * fo + j];
            }
        }
        let expect = naive(&a, &bt, n, fo, fi);
        for (x, y) in c.iter().zip(&expect) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn bias_broadcast() {
        let mut z = vec![0.0; 6];
        add_bias(&mut z, &[1.0, 2.0, 3.0], 2, 3);
        assert_eq!(z, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
    }
}
