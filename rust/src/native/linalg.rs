//! Dense f32 kernels for the native backend: row-major matmuls in the three
//! orientations backprop needs, written as register-blocked microkernels
//! (MR×NR accumulator tiles + k-blocking) in plain safe Rust, relying on
//! auto-vectorization of the fixed-size inner loops.
//!
//! ## Bit-exactness contract
//!
//! Every kernel keeps the *naive* formulation's per-element summation
//! order: each output element is a single accumulator folded over the
//! reduction index in strictly ascending order.  Tiling only changes
//! *which* elements are in flight together (and round-trips accumulators
//! through memory at k-block boundaries, which is exact for f32), never
//! the order of adds into any one element — so results are bit-identical
//! to the straightforward triple loop, and everything downstream (grads,
//! training curves, repro outputs) is unchanged.  Enforced by the
//! `*_bit_identical_to_naive` tests below across odd shapes.
//!
//! §Perf: the previous unblocked ikj loops streamed the full B (or C)
//! panel from cache for every row at ~3 memory ops per FMA; the MR×NR
//! tiles amortize MR+NR loads over MR·NR FMAs (see DESIGN.md
//! §Performance).

/// Accumulator tile rows (output rows held in registers per microkernel).
const MR: usize = 4;
/// Accumulator tile columns; 8 f32 = one AVX2 register per row.
const NR: usize = 8;
/// k-block length: a KC×NR panel of b (8 KiB) stays L1-resident while a
/// tile row of accumulators round-trips through c.
const KC: usize = 256;

/// c[n,fo] = a[n,fi] @ b[fi,fo]   (all row-major)
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], n: usize, fi: usize, fo: usize) {
    debug_assert!(a.len() >= n * fi && b.len() >= fi * fo && c.len() >= n * fo);
    c[..n * fo].fill(0.0);
    let mut k0 = 0;
    while k0 < fi {
        let kend = (k0 + KC).min(fi);
        let mut i0 = 0;
        while i0 < n {
            let iend = (i0 + MR).min(n);
            let mut j0 = 0;
            while j0 < fo {
                let jend = (j0 + NR).min(fo);
                if iend - i0 == MR && jend - j0 == NR {
                    // Full MR×NR microkernel: accumulators live in
                    // registers across the k loop, loaded from / stored to
                    // c at the k-block boundary (exact round-trip).
                    let mut acc = [[0.0f32; NR]; MR];
                    for (r, row) in acc.iter_mut().enumerate() {
                        let crow = &c[(i0 + r) * fo + j0..(i0 + r) * fo + j0 + NR];
                        row.copy_from_slice(crow);
                    }
                    for k in k0..kend {
                        let brow = &b[k * fo + j0..k * fo + j0 + NR];
                        for (r, row) in acc.iter_mut().enumerate() {
                            let aik = a[(i0 + r) * fi + k];
                            for (av, &bv) in row.iter_mut().zip(brow) {
                                *av += aik * bv;
                            }
                        }
                    }
                    for (r, row) in acc.iter().enumerate() {
                        let crow = &mut c[(i0 + r) * fo + j0..(i0 + r) * fo + j0 + NR];
                        crow.copy_from_slice(row);
                    }
                } else {
                    // Remainder tile: plain ikj over the partial extent —
                    // identical per-element add order.
                    for i in i0..iend {
                        let arow = &a[i * fi..(i + 1) * fi];
                        let crow = &mut c[i * fo..(i + 1) * fo];
                        for k in k0..kend {
                            let aik = arow[k];
                            let brow = &b[k * fo..(k + 1) * fo];
                            for j in j0..jend {
                                crow[j] += aik * brow[j];
                            }
                        }
                    }
                }
                j0 = jend;
            }
            i0 = iend;
        }
        k0 = kend;
    }
}

/// c[fi,fo] = a[n,fi]^T @ b[n,fo]   (wgrad; the reduction runs over n)
pub fn matmul_at_b(a: &[f32], b: &[f32], c: &mut [f32], n: usize, fi: usize, fo: usize) {
    debug_assert!(a.len() >= n * fi && b.len() >= n * fo && c.len() >= fi * fo);
    c[..fi * fo].fill(0.0);
    let mut i0 = 0;
    while i0 < n {
        let iend = (i0 + KC).min(n);
        let mut k0 = 0;
        while k0 < fi {
            let kend = (k0 + MR).min(fi);
            let mut j0 = 0;
            while j0 < fo {
                let jend = (j0 + NR).min(fo);
                if kend - k0 == MR && jend - j0 == NR {
                    let mut acc = [[0.0f32; NR]; MR];
                    for (r, row) in acc.iter_mut().enumerate() {
                        let crow = &c[(k0 + r) * fo + j0..(k0 + r) * fo + j0 + NR];
                        row.copy_from_slice(crow);
                    }
                    for i in i0..iend {
                        let brow = &b[i * fo + j0..i * fo + j0 + NR];
                        for (r, row) in acc.iter_mut().enumerate() {
                            let aik = a[i * fi + k0 + r];
                            for (av, &bv) in row.iter_mut().zip(brow) {
                                *av += aik * bv;
                            }
                        }
                    }
                    for (r, row) in acc.iter().enumerate() {
                        let crow = &mut c[(k0 + r) * fo + j0..(k0 + r) * fo + j0 + NR];
                        crow.copy_from_slice(row);
                    }
                } else {
                    for i in i0..iend {
                        let arow = &a[i * fi..(i + 1) * fi];
                        let brow = &b[i * fo..(i + 1) * fo];
                        for k in k0..kend {
                            let aik = arow[k];
                            let crow = &mut c[k * fo..(k + 1) * fo];
                            for j in j0..jend {
                                crow[j] += aik * brow[j];
                            }
                        }
                    }
                }
                j0 = jend;
            }
            k0 = kend;
        }
        i0 = iend;
    }
}

/// Accumulator tile columns for the Bᵀ orientation (output columns index
/// rows of b, so loads are strided; a narrower tile keeps register
/// pressure down while still amortizing the a-row loads).
const NR_T: usize = 4;

/// c[n,fi] = a[n,fo] @ b[fi,fo]^T   (dgrad; b is the row-major weight;
/// the reduction runs over fo)
pub fn matmul_a_bt(a: &[f32], b: &[f32], c: &mut [f32], n: usize, fo: usize, fi: usize) {
    debug_assert!(a.len() >= n * fo && b.len() >= fi * fo && c.len() >= n * fi);
    c[..n * fi].fill(0.0);
    let mut j0 = 0;
    while j0 < fo {
        let jend = (j0 + KC).min(fo);
        let mut i0 = 0;
        while i0 < n {
            let iend = (i0 + MR).min(n);
            let mut k0 = 0;
            while k0 < fi {
                let kend = (k0 + NR_T).min(fi);
                if iend - i0 == MR && kend - k0 == NR_T {
                    let mut acc = [[0.0f32; NR_T]; MR];
                    for (r, row) in acc.iter_mut().enumerate() {
                        let crow = &c[(i0 + r) * fi + k0..(i0 + r) * fi + k0 + NR_T];
                        row.copy_from_slice(crow);
                    }
                    for j in j0..jend {
                        let mut bvals = [0.0f32; NR_T];
                        for (q, bv) in bvals.iter_mut().enumerate() {
                            *bv = b[(k0 + q) * fo + j];
                        }
                        for (r, row) in acc.iter_mut().enumerate() {
                            let av = a[(i0 + r) * fo + j];
                            for (cv, &bv) in row.iter_mut().zip(&bvals) {
                                *cv += av * bv;
                            }
                        }
                    }
                    for (r, row) in acc.iter().enumerate() {
                        let crow = &mut c[(i0 + r) * fi + k0..(i0 + r) * fi + k0 + NR_T];
                        crow.copy_from_slice(row);
                    }
                } else {
                    for i in i0..iend {
                        let arow = &a[i * fo..(i + 1) * fo];
                        let crow = &mut c[i * fi..(i + 1) * fi];
                        for k in k0..kend {
                            let brow = &b[k * fo..(k + 1) * fo];
                            let mut acc = crow[k];
                            for j in j0..jend {
                                acc += arow[j] * brow[j];
                            }
                            crow[k] = acc;
                        }
                    }
                }
                k0 = kend;
            }
            i0 = iend;
        }
        j0 = jend;
    }
}

/// z[n,fo] += broadcast bias[fo]
pub fn add_bias(z: &mut [f32], bias: &[f32], n: usize, fo: usize) {
    for i in 0..n {
        let row = &mut z[i * fo..(i + 1) * fo];
        for (zv, &bv) in row.iter_mut().zip(bias) {
            *zv += bv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The reference formulation every kernel must match bit for bit: one
    /// accumulator per element, reduction index strictly ascending.
    fn naive(a: &[f32], b: &[f32], n: usize, fi: usize, fo: usize) -> Vec<f32> {
        let mut c = vec![0.0; n * fo];
        for i in 0..n {
            for j in 0..fo {
                for k in 0..fi {
                    c[i * fo + j] += a[i * fi + k] * b[k * fo + j];
                }
            }
        }
        c
    }

    fn naive_at_b(a: &[f32], b: &[f32], n: usize, fi: usize, fo: usize) -> Vec<f32> {
        let mut c = vec![0.0; fi * fo];
        for k in 0..fi {
            for j in 0..fo {
                for i in 0..n {
                    c[k * fo + j] += a[i * fi + k] * b[i * fo + j];
                }
            }
        }
        c
    }

    fn naive_a_bt(a: &[f32], b: &[f32], n: usize, fo: usize, fi: usize) -> Vec<f32> {
        let mut c = vec![0.0; n * fi];
        for i in 0..n {
            for k in 0..fi {
                for j in 0..fo {
                    c[i * fi + k] += a[i * fo + j] * b[k * fo + j];
                }
            }
        }
        c
    }

    fn mat(len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|i| (i as f32 * scale).sin()).collect()
    }

    /// Shapes chosen to hit every remainder path: below/at/above MR, NR,
    /// NR_T, and straddling KC.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (2, 3, 5),
        (4, 8, 8),
        (5, 7, 3),
        (3, 9, 17),
        (16, 128, 256),
        (7, 33, 65),
        (4, 257, 12),
        (9, 300, 31),
        (300, 5, 7),
        (5, 40, 300),
    ];

    #[test]
    fn matmul_bit_identical_to_naive() {
        for &(n, fi, fo) in SHAPES {
            let a = mat(n * fi, 0.13);
            let b = mat(fi * fo, 0.29);
            let mut c = vec![0.0; n * fo];
            matmul(&a, &b, &mut c, n, fi, fo);
            assert_eq!(c, naive(&a, &b, n, fi, fo), "shape ({n},{fi},{fo})");
        }
    }

    #[test]
    fn at_b_bit_identical_to_naive() {
        for &(n, fi, fo) in SHAPES {
            let a = mat(n * fi, 0.7);
            let b = mat(n * fo, 0.3);
            let mut c = vec![0.0; fi * fo];
            matmul_at_b(&a, &b, &mut c, n, fi, fo);
            assert_eq!(c, naive_at_b(&a, &b, n, fi, fo), "shape ({n},{fi},{fo})");
        }
    }

    #[test]
    fn a_bt_bit_identical_to_naive() {
        for &(n, fi, fo) in SHAPES {
            let a = mat(n * fo, 0.11);
            let b = mat(fi * fo, 0.17);
            let mut c = vec![0.0; n * fi];
            matmul_a_bt(&a, &b, &mut c, n, fo, fi);
            assert_eq!(c, naive_a_bt(&a, &b, n, fo, fi), "shape ({n},{fo},{fi})");
        }
    }

    #[test]
    fn at_b_is_transpose_product() {
        let (n, fi, fo) = (6, 4, 5);
        let a = mat(n * fi, 0.7);
        let b = mat(n * fo, 0.3);
        let mut c = vec![0.0; fi * fo];
        matmul_at_b(&a, &b, &mut c, n, fi, fo);
        // reference: transpose a then multiply
        let mut at = vec![0.0; fi * n];
        for i in 0..n {
            for k in 0..fi {
                at[k * n + i] = a[i * fi + k];
            }
        }
        let expect = naive(&at, &b, fi, n, fo);
        for (x, y) in c.iter().zip(&expect) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn a_bt_is_transpose_product() {
        let (n, fo, fi) = (3, 6, 4);
        let a = mat(n * fo, 0.11);
        let b = mat(fi * fo, 0.17);
        let mut c = vec![0.0; n * fi];
        matmul_a_bt(&a, &b, &mut c, n, fo, fi);
        let mut bt = vec![0.0; fo * fi];
        for k in 0..fi {
            for j in 0..fo {
                bt[j * fi + k] = b[k * fo + j];
            }
        }
        let expect = naive(&a, &bt, n, fo, fi);
        for (x, y) in c.iter().zip(&expect) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn bias_broadcast() {
        let mut z = vec![0.0; 6];
        add_bias(&mut z, &[1.0, 2.0, 3.0], 2, 3);
        assert_eq!(z, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
    }
}
