//! Dense f32 kernels for the native backend: row-major matmuls in the three
//! orientations backprop needs, written as ikj loops over contiguous rows
//! so the compiler auto-vectorizes the inner accumulation.

/// c[n,fo] = a[n,fi] @ b[fi,fo]   (all row-major)
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], n: usize, fi: usize, fo: usize) {
    debug_assert!(a.len() >= n * fi && b.len() >= fi * fo && c.len() >= n * fo);
    c[..n * fo].fill(0.0);
    for i in 0..n {
        let arow = &a[i * fi..(i + 1) * fi];
        let crow = &mut c[i * fo..(i + 1) * fo];
        for (k, &aik) in arow.iter().enumerate() {
            let brow = &b[k * fo..(k + 1) * fo];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
}

/// c[fi,fo] = a[n,fi]^T @ b[n,fo]   (wgrad)
pub fn matmul_at_b(a: &[f32], b: &[f32], c: &mut [f32], n: usize, fi: usize, fo: usize) {
    debug_assert!(a.len() >= n * fi && b.len() >= n * fo && c.len() >= fi * fo);
    c[..fi * fo].fill(0.0);
    for i in 0..n {
        let arow = &a[i * fi..(i + 1) * fi];
        let brow = &b[i * fo..(i + 1) * fo];
        for (k, &aik) in arow.iter().enumerate() {
            let crow = &mut c[k * fo..(k + 1) * fo];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
}

/// c[n,fi] = a[n,fo] @ b[fi,fo]^T   (dgrad; b is the row-major weight)
pub fn matmul_a_bt(a: &[f32], b: &[f32], c: &mut [f32], n: usize, fo: usize, fi: usize) {
    debug_assert!(a.len() >= n * fo && b.len() >= fi * fo && c.len() >= n * fi);
    for i in 0..n {
        let arow = &a[i * fo..(i + 1) * fo];
        let crow = &mut c[i * fi..(i + 1) * fi];
        for (k, cv) in crow.iter_mut().enumerate() {
            let brow = &b[k * fo..(k + 1) * fo];
            let mut acc = 0.0f32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            *cv = acc;
        }
    }
}

/// z[n,fo] += broadcast bias[fo]
pub fn add_bias(z: &mut [f32], bias: &[f32], n: usize, fo: usize) {
    for i in 0..n {
        let row = &mut z[i * fo..(i + 1) * fo];
        for (zv, &bv) in row.iter_mut().zip(bias) {
            *zv += bv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &[f32], b: &[f32], n: usize, fi: usize, fo: usize) -> Vec<f32> {
        let mut c = vec![0.0; n * fo];
        for i in 0..n {
            for j in 0..fo {
                for k in 0..fi {
                    c[i * fo + j] += a[i * fi + k] * b[k * fo + j];
                }
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let (n, fi, fo) = (5, 7, 3);
        let a: Vec<f32> = (0..n * fi).map(|i| (i as f32 * 0.13).sin()).collect();
        let b: Vec<f32> = (0..fi * fo).map(|i| (i as f32 * 0.29).cos()).collect();
        let mut c = vec![0.0; n * fo];
        matmul(&a, &b, &mut c, n, fi, fo);
        let expect = naive(&a, &b, n, fi, fo);
        for (x, y) in c.iter().zip(&expect) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn at_b_is_transpose_product() {
        let (n, fi, fo) = (6, 4, 5);
        let a: Vec<f32> = (0..n * fi).map(|i| (i as f32 * 0.7).sin()).collect();
        let b: Vec<f32> = (0..n * fo).map(|i| (i as f32 * 0.3).cos()).collect();
        let mut c = vec![0.0; fi * fo];
        matmul_at_b(&a, &b, &mut c, n, fi, fo);
        // reference: transpose a then multiply
        let mut at = vec![0.0; fi * n];
        for i in 0..n {
            for k in 0..fi {
                at[k * n + i] = a[i * fi + k];
            }
        }
        let expect = naive(&at, &b, fi, n, fo);
        for (x, y) in c.iter().zip(&expect) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn a_bt_is_transpose_product() {
        let (n, fo, fi) = (3, 6, 4);
        let a: Vec<f32> = (0..n * fo).map(|i| (i as f32 * 0.11).sin()).collect();
        let b: Vec<f32> = (0..fi * fo).map(|i| (i as f32 * 0.17).cos()).collect();
        let mut c = vec![0.0; n * fi];
        matmul_a_bt(&a, &b, &mut c, n, fo, fi);
        let mut bt = vec![0.0; fo * fi];
        for k in 0..fi {
            for j in 0..fo {
                bt[j * fi + k] = b[k * fo + j];
            }
        }
        let expect = naive(&a, &bt, n, fo, fi);
        for (x, y) in c.iter().zip(&expect) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn bias_broadcast() {
        let mut z = vec![0.0; 6];
        add_bias(&mut z, &[1.0, 2.0, 3.0], 2, 3);
        assert_eq!(z, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
    }
}
