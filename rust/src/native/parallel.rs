//! Thread-parallel native backend: learners are split across pool lanes,
//! each with its own `NativeMlp` scratch (the forward/backward workspaces
//! are not shareable).  Exact same numerics as the serial backend — the
//! per-learner computation is untouched; only the loop is parallel.
//!
//! Lane fan-out dispatches onto the persistent `exec::WorkerPool` (shared
//! with the pooled collective when both are sized alike) instead of
//! spawning scoped threads per step — the dispatch that used to cost a
//! spawn+join per training step now costs a condvar wake.  Chunk
//! boundaries are the same ceil-div math as the old scoped path, so
//! results are bit-identical.

use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::backend::{StepBackend, StepOut};
use crate::data::BatchBuf;
use crate::exec::{self, WorkerPool};
use crate::params::{FlatParams, Rows, RowsMut};

use super::NativeMlp;

pub struct ParallelNativeMlp {
    lanes: Vec<NativeMlp>,
    pool: Arc<WorkerPool>,
    dims: Vec<usize>,
    batch: usize,
    eval_batch_size: usize,
}

/// One lane's share of a `grads` dispatch: its scratch backend plus the
/// disjoint output chunks it owns (gradient rows as a split-off arena
/// view).  Wrapped in a `Mutex` per task so the shared `Fn(usize)` pool
/// closure can take the mutable borrows; each mutex is locked exactly
/// once, uncontended.
struct GradTask<'a> {
    lane: &'a mut NativeMlp,
    gchunk: RowsMut<'a>,
    ochunk: &'a mut [StepOut],
    start: usize,
}

struct EvalTask<'a> {
    lane: &'a mut NativeMlp,
    start: usize,
    len: usize,
    out: (f32, f32),
}

impl ParallelNativeMlp {
    /// `threads` worker lanes (clamped to available parallelism), fanned
    /// out over the process-wide shared pool.
    pub fn new(
        dims: &[usize],
        batch: usize,
        eval_batch_size: usize,
        threads: usize,
    ) -> Result<ParallelNativeMlp> {
        Self::with_pool(dims, batch, eval_batch_size, threads, exec::shared_pool(0))
    }

    /// Like [`ParallelNativeMlp::new`] but on a caller-supplied pool (the
    /// engine passes the run's `--pool-threads`-sized pool so step compute
    /// and reductions share one set of threads).
    pub fn with_pool(
        dims: &[usize],
        batch: usize,
        eval_batch_size: usize,
        threads: usize,
        pool: Arc<WorkerPool>,
    ) -> Result<ParallelNativeMlp> {
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let lanes = threads.clamp(1, hw.max(1));
        Ok(ParallelNativeMlp {
            lanes: (0..lanes)
                .map(|_| NativeMlp::new(dims, batch, eval_batch_size))
                .collect::<Result<_>>()?,
            pool,
            dims: dims.to_vec(),
            batch,
            eval_batch_size,
        })
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }
}

impl StepBackend for ParallelNativeMlp {
    fn train_batch(&self) -> usize {
        self.batch
    }

    fn eval_batch(&self) -> usize {
        self.eval_batch_size
    }

    fn n_params(&self) -> usize {
        self.lanes[0].n_params()
    }

    fn grads(
        &mut self,
        replicas: Rows<'_>,
        batch: &BatchBuf,
        grads_out: RowsMut<'_>,
        outs: &mut [StepOut],
    ) -> Result<()> {
        let p = replicas.rows();
        let b = self.batch;
        let d = self.dims[0];
        if batch.rows != p * b {
            bail!("batch rows {} != P*B = {}", batch.rows, p * b);
        }
        let n_lanes = self.lanes.len().min(p).max(1);
        let per_lane = p.div_ceil(n_lanes);
        // Split the outputs into per-lane chunks (same ceil-div boundaries
        // as the old scoped-thread fan-out; gradient rows split straight
        // out of the arena view) and dispatch.
        let mut tasks: Vec<Mutex<GradTask>> = Vec::with_capacity(n_lanes);
        {
            let mut gs = grads_out;
            let mut os = &mut outs[..p];
            let mut lanes = self.lanes.iter_mut();
            let mut start = 0usize;
            while start < p {
                let take = per_lane.min(p - start);
                let (gchunk, grest) = gs.split_rows_at(take);
                gs = grest;
                let (ochunk, orest) = std::mem::take(&mut os).split_at_mut(take);
                os = orest;
                let lane = lanes.next().expect("at least one lane per chunk");
                tasks.push(Mutex::new(GradTask { lane, gchunk, ochunk, start }));
                start += take;
            }
        }
        let xf = &batch.xf;
        let y = &batch.y;
        self.pool.run(tasks.len(), &|ti| {
            let mut guard = tasks[ti].lock().expect("grad task lock");
            let t = &mut *guard;
            for i in 0..t.gchunk.rows() {
                let j = t.start + i;
                let x = &xf[j * b * d..(j + 1) * b * d];
                let ys = &y[j * b..(j + 1) * b];
                t.ochunk[i] = t.lane.grads_single(replicas.row(j), x, ys, b, t.gchunk.row_mut(i));
            }
        });
        Ok(())
    }

    fn eval_batch_stats(
        &mut self,
        params: &FlatParams,
        batch: &BatchBuf,
        n: usize,
    ) -> Result<(f32, f32)> {
        let d = self.dims[0];
        let lanes = self.lanes.len().min(n).max(1);
        if lanes == 1 {
            return self.lanes[0].eval_batch_stats(params, batch, n);
        }
        // Fan the evaluation rows across lanes like `grads` fans learners;
        // each lane's scratch holds up to eval_batch rows, and a chunk is
        // never larger than that.  Partial sums are combined in lane order,
        // so the result is deterministic for a fixed lane count.
        let per = n.div_ceil(lanes);
        let mut tasks: Vec<Mutex<EvalTask>> = Vec::with_capacity(lanes);
        for (i, lane) in self.lanes.iter_mut().take(lanes).enumerate() {
            let start = i * per;
            if start >= n {
                break;
            }
            let len = per.min(n - start);
            tasks.push(Mutex::new(EvalTask { lane, start, len, out: (0.0, 0.0) }));
        }
        let xf = &batch.xf;
        let y = &batch.y;
        self.pool.run(tasks.len(), &|ti| {
            let mut guard = tasks[ti].lock().expect("eval task lock");
            let t = &mut *guard;
            let x = &xf[t.start * d..(t.start + t.len) * d];
            let ys = &y[t.start..t.start + t.len];
            t.out = t.lane.eval_rows(params, x, ys, t.len);
        });
        let mut sum_loss = 0.0f32;
        let mut ncorrect = 0.0f32;
        for t in tasks {
            let t = t.into_inner().expect("eval task lock");
            sum_loss += t.out.0;
            ncorrect += t.out.1;
        }
        Ok((sum_loss, ncorrect))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{ClassifyData, DataSource, MixtureSpec};
    use crate::params::ParamArena;
    use crate::util::rng::Pcg32;

    #[test]
    fn parallel_matches_serial_exactly() {
        let dims = [12usize, 24, 5];
        let b = 8;
        let p = 7; // deliberately not a multiple of the lane count
        let mut serial = NativeMlp::new(&dims, b, 16).unwrap();
        let mut par = ParallelNativeMlp::new(&dims, b, 16, 3).unwrap();

        let mut rng = Pcg32::seeded(1);
        let init = serial.init(&mut rng);
        let mut replicas = vec![init; p];
        for (j, r) in replicas.iter_mut().enumerate() {
            for v in r.iter_mut() {
                *v += 0.003 * j as f32;
            }
        }
        let data = ClassifyData::generate(MixtureSpec {
            dim: 12,
            classes: 5,
            train_n: 256,
            test_n: 32,
            radius: 1.0,
            noise: 0.7,
            subclusters: 1,
            label_noise: 0.0,
            seed: 3,
        });
        let mut batch = BatchBuf::default();
        let mut brng = Pcg32::seeded(9);
        for _ in 0..p {
            data.fill_train(&mut brng, b, &mut batch);
        }

        let n = serial.n_params();
        let reps = ParamArena::from_rows(&replicas);
        let mut gs = ParamArena::zeroed(p, n);
        let mut os = vec![StepOut::default(); p];
        serial.grads(reps.view(), &batch, gs.view_mut(), &mut os).unwrap();

        let mut gp = ParamArena::zeroed(p, n);
        let mut op = vec![StepOut::default(); p];
        par.grads(reps.view(), &batch, gp.view_mut(), &mut op).unwrap();

        for j in 0..p {
            assert_eq!(gs.row(j), gp.row(j), "learner {j} grads");
            assert_eq!(os[j].loss, op[j].loss);
            assert_eq!(os[j].ncorrect, op[j].ncorrect);
        }
    }

    #[test]
    fn parallel_matches_serial_on_oversubscribed_pool() {
        // More pool slots than hardware threads (and than lanes): the
        // static task assignment keeps results bit-identical anyway.
        let dims = [10usize, 16, 4];
        let b = 4;
        let p = 5;
        let mut serial = NativeMlp::new(&dims, b, 8).unwrap();
        let mut par =
            ParallelNativeMlp::with_pool(&dims, b, 8, 4, exec::shared_pool(32)).unwrap();

        let mut rng = Pcg32::seeded(11);
        let init = serial.init(&mut rng);
        let replicas = vec![init; p];
        let data = ClassifyData::generate(MixtureSpec {
            dim: 10,
            classes: 4,
            train_n: 128,
            test_n: 32,
            radius: 1.0,
            noise: 0.5,
            subclusters: 1,
            label_noise: 0.0,
            seed: 4,
        });
        let mut batch = BatchBuf::default();
        let mut brng = Pcg32::seeded(2);
        for _ in 0..p {
            data.fill_train(&mut brng, b, &mut batch);
        }
        let n = serial.n_params();
        let reps = ParamArena::from_rows(&replicas);
        let mut gs = ParamArena::zeroed(p, n);
        let mut os = vec![StepOut::default(); p];
        serial.grads(reps.view(), &batch, gs.view_mut(), &mut os).unwrap();
        let mut gp = ParamArena::zeroed(p, n);
        let mut op = vec![StepOut::default(); p];
        par.grads(reps.view(), &batch, gp.view_mut(), &mut op).unwrap();
        assert_eq!(gs, gp);
        // Dispatching twice is deterministic.
        let mut gp2 = ParamArena::zeroed(p, n);
        let mut op2 = vec![StepOut::default(); p];
        par.grads(reps.view(), &batch, gp2.view_mut(), &mut op2).unwrap();
        assert_eq!(gp, gp2);
        let _ = (os, op, op2);
    }

    #[test]
    fn parallel_eval_matches_serial() {
        let dims = [10usize, 20, 4];
        let eval_b = 23; // deliberately not a multiple of the lane count
        let mut serial = NativeMlp::new(&dims, 4, eval_b).unwrap();
        let mut par = ParallelNativeMlp::new(&dims, 4, eval_b, 3).unwrap();

        let mut rng = Pcg32::seeded(17);
        let params = serial.init(&mut rng);
        let data = ClassifyData::generate(MixtureSpec {
            dim: 10,
            classes: 4,
            train_n: 64,
            test_n: 64,
            radius: 1.0,
            noise: 0.9,
            subclusters: 1,
            label_noise: 0.0,
            seed: 8,
        });
        let mut buf = BatchBuf::default();
        assert_eq!(data.fill_eval(0, eval_b, &mut buf), eval_b);

        let (ls, cs) = serial.eval_batch_stats(&params, &buf, eval_b).unwrap();
        let (lp, cp) = par.eval_batch_stats(&params, &buf, eval_b).unwrap();
        // Correct counts are integer-valued f32 sums: exact in any order.
        assert_eq!(cs, cp);
        // The loss sum is chunked per lane; only the accumulation order
        // differs, so the results agree to rounding.
        assert!(
            (ls - lp).abs() <= 1e-5 * ls.abs().max(1.0),
            "serial {ls} vs parallel {lp}"
        );
        // Deterministic for a fixed lane count.
        let (lp2, cp2) = par.eval_batch_stats(&params, &buf, eval_b).unwrap();
        assert_eq!((lp, cp), (lp2, cp2));
    }

    #[test]
    fn lane_count_clamps() {
        let par = ParallelNativeMlp::new(&[4, 4, 2], 2, 4, 10_000).unwrap();
        assert!(par.n_lanes() >= 1);
        assert!(par.n_lanes() <= 10_000);
    }
}
