//! Thread-parallel native backend: learners are split across OS threads,
//! each with its own `NativeMlp` scratch (the forward/backward workspaces
//! are not shareable).  Exact same numerics as the serial backend — the
//! per-learner computation is untouched; only the loop is parallel.

use anyhow::{bail, Result};

use crate::backend::{StepBackend, StepOut};
use crate::data::BatchBuf;
use crate::params::FlatParams;

use super::NativeMlp;

pub struct ParallelNativeMlp {
    lanes: Vec<NativeMlp>,
    dims: Vec<usize>,
    batch: usize,
    eval_batch_size: usize,
}

impl ParallelNativeMlp {
    /// `threads` worker lanes (clamped to available parallelism).
    pub fn new(
        dims: &[usize],
        batch: usize,
        eval_batch_size: usize,
        threads: usize,
    ) -> Result<ParallelNativeMlp> {
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let lanes = threads.clamp(1, hw.max(1));
        Ok(ParallelNativeMlp {
            lanes: (0..lanes)
                .map(|_| NativeMlp::new(dims, batch, eval_batch_size))
                .collect::<Result<_>>()?,
            dims: dims.to_vec(),
            batch,
            eval_batch_size,
        })
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }
}

impl StepBackend for ParallelNativeMlp {
    fn train_batch(&self) -> usize {
        self.batch
    }

    fn eval_batch(&self) -> usize {
        self.eval_batch_size
    }

    fn n_params(&self) -> usize {
        self.lanes[0].n_params()
    }

    fn grads(
        &mut self,
        replicas: &[FlatParams],
        batch: &BatchBuf,
        grads_out: &mut [FlatParams],
        outs: &mut [StepOut],
    ) -> Result<()> {
        let p = replicas.len();
        let b = self.batch;
        let d = self.dims[0];
        if batch.rows != p * b {
            bail!("batch rows {} != P*B = {}", batch.rows, p * b);
        }
        let n_lanes = self.lanes.len().min(p).max(1);
        let per_lane = p.div_ceil(n_lanes);
        // Split the output slices into per-lane chunks and fan out.
        let grad_chunks: Vec<&mut [FlatParams]> = grads_out.chunks_mut(per_lane).collect();
        let out_chunks: Vec<&mut [StepOut]> = outs.chunks_mut(per_lane).collect();
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for (lane_idx, (lane, (gchunk, ochunk))) in self
                .lanes
                .iter_mut()
                .zip(grad_chunks.into_iter().zip(out_chunks))
                .enumerate()
            {
                let start = lane_idx * per_lane;
                let xf = &batch.xf;
                let y = &batch.y;
                handles.push(scope.spawn(move || {
                    for (i, (g, o)) in gchunk.iter_mut().zip(ochunk.iter_mut()).enumerate() {
                        let j = start + i;
                        let x = &xf[j * b * d..(j + 1) * b * d];
                        let ys = &y[j * b..(j + 1) * b];
                        *o = lane.grads_single(&replicas[j], x, ys, b, g);
                    }
                }));
            }
            for h in handles {
                h.join().expect("native lane panicked");
            }
        });
        Ok(())
    }

    fn eval_batch_stats(
        &mut self,
        params: &FlatParams,
        batch: &BatchBuf,
        n: usize,
    ) -> Result<(f32, f32)> {
        let d = self.dims[0];
        let lanes = self.lanes.len().min(n).max(1);
        if lanes == 1 {
            return self.lanes[0].eval_batch_stats(params, batch, n);
        }
        // Fan the evaluation rows across lanes like `grads` fans learners;
        // each lane's scratch holds up to eval_batch rows, and a chunk is
        // never larger than that.  Partial sums are combined in lane order,
        // so the result is deterministic for a fixed lane count.
        let per = n.div_ceil(lanes);
        let partials: Vec<(f32, f32)> = std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for (i, lane) in self.lanes.iter_mut().take(lanes).enumerate() {
                let start = i * per;
                if start >= n {
                    break;
                }
                let len = per.min(n - start);
                let x = &batch.xf[start * d..(start + len) * d];
                let y = &batch.y[start..start + len];
                handles.push(scope.spawn(move || lane.eval_rows(params, x, y, len)));
            }
            handles.into_iter().map(|h| h.join().expect("native eval lane panicked")).collect()
        });
        let mut sum_loss = 0.0f32;
        let mut ncorrect = 0.0f32;
        for (l, c) in partials {
            sum_loss += l;
            ncorrect += c;
        }
        Ok((sum_loss, ncorrect))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{ClassifyData, DataSource, MixtureSpec};
    use crate::util::rng::Pcg32;

    #[test]
    fn parallel_matches_serial_exactly() {
        let dims = [12usize, 24, 5];
        let b = 8;
        let p = 7; // deliberately not a multiple of the lane count
        let mut serial = NativeMlp::new(&dims, b, 16).unwrap();
        let mut par = ParallelNativeMlp::new(&dims, b, 16, 3).unwrap();

        let mut rng = Pcg32::seeded(1);
        let init = serial.init(&mut rng);
        let mut replicas = vec![init; p];
        for (j, r) in replicas.iter_mut().enumerate() {
            for v in r.iter_mut() {
                *v += 0.003 * j as f32;
            }
        }
        let data = ClassifyData::generate(MixtureSpec {
            dim: 12,
            classes: 5,
            train_n: 256,
            test_n: 32,
            radius: 1.0,
            noise: 0.7,
            subclusters: 1,
            label_noise: 0.0,
            seed: 3,
        });
        let mut batch = BatchBuf::default();
        let mut brng = Pcg32::seeded(9);
        for _ in 0..p {
            data.fill_train(&mut brng, b, &mut batch);
        }

        let n = serial.n_params();
        let mut gs = vec![vec![0.0f32; n]; p];
        let mut os = vec![StepOut::default(); p];
        serial.grads(&replicas, &batch, &mut gs, &mut os).unwrap();

        let mut gp = vec![vec![0.0f32; n]; p];
        let mut op = vec![StepOut::default(); p];
        par.grads(&replicas, &batch, &mut gp, &mut op).unwrap();

        for j in 0..p {
            assert_eq!(gs[j], gp[j], "learner {j} grads");
            assert_eq!(os[j].loss, op[j].loss);
            assert_eq!(os[j].ncorrect, op[j].ncorrect);
        }
    }

    #[test]
    fn parallel_eval_matches_serial() {
        let dims = [10usize, 20, 4];
        let eval_b = 23; // deliberately not a multiple of the lane count
        let mut serial = NativeMlp::new(&dims, 4, eval_b).unwrap();
        let mut par = ParallelNativeMlp::new(&dims, 4, eval_b, 3).unwrap();

        let mut rng = Pcg32::seeded(17);
        let params = serial.init(&mut rng);
        let data = ClassifyData::generate(MixtureSpec {
            dim: 10,
            classes: 4,
            train_n: 64,
            test_n: 64,
            radius: 1.0,
            noise: 0.9,
            subclusters: 1,
            label_noise: 0.0,
            seed: 8,
        });
        let mut buf = BatchBuf::default();
        assert_eq!(data.fill_eval(0, eval_b, &mut buf), eval_b);

        let (ls, cs) = serial.eval_batch_stats(&params, &buf, eval_b).unwrap();
        let (lp, cp) = par.eval_batch_stats(&params, &buf, eval_b).unwrap();
        // Correct counts are integer-valued f32 sums: exact in any order.
        assert_eq!(cs, cp);
        // The loss sum is chunked per lane; only the accumulation order
        // differs, so the results agree to rounding.
        assert!(
            (ls - lp).abs() <= 1e-5 * ls.abs().max(1.0),
            "serial {ls} vs parallel {lp}"
        );
        // Deterministic for a fixed lane count.
        let (lp2, cp2) = par.eval_batch_stats(&params, &buf, eval_b).unwrap();
        assert_eq!((lp, cp), (lp2, cp2));
    }

    #[test]
    fn lane_count_clamps() {
        let par = ParallelNativeMlp::new(&[4, 4, 2], 2, 4, 10_000).unwrap();
        assert!(par.n_lanes() >= 1);
        assert!(par.n_lanes() <= 10_000);
    }
}
