//! Pure-Rust MLP backend: forward, softmax cross-entropy, and hand-written
//! backprop, numerically identical (up to fp reassociation) to the JAX L2
//! model with the Pallas L1 kernel.
//!
//! Exists as a substrate (per DESIGN.md): it cross-validates the XLA
//! artifacts' numerics in integration tests, runs property sweeps fast, and
//! powers large-P experiments without XLA in the loop.  The dense kernels
//! in [`linalg`] are register-blocked microkernels (bit-identical to the
//! naive loops; DESIGN.md §Performance) and multi-learner dispatch fans
//! out over the persistent worker pool via [`ParallelNativeMlp`].

pub mod linalg;
pub mod parallel;

pub use parallel::ParallelNativeMlp;

use anyhow::{bail, Result};

use crate::backend::{StepBackend, StepOut};
use crate::data::BatchBuf;
use crate::params::{FlatParams, ParamEntry, ParamLayout, Rows, RowsMut};
use crate::util::rng::Pcg32;

use linalg::{add_bias, matmul, matmul_at_b, matmul_a_bt};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    Relu,
    None,
}

/// MLP: dims = (input, hidden..., classes); ReLU on hidden layers, linear
/// head, softmax cross-entropy loss — matching `python/compile/model.py`.
pub struct NativeMlp {
    pub dims: Vec<usize>,
    pub batch: usize,
    pub eval_batch_size: usize,
    layout: ParamLayout,
    // Scratch (per-learner forward/backward workspaces are reused).
    acts: Vec<Vec<f32>>,   // activations per layer (post-act), acts[0] = input copy
    zs: Vec<Vec<f32>>,     // pre-activations
    dz: Vec<f32>,
    dh: Vec<f32>,
}

impl NativeMlp {
    pub fn new(dims: &[usize], batch: usize, eval_batch_size: usize) -> Result<NativeMlp> {
        if dims.len() < 2 {
            bail!("MLP needs at least (input, classes) dims");
        }
        let mut entries = Vec::new();
        let mut offset = 0usize;
        for (i, (&fi, &fo)) in dims.iter().zip(&dims[1..]).enumerate() {
            entries.push(ParamEntry {
                name: format!("{i}/w"),
                shape: vec![fi, fo],
                offset,
                size: fi * fo,
            });
            offset += fi * fo;
            entries.push(ParamEntry {
                name: format!("{i}/b"),
                shape: vec![fo],
                offset,
                size: fo,
            });
            offset += fo;
        }
        // NOTE: manifest order is w,b per layer in tree order; JAX flattens
        // dicts by sorted key ("b" < "w"), so artifact order is b,w.  The
        // native layout is standalone; parity tests map by name.
        let layout = ParamLayout::from_entries(entries)?;
        let max_b = batch.max(eval_batch_size);
        let acts = dims.iter().map(|&d| vec![0.0; max_b * d]).collect();
        let zs = dims[1..].iter().map(|&d| vec![0.0; max_b * d]).collect();
        let max_width = *dims.iter().max().unwrap();
        Ok(NativeMlp {
            dims: dims.to_vec(),
            batch,
            eval_batch_size,
            layout,
            acts,
            zs,
            dz: vec![0.0; max_b * max_width],
            dh: vec![0.0; max_b * max_width],
        })
    }

    pub fn layout(&self) -> &ParamLayout {
        &self.layout
    }

    /// He-normal init (matches model.py's scheme; exact values differ since
    /// the PRNGs differ — parity tests load the artifact blob instead).
    pub fn init(&self, rng: &mut Pcg32) -> FlatParams {
        let mut p = vec![0.0f32; self.layout.total];
        for (i, (&fi, _fo)) in self.dims.iter().zip(&self.dims[1..]).enumerate() {
            let std = (2.0 / fi as f32).sqrt();
            let w = self.layout.slice_mut(2 * i, &mut p);
            for v in w.iter_mut() {
                *v = std * rng.next_normal();
            }
            // biases stay zero
        }
        p
    }

    fn n_layers(&self) -> usize {
        self.dims.len() - 1
    }

    fn w<'a>(&self, l: usize, params: &'a [f32]) -> &'a [f32] {
        self.layout.slice(2 * l, params)
    }

    fn b<'a>(&self, l: usize, params: &'a [f32]) -> &'a [f32] {
        self.layout.slice(2 * l + 1, params)
    }

    /// Forward through all layers for `n` rows starting at `x`.
    /// Leaves activations/pre-activations in scratch; returns nothing.
    fn forward(&mut self, params: &[f32], x: &[f32], n: usize) {
        let d0 = self.dims[0];
        self.acts[0][..n * d0].copy_from_slice(&x[..n * d0]);
        for l in 0..self.n_layers() {
            let (fi, fo) = (self.dims[l], self.dims[l + 1]);
            // z = a_l @ w + b
            let (head, tail) = self.acts.split_at_mut(l + 1);
            let a_in = &head[l][..n * fi];
            let z = &mut self.zs[l][..n * fo];
            matmul(a_in, self.layout.slice(2 * l, params), z, n, fi, fo);
            add_bias(z, self.layout.slice(2 * l + 1, params), n, fo);
            let a_out = &mut tail[0][..n * fo];
            if l + 1 < self.dims.len() - 1 {
                for (a, &zv) in a_out.iter_mut().zip(z.iter()) {
                    *a = zv.max(0.0);
                }
            } else {
                a_out.copy_from_slice(z);
            }
        }
    }

    /// Softmax CE on the logits left by `forward`; returns
    /// (sum_loss, ncorrect) and, if `dlogits` is Some, writes
    /// d(mean loss)/dlogits into it.
    fn loss_from_logits(
        &self,
        y: &[i32],
        n: usize,
        mean_denom: usize,
        mut dlogits: Option<&mut [f32]>,
    ) -> (f32, f32) {
        let c = *self.dims.last().unwrap();
        let logits = &self.acts[self.n_layers()];
        let mut sum_loss = 0.0f64;
        let mut ncorrect = 0.0f32;
        for i in 0..n {
            let row = &logits[i * c..(i + 1) * c];
            let label = y[i] as usize;
            let mut maxv = f32::NEG_INFINITY;
            let mut argmax = 0usize;
            for (j, &v) in row.iter().enumerate() {
                if v > maxv {
                    maxv = v;
                    argmax = j;
                }
            }
            if argmax == label {
                ncorrect += 1.0;
            }
            let mut sumexp = 0.0f32;
            for &v in row {
                sumexp += (v - maxv).exp();
            }
            let logz = maxv + sumexp.ln();
            sum_loss += (logz - row[label]) as f64;
            if let Some(dl) = dlogits.as_deref_mut() {
                let drow = &mut dl[i * c..(i + 1) * c];
                let inv = 1.0 / mean_denom as f32;
                for (j, (&v, dv)) in row.iter().zip(drow.iter_mut()).enumerate() {
                    let p = (v - logz).exp();
                    *dv = (p - if j == label { 1.0 } else { 0.0 }) * inv;
                }
            }
        }
        (sum_loss as f32, ncorrect)
    }

    /// Backprop (after `forward`); writes the flat gradient.
    fn backward(&mut self, params: &[f32], n: usize, grads: &mut [f32]) {
        let nl = self.n_layers();
        for l in (0..nl).rev() {
            let (fi, fo) = (self.dims[l], self.dims[l + 1]);
            // dz currently holds dL/dz_l for n x fo.
            // dw = a_l^T @ dz ; db = colsum(dz)
            let a_in = &self.acts[l][..n * fi];
            let dz = &self.dz[..n * fo];
            matmul_at_b(a_in, dz, self.layout.slice_mut(2 * l, grads), n, fi, fo);
            {
                let db = self.layout.slice_mut(2 * l + 1, grads);
                db.fill(0.0);
                for i in 0..n {
                    for (j, dbj) in db.iter_mut().enumerate() {
                        *dbj += dz[i * fo + j];
                    }
                }
            }
            if l > 0 {
                // dh = dz @ w^T, then through ReLU of layer l-1.
                let w = self.w(l, params);
                matmul_a_bt(dz, w, &mut self.dh[..n * fi], n, fo, fi);
                let z_prev = &self.zs[l - 1][..n * fi];
                for (d, (&h, &z)) in self.dz[..n * fi]
                    .iter_mut()
                    .zip(self.dh[..n * fi].iter().zip(z_prev.iter()))
                {
                    *d = if z > 0.0 { h } else { 0.0 };
                }
            }
        }
    }

    /// Evaluation stats (sum_loss, ncorrect) for `n` rows of a contiguous
    /// batch slice — the kernel shared by the serial eval path and the
    /// parallel backend's per-lane fan-out.
    pub fn eval_rows(&mut self, params: &[f32], x: &[f32], y: &[i32], n: usize) -> (f32, f32) {
        self.forward(params, x, n);
        self.loss_from_logits(y, n, n, None)
    }

    /// One learner's grads + stats from a contiguous batch slice.
    pub fn grads_single(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        n: usize,
        grads: &mut [f32],
    ) -> StepOut {
        self.forward(params, x, n);
        let c = *self.dims.last().unwrap();
        // dlogits into dz scratch
        let (sum_loss, ncorrect) = {
            let mut dl = std::mem::take(&mut self.dz);
            let r = self.loss_from_logits(y, n, n, Some(&mut dl[..n * c]));
            self.dz = dl;
            r
        };
        self.backward(params, n, grads);
        StepOut { loss: sum_loss / n as f32, ncorrect }
    }
}

impl StepBackend for NativeMlp {
    fn train_batch(&self) -> usize {
        self.batch
    }

    fn eval_batch(&self) -> usize {
        self.eval_batch_size
    }

    fn n_params(&self) -> usize {
        self.layout.total
    }

    fn grads(
        &mut self,
        replicas: Rows<'_>,
        batch: &BatchBuf,
        mut grads_out: RowsMut<'_>,
        outs: &mut [StepOut],
    ) -> Result<()> {
        let p = replicas.rows();
        let b = self.batch;
        let d = self.dims[0];
        if batch.rows != p * b {
            bail!("batch rows {} != P*B = {}", batch.rows, p * b);
        }
        for j in 0..p {
            let x = &batch.xf[j * b * d..(j + 1) * b * d];
            let y = &batch.y[j * b..(j + 1) * b];
            outs[j] = self.grads_single(replicas.row(j), x, y, b, grads_out.row_mut(j));
        }
        Ok(())
    }

    fn eval_batch_stats(
        &mut self,
        params: &FlatParams,
        batch: &BatchBuf,
        n: usize,
    ) -> Result<(f32, f32)> {
        let d = self.dims[0];
        Ok(self.eval_rows(params, &batch.xf[..n * d], &batch.y[..n], n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> NativeMlp {
        NativeMlp::new(&[4, 8, 3], 4, 8).unwrap()
    }

    #[test]
    fn layout_total() {
        let m = tiny();
        assert_eq!(m.n_params(), 4 * 8 + 8 + 8 * 3 + 3);
    }

    #[test]
    fn loss_decreases_under_sgd() {
        let mut m = NativeMlp::new(&[6, 16, 3], 8, 8).unwrap();
        let mut rng = Pcg32::seeded(1);
        let mut params = m.init(&mut rng);
        let data = crate::data::ClassifyData::generate(crate::data::MixtureSpec {
            dim: 6,
            classes: 3,
            train_n: 200,
            test_n: 50,
            radius: 1.5,
            noise: 0.4,
            subclusters: 1,
            label_noise: 0.0,
            seed: 2,
        });
        use crate::data::DataSource;
        let mut grads = vec![0.0f32; params.len()];
        let mut first = 0.0;
        let mut last = 0.0;
        let mut buf = crate::data::BatchBuf::default();
        for step in 0..200 {
            buf.clear();
            data.fill_train(&mut rng, 8, &mut buf);
            let out = m.grads_single(&params, &buf.xf, &buf.y, 8, &mut grads);
            if step == 0 {
                first = out.loss;
            }
            last = out.loss;
            for (w, g) in params.iter_mut().zip(&grads) {
                *w -= 0.1 * g;
            }
        }
        assert!(last < first * 0.6, "first={first} last={last}");
    }

    #[test]
    fn gradients_match_finite_differences() {
        let mut m = tiny();
        let mut rng = Pcg32::seeded(7);
        let params = m.init(&mut rng);
        let x: Vec<f32> = (0..16).map(|_| rng.next_normal()).collect();
        let y = vec![0i32, 1, 2, 1];
        let mut grads = vec![0.0f32; params.len()];
        m.grads_single(&params, &x, &y, 4, &mut grads);

        let mut loss_at = |p: &[f32]| {
            m.forward(p, &x, 4);
            let (sum, _) = m.loss_from_logits(&y, 4, 4, None);
            sum / 4.0
        };
        let eps = 1e-3f32;
        // Check a spread of coordinates (weights of both layers + biases).
        for &idx in &[0usize, 5, 31, 33, 40, 55, 58] {
            let mut p2 = params.clone();
            p2[idx] += eps;
            let up = loss_at(&p2);
            p2[idx] -= 2.0 * eps;
            let dn = loss_at(&p2);
            let fd = (up - dn) / (2.0 * eps);
            assert!(
                (fd - grads[idx]).abs() < 2e-3 * (1.0 + fd.abs()),
                "idx={idx} fd={fd} grad={}",
                grads[idx]
            );
        }
    }

    #[test]
    fn eval_counts_correct() {
        let mut m = tiny();
        let mut rng = Pcg32::seeded(3);
        let params = m.init(&mut rng);
        let mut buf = BatchBuf::default();
        buf.xf = (0..8 * 4).map(|_| rng.next_normal()).collect();
        buf.y = vec![0, 1, 2, 0, 1, 2, 0, 1];
        buf.rows = 8;
        let (sum_loss, ncorrect) = m.eval_batch_stats(&params, &buf, 8).unwrap();
        assert!(sum_loss.is_finite() && sum_loss > 0.0);
        assert!((0.0..=8.0).contains(&ncorrect));
    }

    #[test]
    fn deterministic_grads() {
        let mut m = tiny();
        let mut rng = Pcg32::seeded(9);
        let params = m.init(&mut rng);
        let x: Vec<f32> = (0..16).map(|_| rng.next_normal()).collect();
        let y = vec![1i32, 0, 2, 2];
        let mut g1 = vec![0.0f32; params.len()];
        let mut g2 = vec![0.0f32; params.len()];
        m.grads_single(&params, &x, &y, 4, &mut g1);
        m.grads_single(&params, &x, &y, 4, &mut g2);
        assert_eq!(g1, g2);
    }
}
