//! Cluster topology: P learners arranged into local clusters of S.
//!
//! Mirrors the paper's platform model (§1, §3.4): a node hosts S GPUs with
//! high intra-node bandwidth; P/S nodes are interconnected by a slower
//! fabric.  Hier-AVG's local averaging runs within a cluster, global
//! averaging across all P learners.

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkClass {
    /// GPU-to-GPU within a node (NVLink-class).
    IntraNode,
    /// Node-to-node fabric (Infiniband-class).
    InterNode,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Topology {
    /// Total learner count (paper's P).
    pub p: usize,
    /// Learners per local cluster (paper's S); S must divide P.
    pub s: usize,
}

impl Topology {
    pub fn new(p: usize, s: usize) -> Result<Topology> {
        if p == 0 || s == 0 {
            bail!("topology requires p >= 1 and s >= 1 (got p={p}, s={s})");
        }
        if p % s != 0 {
            bail!("S must divide P (paper assumption S|P): p={p}, s={s}");
        }
        Ok(Topology { p, s })
    }

    pub fn n_clusters(&self) -> usize {
        self.p / self.s
    }

    /// Cluster id of learner j.
    pub fn cluster_of(&self, j: usize) -> usize {
        debug_assert!(j < self.p);
        j / self.s
    }

    /// Learner ids in cluster c (contiguous block assignment, matching the
    /// paper's "each group of S workers" and typical MPI rank placement).
    pub fn cluster_members(&self, c: usize) -> std::ops::Range<usize> {
        debug_assert!(c < self.n_clusters());
        c * self.s..(c + 1) * self.s
    }

    pub fn clusters(&self) -> impl Iterator<Item = std::ops::Range<usize>> + '_ {
        (0..self.n_clusters()).map(|c| self.cluster_members(c))
    }

    /// Link class between two learners.
    pub fn link(&self, a: usize, b: usize) -> LinkClass {
        if self.cluster_of(a) == self.cluster_of(b) {
            LinkClass::IntraNode
        } else {
            LinkClass::InterNode
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_is_exact() {
        let t = Topology::new(16, 4).unwrap();
        assert_eq!(t.n_clusters(), 4);
        let mut seen = vec![false; 16];
        for c in t.clusters() {
            for j in c {
                assert!(!seen[j]);
                seen[j] = true;
            }
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn cluster_of_matches_members() {
        let t = Topology::new(24, 3).unwrap();
        for j in 0..24 {
            assert!(t.cluster_members(t.cluster_of(j)).contains(&j));
        }
    }

    #[test]
    fn rejects_bad_shapes() {
        assert!(Topology::new(10, 4).is_err());
        assert!(Topology::new(0, 1).is_err());
        assert!(Topology::new(4, 0).is_err());
    }

    #[test]
    fn degenerate_shapes_ok() {
        // S=1: every learner its own cluster (K-AVG).  S=P: one cluster.
        let t1 = Topology::new(8, 1).unwrap();
        assert_eq!(t1.n_clusters(), 8);
        let t2 = Topology::new(8, 8).unwrap();
        assert_eq!(t2.n_clusters(), 1);
        assert_eq!(t2.link(0, 7), LinkClass::IntraNode);
    }

    #[test]
    fn link_classes() {
        let t = Topology::new(8, 4).unwrap();
        assert_eq!(t.link(0, 3), LinkClass::IntraNode);
        assert_eq!(t.link(0, 4), LinkClass::InterNode);
    }
}
