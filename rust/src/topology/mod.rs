//! Cluster topology: P learners arranged into a hierarchy of nested groups.
//!
//! Two views of the same platform model (paper §1, §3.4):
//!
//! - [`Topology`] — the paper's exact two-level shape: a node hosts S GPUs
//!   with high intra-node bandwidth; P/S nodes are interconnected by a
//!   slower fabric.  Hier-AVG's local averaging runs within a cluster,
//!   global averaging across all P learners.
//! - [`HierTopology`] — the N-level generalization (GPU → node → rack →
//!   …): a non-decreasing divisibility chain of group sizes, each level
//!   tagged with the [`LinkClass`] its reductions are charged to.  The
//!   two-level case ([`Topology::to_hier`]) reproduces `Topology`
//!   semantics exactly, so all paper experiments are the L=2 special case.

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkClass {
    /// GPU-to-GPU within a node (NVLink-class).
    IntraNode,
    /// Node-to-node fabric (Infiniband-class).
    InterNode,
    /// Cross-rack fabric (oversubscribed spine links; the slowest tier).
    RackFabric,
}

impl LinkClass {
    /// Parse the config/CLI spelling (`intra`, `inter`, `rack`).
    pub fn parse(s: &str) -> Option<LinkClass> {
        match s {
            "intra" => Some(LinkClass::IntraNode),
            "inter" => Some(LinkClass::InterNode),
            "rack" => Some(LinkClass::RackFabric),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LinkClass::IntraNode => "intra",
            LinkClass::InterNode => "inter",
            LinkClass::RackFabric => "rack",
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Topology {
    /// Total learner count (paper's P).
    pub p: usize,
    /// Learners per local cluster (paper's S); S must divide P.
    pub s: usize,
}

impl Topology {
    pub fn new(p: usize, s: usize) -> Result<Topology> {
        if p == 0 || s == 0 {
            bail!("topology requires p >= 1 and s >= 1 (got p={p}, s={s})");
        }
        if p > MAX_P {
            bail!(
                "topology has p={p} learners, above the supported maximum of {MAX_P} \
                 (2^24) — timeline-only sweeps handle up to --p 1048576"
            );
        }
        if p % s != 0 {
            bail!("S must divide P (paper assumption S|P): p={p}, s={s}");
        }
        Ok(Topology { p, s })
    }

    pub fn n_clusters(&self) -> usize {
        self.p / self.s
    }

    /// Cluster id of learner j.
    pub fn cluster_of(&self, j: usize) -> usize {
        debug_assert!(j < self.p);
        j / self.s
    }

    /// Learner ids in cluster c (contiguous block assignment, matching the
    /// paper's "each group of S workers" and typical MPI rank placement).
    pub fn cluster_members(&self, c: usize) -> std::ops::Range<usize> {
        debug_assert!(c < self.n_clusters());
        c * self.s..(c + 1) * self.s
    }

    pub fn clusters(&self) -> impl Iterator<Item = std::ops::Range<usize>> + '_ {
        (0..self.n_clusters()).map(|c| self.cluster_members(c))
    }

    /// Link class between two learners.
    pub fn link(&self, a: usize, b: usize) -> LinkClass {
        if self.cluster_of(a) == self.cluster_of(b) {
            LinkClass::IntraNode
        } else {
            LinkClass::InterNode
        }
    }

    /// The equivalent two-level hierarchy `[S, P]` (clusters on the
    /// intra-node link, the global group on the inter-node fabric).
    pub fn to_hier(&self) -> HierTopology {
        HierTopology::new(vec![self.s, self.p]).expect("a valid Topology is a valid 2-level HierTopology")
    }
}

/// An N-level reduction hierarchy over P learners.
///
/// `sizes[l]` is the number of learners in one level-`l` group; level 0 is
/// the innermost tier (e.g. GPUs sharing a node), the last level is the
/// outermost (all P learners).  Sizes form a divisibility chain
/// (`sizes[l]` divides `sizes[l+1]`), so groups nest: the level-`l` group
/// of learner j is `j / sizes[l]`, contained in its level-`l+1` group.
///
/// Each level carries the [`LinkClass`] its reductions are charged to in
/// the α–β cost model.  Default assignment: the innermost level of a
/// multi-level hierarchy is `IntraNode`; every other level is `InterNode`
/// (so the default model stays the paper's two-tier one).  Use
/// [`HierTopology::with_links`] — or the config's per-level `links`
/// override — to charge outer tiers to the slower `RackFabric` class.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HierTopology {
    sizes: Vec<usize>,
    links: Vec<LinkClass>,
}

/// More levels than this and the schedule's inclusion–exclusion counting
/// (2^L subsets) stops being cheap; real platforms have 2-4 tiers.
pub const MAX_LEVELS: usize = 12;

/// Largest learner count a hierarchy will model (16,777,216).  The event
/// engine's timeline-only mode handles P = 1,048,576 comfortably; this
/// cap is headroom above that, placed where every construction path
/// (config, CLI, sweep) funnels through, so a typo'd `--p` fails with an
/// actionable error instead of exhausting memory or overflowing the
/// planner's byte accounting downstream.
pub const MAX_P: usize = 1 << 24;

impl HierTopology {
    pub fn new(sizes: Vec<usize>) -> Result<HierTopology> {
        let links = default_links(sizes.len());
        HierTopology::with_links(sizes, links)
    }

    pub fn with_links(sizes: Vec<usize>, links: Vec<LinkClass>) -> Result<HierTopology> {
        if sizes.is_empty() {
            bail!("hierarchy needs at least one level");
        }
        if sizes.len() > MAX_LEVELS {
            bail!("hierarchy has {} levels (max {MAX_LEVELS})", sizes.len());
        }
        if links.len() != sizes.len() {
            bail!("{} link classes for {} levels", links.len(), sizes.len());
        }
        for (l, &s) in sizes.iter().enumerate() {
            if s == 0 {
                bail!("level {l} has group size 0");
            }
            if s > MAX_P {
                bail!(
                    "level {l} has group size {s}, above the supported maximum of \
                     {MAX_P} learners (2^24) — timeline-only sweeps handle up to \
                     --p 1048576; larger platforms need a coarser model, not more \
                     simulated learners"
                );
            }
        }
        for l in 0..sizes.len() - 1 {
            if sizes[l + 1] % sizes[l] != 0 {
                bail!(
                    "level sizes must form a divisibility chain: {} does not divide {} (levels {l}->{})",
                    sizes[l],
                    sizes[l + 1],
                    l + 1
                );
            }
        }
        Ok(HierTopology { sizes, links })
    }

    /// The 2-level hierarchy of `Topology::new(p, s)`.
    pub fn two_level(p: usize, s: usize) -> Result<HierTopology> {
        Topology::new(p, s).map(|t| t.to_hier())
    }

    pub fn n_levels(&self) -> usize {
        self.sizes.len()
    }

    /// Total learner count (the outermost group size).
    pub fn p(&self) -> usize {
        *self.sizes.last().unwrap()
    }

    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    /// Learners per group at `level`.
    pub fn size(&self, level: usize) -> usize {
        self.sizes[level]
    }

    pub fn link(&self, level: usize) -> LinkClass {
        self.links[level]
    }

    pub fn n_groups(&self, level: usize) -> usize {
        self.p() / self.sizes[level]
    }

    /// Group id of learner `j` at `level`.
    pub fn group_of(&self, level: usize, j: usize) -> usize {
        debug_assert!(j < self.p());
        j / self.sizes[level]
    }

    /// Learner ids in group `g` at `level` (contiguous block assignment,
    /// matching `Topology::cluster_members`).
    pub fn group_members(&self, level: usize, g: usize) -> std::ops::Range<usize> {
        debug_assert!(g < self.n_groups(level));
        let s = self.sizes[level];
        g * s..(g + 1) * s
    }

    pub fn groups(&self, level: usize) -> impl Iterator<Item = std::ops::Range<usize>> + '_ {
        (0..self.n_groups(level)).map(move |g| self.group_members(level, g))
    }

    /// Trace-event tag for a reduction at `level`: 'G' for the outermost
    /// (global), 'L' for the innermost of a multi-level hierarchy, the
    /// level digit for intermediate tiers.
    pub fn trace_kind(&self, level: usize) -> char {
        if level + 1 == self.n_levels() {
            'G'
        } else if level == 0 {
            'L'
        } else {
            char::from_digit(level as u32 % 10, 10).unwrap()
        }
    }
}

fn default_links(n_levels: usize) -> Vec<LinkClass> {
    (0..n_levels)
        .map(|l| if l == 0 && n_levels > 1 { LinkClass::IntraNode } else { LinkClass::InterNode })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_is_exact() {
        let t = Topology::new(16, 4).unwrap();
        assert_eq!(t.n_clusters(), 4);
        let mut seen = vec![false; 16];
        for c in t.clusters() {
            for j in c {
                assert!(!seen[j]);
                seen[j] = true;
            }
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn cluster_of_matches_members() {
        let t = Topology::new(24, 3).unwrap();
        for j in 0..24 {
            assert!(t.cluster_members(t.cluster_of(j)).contains(&j));
        }
    }

    #[test]
    fn rejects_bad_shapes() {
        assert!(Topology::new(10, 4).is_err());
        assert!(Topology::new(0, 1).is_err());
        assert!(Topology::new(4, 0).is_err());
    }

    #[test]
    fn degenerate_shapes_ok() {
        // S=1: every learner its own cluster (K-AVG).  S=P: one cluster.
        let t1 = Topology::new(8, 1).unwrap();
        assert_eq!(t1.n_clusters(), 8);
        let t2 = Topology::new(8, 8).unwrap();
        assert_eq!(t2.n_clusters(), 1);
        assert_eq!(t2.link(0, 7), LinkClass::IntraNode);
    }

    #[test]
    fn link_classes() {
        let t = Topology::new(8, 4).unwrap();
        assert_eq!(t.link(0, 3), LinkClass::IntraNode);
        assert_eq!(t.link(0, 4), LinkClass::InterNode);
    }

    #[test]
    fn hier_two_level_matches_topology() {
        let t = Topology::new(16, 4).unwrap();
        let h = t.to_hier();
        assert_eq!(h.n_levels(), 2);
        assert_eq!(h.p(), 16);
        assert_eq!(h.size(0), 4);
        assert_eq!(h.size(1), 16);
        assert_eq!(h.link(0), LinkClass::IntraNode);
        assert_eq!(h.link(1), LinkClass::InterNode);
        assert_eq!(h.n_groups(0), t.n_clusters());
        for c in 0..t.n_clusters() {
            assert_eq!(h.group_members(0, c), t.cluster_members(c));
        }
        for j in 0..16 {
            assert_eq!(h.group_of(0, j), t.cluster_of(j));
        }
        assert_eq!(h.group_members(1, 0), 0..16);
    }

    #[test]
    fn hier_three_level_partitions_nest() {
        let h = HierTopology::new(vec![2, 8, 32]).unwrap();
        assert_eq!(h.n_levels(), 3);
        assert_eq!(h.p(), 32);
        assert_eq!(h.n_groups(0), 16);
        assert_eq!(h.n_groups(1), 4);
        assert_eq!(h.n_groups(2), 1);
        // every level partitions 0..P exactly
        for level in 0..3 {
            let mut seen = vec![false; 32];
            for g in h.groups(level) {
                for j in g {
                    assert!(!seen[j]);
                    seen[j] = true;
                }
            }
            assert!(seen.iter().all(|&x| x));
        }
        // nesting: a level-0 group lies inside one level-1 group
        for j in 0..32 {
            let g0 = h.group_members(0, h.group_of(0, j));
            let g1 = h.group_members(1, h.group_of(1, j));
            assert!(g1.start <= g0.start && g0.end <= g1.end);
        }
        // default links: innermost intra, the rest inter
        assert_eq!(h.link(0), LinkClass::IntraNode);
        assert_eq!(h.link(1), LinkClass::InterNode);
        assert_eq!(h.link(2), LinkClass::InterNode);
    }

    #[test]
    fn hier_rejects_bad_chains() {
        assert!(HierTopology::new(vec![]).is_err());
        assert!(HierTopology::new(vec![0, 4]).is_err());
        assert!(HierTopology::new(vec![3, 8]).is_err()); // 3 does not divide 8
        assert!(HierTopology::new(vec![4, 2]).is_err()); // decreasing
        assert!(HierTopology::new(vec![2; MAX_LEVELS + 1]).is_err());
        assert!(HierTopology::with_links(vec![2, 4], vec![LinkClass::IntraNode]).is_err());
    }

    #[test]
    fn hier_degenerate_levels_ok() {
        // Single level = flat K-AVG topology; equal sizes = coincident tiers.
        let flat = HierTopology::new(vec![8]).unwrap();
        assert_eq!(flat.n_levels(), 1);
        assert_eq!(flat.link(0), LinkClass::InterNode);
        let dup = HierTopology::new(vec![4, 4]).unwrap();
        assert_eq!(dup.n_groups(0), 1);
        assert_eq!(dup.n_groups(1), 1);
    }

    #[test]
    fn link_class_parse_and_name() {
        for l in [LinkClass::IntraNode, LinkClass::InterNode, LinkClass::RackFabric] {
            assert_eq!(LinkClass::parse(l.name()), Some(l));
        }
        assert_eq!(LinkClass::parse("nvlink"), None);
    }

    #[test]
    fn custom_links_accept_rack_tier() {
        let h = HierTopology::with_links(
            vec![2, 8, 32],
            vec![LinkClass::IntraNode, LinkClass::InterNode, LinkClass::RackFabric],
        )
        .unwrap();
        assert_eq!(h.link(2), LinkClass::RackFabric);
    }

    #[test]
    fn trace_kinds() {
        let h = HierTopology::new(vec![2, 8, 32]).unwrap();
        assert_eq!(h.trace_kind(0), 'L');
        assert_eq!(h.trace_kind(1), '1');
        assert_eq!(h.trace_kind(2), 'G');
        let flat = HierTopology::new(vec![8]).unwrap();
        assert_eq!(flat.trace_kind(0), 'G');
    }
}
