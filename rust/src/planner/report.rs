//! Machine-readable sweep reports: `SWEEP_<p>.json`.
//!
//! Schema (documented in DESIGN.md §Planner; stable keys, additive
//! evolution only — CI uploads these files as artifacts and downstream
//! tooling diffs them across PRs):
//!
//! ```json
//! {
//!   "p": 16, "model": "quickstart", "horizon_steps": 20000,
//!   "n_params": 2762, "bytes_per_reduction": 11048, "strategy": "ring",
//!   "timeline_only": false,
//!   "het": {"het": 0.0, "straggler_prob": 0.0, "straggler_mult": 4.0,
//!           "seed": 42},
//!   "space": {"min_levels": 2, "max_levels": 4, "k1_grid": [1,2,4],
//!             "k2_max": 256, "use_rack": true, "local_averaging": true,
//!             "policy": "static", "compress": ["topk:0.05"]},
//!   "k2_cap_condition_35": 199,
//!   "candidates": [
//!     {"rank": 0, "label": "h4x16-k2_8", "policy": "static",
//!      "levels": [4,16], "ks": [2,8],
//!      "links": ["intra","inter"], "k1": 2, "k2": 8, "s": 4,
//!      "compress": "topk:0.05", "payload_bytes": 1108,
//!      "score": {"time_to_target": 1.2, "comm_seconds": 0.3,
//!                "comm_bytes": 123, "compute_seconds": 0.9,
//!                "makespan_seconds": 1.2,
//!                "bound": 0.01, "condition_35": true},
//!      "cost_levels": [{"level": 0, "size": 4, "link": "intra",
//!                       "events": 1, "reductions": 4, "bytes": 1,
//!                       "seconds": 0.1}],
//!      "validation": {"total_steps": 48, "modelled_comm_seconds": 0.1,
//!                     "measured_comm_seconds": 0.1, "delta_seconds": 0.0,
//!                     "modelled_comm_bytes": 1, "measured_comm_bytes": 1,
//!                     "modelled_level_seconds": [..],
//!                     "measured_level_seconds": [..],
//!                     "modelled_makespan_seconds": 1.2,
//!                     "measured_makespan_seconds": 1.2,
//!                     "makespan_delta_seconds": 0.0,
//!                     "final_train_loss": 1.0, "final_test_acc": 0.5}}
//!   ]
//! }
//! ```
//!
//! `validation` is present only on the entries that were replayed through
//! the engine (`sweep --validate-top N`).

use std::path::Path;

use anyhow::{Context, Result};

use crate::planner::{Ranked, ScoreCtx, SweepSpace, Validation};
use crate::util::json::Json;

fn validation_json(v: &Validation) -> Json {
    let mut o = Json::obj();
    o.set("total_steps", Json::from(v.total_steps as usize))
        .set("modelled_comm_seconds", Json::from(v.modelled_comm_seconds))
        .set("measured_comm_seconds", Json::from(v.measured_comm_seconds))
        .set("delta_seconds", Json::from(v.delta_seconds))
        .set("modelled_comm_bytes", Json::from(v.modelled_comm_bytes as usize))
        .set("measured_comm_bytes", Json::from(v.measured_comm_bytes as usize))
        .set("modelled_level_seconds", Json::from_f64_slice(&v.modelled_level_seconds))
        .set("measured_level_seconds", Json::from_f64_slice(&v.measured_level_seconds))
        .set("modelled_makespan_seconds", Json::from(v.modelled_makespan_seconds))
        .set("measured_makespan_seconds", Json::from(v.measured_makespan_seconds))
        .set("makespan_delta_seconds", Json::from(v.makespan_delta_seconds))
        .set("final_train_loss", Json::from(v.final_train_loss))
        .set("final_test_acc", Json::from(v.final_test_acc));
    o
}

fn candidate_json(rank: usize, r: &Ranked, n_params: usize, validation: Option<&Validation>) -> Json {
    let c = &r.candidate;
    let s = &r.score;
    let (k1, k2, cluster_s) = c.k1k2s();
    let mut score = Json::obj();
    score
        .set("time_to_target", Json::from(s.time_to_target))
        .set("comm_seconds", Json::from(s.comm_seconds))
        .set("comm_bytes", Json::from(s.comm_bytes as usize))
        .set("compute_seconds", Json::from(s.compute_seconds))
        .set("makespan_seconds", Json::from(s.makespan_seconds))
        .set("bound", Json::from(s.bound))
        .set("condition_35", Json::from(s.condition_35));
    let mut cost_levels = Vec::with_capacity(s.levels.len());
    for l in &s.levels {
        let mut o = Json::obj();
        o.set("level", Json::from(l.level))
            .set("size", Json::from(l.size))
            .set("link", Json::from(l.link.name()))
            .set("events", Json::from(l.events as usize))
            .set("reductions", Json::from(l.reductions as usize))
            .set("bytes", Json::from(l.bytes as usize))
            .set("seconds", Json::from(l.seconds));
        cost_levels.push(o);
    }
    let mut o = Json::obj();
    o.set("rank", Json::from(rank))
        .set("label", Json::from(c.label()))
        .set("policy", Json::from(c.policy.spec()))
        .set("levels", Json::Arr(c.levels.iter().map(|&v| Json::from(v)).collect()))
        .set("ks", Json::Arr(c.ks.iter().map(|&v| Json::from(v as usize)).collect()))
        .set(
            "links",
            Json::Arr(c.links.iter().map(|l| Json::from(l.name())).collect()),
        )
        .set("k1", Json::from(k1 as usize))
        .set("k2", Json::from(k2 as usize))
        .set("s", Json::from(cluster_s as usize))
        // Canonical compression spec ("none" for dense entries) plus the
        // per-message wire bytes it prices to — so a report diff shows
        // exactly what a compressed twin saved.
        .set("compress", Json::from(c.compress.spec()))
        .set("payload_bytes", Json::from(c.compress.payload_bytes(n_params)))
        .set("score", score)
        .set("cost_levels", Json::Arr(cost_levels));
    if let Some(v) = validation {
        o.set("validation", validation_json(v));
    }
    o
}

/// The full report as a JSON value.  `validations[i]` pairs with
/// `ranked[i]` (the top of the ranking); shorter is fine.
pub fn sweep_json(
    space: &SweepSpace,
    ctx: &ScoreCtx,
    model: &str,
    ranked: &[Ranked],
    validations: &[Validation],
) -> Json {
    let mut sp = Json::obj();
    sp.set("min_levels", Json::from(space.min_levels))
        .set("max_levels", Json::from(space.max_levels))
        .set(
            "k1_grid",
            Json::Arr(space.k1_grid.iter().map(|&k| Json::from(k as usize)).collect()),
        )
        .set("k2_max", Json::from(space.k2_max as usize))
        .set("use_rack", Json::from(space.use_rack))
        .set("local_averaging", Json::from(space.local_averaging))
        .set("policy", Json::from(space.policy.spec()))
        .set(
            "compress",
            Json::Arr(space.compress.iter().map(|c| Json::from(c.spec())).collect()),
        );
    let candidates: Vec<Json> = ranked
        .iter()
        .enumerate()
        .map(|(i, r)| candidate_json(i, r, ctx.n_params, validations.get(i)))
        .collect();
    // The heterogeneity regime the makespans were priced against — a
    // report is not reproducible without it.
    let mut het = Json::obj();
    het.set("het", Json::from(ctx.het.het))
        .set("straggler_prob", Json::from(ctx.het.straggler_prob))
        .set("straggler_mult", Json::from(ctx.het.straggler_mult))
        .set("seed", Json::from(ctx.het.seed as usize));
    let mut o = Json::obj();
    o.set("p", Json::from(space.p))
        .set("model", Json::from(model))
        .set("horizon_steps", Json::from(ctx.horizon as usize))
        .set("n_params", Json::from(ctx.n_params))
        .set("bytes_per_reduction", Json::from(ctx.n_params * 4))
        .set("strategy", Json::from(ctx.strategy.name()))
        // Whether makespans came from timeline-only replay (true) or the
        // closed form / validation-backed path — rankings are only
        // comparable across reports priced the same way.
        .set("timeline_only", Json::from(ctx.timeline_only))
        .set("het", het)
        .set("space", sp)
        .set("k2_cap_condition_35", Json::from(space.k2_cap(&ctx.bound) as usize))
        .set("candidates", Json::Arr(candidates));
    o
}

/// Write the report to `path` (pretty-printed; parent dirs created).
pub fn write_sweep(
    path: &Path,
    space: &SweepSpace,
    ctx: &ScoreCtx,
    model: &str,
    ranked: &[Ranked],
    validations: &[Validation],
) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let json = sweep_json(space, ctx, model, ranked, validations);
    std::fs::write(path, json.pretty())
        .with_context(|| format!("writing sweep report {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CostModel, ReduceStrategy};
    use crate::planner;

    #[test]
    fn report_roundtrips_and_is_ranked() {
        let space = SweepSpace::new(16).unwrap();
        let ctx = ScoreCtx::for_model(
            "quickstart",
            16,
            2_000,
            ReduceStrategy::Ring,
            CostModel::default(),
        )
        .unwrap();
        let ranked = planner::rank(&space, &ctx).unwrap();
        let j = sweep_json(&space, &ctx, "quickstart", &ranked, &[]);
        let parsed = Json::parse(&j.pretty()).unwrap();
        assert_eq!(parsed.req("p").unwrap().as_usize().unwrap(), 16);
        let cands = parsed.req("candidates").unwrap().as_arr().unwrap();
        assert!(cands.len() >= 20);
        let mut prev = f64::NEG_INFINITY;
        for (i, c) in cands.iter().enumerate() {
            assert_eq!(c.req("rank").unwrap().as_usize().unwrap(), i);
            let tt = c.req("score").unwrap().req("time_to_target").unwrap().as_f64().unwrap();
            assert!(tt >= prev, "candidate {i} out of order");
            prev = tt;
            assert!(c.get("validation").is_none());
            assert_eq!(
                c.req("levels").unwrap().as_arr().unwrap().len(),
                c.req("cost_levels").unwrap().as_arr().unwrap().len()
            );
            // dense entries carry the canonical "none" spec and the dense
            // per-message size
            assert_eq!(c.req("compress").unwrap().as_str().unwrap(), "none");
            assert_eq!(c.req("payload_bytes").unwrap().as_usize().unwrap(), ctx.n_params * 4);
        }
    }

    #[test]
    fn report_carries_compression_fields() {
        use crate::comm::Compression;
        let mut space = SweepSpace::new(16).unwrap();
        space.compress = vec![Compression::parse("topk:0.05").unwrap()];
        let ctx = ScoreCtx::for_model(
            "quickstart",
            16,
            2_000,
            ReduceStrategy::Ring,
            CostModel::default(),
        )
        .unwrap();
        let ranked = planner::rank(&space, &ctx).unwrap();
        let j = sweep_json(&space, &ctx, "quickstart", &ranked, &[]);
        let parsed = Json::parse(&j.pretty()).unwrap();
        let specs = parsed.req("space").unwrap().req("compress").unwrap().as_arr().unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].as_str().unwrap(), "topk:0.05");
        let spec = Compression::parse("topk:0.05").unwrap();
        let cands = parsed.req("candidates").unwrap().as_arr().unwrap();
        let mut seen_compressed = 0usize;
        for c in cands {
            let cspec = c.req("compress").unwrap().as_str().unwrap();
            let payload = c.req("payload_bytes").unwrap().as_usize().unwrap();
            if cspec == "none" {
                assert_eq!(payload, ctx.n_params * 4);
            } else {
                assert_eq!(cspec, "topk:0.05");
                assert_eq!(payload, spec.payload_bytes(ctx.n_params));
                assert!(payload < ctx.n_params * 4);
                seen_compressed += 1;
            }
        }
        assert_eq!(seen_compressed * 2, cands.len());
    }
}
